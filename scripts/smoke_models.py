"""Quick dev smoke: every arch, reduced config, one loss eval + prefill/decode.

    pip install -e . && python scripts/smoke_models.py [arch ...]
(or PYTHONPATH=src without installing)
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, reduce_config
from repro.models import LM


def make_batch(cfg, rng, B=2, S=32):
    if cfg.audio_codebooks:
        return {"codes": rng.integers(0, cfg.vocab_size, (B, cfg.audio_codebooks, S)).astype(np.int32),
                "cond": rng.normal(size=(B, cfg.cond_len, cfg.cond_dim)).astype(np.float32)}
    if cfg.vision:
        return {"tokens": rng.integers(0, cfg.vocab_size, (B, S - cfg.num_patches)).astype(np.int32),
                "patches": rng.normal(size=(B, cfg.num_patches, cfg.vision_dim)).astype(np.float32)}
    if cfg.meta_tokens:
        return {"tokens": rng.integers(0, cfg.vocab_size, (B, S - cfg.meta_tokens)).astype(np.int32)}
    return {"tokens": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)}


def main(names):
    rng = np.random.default_rng(0)
    for name in names:
        cfg = reduce_config(get_config(name))
        lm = LM(cfg)
        params = lm.init(jax.random.key(0))
        n = sum(x.size for x in jax.tree.leaves(params))
        batch = make_batch(cfg, rng)
        loss, metrics = jax.jit(lm.loss)(params, batch)
        ok1 = bool(jnp.isfinite(loss))
        # prefill + decode
        cache, logits = jax.jit(lambda p, b: lm.prefill(p, b, max_seq=48))(params, batch)
        dec_in = {"tokens": np.zeros((2, cfg.audio_codebooks), np.int32)
                  if cfg.audio_codebooks else np.zeros((2,), np.int32)}
        if cfg.audio_codebooks:
            dec_in["cond"] = batch["cond"]
        logits2, cache = jax.jit(lm.decode)(params, cache, dec_in)
        ok2 = bool(jnp.all(jnp.isfinite(logits2)))
        print(f"{name:24s} params={n:9d} loss={float(loss):8.4f} "
              f"finite={ok1} decode_finite={ok2} logits={logits2.shape}")


if __name__ == "__main__":
    main(sys.argv[1:] or ALL_ARCHS)
