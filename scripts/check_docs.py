#!/usr/bin/env python
"""Fail on broken intra-repo links/anchors in docs/*.md + README.md.

Checks every markdown link `[text](target)`:

  - external targets (http/https/mailto) are ignored,
  - relative file targets must exist (resolved against the containing file),
  - `#anchor` fragments must match a heading in the target file, using
    GitHub's slug rules (lowercase; strip punctuation except hyphens;
    spaces → hyphens; duplicate slugs get -1, -2, ... suffixes).

Fenced code blocks are stripped before scanning so code samples containing
bracket syntax don't produce false positives.

    python scripts/check_docs.py [files...]     # default: docs/*.md README.md

Exit status 0 = all links resolve; 1 = broken links (listed on stderr).
"""
from __future__ import annotations

import functools
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_FENCE = re.compile(r"^(```|~~~).*?^\1\s*$", re.M | re.S)
_HEADING = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$", re.M)
_EXTERNAL = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")  # http:, mailto:, ...


def _rel(path: Path) -> str:
    try:
        return str(path.relative_to(REPO))
    except ValueError:
        return str(path)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for one heading line (underscores are
    preserved — GitHub keeps them in anchors, and this repo's API docs use
    snake_case headings)."""
    # drop inline code/emphasis markers and links, keep their text
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)
    text = text.replace("`", "").replace("*", "").strip().lower()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


@functools.lru_cache(maxsize=None)
def anchors_of(path: Path) -> frozenset:
    """All heading anchors of a markdown file, with -N duplicate suffixes."""
    body = _FENCE.sub("", path.read_text(encoding="utf-8"))
    seen: dict = {}
    out = set()
    for m in _HEADING.finditer(body):
        slug = slugify(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return frozenset(out)


def check_file(path: Path):
    """``(broken-link descriptions, total links)`` for one markdown file."""
    errors = []
    n_links = 0
    body = _FENCE.sub("", path.read_text(encoding="utf-8"))
    for m in _LINK.finditer(body):
        n_links += 1
        target = m.group(1)
        if _EXTERNAL.match(target):
            continue
        file_part, _, anchor = target.partition("#")
        dest = path if not file_part else (
            path.parent / file_part).resolve()
        if not dest.exists():
            errors.append(f"{_rel(path)}: broken link "
                          f"'{target}' (no such file {file_part})")
            continue
        if anchor:
            if dest.suffix.lower() not in (".md", ".markdown"):
                continue                      # anchors into non-md: skip
            if anchor not in anchors_of(dest):
                errors.append(
                    f"{_rel(path)}: broken anchor '{target}' "
                    f"(no heading slug '#{anchor}' in {_rel(dest)})")
    return errors, n_links


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    if args:
        files = [Path(a).resolve() for a in args]
    else:
        files = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
    missing = [f for f in files if not f.exists()]
    errors = [f"no such file: {f}" for f in missing]
    n_links = 0
    for f in files:
        if f in missing:
            continue
        errs, n = check_file(f)
        errors.extend(errs)
        n_links += n
    if errors:
        for e in errors:
            print(f"ERROR: {e}", file=sys.stderr)
        print(f"{len(errors)} broken link(s) across {len(files)} file(s)",
              file=sys.stderr)
        return 1
    print(f"docs OK: {len(files)} file(s), {n_links} link(s) resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
