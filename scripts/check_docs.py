#!/usr/bin/env python
"""Standalone docs gate: broken links/anchors + analyzer rule-catalog sync.

Thin wrapper over the DC checkers of ``repro.analysis`` — the single source
of truth for the link/anchor/rule-doc logic lives in
``src/repro/analysis/docs.py`` (and the rule registry in
``src/repro/analysis/rules.py``). Both are stdlib-only with no intra-package
imports, so this script loads them via importlib straight off the source
tree: it works in bare checkouts and pre-commit hooks where the ``repro``
package is not installed.

    python scripts/check_docs.py [files...]     # default: docs/*.md README.md

Exit status 0 = all links resolve, every rule ID is documented, and every
``repro.obs`` span/metric catalog name appears in docs/OBSERVABILITY.md; 1
otherwise (findings listed on stderr). The full analyzer (same checks plus
CK/JP/US/BK) is ``python -m repro.analysis --docs``.
"""
from __future__ import annotations

import importlib.util
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _load(name: str):
    path = REPO / "src" / "repro" / "analysis" / f"{name}.py"
    spec = importlib.util.spec_from_file_location(f"_check_docs_{name}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    docs = _load("docs")
    rules = _load("rules")
    files = [Path(a).resolve() for a in args] if args else None
    findings = docs.check_links(REPO, files=files)
    findings += docs.check_rule_docs(REPO, sorted(rules.RULES))
    findings += docs.check_obs_docs(REPO)
    if findings:
        for f in findings:
            loc = f"{f['path']}:{f['line']}" if f["line"] else f["path"]
            print(f"ERROR: {loc}: {f['rule']} {f['message']}",
                  file=sys.stderr)
        print(f"{len(findings)} docs finding(s)", file=sys.stderr)
        return 1
    print("docs OK: links resolve, every analyzer rule is documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
