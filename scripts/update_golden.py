"""Regenerate the golden regression snapshot ``tests/golden/table2.json``.

The snapshot freezes (a) the nominal-corner Table-2 selections through
``explore`` and (b) the full characterization of a small, fixed config slice
— every metric as the exact float64 repr of the float32 the vmap pipeline
produced. ``tests/test_golden.py`` diffs live results against this file, so
any edit to the physics fails loudly instead of silently drifting.

Two equivalent update paths (documented in docs/API.md):

    python scripts/update_golden.py
    python -m pytest tests/test_golden.py --update-golden

Only regenerate after an *intentional* physics change, and say so in the
commit message.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

GOLDEN_PATH = REPO / "tests" / "golden" / "table2.json"
NLEVEL_PATH = REPO / "tests" / "golden" / "table2_nlevel.json"
VDD_PATH = REPO / "tests" / "golden" / "table2_vdd.json"

# the frozen vdd-sweep operating point: a cold, boosted-supply block
# ((vdd [V], temp_k [K])) under which OS-Si gains the frequency headroom to
# take over retention-marginal L1/L2 buckets — the co-optimization axis must
# keep flipping exactly these Table-2 winners (the MCAIMem effect)
VDD_SWEEP_POINT = (1.2, 233.0)

# the frozen slice: small but covers every mem type, LS on/off, and both a
# shallow and a deep array (delay-chain quantization edge)
SLICE_KW = dict(word_sizes=(16, 64), num_words=(32, 256))

# the frozen N-level reference: 3 levels of gainsight.NLEVEL_REFERENCE,
# composed under each of these (name -> ComposePolicy kwargs) settings
NLEVEL_POLICIES = {
    "preference": dict(),
    "power_bb": dict(objective="power", candidate_mode="all_feasible",
                     search="branch_and_bound"),
}


def build_snapshot() -> dict:
    import jax

    from repro.api import DesignTable, design_space, explore
    from repro.core import gainsight

    report = explore(tasks=gainsight.TASKS)
    table2 = {str(t.task_id): report.labels()[t.task_id]
              for t in gainsight.TASKS}

    configs = design_space(**SLICE_KW)
    table = DesignTable.from_configs(configs)
    rows = []
    for i in range(len(table)):
        row = table.row(i)
        rows.append({k: (float(v) if isinstance(v, float) else v)
                     for k, v in row.items()})
    return {
        "comment": "golden regression snapshot - regenerate ONLY via "
                   "scripts/update_golden.py or pytest --update-golden",
        "jax_version": jax.__version__,
        "slice": {k: list(v) for k, v in SLICE_KW.items()},
        "table2": table2,
        "characterization": rows,
    }


def compose_nlevel(policy_kw: dict):
    """One 3-level reference composition (shared with the golden test so the
    live recomputation and the snapshot can never use different settings)."""
    from repro.core.gainsight import nlevel_task
    from repro.hetero import ComposePolicy, compose
    return compose(None, nlevel_task(3),
                   compose_policy=ComposePolicy(**policy_kw))


def build_nlevel_snapshot() -> dict:
    import jax

    compositions = {}
    for name, kw in NLEVEL_POLICIES.items():
        rep = compose_nlevel(kw)
        best = rep.best
        compositions[name] = {
            "labels": best.labels(),
            "picks": {lvl: [p.config_idx for p in lc.picks]
                      for lvl, lc in best.levels.items()},
            "tiles": {lvl: list(lc.tiles)
                      for lvl, lc in best.levels.items()},
            # exact float64 repr of the float32 the scoring kernel produced
            "metrics": {k: float(v) for k, v in best.metrics.items()},
            "search": rep.search,
            "n_space": rep.n_space,
        }
    return {
        "comment": "golden N-level composition snapshot - regenerate ONLY "
                   "via scripts/update_golden.py or pytest --update-golden",
        "jax_version": jax.__version__,
        "task": "nlevel3",
        "compositions": compositions,
    }


def compose_vdd(task, swept: bool):
    """One Table-2 task composed with/without the frozen vdd sweep (shared
    with tests/test_vdd_sweep.py so live and snapshot settings cannot
    diverge)."""
    from repro.hetero import ComposePolicy, compose
    cp = ComposePolicy(vdd_sweep=(VDD_SWEEP_POINT,)) if swept \
        else ComposePolicy()
    return compose(None, task, compose_policy=cp)


def build_vdd_snapshot() -> dict:
    import jax

    from repro.core import gainsight

    tasks = {}
    for t in gainsight.TASKS:
        base = compose_vdd(t, swept=False)
        swept = compose_vdd(t, swept=True)
        tasks[str(t.task_id)] = {
            "base_labels": base.labels(),
            "swept_labels": swept.labels(),
            "flipped": swept.labels() != base.labels(),
            "picks": {lvl: [[p.family, p.config_idx,
                             p.op.corner if p.op is not None else None,
                             p.refresh_margin]
                            for p in lc.picks]
                      for lvl, lc in swept.best.levels.items()},
            "p_w": {"base": float(base.best.metrics["p_w"]),
                    "swept": float(swept.best.metrics["p_w"])},
        }
    return {
        "comment": "golden vdd-sweep flip snapshot - regenerate ONLY via "
                   "scripts/update_golden.py or pytest --update-golden",
        "jax_version": jax.__version__,
        "vdd_sweep_point": list(VDD_SWEEP_POINT),
        "tasks": tasks,
    }


def write_snapshot(path: Path = GOLDEN_PATH) -> Path:
    snap = build_snapshot()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(snap, indent=1, sort_keys=True) + "\n")
    return path


def write_nlevel_snapshot(path: Path = NLEVEL_PATH) -> Path:
    snap = build_nlevel_snapshot()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(snap, indent=1, sort_keys=True) + "\n")
    return path


def write_vdd_snapshot(path: Path = VDD_PATH) -> Path:
    snap = build_vdd_snapshot()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(snap, indent=1, sort_keys=True) + "\n")
    return path


if __name__ == "__main__":
    for p in (write_snapshot(), write_nlevel_snapshot(),
              write_vdd_snapshot()):
        print(f"wrote {p}")
