"""Quickstart: generate, characterize and emit artifacts for a GCRAM macro.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import MacroConfig, characterize_config, generate_all


def main():
    cfg = MacroConfig(mem_type="gc_sisi", word_size=32, num_words=64,
                      level_shift=True)
    print(f"== OpenGCRAM-JAX quickstart: {cfg.mem_type} "
          f"{cfg.word_size}x{cfg.num_words} (WWLLS={cfg.level_shift}) ==")
    r = characterize_config(cfg)
    print(f"area       {r['area_um2']:.0f} um^2")
    print(f"f_read     {r['f_read_hz'] / 1e6:.0f} MHz   "
          f"f_write {r['f_write_hz'] / 1e6:.0f} MHz")
    print(f"bandwidth  {r['bandwidth_bits_s'] / 8e9:.2f} GB/s (read) / "
          f"{r['bandwidth_total_bits_s'] / 8e9:.2f} GB/s (dual-port total)")
    print(f"leakage    {r['p_leak_w'] * 1e6:.3f} uW   "
          f"retention {r['retention_s']:.3e} s")
    rep = generate_all(cfg, "artifacts/quickstart")
    print(f"artifacts  -> artifacts/quickstart/  "
          f"DRC {'clean' if rep['drc_clean'] else 'ERRORS'}, "
          f"LVS {'clean' if rep['lvs_clean'] else 'ERRORS'}")


if __name__ == "__main__":
    main()
