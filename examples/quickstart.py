"""Quickstart: the three-pillar compiler API in ~20 lines.

1. ``Compiler().compile(cfg) -> Macro`` — characterize one macro (PPA +
   retention) and emit its design-flow artifacts (.sp/.v/.lib/.lef).
2. ``DesignTable`` — the characterized config grid as a columnar table with
   chainable ``feasible``/``pareto``/``best`` queries and npz caching.
3. ``explore() -> DSEReport`` — the full heterogeneous-memory DSE
   (paper Table 2) in one call; see examples/heterogeneous_dse.py.
4. Operating corners — GCRAM retention vs temperature/VDD
   (``OperatingPoint``/``corners=``/``robust="worst_case"``).

Install the package once (``pip install -e .``), then::

    python examples/quickstart.py
"""
from repro.api import Compiler, OperatingPoint


def main():
    compiler = Compiler()
    m = compiler.compile(mem_type="gc_sisi", word_size=32, num_words=64,
                         level_shift=True)
    cfg = m.config
    print(f"== OpenGCRAM-JAX quickstart: {cfg.mem_type} "
          f"{cfg.word_size}x{cfg.num_words} (WWLLS={cfg.level_shift}) ==")
    r = m.ppa
    print(f"area       {r['area_um2']:.0f} um^2")
    print(f"f_read     {r['f_read_hz'] / 1e6:.0f} MHz   "
          f"f_write {r['f_write_hz'] / 1e6:.0f} MHz")
    print(f"bandwidth  {r['bandwidth_bits_s'] / 8e9:.2f} GB/s (read) / "
          f"{r['bandwidth_total_bits_s'] / 8e9:.2f} GB/s (dual-port total)")
    print(f"leakage    {r['p_leak_w'] * 1e6:.3f} uW   "
          f"retention {m.retention_s:.3e} s")
    rep = m.write_all("artifacts/quickstart")
    print(f"artifacts  -> artifacts/quickstart/  "
          f"DRC {'clean' if rep['drc_clean'] else 'ERRORS'}, "
          f"LVS {'clean' if rep['lvs_clean'] else 'ERRORS'}")

    # pillar 2 in one line: the cheapest macro that runs 1 GHz for >= 1 ms
    table = compiler.table(cache="artifacts/dse_cache")
    pick = table.feasible(1.0e9, 1e-3).best("area_um2")
    print(f"1GHz/1ms   cheapest feasible macro: {pick}")

    # pillar 4: retention vs temperature — the knob that flips DSE winners.
    # GCRAM retention is Arrhenius-steep in T: the same OS-Si macro that
    # holds data for ms at 300 K drops below a 5 ms lifetime at 85 degC, so
    # a corner-blind DSE can crown a hot-infeasible winner (fix: build the
    # table with corners=[...] and rank with robust="worst_case").
    print("\n== retention vs operating point (gc_ossi 32x64) ==")
    for vdd, temp_k, label in [(1.1, 233.0, "cold  -40C"),
                               (1.1, 300.0, "nominal   "),
                               (1.1, 358.0, "hot   85C "),
                               (0.9, 300.0, "low-vdd   ")]:
        mc = compiler.compile(mem_type="gc_ossi", word_size=32, num_words=64,
                              op=OperatingPoint(vdd, temp_k, label.strip()))
        print(f"  {label}  vdd={vdd:.1f}V T={temp_k:.0f}K   "
              f"retention {mc.retention_s:10.3e} s   "
              f"p_refresh {mc.ppa['p_refresh_w'] * 1e6:8.3f} uW")

    corner_table = compiler.table(corners=["nominal", "hot"],
                                  cache="artifacts/dse_cache")
    robust = corner_table.worst_case_metrics()
    n_nom = int((table.metrics["retention_s"] >= 5e-3).sum())
    n_rob = int((robust["retention_s"] >= 5e-3).sum())
    print(f"configs holding a 5 ms lifetime: {n_nom} at nominal, "
          f"{n_rob} at every corner (robust)")


if __name__ == "__main__":
    main()
