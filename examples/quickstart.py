"""Quickstart: the three-pillar compiler API in ~20 lines.

1. ``Compiler().compile(cfg) -> Macro`` — characterize one macro (PPA +
   retention) and emit its design-flow artifacts (.sp/.v/.lib/.lef).
2. ``DesignTable`` — the characterized config grid as a columnar table with
   chainable ``feasible``/``pareto``/``best`` queries and npz caching.
3. ``explore() -> DSEReport`` — the full heterogeneous-memory DSE
   (paper Table 2) in one call; see examples/heterogeneous_dse.py.

Install the package once (``pip install -e .``), then::

    python examples/quickstart.py
"""
from repro.api import Compiler


def main():
    compiler = Compiler()
    m = compiler.compile(mem_type="gc_sisi", word_size=32, num_words=64,
                         level_shift=True)
    cfg = m.config
    print(f"== OpenGCRAM-JAX quickstart: {cfg.mem_type} "
          f"{cfg.word_size}x{cfg.num_words} (WWLLS={cfg.level_shift}) ==")
    r = m.ppa
    print(f"area       {r['area_um2']:.0f} um^2")
    print(f"f_read     {r['f_read_hz'] / 1e6:.0f} MHz   "
          f"f_write {r['f_write_hz'] / 1e6:.0f} MHz")
    print(f"bandwidth  {r['bandwidth_bits_s'] / 8e9:.2f} GB/s (read) / "
          f"{r['bandwidth_total_bits_s'] / 8e9:.2f} GB/s (dual-port total)")
    print(f"leakage    {r['p_leak_w'] * 1e6:.3f} uW   "
          f"retention {m.retention_s:.3e} s")
    rep = m.write_all("artifacts/quickstart")
    print(f"artifacts  -> artifacts/quickstart/  "
          f"DRC {'clean' if rep['drc_clean'] else 'ERRORS'}, "
          f"LVS {'clean' if rep['lvs_clean'] else 'ERRORS'}")

    # pillar 2 in one line: the cheapest macro that runs 1 GHz for >= 1 ms
    table = compiler.table(cache="artifacts/dse_cache")
    pick = table.feasible(1.0e9, 1e-3).best("area_um2")
    print(f"1GHz/1ms   cheapest feasible macro: {pick}")


if __name__ == "__main__":
    main()
