"""Serving example: batched prefill + decode with KV cache on a reduced
hymba (hybrid attention+SSM) model — exercises ring/SWA caches and SSM state.

    pip install -e . && python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models import LM
from repro.serve.engine import Engine


def main():
    cfg = reduce_config(get_config("hymba-1.5b"))
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    eng = Engine(cfg, params, max_seq=96)

    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (4, 12)).astype(np.int32)}
    t0 = time.time()
    out = eng.generate(batch, steps=24, temperature=0.8, seed=0)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s, batched, ring+SSM caches)")
    for i, row in enumerate(out[:2]):
        print(f"  request {i}: {row[:16].tolist()} ...")
    print("greedy determinism check:",
          np.array_equal(eng.generate(batch, steps=8),
                         eng.generate(batch, steps=8)))


if __name__ == "__main__":
    main()
