"""End-to-end driver: train a qwen3-style LM for a few hundred steps on CPU
with the full production substrate (sharded AdamW, remat, checkpointing,
fault-tolerant supervisor).

The backbone is 100M-class once a production-size vocabulary is attached
(~96M tied / 174M untied at vocab 151936 — check with --full-vocab); the
driver ships with vocab 8192 (27.3M params) so 300 steps stay tractable on
one CPU core.

    pip install -e . && python examples/train_lm.py --steps 300
Result of the recorded 300-step run (artifacts/train_lm_300.log):
    loss first10=9.41 -> last10=9.07, 6.7 s/step, 0 restarts.
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import SyntheticLMData
from repro.runtime.supervisor import Supervisor, SupervisorConfig
from repro.train.step import init_train_state, make_train_step


def build_100m_cfg(full_vocab: bool = False):
    """qwen3-family 100M-class config; reduced vocab keeps the CPU driver
    tractable (embeddings dominate at this scale)."""
    return get_config("qwen3-8b").replace(
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=151936 if full_vocab else 8192,
        dtype="float32", attn_chunk=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="artifacts/train_lm_ckpt")
    ap.add_argument("--full-vocab", action="store_true")
    args = ap.parse_args()

    cfg = build_100m_cfg(full_vocab=args.full_vocab)
    lm, step = make_train_step(cfg, base_lr=3e-4, warmup=50,
                               total_steps=args.steps)
    step = jax.jit(step, donate_argnums=(0, 1))
    params, opt = init_train_state(cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n / 1e6:.1f}M params, {cfg.num_layers}L d={cfg.d_model}")

    data = SyntheticLMData(cfg, args.batch, args.seq, seed=0)
    sup = Supervisor(step, Checkpointer(args.ckpt_dir, keep=2),
                     SupervisorConfig(ckpt_every=100))
    t0 = time.time()
    params, opt, report = sup.run(params, opt, data, total_steps=args.steps)
    dt = time.time() - t0
    losses = report.losses
    print(f"steps={report.steps_run} restarts={report.restarts} "
          f"time={dt:.1f}s ({dt / max(report.steps_run, 1):.2f}s/step)")
    print(f"loss: first10={np.mean(losses[:10]):.4f} "
          f"last10={np.mean(losses[-10:]):.4f}")
    if args.steps >= 50:   # too noisy to assert on smoke-length runs
        assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss did not improve"
        print("OK: loss decreased; checkpoint at", args.ckpt_dir)


if __name__ == "__main__":
    main()
