"""Heterogeneous memory design-space exploration (paper §5.4):
reproduce Table 2 and run the beyond-paper extras (Pareto front + gradient
sizing).

    PYTHONPATH=src python examples/heterogeneous_dse.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import dse, gainsight
from repro.core.macro import MacroConfig


def main():
    configs = dse.design_space()
    res = dse.evaluate_space(configs)
    print(f"characterized {len(configs)} macro configurations\n")

    print("== Table 2: optimal heterogeneous L1/L2 per task ==")
    for t in gainsight.TASKS:
        l1, _ = dse.select_level(configs, res, t.l1)
        l2, _ = dse.select_level(configs, res, t.l2)
        exp = gainsight.TABLE2_EXPECTED[t.task_id]
        tick = "OK " if (l1, l2) == (exp["L1"], exp["L2"]) else "!! "
        print(f"  {tick}task {t.task_id} {t.name:24s} L1: {l1:14s} L2: {l2}")

    print("\n== Pareto front (area, leak+refresh power, delay) ==")
    pts = np.stack([res["area_um2"],
                    res["p_leak_w"] + res["p_refresh_w"],
                    res["t_read_s"]], axis=1)
    front = dse.pareto_front(pts)
    print(f"  {front.sum()}/{len(configs)} non-dominated configs; examples:")
    for i in np.where(front)[0][:5]:
        c = configs[i]
        print(f"    {c.mem_type:12s} {c.word_size}x{c.num_words} LS={int(c.level_shift)} "
              f"area={res['area_um2'][i]:.0f}um2 f={res['f_op_hz'][i]/1e6:.0f}MHz")

    print("\n== beyond-paper: gradient-based continuous sizing ==")
    out = dse.gradient_size_macro(MacroConfig(mem_type="gc_sisi",
                                              word_size=64, num_words=128))
    print(f"  w_read {0.15:.2f}->{out['w_read_um']:.2f}um, "
          f"w_write {0.12:.2f}->{out['w_write_um']:.2f}um: "
          f"cell critical path {out['t_cell_before_s']*1e12:.1f}ps -> "
          f"{out['t_cell_after_s']*1e12:.1f}ps ({out['speedup']:.2f}x)")


if __name__ == "__main__":
    main()
