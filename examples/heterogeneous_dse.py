"""Heterogeneous memory design-space exploration (paper §5.4) through the
``repro.api`` façade: ``explore()`` reproduces Table 2 in one call, then the
beyond-paper extras run as chainable ``DesignTable`` queries (Pareto front)
and ``Compiler.gradient_size`` (continuous sizing).

    pip install -e . && python examples/heterogeneous_dse.py
"""
from repro.api import Compiler, MacroConfig, explore
from repro.core import gainsight


def main():
    report = explore(tasks=gainsight.TASKS, cache="artifacts/dse_cache")
    table = report.table
    print(f"characterized {len(table)} macro configurations\n")

    print("== Table 2: optimal heterogeneous L1/L2 per task ==")
    labels = report.labels()
    for t in report.tasks:
        got = labels[t.task_id]
        exp = gainsight.TABLE2_EXPECTED[t.task_id]
        tick = "OK " if got == exp else "!! "
        print(f"  {tick}task {t.task_id} {t.name:24s} "
              f"L1: {got['L1']:14s} L2: {got['L2']}")

    print("\n== Pareto front (area, leak+refresh power, delay) ==")
    front = (table
             .with_column("p_static_w",
                          table["p_leak_w"] + table["p_refresh_w"])
             .pareto("area_um2", "p_static_w", "t_read_s"))
    print(f"  {len(front)}/{len(table)} non-dominated configs; examples:")
    for i in range(min(5, len(front))):
        c = front.config(i)
        print(f"    {c.mem_type:12s} {c.word_size}x{c.num_words} "
              f"LS={int(c.level_shift)} "
              f"area={front['area_um2'][i]:.0f}um2 "
              f"f={front['f_op_hz'][i] / 1e6:.0f}MHz")

    print("\n== beyond-paper: gradient-based continuous sizing ==")
    out = Compiler().gradient_size(MacroConfig(mem_type="gc_sisi",
                                               word_size=64, num_words=128))
    print(f"  w_read {0.15:.2f}->{out['w_read_um']:.2f}um, "
          f"w_write {0.12:.2f}->{out['w_write_um']:.2f}um: "
          f"cell critical path {out['t_cell_before_s']*1e12:.1f}ps -> "
          f"{out['t_cell_after_s']*1e12:.1f}ps ({out['speedup']:.2f}x)")


if __name__ == "__main__":
    main()
