"""Heterogeneous memory design-space exploration through ``repro.hetero``:
``Compiler.compose`` scores every joint (L1 tech, L2 tech) system design per
task and prints the full composition report — Table-2 labels, per-bucket
macro picks + tiling, and system area/power/bandwidth — instead of the old
independent per-level picks. The beyond-paper extras (Pareto front, gradient
sizing) ride on the same ``DesignTable``.

    pip install -e . && python examples/heterogeneous_dse.py

Docs: docs/API.md (façade reference), docs/ARCHITECTURE.md (layer map).
"""
from repro.api import Compiler, ComposePolicy, MacroConfig
from repro.core import gainsight


def main():
    compiler = Compiler()
    table = compiler.table(cache="artifacts/dse_cache")
    print(f"characterized {len(table)} macro configurations\n")

    print("== Table 2 via the joint composition engine (repro.hetero) ==")
    reports = {}
    for t in gainsight.TASKS:
        rep = compiler.compose(t, space=table, cache="artifacts/dse_cache")
        reports[t.task_id] = rep
        got = rep.labels()
        exp = gainsight.TABLE2_EXPECTED[t.task_id]
        tick = "OK " if got == exp else "!! "
        print(f"  {tick}task {t.task_id} {t.name:24s} "
              f"L1: {got['L1']:14s} L2: {got['L2']}")

    print("\n== composition report, task 7 (3-technology L2) ==")
    print(reports[7].summary())

    print("\n== simulate-then-rerank: replay phase traces vs the averages ==")
    rep_sim = compiler.simulate(gainsight.TASKS[6], space=table,
                                cache="artifacts/dse_cache")
    m = rep_sim.best.metrics
    print(f"  winner unchanged at defaults: {rep_sim.labels()}")
    print(f"  replayed (prefill+decode):  E={m['sim_e_total_j'] * 1e6:.3f} uJ"
          f"  t={m['sim_t_sim_s'] * 1e3:.3f} ms"
          f"  stall={m['sim_stall_frac']:.1%}"
          f"  util_peak={m['sim_util_peak']:.3f}")
    runner = rep_sim.ranked[1].metrics
    print(f"  runner-up after re-rank:    E={runner['sim_e_total_j'] * 1e6:.3f} uJ"
          f"  (analytic p_w {runner['p_w'] * 1e3:.3f} mW)")

    print("\n== joint tradeoff: same task under a power-first objective ==")
    rep_p = compiler.compose(
        gainsight.TASKS[6], space=table,
        compose_policy=ComposePolicy(objective="power",
                                     candidate_mode="all_feasible"))
    m0, m1 = reports[7].best.metrics, rep_p.best.metrics
    print(f"  preference: {m0['p_w'] * 1e3:8.3f} mW  "
          f"{m0['area_um2'] / 1e6:7.3f} mm^2   {reports[7].labels()}")
    print(f"  power-min:  {m1['p_w'] * 1e3:8.3f} mW  "
          f"{m1['area_um2'] / 1e6:7.3f} mm^2   {rep_p.labels()}")

    print("\n== Pareto front (area, leak+refresh power, delay) ==")
    front = (table
             .with_column("p_static_w",
                          table["p_leak_w"] + table["p_refresh_w"])
             .pareto("area_um2", "p_static_w", "t_read_s"))
    print(f"  {len(front)}/{len(table)} non-dominated configs; examples:")
    for i in range(min(5, len(front))):
        c = front.config(i)
        print(f"    {c.mem_type:12s} {c.word_size}x{c.num_words} "
              f"LS={int(c.level_shift)} "
              f"area={front['area_um2'][i]:.0f}um2 "
              f"f={front['f_op_hz'][i] / 1e6:.0f}MHz")

    print("\n== beyond-paper: gradient-based continuous sizing ==")
    out = Compiler().gradient_size(MacroConfig(mem_type="gc_sisi",
                                               word_size=64, num_words=128))
    print(f"  w_read {0.15:.2f}->{out['w_read_um']:.2f}um, "
          f"w_write {0.12:.2f}->{out['w_write_um']:.2f}um: "
          f"cell critical path {out['t_cell_before_s']*1e12:.1f}ps -> "
          f"{out['t_cell_after_s']*1e12:.1f}ps ({out['speedup']:.2f}x)")


if __name__ == "__main__":
    main()
