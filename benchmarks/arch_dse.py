"""The paper's §5.4 extended to the 10 assigned architectures: dry-run
records -> GainSight-analog requirements -> one ``repro.api.explore`` call
selecting heterogeneous memories for a TPU-v5e-like accelerator's on-chip
buffers."""
from __future__ import annotations

from repro.api import Compiler, SelectionPolicy
from repro.configs import ALL_ARCHS
from repro.profiler.traffic import (arch_task, load_dryrun_record,
                                    step_time_estimate)


PREFER_EXT = ("os-os", "os-si", "si-si", "sram")   # + OS-OS (paper §6)


def arch_dse_table(shapes=("train_4k", "decode_32k"),
                   outdir="artifacts/dryrun"):
    # extended space: include OS-OS (the paper's Future Work adds it; our
    # compiler already characterizes it) and allow refreshed gain cells for
    # long-lived data (hour-scale weight storage, paper §5.3)
    compiler = Compiler(mem_types=("sram6t", "gc_sisi", "gc_ossi",
                                   "gc_osos", "gc_osos_hvt"))
    tasks = []
    recs = {}
    for arch in ALL_ARCHS:
        for shape in shapes:
            rec = load_dryrun_record(arch, shape, outdir=outdir)
            if rec is None:
                continue
            tasks.append(arch_task(arch, shape, rec))
            recs[tasks[-1].task_id] = (arch, shape, rec)
    rows = []
    if tasks:
        report = compiler.explore(
            tasks=tasks,
            policy=SelectionPolicy(preference=PREFER_EXT, allow_refresh=True),
            cache="artifacts/dse_cache")
        labels = report.labels()
        for t in report.tasks:
            arch, shape, rec = recs[t.task_id]
            rows.append({"arch": arch, "shape": shape,
                         "L1": labels[t.task_id]["L1"],
                         "L2": labels[t.task_id]["L2"],
                         "t_step_ms": round(step_time_estimate(rec) * 1e3, 3)})
    n_hetero = sum("+" in r["L2"] or r["L1"] != r["L2"] for r in rows)
    derived = (f"{len(rows)} (arch,shape) cells profiled; {n_hetero} pick "
               f"heterogeneous L1/L2 mixes")
    return rows, derived


if __name__ == "__main__":
    rows, derived = arch_dse_table()
    for r in rows:
        print(f"{r['arch']:22s} {r['shape']:10s} L1={r['L1']:14s} L2={r['L2']}")
    print(derived)
