"""N-level branch-and-bound benchmark -> BENCH_hetero_nlevel.json.

Pits the two composition search engines against each other on the 4-level
reference hierarchy (``repro.core.gainsight.nlevel_task(4)``) with
``all_feasible`` candidates: the exhaustive cross-product grid (trimmed to
``max_compositions``) versus the lossless branch-and-bound of
``repro.hetero.search``. Records scoring throughput and — the headline —
the pruning ratio: how many fewer compositions branch-and-bound scored
while returning the identical best design. Run::

    python -m benchmarks.hetero_nlevel            # full
    python -m benchmarks.hetero_nlevel --quick    # fewer reps (CI)

Fields:

``n_space``                full cross-product size (python int)
``exhaustive``             {n_scored, latency_s, scored_per_s, truncated}
``branch_and_bound``       {n_scored, latency_s, scored_per_s, truncated}
``pruning_ratio``          exhaustive.n_scored / branch_and_bound.n_scored
``identical_best``         both engines picked the same composition (picks
                           AND float32 system metrics, bit-for-bit)
``corner_grid``            2D (compositions x corners) scoring throughput
                           via ``score_grid_corners``
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

if __package__ in (None, ""):          # `python benchmarks/hetero_nlevel.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def _time(fn, repeats: int) -> float:
    fn()                                           # warm (jit compile)
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="fewer timing reps (CI-sized)")
    ap.add_argument("--out", default="BENCH_hetero_nlevel.json")
    ap.add_argument("--cache", default="artifacts/dse_cache")
    args = ap.parse_args(argv)

    import jax
    from repro.api import DesignTable, design_space
    from repro.core.gainsight import nlevel_task
    from repro.hetero import ComposePolicy, compose
    from repro.hetero.system import SYSTEM_METRICS, score_grid_corners

    table = DesignTable.build(design_space(), cache=args.cache)
    task = nlevel_task(4)
    reps = 2 if args.quick else 5
    kw = dict(objective="power", candidate_mode="all_feasible",
              max_candidates_per_bucket=16)
    cp_ex = ComposePolicy(search="exhaustive", max_compositions=200_000, **kw)
    cp_bb = ComposePolicy(search="branch_and_bound", **kw)

    r_ex = compose(table, task, compose_policy=cp_ex)
    r_bb = compose(table, task, compose_policy=cp_bb)
    t_ex = _time(lambda: compose(table, task, compose_policy=cp_ex), reps)
    t_bb = _time(lambda: compose(table, task, compose_policy=cp_bb), reps)

    same_picks = all(
        [p.config_idx for p in r_ex.best.levels[lvl].picks]
        == [p.config_idx for p in r_bb.best.levels[lvl].picks]
        for lvl in task.levels)
    same_metrics = all(r_ex.best.metrics[m] == r_bb.best.metrics[m]
                       for m in SYSTEM_METRICS)

    # --- 2D (compositions x corners) scoring throughput --------------------
    corner_table = DesignTable.build(design_space(), cache=args.cache,
                                     corners=("nominal", "hot", "low_vdd"))
    cms = [corner_table.corner_metrics(c)
           for c in corner_table.corner_labels]
    J = 5_000 if args.quick else 50_000
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(corner_table), size=(J, 5)).astype(np.int32)
    cap = [1e6, 1e8, 1e8, 5e7, 1e6]
    f_req = [1e9, 2e9, 1e9, 5e8, 1e9]
    t_corner = _time(lambda: score_grid_corners(cms, idx, cap, f_req), reps)

    record = {
        "bench": "hetero_nlevel",
        "quick": bool(args.quick),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "task": str(task.task_id),
        "slots": sum(len(lv.buckets) for lv in task.levels.values()),
        "n_space": int(r_ex.n_space),
        "exhaustive": {
            "n_scored": int(r_ex.n_compositions),
            "latency_s": round(t_ex, 6),
            "scored_per_s": round(r_ex.n_compositions / t_ex, 1),
            "truncated": bool(r_ex.truncated),
        },
        "branch_and_bound": {
            "n_scored": int(r_bb.n_compositions),
            "latency_s": round(t_bb, 6),
            "scored_per_s": round(r_bb.n_compositions / t_bb, 1),
            "truncated": bool(r_bb.truncated),
        },
        "pruning_ratio": round(r_ex.n_compositions
                               / max(r_bb.n_compositions, 1), 2),
        "identical_best": bool(same_picks and same_metrics),
        "best_labels": r_bb.labels(),
        "corner_grid": {
            "compositions": J,
            "corners": len(cms),
            "latency_s": round(t_corner, 6),
            "rows_per_s": round(J * len(cms) / t_corner, 1),
        },
    }
    Path(args.out).write_text(json.dumps(record, indent=2) + "\n")
    print(json.dumps(record, indent=2))
    return record


if __name__ == "__main__":
    main()
