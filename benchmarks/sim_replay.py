"""Trace-replay throughput benchmark -> BENCH_sim.json.

Measures the simulator's replay rate (compositions simulated per second)
over a large (J compositions x S slots) grid and multi-phase traces, the
end-to-end ``compose(refine="simulate")`` latency, and the Table-2 parity
count through the simulated re-rank. Run::

    python -m benchmarks.sim_replay            # full grid
    python -m benchmarks.sim_replay --quick    # small grid (CI)

One record per run (overwritten) so CI can upload it as an artifact;
fields:

``grid`` / ``slots`` / ``bins`` / ``phases``   replay problem size
``xla``          {latency_s, comps_per_s} — the jit(vmap(scan)) grid path
``interpret``    {latency_s, comps_per_s} — the per-composition loop oracle
                 (quick mode only times a small slice; reported per-comp)
``simulate_ms``  end-to-end Compiler.simulate() wall time for one paper task
``table2_matches``  how many of the 7 paper tasks refine="simulate" keeps
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

if __package__ in (None, ""):            # `python benchmarks/sim_replay.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def _time(fn, repeats: int) -> float:
    fn()                                           # warm (jit compile)
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small grid + fewer reps (CI-sized)")
    ap.add_argument("--out", default="BENCH_sim.json")
    ap.add_argument("--cache", default="artifacts/dse_cache")
    args = ap.parse_args(argv)

    import jax
    from repro.api import Compiler, DesignTable, design_space
    from repro.core import gainsight
    from repro.core.select import Bucket, LevelReq, TaskReq
    from repro.sim import simulate_traces, task_traces
    from repro.sim.engine import SIM_METRICS
    from repro.sim.rerank import sim_cols

    table = DesignTable.build(design_space(), cache=args.cache)

    # --- correctness anchor: Table 2 through the simulated re-rank ---------
    c = Compiler()
    t0 = time.perf_counter()
    matches = sum(
        c.simulate(t, space=table).matches(gainsight.TABLE2_EXPECTED[t.task_id])
        for t in gainsight.TASKS)
    simulate_ms = (time.perf_counter() - t0) / len(gainsight.TASKS) * 1e3

    # --- throughput: one big synthetic replay grid -------------------------
    # (uniform random rows per slot — the same gather + scan cost profile as
    # a real top-K re-rank, but with a controllable J)
    J = 2_000 if args.quick else 50_000
    bins = 16 if args.quick else 32
    S = 4
    task = TaskReq("bench", "bench", {
        "L1": LevelReq("L1", 1 << 20, (Bucket(0.6, 1.2e9, 2e-6),
                                       Bucket(0.4, 5e8, 1e-4))),
        "L2": LevelReq("L2", 64 << 20, (Bucket(0.5, 1e9, 1e-3),
                                        Bucket(0.5, 2e9, 3e-6)))})
    phases = ("prefill", "decode")
    traces = task_traces(task, phases=phases, n_bins=bins)
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(table), size=(J, S)).astype(np.int32)
    cols = sim_cols(table)
    reps = 3 if args.quick else 10

    t_xla = _time(lambda: simulate_traces(cols, idx, traces, backend="xla"),
                  reps)
    # the interpret oracle is O(J) python dispatches; time a small slice
    J_int = min(J, 64)
    t_int = _time(lambda: simulate_traces(cols, idx[:J_int], traces,
                                          backend="interpret"), 1)

    record = {
        "bench": "sim_replay",
        "quick": bool(args.quick),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "table_configs": len(table),
        "metrics": list(SIM_METRICS),
        "grid": J,
        "slots": S,
        "bins": bins,
        "phases": list(phases),
        "xla": {
            "latency_s": round(t_xla, 6),
            "comps_per_s": round(J / t_xla, 1),
        },
        "interpret": {
            "grid": J_int,
            "latency_s": round(t_int, 6),
            "comps_per_s": round(J_int / t_int, 1),
        },
        "simulate_ms": round(simulate_ms, 3),
        "table2_matches": int(matches),
    }
    Path(args.out).write_text(json.dumps(record, indent=2) + "\n")
    print(json.dumps(record, indent=2))
    return record


if __name__ == "__main__":
    main()
