"""Per-level (vdd, refresh-margin) co-optimization benchmark -> BENCH_vdd.json.

Measures the throughput of the operating-point expansion (swept configs/s,
where a swept config = one table row characterized/priced at one extra
(vdd, margin) point), the end-to-end swept compose latency over the 7
Table-2 tasks, and the search-quality anchors the axis exists for: the
cold-boost sweep point must keep flipping the golden-locked winners, and
branch-and-bound must stay rank-identical to exhaustive on the enlarged
grid. Run::

    python -m benchmarks.vdd_sweep            # full grid, 3 sweep points
    python -m benchmarks.vdd_sweep --quick    # CI-sized

One record per run (overwritten) so CI can upload it as an artifact;
fields:

``configs`` / ``points``       base rows and expansion points (incl. base)
``rows``                       configs × points in the expanded grid
``expand``       {latency_s, swept_configs_per_s} — the per-corner vmapped
                 expansion of every non-base block
``compose``      {latency_s, tasks_per_s} — 7 swept Table-2 composes
``flips``        {matches} — tasks whose winner the sweep flips (golden: 4)
``task<k>``      {best_labels} for every flipped task (exact parity)
``bb.identical_best``          B&B == exhaustive best on the enlarged grid
``table2_matches``             base-point Table-2 parity (must be 7)
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

if __package__ in (None, ""):            # `python benchmarks/vdd_sweep.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# the golden flip point (scripts/update_golden.py VDD_SWEEP_POINT): cold die,
# boosted supply — OS-Si gains the frequency headroom to take L1/L2 buckets
SWEEP_POINT = (1.2, 233.0)


def _time(fn, repeats: int) -> float:
    fn()                                           # warm (jit compile)
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="fewer sweep points + reps (CI-sized)")
    ap.add_argument("--out", default="BENCH_vdd.json")
    args = ap.parse_args(argv)

    import jax
    from repro.api import DesignTable, design_space
    from repro.core import gainsight
    from repro.hetero import ComposePolicy, compose, expand

    if args.quick:
        vdds = (SWEEP_POINT,)
        margins = (0.8,)
        reps = 2
    else:
        vdds = (SWEEP_POINT, (0.9, 300.0), (1.1, 358.0))
        margins = (0.8, 0.5)
        reps = 5

    table = DesignTable.from_configs(design_space())
    cp = ComposePolicy(vdd_sweep=vdds, refresh_margin_sweep=margins)
    points = expand.expansion_points(cp)
    n_base = len(table)
    rows = n_base * len(points)
    swept = rows - n_base                      # non-base blocks actually built

    def expand_once():
        metrics, fams = expand.expand_metrics(table, table.metrics, points)
        jax.block_until_ready(metrics["retention_s"])
        return metrics

    t_expand = _time(expand_once, reps)

    flip_cp = ComposePolicy(vdd_sweep=(SWEEP_POINT,))

    def compose_tasks():
        return [compose(table, t, compose_policy=flip_cp)
                for t in gainsight.TASKS]

    t_compose = _time(compose_tasks, max(reps // 2, 1))

    # search-quality anchors: base parity, golden flips, B&B losslessness
    base = {t.task_id: compose(table, t) for t in gainsight.TASKS}
    matches = sum(base[t.task_id].matches(gainsight.TABLE2_EXPECTED[t.task_id])
                  for t in gainsight.TASKS)
    flipped = {}
    for t, rep in zip(gainsight.TASKS, compose_tasks()):
        if rep.labels() != base[t.task_id].labels():
            flipped[f"task{t.task_id}"] = {"best_labels": rep.labels()}

    bb_kw = dict(vdd_sweep=vdds, refresh_margin_sweep=margins,
                 objective="power", candidate_mode="all_feasible")
    ex = compose(table, gainsight.TASKS[0], compose_policy=ComposePolicy(
        search="exhaustive", **bb_kw))
    bb = compose(table, gainsight.TASKS[0], compose_policy=ComposePolicy(
        search="branch_and_bound", **bb_kw))
    bb_same = bool(bb.labels() == ex.labels()
                   and bb.best.metrics == ex.best.metrics)

    record = {
        "bench": "vdd_sweep",
        "quick": bool(args.quick),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "configs": n_base,
        "points": len(points),
        "rows": rows,
        "sweep_point": list(SWEEP_POINT),
        "expand": {
            "latency_s": round(t_expand, 6),
            "swept_configs_per_s": round(swept / t_expand, 1),
        },
        "compose": {
            "latency_s": round(t_compose, 6),
            "tasks_per_s": round(len(gainsight.TASKS) / t_compose, 2),
        },
        "flips": {"matches": sorted(flipped)},
        **flipped,
        "bb": {"identical_best": bb_same},
        "table2_matches": int(matches),
    }
    Path(args.out).write_text(json.dumps(record, indent=2) + "\n")
    print(json.dumps(record, indent=2))
    return record


if __name__ == "__main__":
    main()
