"""Perf-regression diff of BENCH_*.json records against committed baselines.

``python -m benchmarks.run --quick --compare benchmarks/baselines`` runs the
benchmark suite and then this module: every emitted ``BENCH_*.json`` is
flattened (nested dicts become dotted keys) and diffed per-metric against the
same-named file in the baseline directory. Classification:

- **environment keys** (``jax``, ``backend``, ``devices``, ``quick``, ...)
  are recorded but never judged — CI machines legitimately differ.
- **exactness keys** (``table2_matches``, ``identical_best``, ``matches``,
  ``best_labels``, ``arch_labels``) must match bit-for-bit; any drift is a
  ``regression`` — these encode paper-parity, not speed.
- **rates** (``*_per_s``): higher is better. current/baseline below
  ``rate_tolerance`` -> ``regression``; above ``1/rate_tolerance`` ->
  ``improved``. The default tolerance (0.5, i.e. 2x either way) is wide on
  purpose: shared CI runners are noisy and the diff is informational.
- **times** (``*_s``, ``*_ms``, ``latency*``): lower is better, same 2x
  band inverted.
- anything else numeric that moved is ``changed`` (informational).

The result is written as ``BENCH_diff.json`` with an ``ok`` flag and the
list of regressions; the exit code stays 0 unless ``--fail-on-regression``
is passed (CI uploads the diff as an artifact instead of failing the build).
"""
from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Any, Dict, Optional

# keys describing the machine / invocation, not the result
ENV_KEYS = {"bench", "quick", "jax", "backend", "devices", "cache"}
# keys encoding paper parity / search correctness: compared exactly
EXACT_KEYS = {"table2_matches", "identical_best", "matches", "best_labels",
              "arch_labels", "configs", "corners", "rows", "slots", "task",
              "grid", "n_space"}


def flatten(record: Dict[str, Any], prefix: str = "") -> Dict[str, Any]:
    """``{"sweep": {"rows_per_s": 9e3}}`` -> ``{"sweep.rows_per_s": 9e3}``."""
    out: Dict[str, Any] = {}
    for k, v in record.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict) and v and all(isinstance(x, str)
                                             for x in v.values()):
            out[key] = v                      # label maps stay atomic
        elif isinstance(v, dict):
            out.update(flatten(v, prefix=f"{key}."))
        else:
            out[key] = v
    return out


def _leaf(key: str) -> str:
    return key.rsplit(".", 1)[-1]


def _is_env(key: str) -> bool:
    return _leaf(key) in ENV_KEYS


def _is_exact(key: str) -> bool:
    return _leaf(key) in EXACT_KEYS


def _is_rate(key: str) -> bool:
    return _leaf(key).endswith("_per_s")


def _is_time(key: str) -> bool:
    leaf = _leaf(key)
    return (leaf.endswith("_s") or leaf.endswith("_ms")
            or leaf.startswith("latency")) and not leaf.endswith("_per_s")


def _ratio(base, cur) -> Optional[float]:
    try:
        b, c = float(base), float(cur)
    except (TypeError, ValueError):
        return None
    if not (math.isfinite(b) and math.isfinite(c)) or b <= 0:
        return None
    return c / b


def diff_records(baseline: Dict[str, Any], current: Dict[str, Any],
                 rate_tolerance: float = 0.5) -> Dict[str, Any]:
    """Per-metric diff of two flattened-able records. Returns
    ``{"metrics": {key: {...}}, "regressions": [...], "ok": bool}``."""
    base, cur = flatten(baseline), flatten(current)
    metrics: Dict[str, Any] = {}
    regressions = []
    for key in sorted(set(base) | set(cur)):
        b, c = base.get(key), cur.get(key)
        entry: Dict[str, Any] = {"baseline": b, "current": c}
        if _is_env(key):
            entry["status"] = "env"
        elif b is None or c is None:
            entry["status"] = "missing"
        elif _is_exact(key):
            entry["status"] = "ok" if b == c else "regression"
        elif _is_rate(key) or _is_time(key):
            r = _ratio(b, c)
            entry["ratio"] = None if r is None else round(r, 4)
            if r is None:
                entry["status"] = "ok" if b == c else "changed"
            else:
                # normalize so that lo < tolerance always means "got worse"
                lo = r if _is_rate(key) else (1.0 / r if r > 0 else 0.0)
                entry["status"] = ("regression" if lo < rate_tolerance else
                                   "improved" if lo > 1.0 / rate_tolerance
                                   else "ok")
        elif b == c:
            entry["status"] = "ok"
        else:
            entry["status"] = "changed"
        if entry["status"] == "regression":
            regressions.append(key)
        metrics[key] = entry
    return {"metrics": metrics, "regressions": regressions,
            "ok": not regressions}


def diff_suite(baseline_dir, current_dir,
               rate_tolerance: float = 0.5) -> Dict[str, Any]:
    """Diff every ``BENCH_*.json`` in ``current_dir`` against the same-named
    baseline. Baselines with no current record (and vice versa) are reported,
    not failed — benches can be added without regenerating everything."""
    bdir, cdir = Path(baseline_dir), Path(current_dir)
    names = sorted(({p.name for p in bdir.glob("BENCH_*.json")}
                    | {p.name for p in cdir.glob("BENCH_*.json")})
                   - {"BENCH_diff.json"})
    benches: Dict[str, Any] = {}
    regressions = []
    for name in names:
        bp, cp = bdir / name, cdir / name
        if not bp.exists() or not cp.exists():
            benches[name] = {"status": "missing",
                             "baseline": bp.exists(), "current": cp.exists()}
            continue
        d = diff_records(json.loads(bp.read_text()),
                         json.loads(cp.read_text()),
                         rate_tolerance=rate_tolerance)
        benches[name] = d
        regressions += [f"{name}:{k}" for k in d["regressions"]]
    return {"baseline_dir": str(bdir), "current_dir": str(cdir),
            "rate_tolerance": rate_tolerance, "benches": benches,
            "regressions": regressions, "ok": not regressions}


def summarize(diff: Dict[str, Any]) -> str:
    lines = [f"bench compare vs {diff['baseline_dir']} "
             f"(tolerance {diff['rate_tolerance']}x)"]
    for name, d in diff["benches"].items():
        if d.get("status") == "missing":
            side = "baseline" if not d["baseline"] else "current"
            lines.append(f"  {name}: missing {side} record")
            continue
        counts: Dict[str, int] = {}
        for m in d["metrics"].values():
            counts[m["status"]] = counts.get(m["status"], 0) + 1
        lines.append(f"  {name}: " + ", ".join(
            f"{v} {k}" for k, v in sorted(counts.items())))
        for key in d["regressions"]:
            m = d["metrics"][key]
            lines.append(f"    REGRESSION {key}: "
                         f"{m['baseline']} -> {m['current']}")
    lines.append("ok" if diff["ok"]
                 else f"{len(diff['regressions'])} regression(s)")
    return "\n".join(lines)
