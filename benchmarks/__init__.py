"""Paper-figure/table reproduction benchmarks (run via ``python -m
benchmarks.run`` or ``python benchmarks/run.py`` from the repo root)."""
