"""Operating-corner sweep benchmark -> BENCH_corners.json.

Measures the throughput of the (designs × corners) vmapped characterization
(rows/s where a row = one config at one corner), the corner-robust
worst-case DSE latency, and the physics deltas the corner axis exists for
(hot-corner retention shrink, nominal Table-2 parity). Run::

    python -m benchmarks.corner_sweep            # full grid, 4 corners
    python -m benchmarks.corner_sweep --quick    # CI-sized

One record per run (overwritten) so CI can upload it as an artifact;
fields:

``configs`` / ``corners``      sweep problem size
``rows``                       configs × corners characterized per sweep
``sweep``        {latency_s, rows_per_s} — the jit(vmap(vmap)) corner grid
``nominal``      {latency_s, rows_per_s} — the single-corner baseline vmap
``robust_explore_ms``          worst-case explore() over the corner table
``retention_shrink_hot``       median nominal/hot retention ratio (GC rows)
``table2_matches``             nominal-corner Table-2 parity (must be 7)
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

if __package__ in (None, ""):          # `python benchmarks/corner_sweep.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def _time(fn, repeats: int) -> float:
    fn()                                           # warm (jit compile)
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller grid + fewer reps (CI-sized)")
    ap.add_argument("--out", default="BENCH_corners.json")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro.api import DesignTable, design_space, explore
    from repro.core import corners, gainsight
    from repro.core.characterize import (characterize_batch,
                                         characterize_corners)

    if args.quick:
        configs = design_space(word_sizes=(16, 64), num_words=(32, 256))
        ops = [corners.NOMINAL, corners.HOT]
        reps = 3
    else:
        configs = design_space(
            word_sizes=(16, 32, 64, 128),
            num_words=(16, 32, 64, 128, 256, 512, 1024))
        ops = [corners.NOMINAL, corners.HOT, corners.COLD, corners.LOW_VDD]
        reps = 10

    vecs = jnp.stack([c.to_vector() for c in configs])
    rows = len(configs) * len(ops)

    def sweep():
        out = characterize_corners(vecs, ops)
        jax.block_until_ready(out["retention_s"])
        return out

    def nominal():
        out = characterize_batch(vecs)
        jax.block_until_ready(out["retention_s"])
        return out

    t_sweep = _time(sweep, reps)
    t_nom = _time(nominal, reps)

    # physics deltas + DSE anchors
    grid = sweep()
    ret = np.asarray(grid["retention_s"], np.float64)
    gc = ret[:, 0] < 1e11                          # GC rows (SRAM rows = 1e12)
    shrink = float(np.median(ret[gc, 0] / ret[gc, 1]))   # nominal / hot

    table = DesignTable.from_configs(configs, corners=ops)
    t0 = time.perf_counter()
    explore(space=table, tasks=gainsight.TASKS, robust="worst_case")
    robust_ms = (time.perf_counter() - t0) * 1e3

    matches = explore(tasks=gainsight.TASKS).matches(
        gainsight.TABLE2_EXPECTED)

    record = {
        "bench": "corner_sweep",
        "quick": bool(args.quick),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "configs": len(configs),
        "corners": [op.corner for op in ops],
        "rows": rows,
        "sweep": {
            "latency_s": round(t_sweep, 6),
            "rows_per_s": round(rows / t_sweep, 1),
        },
        "nominal": {
            "latency_s": round(t_nom, 6),
            "rows_per_s": round(len(configs) / t_nom, 1),
        },
        "robust_explore_ms": round(robust_ms, 3),
        "retention_shrink_hot": round(shrink, 2),
        "table2_matches": int(matches),
    }
    Path(args.out).write_text(json.dumps(record, indent=2) + "\n")
    print(json.dumps(record, indent=2))
    return record


if __name__ == "__main__":
    main()
