"""Benchmarks for every paper table/figure (Figs 7-11, Tables 1-2), driven
through the ``repro.api`` façade (Compiler / DesignTable / explore).

Each function returns (rows, derived) where rows are printable dicts and
`derived` is a one-line summary of the claim being reproduced.
"""
from __future__ import annotations

import numpy as np

from repro.api import Compiler, DesignTable, MacroConfig, explore
from repro.core import bitcells, gainsight, retention

KB_SIZES = [(16, 16), (32, 32), (64, 32), (64, 64), (128, 64), (128, 128)]

_COMPILER = Compiler()


def fig7_area():
    """Fig 7: array + total bank area, dual-port GC vs single-port SRAM."""
    rows = []
    crossover_ok = []
    for wz, nw in KB_SIZES:
        r = {}
        for mt in ("sram6t", "gc_sisi", "gc_ossi"):
            c = _COMPILER.compile(mem_type=mt, word_size=wz,
                                  num_words=nw).ppa
            r[f"{mt}_array_um2"] = round(c["area_array_um2"], 1)
            r[f"{mt}_total_um2"] = round(c["area_um2"], 1)
        kb = wz * nw / 1024
        rows.append({"size_kb": kb, **r})
        if kb > 1:
            crossover_ok.append(r["gc_sisi_total_um2"] < r["sram6t_total_um2"])
        assert r["gc_ossi_total_um2"] < r["gc_sisi_total_um2"]
        assert r["gc_sisi_array_um2"] < r["sram6t_array_um2"]
    derived = (f"GC arrays always smaller; Si-Si bank < SRAM above 1Kb in "
               f"{sum(crossover_ok)}/{len(crossover_ok)} sizes; OS-Si smallest everywhere")
    return rows, derived


def fig8_speed_power():
    """Fig 8: operating frequency, effective bandwidth, leakage power."""
    rows = []
    for mt in ("sram6t", "gc_sisi", "gc_ossi"):
        for wz, nw, tag in ((128, 32, "4:1"), (64, 64, "1:1"), (32, 128, "1:4")):
            for ls in ((False, True) if mt != "sram6t" else (False,)):
                c = _COMPILER.compile(mem_type=mt, word_size=wz,
                                      num_words=nw, mux=1,
                                      level_shift=ls).ppa
                rows.append({
                    "mem": mt, "org": f"{wz}x{nw}({tag})", "ls": int(ls),
                    "f_op_mhz": round(c["f_op_hz"] / 1e6, 1),
                    "bw_gbs": round(c["bandwidth_bits_s"] / 8e9, 2),
                    "bw_total_gbs": round(c["bandwidth_total_bits_s"] / 8e9, 2),
                    "p_leak_uw": round(c["p_leak_w"] * 1e6, 4),
                })
    sram_f = max(r["f_op_mhz"] for r in rows if r["mem"] == "sram6t")
    sisi_f = max(r["f_op_mhz"] for r in rows if r["mem"] == "gc_sisi")
    ossi_f = max(r["f_op_mhz"] for r in rows if r["mem"] == "gc_ossi")
    sram_leak = np.mean([r["p_leak_uw"] for r in rows if r["mem"] == "sram6t"])
    gc_leak = np.mean([r["p_leak_uw"] for r in rows if r["mem"] != "sram6t"])
    derived = (f"f_op: SRAM {sram_f:.0f} > Si-Si {sisi_f:.0f} > OS-Si "
               f"{ossi_f:.0f} MHz; GC leakage {sram_leak/gc_leak:.0f}x below SRAM")
    return rows, derived


def fig9_retention():
    """Fig 9: retention + modulation via VT and WWLLS."""
    rows = []
    for name in ("gc_sisi", "gc_sisi_hvt", "gc_ossi", "gc_ossi_hvt",
                 "gc_osos", "gc_osos_hvt"):
        cell = bitcells.BITCELLS[name]
        for ls in (0, 1):
            rows.append({"cell": name, "ls": ls,
                         "t_ret_s": float(retention.retention_time(cell, ls)),
                         "v0": float(bitcells.sn_high_level(cell, ls))})
    by = {(r["cell"], r["ls"]): r["t_ret_s"] for r in rows}
    derived = (f"Si-Si {by[('gc_sisi',0)]:.1e}s (us-scale); OS-Si "
               f"{by[('gc_ossi',0)]:.1e}s (ms-scale); OS-OS+HVT+LS "
               f"{by[('gc_osos_hvt',1)]:.1e}s (>10s); WWLLS improves retention")
    return rows, derived


def fig10_requirements():
    """Fig 10 (reconstructed): per-task L1/L2 frequency + lifetime needs."""
    rows = []
    l2_higher = 0
    for t in gainsight.TASKS:
        f1 = max(b.f_hz for b in t.l1.buckets)
        f2 = max(b.f_hz for b in t.l2.buckets)
        l2_higher += f2 > f1
        rows.append({"task": t.task_id, "name": t.name,
                     "l1_f_ghz": round(f1 / 1e9, 2),
                     "l2_f_ghz": round(f2 / 1e9, 2),
                     "l1_lifetime_s": max(b.lifetime_s for b in t.l1.buckets),
                     "l2_lifetime_s": max(b.lifetime_s for b in t.l2.buckets)})
    derived = (f"{l2_higher}/7 tasks need higher L2 read frequency than L1 "
               f"(shared-L2 effect the paper highlights)")
    return rows, derived


def table2_optimal():
    """Table 2: optimal heterogeneous L1/L2 configuration per task."""
    report = explore(tasks=gainsight.TASKS, cache="artifacts/dse_cache")
    labels = report.labels()
    rows = []
    for t in report.tasks:
        exp = gainsight.TABLE2_EXPECTED[t.task_id]
        rows.append({"task": t.task_id, **labels[t.task_id],
                     "match": labels[t.task_id] == exp})
    matches = report.matches(gainsight.TABLE2_EXPECTED)
    derived = f"Table 2 reproduced {matches}/7 tasks exactly"
    return rows, derived


def fig11_shmoo():
    """Fig 11: single-bank Si-Si feasibility shmoo (16x16 .. 128x128)."""
    sizes = [16, 32, 64, 128]
    cfgs = [MacroConfig(mem_type="gc_sisi", word_size=wz, num_words=nw, mux=1)
            for wz in sizes for nw in sizes]
    table = DesignTable.from_configs(cfgs)
    rows = []
    for t in gainsight.TASKS:
        for lvl_name, lvl in (("L1", t.l1), ("L2", t.l2)):
            b = lvl.buckets[0]
            ok = table.shmoo(b.f_hz, b.lifetime_s)
            rows.append({"task": t.task_id, "level": lvl_name,
                         "workable": int(ok.sum()), "of": len(cfgs),
                         "grid": "".join("G" if o else "R" for o in ok)})
    n_green = sum(r["workable"] for r in rows)
    derived = f"shmoo: {n_green}/{len(rows) * len(cfgs)} green cells across 7 tasks x L1/L2"
    return rows, derived


ALL = [fig7_area, fig8_speed_power, fig9_retention, fig10_requirements,
       table2_optimal, fig11_shmoo]
