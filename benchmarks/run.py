"""Benchmark harness: CSV summary (default) or the JSON suite + compare mode.

Default (no flags) prints the legacy ``name,us_per_call,derived`` CSV —
one line per paper table/figure plus compiler-throughput and roofline
summaries::

    pip install -e . && python -m benchmarks.run

Suite mode runs the four record-emitting benchmark modules **in-process**
(one process, so a single ``REPRO_TRACE`` trace covers the whole suite) and
optionally diffs the emitted ``BENCH_*.json`` set against committed
baselines (``benchmarks/compare.py``)::

    python -m benchmarks.run --quick --compare benchmarks/baselines

Emitted file set (the *only* BENCH files this repo produces; committed
baselines live under ``benchmarks/baselines/``):

    BENCH_hetero.json          benchmarks.hetero_dse
    BENCH_hetero_nlevel.json   benchmarks.hetero_nlevel
    BENCH_sim.json             benchmarks.sim_replay
    BENCH_corners.json         benchmarks.corner_sweep
    BENCH_vdd.json             benchmarks.vdd_sweep
    BENCH_diff.json            the compare result (suite mode only)
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

if __package__ in (None, ""):                    # `python benchmarks/run.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

# (bench label, module name, emitted record file) — keep in sync with the
# committed baseline set and docs/OBSERVABILITY.md
SUITE = (
    ("hetero", "benchmarks.hetero_dse", "BENCH_hetero.json"),
    ("hetero_nlevel", "benchmarks.hetero_nlevel", "BENCH_hetero_nlevel.json"),
    ("sim", "benchmarks.sim_replay", "BENCH_sim.json"),
    ("corners", "benchmarks.corner_sweep", "BENCH_corners.json"),
    ("vdd", "benchmarks.vdd_sweep", "BENCH_vdd.json"),
)


def _timed(fn, repeats=1):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn()
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6


def run_suite(quick: bool, out_dir: Path,
              compare_dir=None, rate_tolerance: float = 0.5) -> dict:
    """Run every SUITE module main() in-process, then (optionally) diff the
    emitted records against ``compare_dir``. Returns the diff (or a stub
    with ``ok=True`` when no compare was requested)."""
    import importlib

    out_dir.mkdir(parents=True, exist_ok=True)
    for label, modname, fname in SUITE:
        print(f"[suite] {label}: python -m {modname}"
              f"{' --quick' if quick else ''}", flush=True)
        mod = importlib.import_module(modname)
        argv = ["--out", str(out_dir / fname)] + (["--quick"] if quick else [])
        mod.main(argv)

    if compare_dir is None:
        return {"ok": True, "benches": {}, "regressions": []}

    from benchmarks import compare

    diff = compare.diff_suite(compare_dir, out_dir,
                              rate_tolerance=rate_tolerance)
    diff_path = out_dir / "BENCH_diff.json"
    diff_path.write_text(json.dumps(diff, indent=2) + "\n")
    print(compare.summarize(diff))
    print(f"[suite] wrote {diff_path}")
    return diff


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="suite mode, CI-sized grids")
    ap.add_argument("--suite", action="store_true",
                    help="suite mode, full grids")
    ap.add_argument("--compare", metavar="BASELINE_DIR", default=None,
                    help="diff emitted BENCH_*.json against this directory "
                         "and write BENCH_diff.json (implies suite mode)")
    ap.add_argument("--out-dir", default=".",
                    help="where suite mode writes BENCH_*.json (default: cwd)")
    ap.add_argument("--rate-tolerance", type=float, default=0.5,
                    help="throughput ratio below this is a regression "
                         "(default 0.5 = 2x band)")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 when the compare finds regressions "
                         "(default: informational, exit 0)")
    args = ap.parse_args(argv)

    if args.quick or args.suite or args.compare is not None:
        diff = run_suite(args.quick, Path(args.out_dir),
                         compare_dir=args.compare,
                         rate_tolerance=args.rate_tolerance)
        if args.fail_on_regression and not diff["ok"]:
            sys.exit(1)
        return
    _csv_main()


def _csv_main() -> None:
    from benchmarks import paper_figs

    print("name,us_per_call,derived")
    for fn in paper_figs.ALL:
        (rows, derived), us = _timed(fn)
        print(f"{fn.__name__},{us:.0f},\"{derived}\"")

    # compiler throughput: vmap'd characterization of the whole design space
    from repro.api import DesignTable, design_space

    def sweep():
        table = DesignTable.from_configs(design_space())
        return table, len(table)

    (table, n), us = _timed(sweep)
    print(f"characterize_design_space,{us:.0f},\"{n} configs PPA+retention "
          f"({us / max(n,1):.0f} us/config incl. transient solve)\"")

    # Pallas retention kernel (interpret mode on CPU)
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.retention_kernel import retention_pallas
    from repro.core.retention import time_grid
    rng = np.random.default_rng(0)
    params = jnp.asarray(np.column_stack([
        rng.uniform(0.4, 0.8, 256), np.full(256, 1.2),
        np.full(256, 2e-6), np.full(256, 0.05), np.full(256, 1e-16),
        np.full(256, 3e-15), np.full(256, 1e-15), np.full(256, 0.1),
        rng.uniform(0.6, 1.1, 256), np.full(256, 0.5)]), jnp.float32)
    ts = time_grid()
    _, us = _timed(lambda: retention_pallas(params, ts, interpret=True)
                   .block_until_ready())
    print(f"retention_kernel_interpret,{us:.0f},\"256-config RK4 transient "
          f"(Pallas interpret; TPU target is the native path)\"")

    # joint composition throughput (full record: python -m benchmarks.hetero_dse)
    from repro.core import gainsight
    from repro.hetero import compose

    def compose_all(refine=None):
        reports = [compose(table, t, refine=refine) for t in gainsight.TASKS]
        return reports, sum(r.matches(gainsight.TABLE2_EXPECTED[r.task.task_id])
                            for r in reports)

    (_, n_match), us = _timed(compose_all)
    print(f"hetero_compose,{us:.0f},\"joint (L1,L2) composition for 7 tasks; "
          f"Table 2 matches {n_match}/7\"")

    # N-level branch-and-bound vs exhaustive (full record:
    # python -m benchmarks.hetero_nlevel)
    from repro.core.gainsight import nlevel_task
    from repro.hetero import ComposePolicy

    def nlevel_bb():
        kw = dict(objective="power", candidate_mode="all_feasible",
                  max_candidates_per_bucket=16)
        ex = compose(table, nlevel_task(4), compose_policy=ComposePolicy(
            search="exhaustive", max_compositions=50_000, **kw))
        bb = compose(table, nlevel_task(4), compose_policy=ComposePolicy(
            search="branch_and_bound", **kw))
        same = bb.labels() == ex.labels()
        return (ex, bb), (ex.n_compositions, bb.n_compositions, same)

    (_, (n_ex, n_bb, same)), us = _timed(nlevel_bb)
    print(f"hetero_nlevel,{us:.0f},\"4-level B&B scored {n_bb} vs "
          f"{n_ex} exhaustive ({n_ex / max(n_bb, 1):.0f}x pruning); "
          f"identical best: {same}\"")

    # trace replay + simulated re-rank (full record: python -m benchmarks.sim_replay)
    (_, n_sim_match), us = _timed(lambda: compose_all(refine="simulate"))
    print(f"sim_replay,{us:.0f},\"simulate-then-rerank for 7 tasks "
          f"(prefill+decode traces, top-8 re-rank); Table 2 matches "
          f"{n_sim_match}/7\"")

    # operating-corner sweep (full record: python -m benchmarks.corner_sweep)
    import jax
    from repro.core import corners
    from repro.core.characterize import characterize_corners

    ops = [corners.NOMINAL, corners.HOT]
    corner_vecs = jnp.stack([c.to_vector() for c in design_space()])

    def corner_sweep():
        out = characterize_corners(corner_vecs, ops)
        jax.block_until_ready(out["retention_s"])
        return out, corner_vecs.shape[0] * len(ops)

    (_, n_rows), us = _timed(corner_sweep)
    print(f"corner_sweep,{us:.0f},\"{n_rows} (config,corner) rows "
          f"PPA+retention under one vmapped corner grid\"")

    # per-arch heterogeneous-memory DSE (the paper's technique on our archs)
    try:
        from benchmarks.arch_dse import arch_dse_table
        (rows, derived), us = _timed(arch_dse_table)
        print(f"arch_dse,{us:.0f},\"{derived}\"")
    except Exception as e:
        print(f"arch_dse,0,\"skipped: {e}\"")

    # roofline table from dry-run artifacts (if present)
    try:
        from repro.launch.roofline import load_table
        rows = load_table()
        if rows:
            worst = min(rows, key=lambda r: r["roofline_fraction"])
            bound = {}
            for r in rows:
                bound[r["bottleneck"]] = bound.get(r["bottleneck"], 0) + 1
            print(f"roofline_single_pod,0,\"{len(rows)} cells; bottlenecks "
                  f"{bound}; worst fraction {worst['roofline_fraction']:.2%} "
                  f"({worst['arch']}/{worst['shape']})\"")
        else:
            print("roofline_single_pod,0,\"no dry-run artifacts\"")
    except Exception as e:  # artifacts may not exist in fresh checkouts
        print(f"roofline_single_pod,0,\"skipped: {e}\"")


if __name__ == '__main__':
    main()
