"""Heterogeneous-composition throughput benchmark -> BENCH_hetero.json.

Measures the composition engine's scoring rate (compositions priced per
second) over a large joint (L1, L2) grid, single-device vs sharded across
every visible device, plus the end-to-end ``compose()`` latency and the
Table-2 parity count. Run::

    python -m benchmarks.hetero_dse            # full grid
    python -m benchmarks.hetero_dse --quick    # small grid (CI)

The record is appended-to-by-overwrite (one file per run) so CI can upload
it as an artifact; fields:

``grid``             compositions scored per timing rep
``single_device``    {latency_s, configs_per_s}
``sharded``          {latency_s, configs_per_s, devices}  (equal results —
                     see tests/test_hetero.py for the equivalence proof)
``compose_ms``       end-to-end compose() wall time for one paper task
``table2_matches``   how many of the 7 paper tasks compose() reproduces
``arch_tasks``       profiler-side (arch x shape) cells composed, if dry-run
                     artifacts exist in this checkout
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

if __package__ in (None, ""):            # `python benchmarks/hetero_dse.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def _time(fn, repeats: int) -> float:
    fn()                                           # warm (jit compile)
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small grid + fewer reps (CI-sized)")
    ap.add_argument("--out", default="BENCH_hetero.json")
    ap.add_argument("--cache", default="artifacts/dse_cache")
    args = ap.parse_args(argv)

    import jax
    from repro.api import DesignTable, design_space
    from repro.core import gainsight
    from repro.hetero import ComposePolicy, compose
    from repro.hetero.system import METRIC_COLS, score_grid

    table = DesignTable.build(design_space(), cache=args.cache)

    # --- correctness anchor: Table 2 through the joint path ----------------
    t0 = time.perf_counter()
    matches = sum(
        compose(table, t).matches(gainsight.TABLE2_EXPECTED[t.task_id])
        for t in gainsight.TASKS)
    compose_ms = (time.perf_counter() - t0) / len(gainsight.TASKS) * 1e3

    # --- profiler-side tasks (present only when dry-runs were generated) ---
    from repro.profiler.traffic import available_arch_tasks
    arch_tasks = available_arch_tasks()
    arch_labels = {}
    for t in arch_tasks:
        arch_labels[str(t.task_id)] = compose(
            table, t, compose_policy=ComposePolicy(objective="power")).labels()

    # --- throughput: one big synthetic joint grid --------------------------
    # (uniform random rows per slot — same gather/reduce cost profile as a
    # real all_feasible cross-product, but with a controllable J)
    J = 20_000 if args.quick else 500_000
    S = 5                                   # L1 x1 + L2 x3 + spill slot
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(table), size=(J, S)).astype(np.int32)
    cap = [1e6, 1e8, 1e8, 5e7, 1e6]
    f_req = [1e9, 2e9, 1e9, 5e8, 1e9]
    reps = 3 if args.quick else 10

    t_single = _time(lambda: score_grid(table.metrics, idx, cap, f_req,
                                        sharded=False), reps)
    t_sharded = _time(lambda: score_grid(table.metrics, idx, cap, f_req,
                                         sharded=True), reps)

    record = {
        "bench": "hetero_dse",
        "quick": bool(args.quick),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "table_configs": len(table),
        "metric_cols": list(METRIC_COLS),
        "grid": J,
        "slots": S,
        "single_device": {
            "latency_s": round(t_single, 6),
            "configs_per_s": round(J / t_single, 1),
        },
        "sharded": {
            "latency_s": round(t_sharded, 6),
            "configs_per_s": round(J / t_sharded, 1),
            "devices": jax.device_count(),
        },
        "compose_ms": round(compose_ms, 3),
        "table2_matches": int(matches),
        "arch_tasks": len(arch_tasks),
        "arch_labels": arch_labels,
    }
    Path(args.out).write_text(json.dumps(record, indent=2) + "\n")
    print(json.dumps(record, indent=2))
    return record


if __name__ == "__main__":
    main()
