"""Interpret-vs-xla divergence sweep over every registered backend op.

``test_kernels.py`` proves each kernel against its oracle on hand-picked
shapes; this sweep closes the registry-level gap: every op that registers
BOTH an ``interpret`` and an ``xla`` implementation is driven through both
on the same inputs and compared under a per-op tolerance budget. A new op
cannot land without a builder here (``test_every_registered_op_has_builder``
fails), so silent interpret/xla divergence has nowhere to hide.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.hetero.system  # noqa: F401  (registers the compose_score op)
import repro.sim.engine  # noqa: F401  (registers the sim_replay op)
from repro.core import bitcells, devices, retention
from repro.kernels import backend, ops  # noqa: F401  (registers kernel ops)


def _pack_cells(names, ls):
    rows = []
    for name in names:
        c = bitcells.BITCELLS[name]
        wd = devices.take_device(bitcells.DEVICE_STACK, int(c.write_dev))
        rd = devices.take_device(bitcells.DEVICE_STACK, int(c.read_dev))
        v0 = float(bitcells.sn_high_level(c, ls))
        vmin = float(retention.read_margin_threshold(c))
        rows.append([float(wd.vt), float(wd.n), float(wd.ispec),
                     float(wd.eta_dibl), float(wd.i_floor),
                     float(rd.j_gate * c.w_read / 1.1),
                     float(c.c_sn), float(c.w_write), v0, vmin])
    return jnp.asarray(rows, jnp.float32)


def _attention_inputs():
    rng = np.random.default_rng(11)
    B, H, S, D = 1, 2, 256, 64
    q, k, v = (jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
               for _ in range(3))
    return (q, k, v), {"causal": True}


def _ssm_inputs():
    # di = 512, S = 128: divisible by the kernel's default block sizes, so
    # the same positional args drive both impls with no backend-only kwargs
    rng = np.random.default_rng(12)
    B, S, di, n = 1, 128, 512, 8
    x = jnp.asarray(rng.normal(size=(B, S, di)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(B, S, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(di, n)), jnp.float32)
    Bc = jnp.asarray(rng.normal(size=(B, S, n)), jnp.float32)
    Cc = jnp.asarray(rng.normal(size=(B, S, n)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(di,)), jnp.float32)
    return (x, dt, A, Bc, Cc, D), {}


def _retention_inputs():
    params = _pack_cells(sorted(bitcells.BITCELLS), ls=0)
    ts = jnp.asarray(retention.time_grid(), jnp.float32)
    return (params, ts), {}


def _sim_replay_inputs():
    rng = np.random.default_rng(13)
    J, S, T = 3, 2, 8
    base = {"bits": 4096.0, "word_bits": 32.0, "e_read_j": 1e-12,
            "e_write_j": 2e-12, "f_op_hz": 1e9, "p_leak_w": 1e-6,
            "retention_s": 1e-3, "tiles": 4.0, "interval_s": 5e-4}
    params = {k: jnp.asarray(v * rng.uniform(0.5, 1.5, (J, S)), jnp.float32)
              for k, v in base.items()}
    slot = {"cap_bits": jnp.full((S,), 1e6, jnp.float32),
            "lifetime_s": jnp.full((S,), 1e-2, jnp.float32)}
    xs = (jnp.full((T,), 1e-5, jnp.float32),
          jnp.asarray(rng.uniform(0, 100, (T, S)), jnp.float32),
          jnp.asarray(rng.uniform(0, 512, (T, S)), jnp.float32),
          jnp.asarray(rng.uniform(0, 1, (T, S)), jnp.float32))
    # drift + adaptive controller active so the divergence sweep exercises
    # the in-scan Arrhenius/turnover terms too (T = 8 bins x 1e-5 s window)
    consts = jnp.asarray([1.0, 2.0, 1.0, 45.0, 8e-5], jnp.float32)
    return (params, slot, xs, consts), {}


def _compose_score_inputs():
    from repro.hetero.system import METRIC_COLS
    rng = np.random.default_rng(14)
    n, J, S = 12, 9, 3
    scale = {"area_um2": 1e4, "bits": 65536.0, "p_leak_w": 1e-5,
             "p_refresh_w": 1e-6, "e_read_j": 1e-12, "f_op_hz": 2e9}
    cols = {k: jnp.asarray(scale[k] * rng.uniform(0.5, 1.5, n), jnp.float32)
            for k in METRIC_COLS}
    idx = rng.integers(0, n, (J, S)).astype(np.int32)
    idx[-1, 1] = -1         # a sentinel slot: both impls must price it +inf
    cap = jnp.asarray([1e6, 4e6, 2e5], jnp.float32)
    f_req = jnp.asarray([1.5e9, 4e8, 8e8], jnp.float32)
    return (jnp.asarray(idx), cols, cap, f_req), {}


# op -> (input builder, rtol/atol budget). sim_replay's interpret path is a
# Python loop over the very scan the xla path vmaps, so it must agree to
# float32 roundoff; the Pallas kernels accumulate in different block orders
# and get the same budgets the oracle tests use.
BUILDERS = {
    "attention": (_attention_inputs, 2e-5),
    "ssm_scan": (_ssm_inputs, 1e-4),
    "retention": (_retention_inputs, 1e-5),
    "sim_replay": (_sim_replay_inputs, 1e-6),
    # numpy float32 mirror of the one-dispatch gather/reduce scorer: same
    # dtype, same reduction order (axis-1 sums) — float32 roundoff only
    "compose_score": (_compose_score_inputs, 1e-6),
}


def test_every_registered_op_has_builder():
    missing = [op for op in backend.registered() if op not in BUILDERS]
    assert not missing, (
        f"registered op(s) {missing} have no divergence builder — add them "
        f"to BUILDERS in {__file__}")


def _as_arrays(out):
    if isinstance(out, dict):
        return {k: np.asarray(v, np.float64) for k, v in sorted(out.items())}
    return {"out": np.asarray(out, np.float64)}


@pytest.mark.parametrize("op", sorted(BUILDERS))
def test_interpret_matches_xla(op):
    impls = backend.impl_map(op)
    if not {"interpret", "xla"} <= set(impls):
        pytest.skip(f"{op}: needs both interpret and xla impls "
                    f"(has {sorted(impls)})")
    build, tol = BUILDERS[op]
    args, kwargs = build()
    got = _as_arrays(impls["interpret"](*args, **kwargs))
    want = _as_arrays(impls["xla"](*args, **kwargs))
    assert got.keys() == want.keys()
    for key in want:
        np.testing.assert_allclose(got[key], want[key], rtol=tol, atol=tol,
                                   err_msg=f"{op}[{key}]")
