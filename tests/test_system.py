"""End-to-end behaviour: training convergence, microbatch equivalence, MoE
balancing, serve engine generation, and the compiler->DSE->accelerator loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.data.pipeline import SyntheticLMData
from repro.models import LM
from repro.serve.engine import Engine
from repro.train.step import init_train_state, make_train_step


def test_training_reduces_loss():
    """A tiny dense LM must learn the synthetic bigram structure."""
    cfg = reduce_config(get_config("internlm2-1.8b")).replace(num_layers=2)
    lm, step = make_train_step(cfg, base_lr=3e-3, warmup=10, total_steps=300)
    step = jax.jit(step)
    params, opt = init_train_state(cfg, jax.random.key(0))
    data = SyntheticLMData(cfg, 8, 32, seed=5)
    losses = []
    for i in range(120):
        params, opt, m = step(params, opt, data.next_batch(), i)
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    assert last < first - 0.5, (first, last)


def test_microbatch_accumulation_matches_full_batch():
    cfg = reduce_config(get_config("internlm2-1.8b")).replace(num_layers=2)
    _, step_full = make_train_step(cfg, base_lr=1e-3)
    _, step_mb = make_train_step(cfg, base_lr=1e-3, microbatch=2)
    params, opt = init_train_state(cfg, jax.random.key(1))
    data = SyntheticLMData(cfg, 4, 16, seed=2)
    batch = data.next_batch()
    p1, _, m1 = jax.jit(step_full)(params, opt, batch, 0)
    p2, _, m2 = jax.jit(step_mb)(params, opt, batch, 0)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    diffs = [float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))
             if a.dtype in (jnp.float32, jnp.bfloat16)]
    assert max(diffs) < 5e-3


def test_moe_bias_balancing_mechanism():
    """Aux-loss-free balancing: the routing bias must move AGAINST observed
    load (overloaded experts get pushed down), and metrics must be present."""
    cfg = reduce_config(get_config("moonshot-v1-16b-a3b")).replace(num_layers=2)
    lm, step = make_train_step(cfg, base_lr=1e-3)
    step = jax.jit(step)
    params, opt = init_train_state(cfg, jax.random.key(0))
    data = SyntheticLMData(cfg, 4, 32, seed=1)
    seen_metric = False
    loads = None
    for i in range(10):
        batch = data.next_batch()
        # observe the load this step will see, then check the bias reaction
        loss, metrics = jax.jit(lm.loss)(params, batch)
        loads = np.asarray(metrics["moe_load"])          # (Lmoe, E)
        bias_before = np.asarray(params["moe"]["moe"]["bias"])
        params, opt, m = step(params, opt, batch, i)
        seen_metric |= "moe_balance" in m
        bias_after = np.asarray(params["moe"]["moe"]["bias"])
        delta = bias_after - bias_before
        for l in range(loads.shape[0]):
            over = loads[l] > loads[l].mean()
            under = loads[l] < loads[l].mean()
            if over.any():
                assert np.all(delta[l][over] <= 0)       # pushed down
            if under.any():
                assert np.all(delta[l][under] >= 0)      # pulled up
    assert seen_metric, "moe metrics missing"


def test_engine_generates_tokens():
    cfg = reduce_config(get_config("qwen3-8b")).replace(num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    eng = Engine(cfg, params, max_seq=64)
    batch = {"tokens": np.ones((3, 8), np.int32)}
    out = eng.generate(batch, steps=5)
    assert out.shape == (3, 5)
    assert out.dtype == np.int32
    out_t = eng.generate(batch, steps=5, temperature=0.7, seed=1)
    assert out_t.shape == (3, 5)


def test_profiler_to_dse_loop():
    """The paper's technique applied to an assigned arch: dry-run record ->
    requirements -> heterogeneous memory selection."""
    import pathlib
    if not pathlib.Path("artifacts/dryrun").exists():
        pytest.skip("dry-run artifacts not generated")
    from repro.core import dse
    from repro.profiler.traffic import arch_requirements, load_dryrun_record
    rec = load_dryrun_record("qwen3-8b", "decode_32k")
    if rec is None:
        pytest.skip("qwen3-8b decode record missing")
    reqs = arch_requirements("qwen3-8b", "decode_32k", rec)
    configs = dse.design_space()
    res = dse.evaluate_space(configs)
    label_l1, picks = dse.select_level(configs, res, reqs["L1"])
    label_l2, _ = dse.select_level(configs, res, reqs["L2"])
    assert label_l1 != "infeasible"
    assert label_l2 != "infeasible"
    # L1-analog buffers are core-clock latency-critical -> never OS-Si
    assert "OS-Si" not in label_l1
