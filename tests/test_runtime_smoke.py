"""Smoke/unit tests for the runtime modules: ``data.pipeline`` determinism
and state round-trip, ``serve.engine`` construction + one generation request,
and ``runtime.supervisor`` checkpoint/restart/straggler behaviour — each
constructed fresh, run for one step/request, shapes asserted, and no
warnings raised from repro code."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import get_config, reduce_config
from repro.data.pipeline import DataState, SyntheticLMData
from repro.runtime.supervisor import Supervisor, SupervisorConfig
from repro.serve.engine import (Engine, make_decode_step, make_prefill_step,
                                sample_greedy, sample_temperature)


def tiny_cfg():
    return reduce_config(get_config("internlm2-1.8b")).replace(num_layers=1)


def _assert_no_repro_warnings(records):
    ours = [w for w in records if "repro" in (w.filename or "")]
    assert not ours, [str(w.message) for w in ours]


# ------------------------------------------------------------ data.pipeline
def test_pipeline_batches_are_pure_functions_of_seed_and_step(recwarn):
    cfg = tiny_cfg()
    a = SyntheticLMData(cfg, batch_size=4, seq_len=16, seed=7)
    b = SyntheticLMData(cfg, batch_size=4, seq_len=16, seed=7)
    ba, bb = a.next_batch(), b.next_batch()
    assert ba["tokens"].shape == (4, 16) and ba["tokens"].dtype == np.int32
    assert np.all((ba["tokens"] >= 0) & (ba["tokens"] < cfg.vocab_size))
    np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
    # a different seed diverges, a different step diverges
    other = SyntheticLMData(cfg, batch_size=4, seq_len=16, seed=8)
    assert not np.array_equal(other.next_batch()["tokens"], ba["tokens"])
    assert not np.array_equal(a.next_batch()["tokens"], ba["tokens"])
    _assert_no_repro_warnings(recwarn.list)


def test_pipeline_state_roundtrip_resumes_exact_stream():
    cfg = tiny_cfg()
    a = SyntheticLMData(cfg, batch_size=2, seq_len=8, seed=3)
    a.next_batch()
    a.next_batch()
    saved = a.state.to_dict()
    expected = a.next_batch()

    # same construction seed (the bigram map is built at construction; the
    # supervisor's restore path re-seats state on the same pipeline object)
    resumed = SyntheticLMData(cfg, batch_size=2, seq_len=8, seed=3)
    resumed.state = DataState.from_dict(saved)
    np.testing.assert_array_equal(resumed.next_batch()["tokens"],
                                  expected["tokens"])


# -------------------------------------------------------------- serve.engine
def test_engine_one_generation_request(recwarn):
    cfg = tiny_cfg()
    lm, prefill = make_prefill_step(cfg, max_seq=32)
    params = lm.init(jax.random.key(0))
    cache, logits = jax.jit(prefill)(params,
                                     {"tokens": jnp.zeros((2, 4), jnp.int32)})
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size

    eng = Engine(cfg, params, max_seq=32)
    out = eng.generate({"tokens": jnp.zeros((2, 4), jnp.int32)}, steps=3)
    assert out.shape == (2, 3) and out.dtype == np.int32
    assert np.all((out >= 0) & (out < cfg.vocab_size))
    # greedy decoding is deterministic request-to-request
    out2 = eng.generate({"tokens": jnp.zeros((2, 4), jnp.int32)}, steps=3)
    np.testing.assert_array_equal(out, out2)
    # temperature path samples valid ids
    out_t = eng.generate({"tokens": jnp.zeros((2, 4), jnp.int32)}, steps=2,
                         temperature=0.8, seed=1)
    assert out_t.shape == (2, 2)
    assert np.all((out_t >= 0) & (out_t < cfg.vocab_size))
    _assert_no_repro_warnings(recwarn.list)


def test_samplers():
    logits = jnp.asarray([[0.1, 3.0, -1.0], [2.0, 0.0, 1.0]])
    np.testing.assert_array_equal(np.asarray(sample_greedy(logits)), [1, 0])
    tok = sample_temperature(jax.random.key(0), logits, temperature=0.5)
    assert tok.shape == (2,) and tok.dtype == jnp.int32


# ------------------------------------------------------- runtime.supervisor
class _CountingData:
    """Minimal data source with the pipeline's state contract."""

    def __init__(self):
        self.state = DataState(seed=0, step=0)

    def next_batch(self):
        self.state.step += 1
        return {"x": np.full((2,), float(self.state.step), np.float32)}


def _step_fn(params, opt_state, batch, step):
    loss = jnp.mean(batch["x"]) * 0.0 + 1.0 / (step + 1.0)
    return params, opt_state, {"loss": loss}


def _run(tmp_path, total_steps=4, **sup_kw):
    ckpt = Checkpointer(tmp_path / "ckpt", async_write=False)
    sup = Supervisor(_step_fn, ckpt,
                     cfg=SupervisorConfig(ckpt_every=2, max_restarts=2),
                     **sup_kw)
    params = {"w": jnp.zeros((2,), jnp.float32)}
    opt = {"m": jnp.zeros((2,), jnp.float32)}
    return sup.run(params, opt, _CountingData(), total_steps=total_steps)


def test_supervisor_clean_run_checkpoints_and_reports(tmp_path, recwarn):
    params, opt, report = _run(tmp_path)
    assert report.steps_run == 4 and report.restarts == 0
    assert len(report.losses) == 4 and len(report.heartbeats) == 4
    assert np.all(np.isfinite(report.losses))
    ckpt = Checkpointer(tmp_path / "ckpt", async_write=False)
    assert ckpt.latest_step() == 4          # final-step checkpoint landed
    _assert_no_repro_warnings(recwarn.list)


def test_supervisor_restarts_from_latest_checkpoint(tmp_path):
    tripped = []

    def fail_once(step):
        if step == 3 and not tripped:
            tripped.append(step)
            raise RuntimeError("injected fault")

    params, opt, report = _run(tmp_path, failure_injector=fail_once)
    assert tripped == [3]
    assert report.restarts == 1
    assert report.steps_run >= 4            # re-ran the failed step


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    def always_fail(step):
        raise RuntimeError("persistent fault")

    with pytest.raises(RuntimeError, match="persistent fault"):
        _run(tmp_path, failure_injector=always_fail)


def test_supervisor_flags_stragglers(tmp_path):
    def slow_at(step):
        return 0.25 if step == 8 else 0.0

    params, opt, report = _run(tmp_path, total_steps=10,
                               straggler_injector=slow_at)
    assert 8 in report.straggler_events
    assert report.steps_run == 10
