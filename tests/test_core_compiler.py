"""OpenGCRAM core: device/retention physics, macro PPA trends, DSE,
artifacts — unit + hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is a dev extra: property tests skip where absent, unit tests run
from _hypothesis_compat import given, settings, st

from repro.core import bitcells, devices, dse, gainsight, retention, tech
from repro.core.artifacts import emit_lef, emit_lib, emit_verilog, generate_all
from repro.core.characterize import characterize_config
from repro.core.macro import MacroConfig

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------- devices
def test_device_ion_calibration():
    assert abs(float(devices.i_on(devices.SI_NMOS, 1.0)) - 600e-6) / 600e-6 < 0.01
    assert abs(float(devices.i_on(devices.ITO_OS, 1.0)) - 110e-6) / 110e-6 < 0.01


def test_os_off_current_orders_below_si():
    i_si = float(devices.i_off(devices.SI_NMOS, 1.0))
    i_os = float(devices.i_off(devices.ITO_OS_HVT, 1.0))
    assert i_os < 1e-4 * i_si          # orders of magnitude (paper: <1e-18 A/um)


@given(vgs=st.floats(0.0, 1.1), vds=st.floats(0.05, 1.1))
def test_device_current_monotone_in_vgs(vgs, vds):
    i1 = float(devices.mosfet_id(devices.SI_NMOS, vgs, vds, 1.0))
    i2 = float(devices.mosfet_id(devices.SI_NMOS, vgs + 0.05, vds, 1.0))
    assert i2 >= i1 * (1 - 1e-6)


# -------------------------------------------------------------------- retention
def test_retention_ordering_matches_paper():
    """Fig 9: Si-Si microseconds < OS-Si milliseconds < OS-OS(+HVT) > 10 s."""
    t_sisi = float(retention.retention_time(bitcells.BITCELLS["gc_sisi"], 0))
    t_ossi = float(retention.retention_time(bitcells.BITCELLS["gc_ossi"], 0))
    t_osos_hvt = float(retention.retention_time(
        bitcells.BITCELLS["gc_osos_hvt"], 1))
    assert 1e-7 < t_sisi < 1e-4          # microseconds
    assert 1e-4 < t_ossi < 1.0           # millisecond-level
    assert t_osos_hvt > 10.0             # ">10 s with VT engineering"
    assert t_sisi < t_ossi < t_osos_hvt


def test_level_shifter_improves_retention():
    for name in ("gc_sisi", "gc_ossi", "gc_osos"):
        c = bitcells.BITCELLS[name]
        assert float(retention.retention_time(c, 1)) > \
            float(retention.retention_time(c, 0))


def test_transient_matches_estimate_within_grid():
    """The RK4 solve should land within ~1 order of the closed-form C*dV/I."""
    for name in ("gc_sisi", "gc_ossi", "gc_osos"):
        c = bitcells.BITCELLS[name]
        t = float(retention.retention_time(c, 0))
        est = float(retention.retention_estimate(c, 0))
        assert 0.1 < t / est < 10.0


# ------------------------------------------------------------------------ macro
def test_bitcell_area_ratios_match_paper():
    a_sram = tech.SRAM6T_W * tech.SRAM6T_H
    assert abs(tech.GC_SISI_W * tech.GC_SISI_H / a_sram - 0.69) < 0.02
    assert abs(tech.GC_OSSI_W * tech.GC_OSSI_H / a_sram - 0.35) < 0.02


@given(wz=st.sampled_from([16, 32, 64]), nw=st.sampled_from([32, 64, 128, 256]))
def test_area_monotone_in_capacity(wz, nw):
    r1 = characterize_config(MacroConfig(mem_type="gc_sisi", word_size=wz,
                                         num_words=nw))
    r2 = characterize_config(MacroConfig(mem_type="gc_sisi", word_size=wz,
                                         num_words=nw * 2))
    assert r2["area_um2"] > r1["area_um2"]


def test_macro_area_crossover_above_1kb():
    """Fig 7b: Si-Si macro smaller than SRAM above ~1 Kb; OS-Si smallest."""
    small = {mt: characterize_config(MacroConfig(mem_type=mt, word_size=16,
                                                 num_words=16))["area_um2"]
             for mt in ("sram6t", "gc_sisi", "gc_ossi")}
    big = {mt: characterize_config(MacroConfig(mem_type=mt, word_size=128,
                                               num_words=128))["area_um2"]
           for mt in ("sram6t", "gc_sisi", "gc_ossi")}
    assert small["sram6t"] < small["gc_sisi"]      # dual-port overhead below 1Kb
    assert big["gc_sisi"] < big["sram6t"]          # crossover
    assert big["gc_ossi"] < big["gc_sisi"]         # OS-Si smallest


def test_speed_order_and_leakage():
    """Fig 8: SRAM fastest; GCRAM leakage orders below SRAM."""
    r = {mt: characterize_config(MacroConfig(mem_type=mt, word_size=64,
                                             num_words=64))
         for mt in ("sram6t", "gc_sisi", "gc_ossi")}
    assert r["sram6t"]["f_op_hz"] > r["gc_sisi"]["f_op_hz"] > r["gc_ossi"]["f_op_hz"]
    assert r["gc_sisi"]["p_leak_w"] < 0.2 * r["sram6t"]["p_leak_w"]


def test_wwlls_speeds_up_os_write():
    r0 = characterize_config(MacroConfig(mem_type="gc_ossi", word_size=32,
                                         num_words=64, level_shift=False))
    r1 = characterize_config(MacroConfig(mem_type="gc_ossi", word_size=32,
                                         num_words=64, level_shift=True))
    assert r1["f_write_hz"] > r0["f_write_hz"]
    assert r1["area_um2"] > r0["area_um2"]          # extra ring + LS cells


def test_aspect_ratio_frequency_cliff():
    """Fig 8a: tall 1:1 organizations lose a delay-chain stage vs 4:1."""
    tall = characterize_config(MacroConfig(mem_type="gc_sisi", word_size=32,
                                           num_words=512, mux=1))
    wide = characterize_config(MacroConfig(mem_type="gc_sisi", word_size=128,
                                           num_words=128, mux=1))
    assert wide["f_read_hz"] >= tall["f_read_hz"]
    assert tall["rows"] > wide["rows"]


# -------------------------------------------------------------------------- DSE
def test_table2_reproduced_exactly():
    configs = dse.design_space()
    res = dse.evaluate_space(configs)
    for t in gainsight.TASKS:
        l1, _ = dse.select_level(configs, res, t.l1)
        l2, _ = dse.select_level(configs, res, t.l2)
        exp = gainsight.TABLE2_EXPECTED[t.task_id]
        assert l1 == exp["L1"], f"task {t.task_id} L1 {l1} != {exp['L1']}"
        assert l2 == exp["L2"], f"task {t.task_id} L2 {l2} != {exp['L2']}"


def test_feasibility_antitone_in_requirements():
    configs = dse.design_space()
    res = dse.evaluate_space(configs)
    easy = dse.feasible_mask(res, 0.2e9, 1e-6)
    hard = dse.feasible_mask(res, 2.0e9, 1e-3)
    assert easy.sum() >= hard.sum()
    assert np.all(easy | ~hard)                     # hard ⊆ easy


@given(st.integers(0, 10**6))
def test_pareto_front_correct(seed):
    rng = np.random.default_rng(seed)
    pts = rng.random((40, 3))
    mask = dse.pareto_front(pts)
    for i in range(len(pts)):
        dominated = any(np.all(pts[j] <= pts[i]) and np.any(pts[j] < pts[i])
                        for j in range(len(pts)) if j != i)
        assert mask[i] == (not dominated)


def test_gradient_sizing_improves_cell_delay():
    out = dse.gradient_size_macro(MacroConfig(mem_type="gc_sisi",
                                              word_size=64, num_words=128))
    assert out["speedup"] > 1.0


# -------------------------------------------------------------------- artifacts
@pytest.mark.parametrize("mt", ["gc_sisi", "gc_ossi", "sram6t"])
def test_compiler_flow_drc_lvs_clean(tmp_path, mt):
    rep = generate_all(MacroConfig(mem_type=mt, word_size=32, num_words=64,
                                   level_shift=(mt != "sram6t")), tmp_path)
    assert rep["drc_clean"], rep["drc_errors"][:5]
    assert rep["lvs_clean"], rep["lvs_errors"][:5]
    files = {p.suffix for p in tmp_path.iterdir()}
    assert {".sp", ".v", ".lib", ".lef", ".json"} <= files


def test_artifact_formats():
    cfg = MacroConfig(mem_type="gc_sisi", word_size=16, num_words=32)
    v = emit_verilog(cfg)
    assert "module gc_sisi_16x32" in v and "endmodule" in v
    lib = emit_lib(cfg)
    assert "library (" in lib and "cell_rise (delay_3x3)" in lib
    lef = emit_lef(cfg)
    assert "MACRO gc_sisi_16x32" in lef and "SIZE" in lef
