"""The runtime sanitizer shim: switch precedence, checkify wrapping, and the
wired entry points (characterize / score / sim) running clean under it with
bit-identical outputs."""
import numpy as np
import pytest

from repro.analysis import sanitize


def test_disabled_by_default_returns_fn_unchanged():
    def f(x):
        return x
    assert sanitize.maybe_wrap(f) is f
    assert not sanitize.enabled()


def test_switch_precedence(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanitize.enabled()
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize.enabled()
    with sanitize.enabled_scope(False):        # scope beats env
        assert not sanitize.enabled()
        with sanitize.enabled_scope(True):     # innermost wins
            assert sanitize.enabled()
            assert not sanitize.enabled(explicit=False)  # explicit beats all
        assert not sanitize.enabled()
    assert sanitize.enabled()


def test_wrap_catches_nan_and_oob_index():
    import jax.numpy as jnp
    f = sanitize.wrap(lambda x: jnp.log(x))
    with pytest.raises(Exception, match="nan"):
        f(jnp.asarray([-1.0], jnp.float32))
    g = sanitize.wrap(lambda x, i: x[i])
    with pytest.raises(Exception, match="out-of-bounds|index"):
        g(jnp.arange(4.0), jnp.asarray(9, jnp.int32))


def test_wrap_preserves_values():
    import jax.numpy as jnp
    def f(x):
        return {"y": jnp.sqrt(x), "z": x * 2}
    x = jnp.asarray([1.0, 4.0], jnp.float32)
    plain, wrapped = f(x), sanitize.wrap(f)(x)
    for k in plain:
        np.testing.assert_array_equal(np.asarray(plain[k]),
                                      np.asarray(wrapped[k]))


def test_compiler_sanitize_flag_scopes_characterization():
    from repro.api import Compiler
    clean = Compiler().compile(mem_type="gc_sisi", word_size=32,
                               num_words=64)
    checked = Compiler(sanitize=True).compile(mem_type="gc_sisi",
                                              word_size=32, num_words=64)
    assert clean.ppa == checked.ppa     # bit-identical floats


def test_wired_entry_points_run_clean_under_env(monkeypatch):
    """characterize (incl. the SRAM masked-lane path), score_grid and both
    sim backends all pass nan+index checks on real inputs."""
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    from repro.api import DesignTable, design_space
    from repro.hetero import system
    from repro.sim import engine
    from repro.sim.trace import Trace

    table = DesignTable.from_configs(
        design_space(word_sizes=(16,), num_words=(16, 32)))
    assert len(table) == 10

    vals = {"area_um2": 100.0, "bits": 1024.0, "p_leak_w": 1e-6,
            "p_refresh_w": 1e-7, "e_read_j": 1e-12, "f_op_hz": 1e9}
    metrics = {k: np.full(8, v, np.float32) for k, v in vals.items()}
    out = system.score_grid(metrics, np.zeros((4, 2), np.int64),
                            [1e6, 1e6], [1e8, 1e8])
    assert np.isfinite(out["area_um2"]).all()

    S, T = 2, 8
    trace = Trace(phase="prefill", t_bin_s=np.full(T, 1e-5),
                  reads=np.ones((S, T)), write_bits=np.full((S, T), 64.0),
                  occupancy=np.full((S, T), 0.5),
                  cap_bits=np.full(S, 1e6), f_req_hz=np.full(S, 1e8),
                  lifetime_s=np.full(S, 1e-2))
    sim_vals = {"bits": 4096.0, "word_bits": 32.0, "e_read_j": 1e-12,
                "e_write_j": 2e-12, "f_op_hz": 1e9, "p_leak_w": 1e-6,
                "retention_s": 1e-3}
    cols = {k: np.full(4, v, np.float32) for k, v in sim_vals.items()}
    for backend in ("xla", "interpret"):
        res = engine.simulate_traces(cols, np.zeros((3, 2), np.int64),
                                     [trace], backend=backend)
        assert np.isfinite(res["e_total_j"]).all()


def test_sanitized_table_matches_unsanitized_bitexact():
    from repro.api import DesignTable, design_space
    space = design_space(word_sizes=(32,), num_words=(64,))
    base = DesignTable.from_configs(space)
    with sanitize.enabled_scope(True):
        checked = DesignTable.from_configs(space)
    for k in base.metric_names:
        np.testing.assert_array_equal(base[k], checked[k], err_msg=k)
