"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitcells, devices, retention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref, retention_ref, ssm_scan_ref
from repro.kernels.retention_kernel import retention_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas


@pytest.mark.parametrize("shape", [(1, 2, 256, 64), (2, 1, 128, 128),
                                   (1, 4, 512, 64), (2, 2, 256, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(shape, dtype, causal):
    B, H, S, D = shape
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=shape), dtype)
    k = jnp.asarray(rng.normal(size=shape), dtype)
    v = jnp.asarray(rng.normal(size=shape), dtype)
    o = flash_attention(q, k, v, causal=causal, interpret=True)
    o_ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bq,bk", [(128, 128), (64, 128), (128, 64)])
def test_flash_attention_block_shapes(bq, bk):
    B, H, S, D = 1, 2, 256, 64
    rng = np.random.default_rng(1)
    q, k, v = (jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
               for _ in range(3))
    o = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(
        attention_ref(q, k, v)), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,di,n", [(1, 128, 256, 16), (2, 256, 512, 8),
                                      (1, 64, 1024, 16)])
def test_ssm_scan_matches_ref(B, S, di, n):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(B, S, di)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(B, S, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(di, n)), jnp.float32)
    Bc = jnp.asarray(rng.normal(size=(B, S, n)), jnp.float32)
    Cc = jnp.asarray(rng.normal(size=(B, S, n)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(di,)), jnp.float32)
    y = ssm_scan_pallas(x, dt, A, Bc, Cc, D, block_d=min(256, di),
                        chunk=min(64, S), interpret=True)
    y_ref, _ = ssm_scan_ref(x, dt, A, Bc, Cc, D, jnp.zeros((B, di, n)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssm_scan_chunk_invariance():
    """Result must not depend on the chunk partitioning."""
    rng = np.random.default_rng(3)
    B, S, di, n = 1, 128, 256, 8
    x = jnp.asarray(rng.normal(size=(B, S, di)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(B, S, di)), jnp.float32)
    A = -jnp.ones((di, n), jnp.float32)
    Bc = jnp.asarray(rng.normal(size=(B, S, n)), jnp.float32)
    Cc = jnp.asarray(rng.normal(size=(B, S, n)), jnp.float32)
    D = jnp.zeros((di,), jnp.float32)
    y1 = ssm_scan_pallas(x, dt, A, Bc, Cc, D, chunk=32, interpret=True)
    y2 = ssm_scan_pallas(x, dt, A, Bc, Cc, D, chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


def _pack_cells(names, ls):
    rows = []
    for name in names:
        c = bitcells.BITCELLS[name]
        wd = devices.take_device(bitcells.DEVICE_STACK, int(c.write_dev))
        rd = devices.take_device(bitcells.DEVICE_STACK, int(c.read_dev))
        v0 = float(bitcells.sn_high_level(c, ls))
        vmin = float(retention.read_margin_threshold(c))
        rows.append([float(wd.vt), float(wd.n), float(wd.ispec),
                     float(wd.eta_dibl), float(wd.i_floor),
                     float(rd.j_gate * c.w_read / 1.1),
                     float(c.c_sn), float(c.w_write), v0, vmin])
    return jnp.asarray(rows, jnp.float32)


def test_retention_kernel_matches_ref_and_core():
    names = ["gc_sisi", "gc_sisi_hvt", "gc_ossi", "gc_ossi_hvt", "gc_osos"]
    ts = retention.time_grid()
    for ls in (0, 1):
        p = _pack_cells(names, ls)
        t_k = retention_pallas(p, ts, interpret=True)
        t_r = retention_ref(p, ts)
        np.testing.assert_allclose(np.asarray(t_k), np.asarray(t_r),
                                   rtol=1e-5)
        # and both match the core solver (same physics, structured API)
        for i, name in enumerate(names):
            t_core = float(retention.retention_time(bitcells.BITCELLS[name], ls))
            if t_core <= 2e-9:      # unwritable corner (HVT without LS)
                continue
            assert abs(np.log(float(t_r[i]) / t_core)) < 0.2, (name, ls)


def test_retention_kernel_padding():
    """Non-multiple-of-128 batch sizes are padded correctly."""
    ts = retention.time_grid()
    p = _pack_cells(["gc_sisi", "gc_ossi", "gc_osos"], 0)
    t3 = retention_pallas(p, ts, interpret=True)
    t1 = retention_pallas(p[:1], ts, interpret=True)
    np.testing.assert_allclose(np.asarray(t3[:1]), np.asarray(t1), rtol=1e-6)
