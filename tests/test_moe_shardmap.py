"""shard_map expert-parallel MoE == GSPMD MoE on a real 2x4 device mesh
(subprocess: device count must be set before jax initializes)."""
import json
import os
import subprocess
import sys
from pathlib import Path

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_MOE_SHARDMAP"] = "1"
import json, sys
import jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, "src")
from repro.compat import make_mesh
from repro.configs import get_config, reduce_config
from repro.models import moe as moe_mod

cfg = reduce_config(get_config("moonshot-v1-16b-a3b"))
p = moe_mod.init_moe(jax.random.key(0), cfg, jnp.float32)
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16, cfg.d_model)),
                jnp.float32)
mesh = make_mesh((2, 4), ("data", "model"))
with mesh:
    y_sm, aux = jax.jit(lambda p, x: moe_mod.moe_block(p, x, cfg))(p, x)
    # gradient flows through the shard_map psum
    g = jax.jit(jax.grad(lambda p, x: moe_mod.moe_block(p, x, cfg)[0].sum()))(p, x)
os.environ.pop("REPRO_MOE_SHARDMAP")
y_ref, aux_ref = jax.jit(lambda p, x: moe_mod._moe_block_gspmd(p, x, cfg))(p, x)
print(json.dumps({
    "y_err": float(jnp.max(jnp.abs(y_sm - y_ref))),
    "load_err": float(jnp.max(jnp.abs(aux["load"] - aux_ref["load"]))),
    "grad_finite": bool(all(jnp.all(jnp.isfinite(l)) for l in jax.tree.leaves(g))),
}))
"""


def test_shardmap_moe_matches_gspmd_on_2x4_mesh(tmp_path):
    script = tmp_path / "moe_equiv.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_MOE_SHARDMAP", None)
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, cwd=str(Path(__file__).resolve().parents[1]),
                         env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["y_err"] < 1e-4, res
    assert res["load_err"] < 1e-6, res
    assert res["grad_finite"], res
