"""Operating-corner physics: TechParams derivation + nominal parity,
voltage/temperature monotonicity properties, corner-batched DesignTable,
corner-robust DSE, the hot-corner simulator path, and the stale-cache
rejection."""
import json
import warnings

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import api
from repro.api import (Compiler, DesignTable, MacroConfig, OperatingPoint,
                       SimPolicy, TechParams, compose, explore)
from repro.core import bitcells, corners, retention, tech
from repro.sim import refresh

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

HOT, NOMINAL = corners.HOT, corners.NOMINAL


def small_space():
    return api.design_space(word_sizes=(16, 32), num_words=(32, 64))


@pytest.fixture(scope="module")
def corner_table():
    return DesignTable.from_configs(small_space(), corners=[NOMINAL, HOT])


# jitted per-corner retention probe: one compile, many corners
_CELL = bitcells.BITCELLS["gc_ossi"]
_ret_at = jax.jit(lambda tp: retention.retention_time(_CELL, 0, tp))


def _retention_s(op: OperatingPoint) -> float:
    return float(_ret_at(TechParams.from_op(op)))


# ------------------------------------------------------------ TechParams
def test_nominal_techparams_reproduces_legacy_constants():
    tp = TechParams.from_op(NOMINAL)
    assert tp == TechParams()                      # the all-defaults object
    assert tp.vdd == tech.VDD and tp.vdd_boost == tech.VDD_BOOST
    assert tp.ut == tech.UT and tp.temp_k == tech.TEMP_K
    assert tp.leak_scale == 1.0 and tp.drive_scale == 1.0
    assert tp.v_sense == tech.V_SENSE
    assert tp.v_sense_sram == tech.V_SENSE_SRAM
    assert hash(tp) == hash(TechParams())          # hashable (cache keys)


def test_techparams_scales_move_the_right_way():
    hot = TechParams.from_op(HOT)
    cold = TechParams.from_op(corners.COLD)
    assert hot.ut > tech.UT > cold.ut              # kT/q linear in T
    assert hot.leak_scale > 1.0 > cold.leak_scale  # Arrhenius
    assert hot.drive_scale < 1.0 < cold.drive_scale  # mobility ~ T^-1.5
    lv = TechParams.from_op(corners.LOW_VDD)
    assert lv.vdd_boost < tech.VDD_BOOST and lv.v_sense < tech.V_SENSE


def test_operating_point_coercion_and_validation():
    assert corners.as_operating_point("hot") is HOT
    op = corners.as_operating_point((1.0, 330.0))
    assert op.vdd == 1.0 and op.temp_k == 330.0
    with pytest.raises(KeyError):
        corners.as_operating_point("nosuch")
    with pytest.raises(ValueError):
        OperatingPoint(vdd=-1.0)
    with pytest.raises(ValueError):
        corners.as_corners([NOMINAL, OperatingPoint(corner="nominal",
                                                    temp_k=310.0)])
    assert corners.as_corners(None) == (NOMINAL,)


def test_nominal_corner_column_matches_plain_batch():
    """The corner grid's nominal column IS the default batch path (the
    dispatcher routes nominal to ``characterize_batch``), so parity is
    bit-for-bit — not merely to float32 round-off as in the old stacked
    traced-tp implementation, whose simplifier reassociated constants."""
    import jax.numpy as jnp
    from repro.core import characterize as chz
    vecs = jnp.stack([c.to_vector() for c in small_space()[:8]])
    plain = chz.characterize_batch(vecs)
    grid = chz.characterize_corners(vecs, [NOMINAL, HOT])
    for k in plain:
        a = np.asarray(plain[k])
        b = np.asarray(grid[k])[:, 0]
        np.testing.assert_array_equal(a, b, err_msg=f"metric {k}")


def test_batched_corners_bit_parity_with_scalar_path():
    """Regression for the stack_tech float32 downcast: every named corner's
    batched column must equal the scalar ``characterize_config`` result for
    the SAME corner bit for bit — the per-corner vmap closes over the same
    python-float TechParams the scalar jit folds, instead of a stacked
    f32-downcast operand."""
    import jax.numpy as jnp
    from repro.core import characterize as chz
    cfgs = small_space()[:6]
    vecs = jnp.stack([c.to_vector() for c in cfgs])
    ops = [corners.CORNERS[name] for name in sorted(corners.CORNERS)]
    grid = chz.characterize_corners(vecs, ops)
    for c, op in enumerate(ops):
        for i, cfg in enumerate(cfgs):
            scalar = chz.characterize_config(cfg, tp=op)
            for k, v in scalar.items():
                got = float(np.asarray(grid[k])[i, c])
                assert got == v, (f"{op.corner}/{cfg.mem_type}[{k}]: "
                                  f"batched {got!r} != scalar {v!r}")


# ------------------------------------------------ physics monotonicity
@given(temp_k=st.floats(260.0, 370.0))
def test_retention_monotone_decreasing_in_temperature(temp_k):
    t_lo = _retention_s(OperatingPoint(temp_k=temp_k, corner="a"))
    t_hi = _retention_s(OperatingPoint(temp_k=temp_k + 20.0, corner="b"))
    assert t_hi < t_lo


@given(vdd=st.floats(0.9, 1.25))
def test_retention_monotone_nondecreasing_in_vdd(vdd):
    t_lo = _retention_s(OperatingPoint(vdd=vdd, corner="a"))
    t_hi = _retention_s(OperatingPoint(vdd=vdd + 0.05, corner="b"))
    assert t_hi >= t_lo * (1.0 - 1e-6)


def test_hot_corner_shortens_gcram_retention_measurably():
    t_nom = _retention_s(NOMINAL)
    t_hot = _retention_s(HOT)
    assert t_hot < 0.5 * t_nom      # 358 K cuts OS-Si retention >2x (it's ~13x)


@given(retention_s=st.floats(1e-6, 10.0), margin=st.floats(0.1, 0.9))
def test_refresh_interval_monotone_in_retention_and_margin(retention_s,
                                                          margin):
    base = refresh.refresh_interval_s(retention_s, margin)
    assert refresh.refresh_interval_s(retention_s * 2.0, margin) >= base
    assert refresh.refresh_interval_s(retention_s, min(margin + 0.05, 1.0)) \
        >= base
    assert base == pytest.approx(margin * retention_s)


# ------------------------------------------------ DesignTable invariants
@given(objectives=st.sampled_from([("area_um2", "p_leak_w"),
                                   ("area_um2", "p_leak_w", "t_read_s"),
                                   ("e_read_j", "-retention_s")]))
def test_pareto_rows_mutually_nondominated(objectives):
    table = DesignTable.from_configs(small_space())
    front = table.pareto(*objectives)
    cols = []
    for name in objectives:
        sign = -1.0 if name.startswith("-") else 1.0
        cols.append(sign * np.asarray(front[name.lstrip("-")], np.float64))
    pts = np.stack(cols, axis=1)
    for i in range(len(pts)):
        for j in range(len(pts)):
            if i != j:
                assert not (np.all(pts[j] <= pts[i])
                            and np.any(pts[j] < pts[i])), \
                    f"front row {i} dominated by {j} under {objectives}"


@given(f_hz=st.sampled_from([2e8, 1e9, 3e9]),
       lifetime_s=st.sampled_from([1e-6, 1e-3, 1.0]))
def test_feasible_is_subset_of_table(f_hz, lifetime_s):
    table = DesignTable.from_configs(small_space())
    feas = table.feasible(f_hz, lifetime_s)
    assert len(feas) <= len(table)
    all_cfgs = table.to_configs()
    assert all(c in all_cfgs for c in feas.to_configs())
    mask = table.shmoo(f_hz, lifetime_s)
    assert len(feas) == int(mask.sum())


# ------------------------------------------------ corner-batched tables
def test_corner_table_columns_and_worst_case(corner_table):
    t = corner_table
    assert t.corners == (NOMINAL, HOT)
    assert "retention_s@hot" in t.metric_names
    assert "f_op_hz@nominal" in t.metric_names
    gc = t["mem_type"] != "sram6t"
    assert np.all(t["retention_s@hot"][gc] < t["retention_s@nominal"][gc])
    # base columns come from corners[0] == nominal
    np.testing.assert_array_equal(t["retention_s"], t["retention_s@nominal"])
    wc = t.worst_case_metrics()
    assert np.all(wc["retention_s"] <= t["retention_s"])
    assert np.all(wc["p_leak_w"] >= t["p_leak_w"])
    np.testing.assert_array_equal(wc["bits"], t["bits"])   # geometry passthru
    cm = t.corner_metrics("hot")
    np.testing.assert_array_equal(cm["retention_s"], t["retention_s@hot"])
    with pytest.raises(KeyError):
        t.corner_metrics("cold")


def test_corner_table_roundtrip_and_grid_hash(tmp_path, corner_table):
    path = corner_table.save(tmp_path / "t.npz")
    t2 = DesignTable.load(path)
    assert t2.corners == corner_table.corners
    np.testing.assert_array_equal(t2["retention_s@hot"],
                                  corner_table["retention_s@hot"])
    assert t2.grid_hash == corner_table.grid_hash
    cfgs = small_space()
    assert api.grid_hash(cfgs) != api.grid_hash(cfgs, corners=[NOMINAL, HOT])
    plain = DesignTable.from_configs(cfgs)
    assert plain.grid_hash != corner_table.grid_hash
    # filter keeps the corner axis
    assert corner_table.filter(corner_table["bits"] > 0).corners \
        == corner_table.corners


def test_build_rejects_conflicting_corners(corner_table):
    with pytest.raises(ValueError):
        DesignTable.build(corner_table, corners=[NOMINAL])
    # matching corners pass through
    assert DesignTable.build(corner_table, corners=[NOMINAL, HOT]) \
        is corner_table


# ------------------------------------------------------ corner-robust DSE
def _req(f_hz, lifetime_s, cap_kb=64):
    from repro.core.select import Bucket, LevelReq
    return LevelReq("L1", cap_kb * 8 * 1024, (Bucket(1.0, f_hz, lifetime_s),))


def test_robust_explore_picks_survive_every_corner(corner_table):
    task = {"task_id": "t", "name": "t", "L1": _req(0.4e9, 5e-3)}
    rep = explore(space=corner_table, tasks=[task], robust="worst_case")
    assert rep.robust == "worst_case"
    sel = rep.selections["t"]["L1"]
    assert sel.feasible
    for pick in sel.picks:
        i = pick.config_idx
        for lbl in corner_table.corner_labels:
            assert corner_table[f"f_op_hz@{lbl}"][i] >= 0.4e9
            assert corner_table[f"retention_s@{lbl}"][i] >= 5e-3
    # the same requirement at nominal-only admits a GCRAM pick that the hot
    # corner disqualifies (corner-blind DSE crowns an infeasible winner)
    nom = explore(space=corner_table, tasks=[task])
    i_nom = nom.selections["t"]["L1"].picks[0].config_idx
    assert corner_table["retention_s"][i_nom] >= 5e-3
    assert corner_table["retention_s@hot"][i_nom] < 5e-3
    assert nom.selections["t"]["L1"].label != sel.label


def test_worst_case_passes_through_derived_columns(corner_table):
    t2 = corner_table.with_column(
        "p_static_w", corner_table["p_leak_w"] + corner_table["p_refresh_w"])
    wc = t2.worst_case_metrics()         # must not KeyError on the derived col
    np.testing.assert_array_equal(wc["p_static_w"], t2["p_static_w"])
    assert np.all(wc["retention_s"] <= t2["retention_s"])


def test_low_vdd_corner_cuts_switching_energy():
    from repro.core import periphery
    tp = TechParams.from_op(corners.LOW_VDD)
    _, _, e_nom, _ = periphery.sense_amp()
    _, _, e_lv, _ = periphery.sense_amp(tp=tp)
    assert float(e_lv) < float(e_nom)    # sense op is CV^2-class
    m_nom = Compiler().compile(mem_type="gc_sisi", word_size=32, num_words=64)
    m_lv = Compiler().compile(mem_type="gc_sisi", word_size=32, num_words=64,
                              op=corners.LOW_VDD)
    assert m_lv.ppa["e_read_j"] < m_nom.ppa["e_read_j"]


def test_compiler_simulate_accepts_corners_and_robust(tmp_path):
    task = {"task_id": "t", "name": "t", "L1": _req(0.4e9, 5e-3)}
    rep = Compiler().simulate(task, space=small_space(),
                              corners=[NOMINAL, HOT], robust="worst_case")
    assert rep.refined == "simulate" and rep.robust == "worst_case"
    assert rep.table.corners == (NOMINAL, HOT)


def test_robust_compose_matches_explore_winner(corner_table):
    task = {"task_id": "t", "name": "t", "L1": _req(0.4e9, 5e-3)}
    rep_x = explore(space=corner_table, tasks=[task], robust="worst_case")
    rep_c = compose(corner_table, task, robust="worst_case")
    assert rep_c.robust == "worst_case"
    assert rep_c.labels()["L1"] == rep_x.selections["t"]["L1"].label
    with pytest.raises(ValueError):
        corner_table.robust_metrics("nosuch")


def test_robust_compose_cache_roundtrip(tmp_path, corner_table):
    from repro.hetero.system import composition_eval_count
    task = {"task_id": "t", "name": "t", "L1": _req(0.4e9, 5e-3)}
    cfgs = small_space()
    r1 = compose(cfgs, task, cache=tmp_path, corners=[NOMINAL, HOT],
                 robust="worst_case")
    n = composition_eval_count()
    r2 = compose(cfgs, task, cache=tmp_path, corners=[NOMINAL, HOT],
                 robust="worst_case")
    assert composition_eval_count() == n, "robust cache hit must not rescore"
    assert r2.labels() == r1.labels() and r2.robust == "worst_case"
    # robust=None is a different cache entry AND a different ranking input
    r3 = compose(cfgs, task, cache=tmp_path, corners=[NOMINAL, HOT])
    assert composition_eval_count() == n + 1
    assert r3.robust is None


# ---------------------------------------------------- simulator hot corner
def test_sim_refresh_intervals_follow_hot_corner(corner_table):
    m = corner_table.metrics
    base = refresh.refresh_intervals(m)
    hot = refresh.refresh_intervals(m, corner="hot")
    gc = corner_table["mem_type"] != "sram6t"
    assert np.all(hot[gc] < base[gc])
    with pytest.raises(KeyError):
        refresh.refresh_intervals(DesignTable.from_configs(
            small_space()).metrics, corner="hot")


def test_simulate_hot_corner_pays_more_refresh(corner_table):
    task = {"task_id": "t", "name": "t", "L1": _req(0.4e9, 5e-3)}
    r_nom = Compiler().simulate(task, space=corner_table)
    r_hot = Compiler().simulate(task, space=corner_table,
                                sim_policy=SimPolicy(corner="hot"))
    e_nom = r_nom.best.metrics["sim_e_refresh_j"] \
        + r_nom.best.metrics["sim_e_rewrite_j"]
    e_hot = r_hot.best.metrics["sim_e_refresh_j"] \
        + r_hot.best.metrics["sim_e_rewrite_j"]
    assert e_hot > e_nom     # shorter intervals -> more refresh/rewrite energy
    # a nominal-only table cannot serve a hot-corner schedule
    with pytest.raises(KeyError):
        Compiler().simulate(task, space=DesignTable.from_configs(
            small_space()), sim_policy=SimPolicy(corner="hot"))


def test_compile_at_corner():
    m_nom = Compiler().compile(mem_type="gc_ossi", word_size=16, num_words=32)
    m_hot = Compiler().compile(mem_type="gc_ossi", word_size=16, num_words=32,
                               op=HOT)
    assert m_hot.retention_s < m_nom.retention_s
    # shorter retention -> the refresh power the analytic model prices rises
    assert m_hot.ppa["p_refresh_w"] > m_nom.ppa["p_refresh_w"]


# ------------------------------------------------------ stale-cache guard
def _tamper_meta(path, **patch):
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        payload = {k: z[k] for k in z.files if k != "__meta__"}
    meta.update(patch)
    np.savez(path, __meta__=json.dumps(meta), **payload)


def test_load_rejects_stale_physics_fingerprint(tmp_path):
    table = DesignTable.from_configs(small_space())
    path = table.save(tmp_path / "t.npz")
    assert DesignTable.load(path).grid_hash == table.grid_hash  # fresh: loads
    _tamper_meta(path, physics="deadbeefdeadbeef")
    with pytest.raises(ValueError, match="stale physics fingerprint"):
        DesignTable.load(path)


def test_build_reports_and_rebuilds_stale_cache(tmp_path):
    cfgs = small_space()
    table = DesignTable.build(cfgs, cache=tmp_path)
    cache_file = tmp_path / f"table_{api.grid_hash(cfgs)}.npz"
    assert cache_file.exists()
    _tamper_meta(cache_file, physics="deadbeefdeadbeef")
    n = api.characterize_call_count()
    with pytest.warns(RuntimeWarning, match="stale physics fingerprint"):
        t2 = DesignTable.build(cfgs, cache=tmp_path)
    assert api.characterize_call_count() == n + 1, \
        "stale cache must be re-characterized, not reused"
    np.testing.assert_array_equal(t2["f_op_hz"], table["f_op_hz"])
    # and the rebuild healed the cache file
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        DesignTable.build(cfgs, cache=tmp_path)
