"""Golden regression snapshot for the N-level composition path.

``tests/golden/table2_nlevel.json`` freezes the 3-level reference
composition (``repro.core.gainsight.nlevel_task(3)``) under two settings —
the default preference policy through the exhaustive grid, and the power
objective through forced branch-and-bound — with every system metric stored
as the exact float64 repr of the float32 the scoring kernel produced. These
tests diff live results against the snapshot **bit-for-bit**, and separately
prove that the 2-level Table-2 results are unchanged through the N-level
code path (``levels=("L1", "L2")``).

Regenerate after an *intentional* physics or ranking change with either

    python scripts/update_golden.py
    python -m pytest tests/test_golden_nlevel.py --update-golden
"""
import json
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "scripts"))

from update_golden import (NLEVEL_PATH, NLEVEL_POLICIES,  # noqa: E402
                           compose_nlevel, write_nlevel_snapshot)


@pytest.fixture(scope="module")
def golden(request):
    if request.config.getoption("--update-golden"):
        write_nlevel_snapshot()
    assert NLEVEL_PATH.exists(), \
        "missing tests/golden/table2_nlevel.json (run " \
        "scripts/update_golden.py)"
    return json.loads(NLEVEL_PATH.read_text())


def test_nlevel_composition_is_bit_for_bit(golden):
    assert set(golden["compositions"]) == set(NLEVEL_POLICIES), \
        "golden policy set changed; regenerate the snapshot"
    drift = []
    for name, kw in NLEVEL_POLICIES.items():
        want = golden["compositions"][name]
        rep = compose_nlevel(kw)
        best = rep.best
        if best.labels() != want["labels"]:
            drift.append(f"{name}: labels {best.labels()} != "
                         f"{want['labels']}")
        for lvl, lc in best.levels.items():
            if [p.config_idx for p in lc.picks] != want["picks"][lvl]:
                drift.append(f"{name} {lvl}: picks drifted")
            if list(lc.tiles) != want["tiles"][lvl]:
                drift.append(f"{name} {lvl}: tiles drifted")
        for k, v in want["metrics"].items():
            if float(best.metrics[k]) != v:           # float-repr exact
                drift.append(f"{name} metric {k}: "
                             f"golden={v!r} live={best.metrics[k]!r}")
        if rep.search != want["search"]:
            drift.append(f"{name}: search engine {rep.search} != "
                         f"{want['search']}")
        if rep.n_space != want["n_space"]:
            drift.append(f"{name}: n_space {rep.n_space} != "
                         f"{want['n_space']}")
    assert not drift, (
        "N-level composition drifted from the golden snapshot:\n  "
        + "\n  ".join(drift)
        + "\nIf intentional, regenerate via scripts/update_golden.py "
          "or pytest --update-golden.")


def test_table2_unchanged_through_nlevel_path(golden):
    """Regression proof: routing the 2-level tasks through the generalized
    N-level machinery (``levels=("L1", "L2")``) changes nothing — labels
    reproduce Table 2 and every system metric of the winner is bit-identical
    to the default invocation."""
    from repro.core import gainsight
    from repro.hetero import compose
    from repro.hetero.system import SYSTEM_METRICS

    for t in gainsight.TASKS:
        base = compose(None, t)
        via = compose(None, t, levels=("L1", "L2"))
        assert via.labels() == base.labels() == \
            gainsight.TABLE2_EXPECTED[t.task_id], t.task_id
        for a, b in zip(base.ranked, via.ranked):
            assert a.labels() == b.labels()
            for m in SYSTEM_METRICS:
                av, bv = a.metrics[m], b.metrics[m]
                assert av == bv or (av != av and bv != bv), (t.task_id, m)
