"""The (vdd, refresh-margin) co-optimization axis of ``compose``.

Covers the searched expansion end to end: golden-locked Table-2 winner flips
at the frozen cold-boost sweep point (the MCAIMem effect — a scaled/boosted
supply changes which technology wins a retention-marginal level), block-0
passthrough bit-exactness, branch-and-bound rank identity on the enlarged
grid, cache key sensitivity + swept-report roundtrip, policy validation, and
the solver-property tests (retention monotone in temperature, swept refresh
intervals positive/finite).
"""
import functools
import json
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "scripts"))

from _hypothesis_compat import given, settings, st  # noqa: E402
from update_golden import (VDD_PATH, VDD_SWEEP_POINT,  # noqa: E402
                           compose_vdd, write_vdd_snapshot)

from repro.api import DesignTable, design_space  # noqa: E402
from repro.core import bitcells, corners, gainsight, retention  # noqa: E402
from repro.hetero import (ComposePolicy, compose,  # noqa: E402
                          composition_eval_count, expand)
from repro.sim.refresh import refresh_interval_s  # noqa: E402


@pytest.fixture(scope="module")
def table():
    return DesignTable.from_configs(design_space())


@pytest.fixture(scope="module")
def vdd_golden(request):
    if request.config.getoption("--update-golden"):
        write_vdd_snapshot()
    assert VDD_PATH.exists(), \
        "missing tests/golden/table2_vdd.json (run scripts/update_golden.py)"
    return json.loads(VDD_PATH.read_text())


# ------------------------------------------------------------ golden flips
def test_vdd_sweep_flips_table2_winners_golden(vdd_golden):
    """The frozen cold-boost point must keep flipping exactly the same
    Table-2 winners, with bit-identical picks and operating points."""
    assert vdd_golden["vdd_sweep_point"] == list(VDD_SWEEP_POINT)
    flipped = []
    for t in gainsight.TASKS:
        want = vdd_golden["tasks"][str(t.task_id)]
        base = compose_vdd(t, swept=False)
        swept = compose_vdd(t, swept=True)
        assert base.labels() == want["base_labels"], f"task {t.task_id}"
        assert swept.labels() == want["swept_labels"], f"task {t.task_id}"
        assert (swept.labels() != base.labels()) == want["flipped"]
        got_picks = {lvl: [[p.family, p.config_idx,
                            p.op.corner if p.op is not None else None,
                            p.refresh_margin] for p in lc.picks]
                     for lvl, lc in swept.best.levels.items()}
        assert got_picks == want["picks"], f"task {t.task_id}"
        assert float(base.best.metrics["p_w"]) == want["p_w"]["base"]
        assert float(swept.best.metrics["p_w"]) == want["p_w"]["swept"]
        if want["flipped"]:
            flipped.append(t.task_id)
    assert flipped, "the sweep point no longer flips any Table-2 winner"


def test_base_table2_parity_survives_the_sweep_machinery():
    """With empty sweeps the compose path must still reproduce all 7 paper
    selections (the expansion is pure opt-in)."""
    for t in gainsight.TASKS:
        rep = compose_vdd(t, swept=False)
        assert rep.labels() == gainsight.TABLE2_EXPECTED[t.task_id]
        for lc in rep.best.levels.values():
            assert all(p.op is None and p.refresh_margin is None
                       for p in lc.picks)


# ------------------------------------------------------- expansion mechanics
def test_block0_passthrough_is_bit_identical(table):
    """The base block of an expanded metric dict is the input columns
    untouched — the sweep can never perturb un-swept numbers."""
    cp = ComposePolicy(vdd_sweep=(VDD_SWEEP_POINT,),
                       refresh_margin_sweep=(0.8,))
    points = expand.expansion_points(cp)
    assert points[0] == (None, None)
    assert len(points) == 4          # (base + 1 vdd) x (base + 1 margin)
    metrics, fams = expand.expand_metrics(table, table.metrics, points)
    n = len(table)
    assert len(fams) == 4 * n
    assert list(fams[:n]) == list(np.asarray(table.families))
    assert list(fams[n:2 * n]) == list(np.asarray(table.families))
    for k, col in table.metrics.items():
        np.testing.assert_array_equal(np.asarray(metrics[k][:n]),
                                      np.asarray(col), err_msg=k)
    # margin block: refresh power scaled by 1/margin, retention untouched
    np.testing.assert_array_equal(
        np.asarray(metrics["p_refresh_w"][n:2 * n]),
        np.asarray(table.metrics["p_refresh_w"]) / 0.8)
    np.testing.assert_array_equal(
        np.asarray(metrics["retention_s"][n:2 * n]),
        np.asarray(table.metrics["retention_s"]))


def test_to_base_preserves_sentinels():
    idx = np.array([[0, 5, -1], [7, 3, 9]])
    out = expand.to_base(idx, 4)
    np.testing.assert_array_equal(out, [[0, 1, -1], [3, 3, 1]])


def test_bb_rank_identical_to_exhaustive_on_expanded_grid(table):
    """Per-slot contributions still decompose over virtual rows, so the
    branch-and-bound proof stays lossless on the enlarged grid."""
    t = gainsight.TASKS[0]
    kw = dict(vdd_sweep=(VDD_SWEEP_POINT, (0.9, 300.0)),
              refresh_margin_sweep=(0.8,),
              candidate_mode="all_feasible", top_k=5)
    for objective in ("preference", "power"):
        rx = compose(table, t, compose_policy=ComposePolicy(
            search="exhaustive", objective=objective, **kw))
        rb = compose(table, t, compose_policy=ComposePolicy(
            search="branch_and_bound", objective=objective, **kw))
        assert rx.n_space == rb.n_space
        for cx, cb in zip(rx.ranked, rb.ranked):
            assert cx.labels() == cb.labels(), objective
            assert cx.metrics == cb.metrics, objective
            for lvl in cx.levels:
                assert [(p.family, p.config_idx,
                         p.op.corner if p.op else None, p.refresh_margin)
                        for p in cx.levels[lvl].picks] == \
                       [(p.family, p.config_idx,
                         p.op.corner if p.op else None, p.refresh_margin)
                        for p in cb.levels[lvl].picks], objective


# ------------------------------------------------------------------- caching
def test_vdd_sweep_cache_key_sensitivity_and_roundtrip(table, tmp_path):
    """A changed sweep misses; an identical re-call hits and reconstructs
    the swept picks (operating point + margin) exactly."""
    t = gainsight.TASKS[0]
    cp = ComposePolicy(vdd_sweep=(VDD_SWEEP_POINT,),
                       refresh_margin_sweep=(0.8,))
    r1 = compose(table, t, cache=tmp_path, compose_policy=cp)
    n = composition_eval_count()
    r2 = compose(table, t, cache=tmp_path, compose_policy=cp)
    assert composition_eval_count() == n, "identical sweep re-call must hit"
    def picks(rep):
        return {lvl: [(p.family, p.config_idx,
                       p.op.corner if p.op is not None else None,
                       p.refresh_margin) for p in lc.picks]
                for lvl, lc in rep.best.levels.items()}
    assert picks(r2) == picks(r1)
    assert {lvl: lc.tiles for lvl, lc in r2.best.levels.items()} == \
           {lvl: lc.tiles for lvl, lc in r1.best.levels.items()}
    assert r2.best.metrics == r1.best.metrics
    # any change to either sweep axis is a different key -> miss
    compose(table, t, cache=tmp_path,
            compose_policy=ComposePolicy(vdd_sweep=((0.9, 300.0),),
                                         refresh_margin_sweep=(0.8,)))
    assert composition_eval_count() == n + 1
    compose(table, t, cache=tmp_path,
            compose_policy=ComposePolicy(vdd_sweep=(VDD_SWEEP_POINT,),
                                         refresh_margin_sweep=(0.5,)))
    assert composition_eval_count() == n + 2
    compose(table, t, cache=tmp_path,
            compose_policy=ComposePolicy(vdd_sweep=(VDD_SWEEP_POINT,)))
    assert composition_eval_count() == n + 3


# ---------------------------------------------------------------- validation
def test_compose_policy_sweep_validation():
    cp = ComposePolicy(vdd_sweep=(0.9, "hot", (1.2, 233.0)))
    assert [p.corner for p in cp.vdd_sweep] == \
        ["v0.9_t300", "hot", "v1.2_t233"]
    assert all(isinstance(p, corners.OperatingPoint) for p in cp.vdd_sweep)
    with pytest.raises(ValueError, match="collide"):
        ComposePolicy(vdd_sweep=(0.9, (0.9, 300.0)))
    for bad in (0.0, -0.5, 1.5, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="refresh_margin_sweep"):
            ComposePolicy(refresh_margin_sweep=(bad,))
    with pytest.raises(ValueError, match="repeats"):
        ComposePolicy(refresh_margin_sweep=(0.8, 0.8))


def test_sweeps_reject_robust_mode():
    with pytest.raises(ValueError, match="worst_case"):
        compose(None, gainsight.TASKS[0], robust="worst_case",
                compose_policy=ComposePolicy(vdd_sweep=(0.9,)))
    with pytest.raises(ValueError, match="worst_case"):
        compose(None, gainsight.TASKS[0], robust="worst_case",
                compose_policy=ComposePolicy(refresh_margin_sweep=(0.5,)))


# ------------------------------------------------------- solver properties
_GC_CELLS = tuple(sorted(set(bitcells.BITCELLS) - {"sram6t"}))


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(_GC_CELLS),
       st.floats(min_value=233.0, max_value=370.0),
       st.floats(min_value=1.0, max_value=40.0))
def test_solver_retention_monotone_non_increasing_in_temperature(
        name, temp_k, dt_k):
    """Hotter die -> the transient solver may never report LONGER retention
    (the property the vdd/temp sweep and the sim drift schedule rely on)."""
    cell = bitcells.BITCELLS[name]
    tp_lo = corners.resolve(corners.as_operating_point((1.1, temp_k)))
    tp_hi = corners.resolve(corners.as_operating_point((1.1, temp_k + dt_k)))
    r_lo = float(retention.retention_time(cell, 0, tp_lo))
    r_hi = float(retention.retention_time(cell, 0, tp_hi))
    assert np.isfinite(r_lo) and r_lo > 0.0
    assert np.isfinite(r_hi) and r_hi > 0.0
    assert r_lo >= r_hi, f"{name}: retention rose {r_lo} -> {r_hi} " \
                         f"with temperature {temp_k} -> {temp_k + dt_k}"


@functools.lru_cache(maxsize=None)
def _swept_retention(vdd: float) -> tuple:
    tbl = DesignTable.from_configs(
        design_space(word_sizes=(16, 64), num_words=(32, 256)))
    pts = ((None, None),
           (corners.as_operating_point((vdd, 300.0)), None))
    metrics, _ = expand.expand_metrics(tbl, tbl.metrics, pts)
    return tuple(np.asarray(metrics["retention_s"], np.float64))


@settings(max_examples=25, deadline=None)
@given(st.sampled_from((0.8, 0.9, 1.2, 1.3)),
       st.floats(min_value=0.05, max_value=1.0))
def test_swept_refresh_intervals_positive_finite(vdd, margin):
    """Every refresh interval derived across the vdd_sweep grid must stay
    positive and finite for every legal margin."""
    ret = np.asarray(_swept_retention(vdd))
    iv = refresh_interval_s(ret, margin)
    assert np.all(iv > 0.0)
    assert np.all(np.isfinite(iv))
