"""N-level composition: SystemBudget rails, branch-and-bound vs exhaustive
rank-identity (property-tested), deep-hierarchy trimming/pinning, the
``levels=`` subset path, cache roundtrips of the search fields, and the 2D
(compositions x corners) scoring/sharding equivalences."""
import dataclasses
import json
import math
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro import api
from repro.api import Compiler, DesignTable, design_space
from repro.core import gainsight
from repro.core.gainsight import nlevel_task
from repro.core.select import Bucket, LevelReq, SelectionPolicy, TaskReq
from repro.hetero import (ComposePolicy, SystemBudget, bucket_candidates,
                          compose, composition_eval_count)
from repro.hetero.compose import OBJECTIVES, _trim_to_budget
from repro.hetero.search import balanced_norms, slot_contributions
from repro.hetero.system import SYSTEM_METRICS, score_grid, score_grid_corners
from repro.parallel.grid import _factor_devices

KB = gainsight.KB


@pytest.fixture(scope="module")
def table():
    return DesignTable.from_configs(design_space())


# --------------------------------------------------------------- SystemBudget
def test_system_budget_basics():
    none = SystemBudget()
    assert not none.active and none.ensure_orders() == ()
    b = SystemBudget(area_um2=1e6, bw_margin_min=1.0)
    assert b.active
    assert b.ensure_orders() == ("area", "bandwidth")
    scores = {"area_um2": np.array([5e5, 2e6]),
              "p_w": np.array([1.0, 1.0]),
              "bw_margin": np.array([1.5, 0.5])}
    np.testing.assert_array_equal(b.feasible(scores), [True, False])
    assert SystemBudget(power_w=2.0).feasible(scores).all()


def test_compose_policy_validation():
    with pytest.raises(ValueError):
        ComposePolicy(search="nosuch")
    with pytest.raises(ValueError):
        ComposePolicy(budget=SystemBudget(area_um2=1e6), area_budget_um2=1e6)
    # legacy rails fold into the effective SystemBudget
    legacy = ComposePolicy(area_budget_um2=2e6, power_budget_w=0.5)
    assert legacy.system_budget() == SystemBudget(area_um2=2e6, power_w=0.5)
    assert ComposePolicy(budget=SystemBudget(power_w=1.0)).system_budget() \
        == SystemBudget(power_w=1.0)


def test_bandwidth_rail_pins_fastest_row(table):
    metrics, fams = table.metrics, table.families
    b = Bucket(1.0, 0.5e9, 1e-4)
    bc = bucket_candidates(metrics, fams, b, level_name="L1", bucket_index=0,
                           capacity_bits=1e6, ensure_orders=("bandwidth",))
    f_op = np.asarray(metrics["f_op_hz"], np.float64)
    kept = [c.config_idx for c in bc.candidates]
    fastest_kept = max(kept, key=lambda r: f_op[r])
    # the pinned row is at least as fast as anything else in the list
    assert any(f_op[r] >= f_op[fastest_kept] for r in bc.pinned)
    assert set(bc.pinned) <= set(kept)


def test_bw_margin_budget_filters_and_proves_unmeetable(table):
    t = gainsight.TASKS[0]
    base = compose(table, t)
    need = base.best.metrics["bw_margin"] * 0.999
    rb = compose(table, t, compose_policy=ComposePolicy(
        budget=SystemBudget(bw_margin_min=need)))
    assert rb.n_feasible > 0
    assert rb.best.feasible and rb.best.metrics["bw_margin"] >= need
    # an absurd floor: the argmax-f_op pins make "nothing fits" trustworthy
    impossible = compose(table, t, compose_policy=ComposePolicy(
        budget=SystemBudget(bw_margin_min=1e9)))
    assert impossible.n_feasible == 0 and not impossible.best.feasible


# ------------------------------------------------------------ levels= subset
def test_levels_subset_matches_dedicated_task():
    """Composing 3 levels out of the 5-level reference == composing the
    3-level task directly (identical slots => identical scores, bitwise)."""
    full = compose(None, nlevel_task(5), levels=("RF", "L1", "L2"))
    direct = compose(None, nlevel_task(3))
    assert full.labels() == direct.labels()
    assert full.n_space == direct.n_space
    for a, b in zip(full.ranked, direct.ranked):
        for m in SYSTEM_METRICS:
            av, bv = a.metrics[m], b.metrics[m]
            assert av == bv or (av != av and bv != bv), m


def test_levels_subset_through_compiler_reproduces_table2():
    c = Compiler()
    hits = sum(c.compose(t, levels=("L1", "L2")).matches(
        gainsight.TABLE2_EXPECTED[t.task_id]) for t in gainsight.TASKS)
    assert hits == 7
    with pytest.raises(KeyError):
        c.compose(gainsight.TASKS[0], levels=("L1", "L3"))


def test_single_level_subset(table):
    rep = compose(table, nlevel_task(3), levels=("L2",))
    assert list(rep.best.levels) == ["L2"]
    assert rep.best.feasible


# ------------------------------------- branch-and-bound vs exhaustive (prop.)
_MEM_TYPES = ("sram6t", "gc_sisi", "gc_ossi", "gc_osos", "gc_sisi_hvt")


def _random_space(seed: int):
    """A synthetic DesignTable + 2..4-level task with randomized metrics,
    small enough that the exhaustive grid is never trimmed."""
    rng = np.random.default_rng(1000 + seed)
    n = 10
    metrics = {
        "area_um2": rng.uniform(100.0, 5000.0, n).astype(np.float32),
        "bits": rng.choice([1024.0, 4096.0, 16384.0, 65536.0],
                           n).astype(np.float32),
        "p_leak_w": rng.uniform(1e-7, 1e-4, n).astype(np.float32),
        "p_refresh_w": rng.uniform(0.0, 1e-5, n).astype(np.float32),
        "p_dyn_w": rng.uniform(1e-6, 1e-3, n).astype(np.float32),
        "e_read_j": rng.uniform(1e-13, 1e-11, n).astype(np.float32),
        "f_op_hz": rng.uniform(0.2e9, 3e9, n).astype(np.float32),
        "retention_s": (10.0 ** rng.uniform(-5, 2, n)).astype(np.float32),
    }
    axes = {"mem_type": rng.choice(_MEM_TYPES, n)}
    table = DesignTable(axes, metrics)
    n_levels = 2 + seed % 3
    levels = {}
    for i in range(n_levels):
        name = f"V{i}"
        levels[name] = LevelReq(name, int(rng.uniform(1e5, 1e7)), (
            Bucket(1.0, float(rng.uniform(0.3e9, 2.5e9)),
                   float(10.0 ** rng.uniform(-6, 0))),))
    return table, TaskReq(f"rand{seed}", f"rand-{seed}", levels)


@settings(max_examples=24, deadline=None)
@given(seed=st.integers(0, 2),
       objective=st.sampled_from(OBJECTIVES),
       budgeted=st.booleans())
def test_bb_rank_identical_to_exhaustive(seed, objective, budgeted):
    """On every untruncated grid, branch-and-bound must return the same
    ranked list as the exhaustive cross-product — same rows, same float32
    metrics bit-for-bit, same feasibility — for all four objectives, with
    and without an active SystemBudget."""
    table, task = _random_space(seed)
    budget = None
    if budgeted:
        ref = compose(table, task, compose_policy=ComposePolicy(
            objective=objective, candidate_mode="all_feasible", top_k=5))
        m = ref.best.metrics
        budget = SystemBudget(
            area_um2=float(m["area_um2"]) * 1.5,
            power_w=float(m["p_w"]) * 3.0,
            bw_margin_min=1.0)
    cp_ex = ComposePolicy(objective=objective, candidate_mode="all_feasible",
                          top_k=5, budget=budget, search="exhaustive")
    cp_bb = dataclasses.replace(cp_ex, search="branch_and_bound")
    r_ex = compose(table, task, compose_policy=cp_ex)
    r_bb = compose(table, task, compose_policy=cp_bb)
    assert not r_ex.truncated and not r_bb.truncated
    assert r_ex.search == "exhaustive"
    assert r_bb.search == "branch_and_bound"
    assert r_bb.n_space == r_ex.n_space == r_ex.n_compositions
    assert r_bb.n_compositions <= r_ex.n_compositions
    assert len(r_bb.ranked) == len(r_ex.ranked)
    for k, (a, b) in enumerate(zip(r_ex.ranked, r_bb.ranked)):
        for lvl in task.levels:
            assert [p.config_idx for p in a.levels[lvl].picks] == \
                [p.config_idx for p in b.levels[lvl].picks], (k, lvl)
        assert a.feasible == b.feasible and a.pref_rank == b.pref_rank
        for m in SYSTEM_METRICS:
            av, bv = a.metrics[m], b.metrics[m]
            assert av == bv or (av != av and bv != bv), (k, m)


# --------------------------------------------------- deep-hierarchy trimming
def _fake_slots(n_slots=11, n_cands=64, pinned=()):
    from repro.hetero.candidates import BucketCandidates, Candidate
    return [BucketCandidates(
        level_name=f"M{s}", bucket_index=0, bucket=Bucket(1.0, 1e9, 1e-3),
        capacity_bits=1e6,
        candidates=tuple(Candidate("sram", i, 0) for i in range(n_cands)),
        pinned=tuple(pinned)) for s in range(n_slots)]


def test_trim_to_budget_11_slots_past_int64():
    """11 slots at the 64-candidate cap: the product (2^66) overflows int64,
    the regime where an np.prod-based guard would wrap (to 0 here) and skip
    trimming entirely. math.prod must keep trimming."""
    slots = _fake_slots()
    full = math.prod(len(s.candidates) for s in slots)
    assert full == 64 ** 11 > np.iinfo(np.int64).max
    # the exact wrap an int64 product would produce: 2**66 mod 2**64 == 0,
    # so a `<= max_compositions` guard on it would never trim at all
    wrapped = np.multiply.reduce(np.full(11, 64, np.int64), dtype=np.int64)
    assert wrapped == 0
    lists, truncated = _trim_to_budget(slots, 10_000)
    assert truncated
    assert math.prod(len(c) for c in lists) <= 10_000


def test_trim_to_budget_keeps_pins_at_depth():
    """Budget-pinned rows (worst-positioned on purpose) survive trimming in
    every one of the 11 slots."""
    slots = _fake_slots(pinned=(63,))
    lists, truncated = _trim_to_budget(slots, 1_000)
    assert truncated
    for lst in lists:
        assert any(c.config_idx == 63 for c in lst)
    assert math.prod(len(c) for c in lists) <= 1_000


def _deep_task(n_slots=11):
    """An 11-slot hierarchy over the real table: per-level requirements
    cycle through Fig-10-plausible (f, lifetime) points so every slot has a
    rich feasible set."""
    reqs = [(0.40e9, 5e-3), (1.2e9, 2e-6), (0.50e9, 2e-3), (1.6e9, 3e-6),
            (0.35e9, 8e-4), (1.3e9, 2e-6), (0.55e9, 1e-3), (1.8e9, 3e-6),
            (0.45e9, 1e-3), (0.30e9, 1e-2), (1.5e9, 3e-6)]
    levels = {}
    for i in range(n_slots):
        f, lt = reqs[i % len(reqs)]
        name = f"M{i}"
        levels[name] = LevelReq(name, 64 * KB, (Bucket(1.0, f, lt),))
    return TaskReq("deep11", "deep-11", levels)


def test_compose_truncates_and_pins_at_11_slots(table):
    task = _deep_task()
    cp = ComposePolicy(objective="power", candidate_mode="all_feasible",
                       search="exhaustive", max_compositions=4096)
    rep = compose(table, task, compose_policy=cp)
    assert rep.truncated
    assert rep.n_compositions <= 4096
    assert rep.n_space > 10 ** 9           # deep grid, python-int exact
    assert rep.best.feasible
    # exact min-area at depth, via branch-and-bound...
    bb = compose(table, task, compose_policy=ComposePolicy(
        objective="area", candidate_mode="all_feasible",
        search="branch_and_bound"))
    # (bb.truncated may be set by per-bucket caps — the search proof itself
    # closed: far fewer scored than the node budget)
    assert bb.n_compositions < bb.compose_policy.max_compositions
    # ...equals the analytic slot-decomposed optimum
    from repro.hetero.candidates import level_candidates
    slots = [bc for lvl in task.levels.values()
             for bc in level_candidates(table.metrics, table.families, lvl,
                                        SelectionPolicy(),
                                        mode="all_feasible",
                                        order_by="area")]
    area_c, _ = slot_contributions(slots, table.metrics)
    analytic = sum(float(np.min(a)) for a in area_c)
    assert bb.best.metrics["area_um2"] == pytest.approx(analytic, rel=1e-5)
    # an area budget just above that optimum stays feasible on the trimmed
    # exhaustive grid: the pin puts the min-area composition into the grid
    # no matter how hard max_compositions squeezes 11 slots
    budgeted = compose(table, task, compose_policy=ComposePolicy(
        objective="power", candidate_mode="all_feasible",
        search="exhaustive", max_compositions=64,
        budget=SystemBudget(area_um2=analytic * 1.001)))
    assert budgeted.truncated
    assert budgeted.n_feasible > 0 and budgeted.best.feasible
    assert budgeted.best.metrics["area_um2"] <= analytic * 1.0011


def test_balanced_norms_are_candidate_analytic(table):
    """The balanced normalizers depend on the candidate lists alone, and
    lower-bound every scored composition's area/power."""
    from repro.hetero.candidates import level_candidates
    task = nlevel_task(3)
    slots = [bc for lvl in task.levels.values()
             for bc in level_candidates(table.metrics, table.families, lvl,
                                        SelectionPolicy(),
                                        mode="all_feasible",
                                        order_by="balanced")]
    a0, p0 = balanced_norms(slots, table.metrics)
    assert a0 > 0 and p0 > 0
    rep = compose(table, task, compose_policy=ComposePolicy(
        objective="balanced", candidate_mode="all_feasible"))
    assert rep.best.metrics["area_um2"] >= a0 * (1 - 1e-6)
    assert rep.best.metrics["p_w"] >= p0 * (1 - 1e-6)


# -------------------------------------------------- pruning on 4-level space
def test_bb_prunes_4level_space_10x_with_identical_best(table):
    task = nlevel_task(4)
    kw = dict(objective="power", candidate_mode="all_feasible",
              max_candidates_per_bucket=16)
    ex = compose(table, task, compose_policy=ComposePolicy(
        search="exhaustive", max_compositions=50_000, **kw))
    bb = compose(table, task, compose_policy=ComposePolicy(
        search="branch_and_bound", **kw))
    assert bb.n_space == ex.n_space >= 16 ** 4
    # the bound proof closed well inside the node budget
    assert bb.n_compositions < bb.compose_policy.max_compositions
    assert bb.n_compositions * 10 <= ex.n_compositions
    assert bb.labels() == ex.labels()
    for lvl in task.levels:
        assert [p.config_idx for p in bb.best.levels[lvl].picks] == \
            [p.config_idx for p in ex.best.levels[lvl].picks]
    assert bb.best.metrics["p_w"] == ex.best.metrics["p_w"]


def test_auto_search_switches_on_space_size(table):
    task = nlevel_task(4)
    big = compose(table, task, compose_policy=ComposePolicy(
        objective="power", candidate_mode="all_feasible"))
    assert big.n_space > big.compose_policy.search_threshold
    assert big.search == "branch_and_bound"
    small = compose(table, task)               # per_family_best: tiny grid
    assert small.n_space <= small.compose_policy.search_threshold
    assert small.search == "exhaustive"


# ------------------------------------------------------------------- caching
def test_bb_cache_roundtrip_preserves_search_fields(tmp_path):
    task = nlevel_task(3)
    cp = ComposePolicy(objective="power", candidate_mode="all_feasible",
                       search="branch_and_bound")
    r1 = compose(None, task, compose_policy=cp, cache=tmp_path)
    n_chz, n_eval = api.characterize_call_count(), composition_eval_count()
    r2 = compose(None, task, compose_policy=cp, cache=tmp_path)
    assert api.characterize_call_count() == n_chz
    assert composition_eval_count() == n_eval, \
        "cache hit must not re-run the branch-and-bound scoring"
    assert r2.search == "branch_and_bound" == r1.search
    assert r2.n_space == r1.n_space > 10 ** 6
    assert (r2.n_compositions, r2.n_feasible, r2.truncated) == \
        (r1.n_compositions, r1.n_feasible, r1.truncated)
    assert [c.labels() for c in r2.ranked] == [c.labels() for c in r1.ranked]
    for a, b in zip(r1.ranked, r2.ranked):
        for m in SYSTEM_METRICS:
            assert b.metrics[m] == pytest.approx(a.metrics[m])
    # the search mode is part of the cache key: not a false hit
    compose(None, task, cache=tmp_path,
            compose_policy=dataclasses.replace(cp, search="exhaustive"))
    n_after_mode = composition_eval_count()
    assert n_after_mode > n_eval
    # ...and a different budget misses too
    compose(None, task, cache=tmp_path, compose_policy=dataclasses.replace(
        cp, budget=SystemBudget(bw_margin_min=1.0)))
    assert composition_eval_count() > n_after_mode


# ----------------------------------------- corners x compositions (2D) path
def test_score_grid_corners_matches_per_corner_sweeps():
    t = DesignTable.from_configs(
        design_space(word_sizes=(16, 64), num_words=(32, 256)),
        corners=("nominal", "hot"))
    cms = [t.corner_metrics(c) for c in t.corner_labels]
    rng = np.random.default_rng(5)
    idx = rng.integers(0, len(t), (37, 3)).astype(np.int32)
    idx[3, 1] = -1                             # sentinel slot
    cap, f = [1e5, 2e5, 1e6], [1e9, 5e8, 2e9]
    n_eval = composition_eval_count()
    out = score_grid_corners(cms, idx, cap, f)
    assert composition_eval_count() == n_eval + 1    # ONE dispatch for all C
    for c, m in enumerate(cms):
        ref = score_grid(m, idx, cap, f)
        for k in SYSTEM_METRICS:
            np.testing.assert_array_equal(out[k][c], ref[k], err_msg=(c, k))


def test_factor_devices():
    assert _factor_devices(8, 3) == (4, 2)     # largest divisor of 8 <= 3
    assert _factor_devices(8, 1) == (8, 1)
    assert _factor_devices(6, 4) == (2, 3)
    assert _factor_devices(8, 16) == (1, 8)
    assert _factor_devices(1, 5) == (1, 1)
    assert _factor_devices(7, 3) == (7, 1)     # prime: all on the major axis


_SHARD2D_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np, jax
sys.path.insert(0, "src")
assert jax.device_count() == 8
from repro.api import DesignTable, design_space
from repro.hetero.system import score_grid_corners

table = DesignTable.from_configs(
    design_space(word_sizes=(16, 64), num_words=(32, 256)),
    corners=("nominal", "hot", "cold"))
cms = [table.corner_metrics(c) for c in table.corner_labels]
rng = np.random.default_rng(0)
idx = rng.integers(0, len(table), size=(1003, 4)).astype(np.int32)
idx[7, 2] = -1
cap, f = [1e5, 2e5, 4e5, 1e6], [1e9, 5e8, 2e9, 1e9]
a = score_grid_corners(cms, idx, cap, f, sharded=False)
b = score_grid_corners(cms, idx, cap, f, sharded=True)
print(json.dumps({
    "exact": all(bool(np.array_equal(a[k], b[k])) for k in a),
    "shape_ok": all(b[k].shape == (3, 1003) for k in b)}))
"""


def test_shard2d_equals_single_device_8dev(tmp_path):
    """8-virtual-device 2D (compositions x corners) mesh == single device,
    bit exact (subprocess: the device count must be set before jax
    initializes). 3 corners forces uneven padding on the minor axis."""
    script = tmp_path / "shard2d_equiv.py"
    script.write_text(_SHARD2D_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True,
                         cwd=str(Path(__file__).resolve().parents[1]),
                         env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res == {"exact": True, "shape_ok": True}
