"""repro.obs: tracer / metrics / export / report contracts, the hot-path
instrumentation, and the benchmark perf-compare.

The two non-negotiable guarantees proven here:

- **telemetry off is free**: compose results are bit-identical with tracing
  on vs off, and re-driving a warm jit site under an enabled scope adds
  zero trace-cache entries (the probe is read, never wrapped).
- **the catalog is the surface**: every span/metric name the pipeline emits
  is covered by ``repro.obs.catalog`` (and DC04 forces the docs to match).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from benchmarks import compare
from repro import obs
from repro.api import (Compiler, DesignTable, characterize_call_count,
                       design_space)
from repro.core import gainsight
from repro.hetero import ComposePolicy, compose, composition_eval_count
from repro.kernels import backend as kbackend
from repro.obs import catalog, export
from repro.obs import report as obs_report
from repro.sim.engine import sim_eval_count

SRC = Path(__file__).resolve().parents[1] / "src"


@pytest.fixture(autouse=True)
def _clean_trace():
    """Every test starts with an empty event list and tracing off."""
    obs.disable()
    obs.clear()
    yield
    obs.disable()
    obs.clear()


@pytest.fixture(scope="module")
def table():
    return DesignTable.from_configs(design_space())


# ------------------------------------------------------------------ tracer
def test_span_nesting_depth_and_timing():
    with obs.enabled_scope(True):
        with obs.span("t.outer"):
            with obs.span("t.mid"):
                with obs.span("t.inner"):
                    pass
            with obs.span("t.mid2"):
                pass
    ev = {e["name"]: e for e in obs.events()}
    assert set(ev) == {"t.outer", "t.mid", "t.inner", "t.mid2"}
    assert ev["t.outer"]["depth"] == 0
    assert ev["t.mid"]["depth"] == ev["t.mid2"]["depth"] == 1
    assert ev["t.inner"]["depth"] == 2
    # children are contained in the parent's [ts, ts+dur] window
    o = ev["t.outer"]
    for child in ("t.mid", "t.inner", "t.mid2"):
        c = ev[child]
        assert o["ts"] <= c["ts"]
        assert c["ts"] + c["dur"] <= o["ts"] + o["dur"] + 1e-6
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in obs.events())


def test_span_exception_closes_and_propagates():
    with obs.enabled_scope(True):
        with pytest.raises(ValueError, match="boom"):
            with obs.span("t.fail"):
                raise ValueError("boom")
        with obs.span("t.after"):
            pass
    ev = {e["name"]: e for e in obs.events()}
    assert ev["t.fail"]["args"]["error"] == "ValueError"
    # the failed span restored nesting depth for its successors
    assert ev["t.after"]["depth"] == 0
    assert "error" not in ev["t.after"]["args"]


def test_disabled_span_is_shared_noop_and_emits_nothing():
    assert not obs.enabled()
    s1, s2 = obs.span("t.a"), obs.span("t.b", k=1)
    assert s1 is s2                       # one shared null singleton
    with s1:
        s1.set(ignored=True)
    assert obs.events() == []


def test_span_set_lands_in_args():
    with obs.enabled_scope(True):
        with obs.span("t.s", static=1) as sp:
            sp.set(dynamic=2)
    (e,) = obs.events()
    assert e["args"]["static"] == 1 and e["args"]["dynamic"] == 2


# ----------------------------------------------------------------- metrics
def test_metrics_registry_shapes():
    c = obs.counter("t.count")
    assert obs.counter("t.count") is c    # get-or-create returns same object
    c.inc()
    c.inc(4)
    obs.gauge("t.level").set(2.5)
    h = obs.histogram("t.lat_s")
    for v in (0.1, 0.3, 0.2):
        h.observe(v)
    snap = obs.snapshot()
    assert snap["counters"]["t.count"] == 5
    assert obs.value("t.count") == 5
    assert snap["gauges"]["t.level"] == 2.5
    hs = snap["histograms"]["t.lat_s"]
    assert hs["count"] == 3 and hs["min"] == 0.1 and hs["max"] == 0.3
    assert hs["mean"] == pytest.approx(0.2)
    obs.REGISTRY.reset()
    snap = obs.snapshot()
    assert snap["counters"]["t.count"] == 0          # names survive a reset
    assert snap["histograms"]["t.lat_s"]["count"] == 0


# ------------------------------------------------------------------ export
@pytest.mark.parametrize("suffix", [".json", ".jsonl"])
def test_export_roundtrip(tmp_path, suffix):
    with obs.enabled_scope(True):
        with obs.span("t.a", k="v"):
            with obs.span("t.b"):
                pass
    n0 = obs.value("t.rt_count")
    obs.counter("t.rt_count").inc(3)
    path = tmp_path / f"trace{suffix}"
    export.write(path, obs.events(), obs.snapshot())
    events, metrics = export.read(path)
    assert len(events) == len(obs.events())
    for got, want in zip(events, obs.events()):
        assert set(got) == set(want)
        for k in ("name", "cat", "ph", "tid", "depth", "args"):
            assert got[k] == want[k]
        for k in ("ts", "dur"):                # writer rounds to 1 ns
            assert got[k] == pytest.approx(want[k], abs=1e-3)
    assert metrics["counters"]["t.rt_count"] == n0 + 3


def test_chrome_trace_is_perfetto_shaped(tmp_path):
    with obs.enabled_scope(True):
        with obs.span("t.x"):
            pass
    obs.counter("t.ctr").inc()
    path = tmp_path / "trace.json"
    export.write_chrome(path, obs.events(), obs.snapshot())
    doc = json.loads(path.read_text())
    assert doc["otherData"]["schema"] == export.SCHEMA_VERSION
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert phases == {"X", "C"}
    x = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert {"name", "cat", "ts", "dur", "pid", "tid"} <= set(x)
    c = next(e for e in doc["traceEvents"]
             if e["ph"] == "C" and e["name"] == "t.ctr")
    assert c["args"]["value"] == 1


def test_report_render(tmp_path):
    with obs.enabled_scope(True):
        with obs.span("t.render_me"):
            pass
    obs.counter("t.render_count").inc(7)
    text = obs_report.render(obs.events(), obs.snapshot())
    assert "t.render_me" in text and "t.render_count" in text
    path = tmp_path / "trace.json"
    obs.write(path)
    assert "t.render_me" in obs_report.render_file(path)


def test_report_cli_module(tmp_path):
    with obs.enabled_scope(True):
        with obs.span("t.cli"):
            pass
    path = tmp_path / "trace.json"
    obs.write(path)
    out = subprocess.run(
        [sys.executable, "-m", "repro.obs", "report", str(path)],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": str(SRC)})
    assert out.returncode == 0, out.stderr
    assert "t.cli" in out.stdout


def test_env_var_enables_and_atexit_flushes(tmp_path):
    path = tmp_path / "envtrace.json"
    code = ("import repro.obs as obs\n"
            "assert obs.enabled()\n"
            "with obs.span('t.env'):\n"
            "    pass\n")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": str(SRC),
             "REPRO_TRACE": str(path)})
    assert out.returncode == 0, out.stderr
    events, _ = export.read(path)
    assert [e["name"] for e in events] == ["t.env"]


# ----------------------------------------------- counter-backed public API
def test_counter_migration_backs_public_counts(table):
    t = gainsight.TASKS[0]
    c0, s0 = composition_eval_count(), sim_eval_count()
    compose(table, t, refine="simulate")
    assert composition_eval_count() > c0       # scoring sweep counted
    assert sim_eval_count() == s0 + 1          # one replay sweep
    assert obs.value("hetero.compose_evals") == composition_eval_count()
    assert obs.value("sim.replay_calls") == sim_eval_count()
    k0 = characterize_call_count()
    DesignTable.from_configs(design_space()[:2])
    assert characterize_call_count() == k0 + 1
    assert obs.value("api.characterize_calls") == characterize_call_count()


# --------------------------------------------------- off-is-free contracts
def test_bit_identical_with_telemetry_on(table):
    t = gainsight.TASKS[1]
    ref = compose(table, t)
    with obs.enabled_scope(True):
        traced = compose(table, t)
    assert obs.events()                        # tracing actually happened
    assert traced.labels() == ref.labels()
    for a, b in zip(ref.ranked, traced.ranked):
        assert set(a.metrics) == set(b.metrics)
        for k in a.metrics:
            assert a.metrics[k] == b.metrics[k], k   # bit-exact, no tol


def test_no_retrace_under_enabled_scope(table):
    from repro.hetero import system

    t = gainsight.TASKS[2]
    compose(table, t)                          # warm the score jit
    n0 = system._score_jit._cache_size()
    with obs.enabled_scope(True):
        compose(table, t)
    assert system._score_jit._cache_size() == n0
    score_spans = [e for e in obs.events() if e["name"] == "hetero.score"]
    assert score_spans
    assert all("new_traces" not in e["args"] for e in score_spans)


# ------------------------------------------------- end-to-end acceptance
def test_trace_of_compose_simulate_run(table, tmp_path):
    """One compose(refine="simulate") under tracing yields a Perfetto-shaped
    trace holding characterize/score/search/replay spans plus cache-hit and
    B&B-pruning counters (the ISSUE acceptance criterion)."""
    t = gainsight.TASKS[0]
    hit0 = obs.value("hetero.cache_hits")
    miss0 = obs.value("hetero.cache_misses")
    nodes0 = obs.value("hetero.search_nodes")
    pruned0 = obs.value("hetero.search_pruned")
    cp = ComposePolicy(search="branch_and_bound")
    with obs.enabled_scope(True):
        small = DesignTable.from_configs(design_space())
        compose(small, t, compose_policy=cp, cache=tmp_path,
                refine="simulate")
        compose(small, t, compose_policy=cp, cache=tmp_path,
                refine="simulate")             # second call: report-cache hit
        path = tmp_path / "trace.json"
        obs.write(path)

    names = {e["name"] for e in obs.events()}
    assert {"api.characterize", "hetero.compose", "hetero.search",
            "hetero.score", "sim.replay", "sim.rerank"} <= names
    assert obs.value("hetero.cache_misses") == miss0 + 1
    assert obs.value("hetero.cache_hits") == hit0 + 1
    assert obs.value("hetero.search_nodes") > nodes0       # B&B ran
    assert obs.value("hetero.search_pruned") >= pruned0
    hits = [e for e in obs.events()
            if e["name"] == "hetero.compose" and
            e["args"].get("cache") == "hit"]
    assert len(hits) == 1

    doc = json.loads(path.read_text())         # Perfetto-loadable shape
    ctrs = doc["otherData"]["metrics"]["counters"]
    assert "hetero.cache_hits" in ctrs and "hetero.search_pruned" in ctrs
    assert {e["name"] for e in doc["traceEvents"] if e["ph"] == "C"} >= {
        "hetero.cache_hits", "hetero.search_pruned"}


def test_compiler_telemetry_flag(table):
    t = gainsight.TASKS[0]
    Compiler().compose(t, space=table)
    assert obs.events() == []                  # default: off
    Compiler(telemetry=True).compose(t, space=table)
    assert {e["name"] for e in obs.events()} >= {"hetero.compose",
                                                 "hetero.search"}
    assert not obs.enabled()                   # scope-local, not sticky


def test_serve_engine_prefill_decode_spans():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_config
    from repro.serve.engine import Engine, make_prefill_step

    cfg = reduce_config(get_config("internlm2-1.8b")).replace(num_layers=1)
    lm, _ = make_prefill_step(cfg, max_seq=32)
    params = lm.init(jax.random.key(0))
    eng = Engine(cfg, params, max_seq=32)
    p0 = obs.value("serve.prefill_calls")
    d0 = obs.value("serve.decode_steps")
    h0 = obs.snapshot()["histograms"].get(
        "serve.decode_step_s", {"count": 0})["count"]
    s0 = obs.snapshot()["histograms"].get(
        "serve.sample_s", {"count": 0})["count"]
    with obs.enabled_scope(True):
        eng.generate({"tokens": jnp.zeros((2, 4), jnp.int32)}, steps=3)
    names = [e["name"] for e in obs.events()]
    assert names.count("serve.prefill") == 1
    assert names.count("serve.decode_step") == 3
    assert obs.value("serve.prefill_calls") == p0 + 1
    assert obs.value("serve.decode_steps") == d0 + 3
    hs = obs.snapshot()["histograms"]["serve.decode_step_s"]
    assert hs["count"] == h0 + 3 and hs["min"] > 0
    # cold engine: the first generate() compiles, and the probe sees it
    prefill = next(e for e in obs.events() if e["name"] == "serve.prefill")
    assert prefill["args"].get("new_traces", 0) >= 1
    # sampling has its own span + histogram: decode_step time must no longer
    # absorb the sampling math or the host sync (the timing-attribution fix)
    assert names.count("serve.sample") == 3
    ss = obs.snapshot()["histograms"]["serve.sample_s"]
    assert ss["count"] == s0 + 3 and ss["min"] > 0
    by_start = sorted((e for e in obs.events()
                       if e["name"] in ("serve.decode_step", "serve.sample")),
                      key=lambda e: e["ts"])
    # the loop samples from the previous logits, then decodes: strict
    # (sample, decode) alternation with disjoint spans — the host sync
    # between them is charged to neither
    for samp, dec in zip(by_start[::2], by_start[1::2]):
        assert (samp["name"], dec["name"]) == ("serve.sample",
                                               "serve.decode_step")
        assert dec["ts"] >= samp["ts"] + samp["dur"]


def test_kernels_dispatch_counter():
    name = "kernels.dispatch.sim_replay.xla"
    n0 = obs.value(name)
    kbackend.get_impl("sim_replay", backend="xla")
    assert obs.value(name) == n0 + 1


def test_catalog_covers_every_emitted_name(table):
    with obs.enabled_scope(True):
        compose(table, gainsight.TASKS[0], refine="simulate")
    for e in obs.events():
        assert catalog.covers(e["name"]), e["name"]
    snap = obs.snapshot()
    for section in ("counters", "gauges", "histograms"):
        for name in snap[section]:
            if name.startswith("t."):          # fixtures from this file
                continue
            assert catalog.covers(name), name


# ------------------------------------------------------- bench perf-compare
def test_compare_flatten_and_classify():
    base = {"bench": "x", "quick": True, "table2_matches": 7,
            "sweep": {"latency_s": 1.0, "rows_per_s": 100.0},
            "best_labels": {"L1": "SRAM"}, "n_extra": 5}
    # identical -> ok everywhere, env keys never judged
    d = compare.diff_records(base, dict(base))
    assert d["ok"] and not d["regressions"]
    assert d["metrics"]["bench"]["status"] == "env"
    assert d["metrics"]["sweep.rows_per_s"]["status"] == "ok"
    # parity drift is a regression regardless of magnitude
    cur = json.loads(json.dumps(base))
    cur["table2_matches"] = 6
    d = compare.diff_records(base, cur)
    assert d["regressions"] == ["table2_matches"] and not d["ok"]
    # label maps stay atomic and exact
    cur = json.loads(json.dumps(base))
    cur["best_labels"] = {"L1": "OS-Si GCRAM"}
    assert compare.diff_records(base, cur)["regressions"] == ["best_labels"]
    # throughput: 3x slower is a regression, 3x faster an improvement
    cur = json.loads(json.dumps(base))
    cur["sweep"]["rows_per_s"] = 30.0
    d = compare.diff_records(base, cur)
    assert d["metrics"]["sweep.rows_per_s"]["status"] == "regression"
    cur["sweep"]["rows_per_s"] = 300.0
    d = compare.diff_records(base, cur)
    assert d["metrics"]["sweep.rows_per_s"]["status"] == "improved"
    # latency inverts the rule; inside the band is ok
    cur = json.loads(json.dumps(base))
    cur["sweep"]["latency_s"] = 3.0
    assert compare.diff_records(base, cur)["metrics"][
        "sweep.latency_s"]["status"] == "regression"
    cur["sweep"]["latency_s"] = 1.5
    assert compare.diff_records(base, cur)["metrics"][
        "sweep.latency_s"]["status"] == "ok"
    # non-keyed numeric drift is informational
    cur = json.loads(json.dumps(base))
    cur["n_extra"] = 6
    d = compare.diff_records(base, cur)
    assert d["metrics"]["n_extra"]["status"] == "changed" and d["ok"]


def test_compare_suite_and_missing_files(tmp_path):
    bdir, cdir = tmp_path / "base", tmp_path / "cur"
    bdir.mkdir(), cdir.mkdir()
    rec = {"bench": "b", "table2_matches": 7, "rows_per_s": 10.0}
    (bdir / "BENCH_a.json").write_text(json.dumps(rec))
    (cdir / "BENCH_a.json").write_text(json.dumps(rec))
    (bdir / "BENCH_gone.json").write_text(json.dumps(rec))
    (cdir / "BENCH_diff.json").write_text("{}")     # never treated as a bench
    diff = compare.diff_suite(bdir, cdir)
    assert set(diff["benches"]) == {"BENCH_a.json", "BENCH_gone.json"}
    assert diff["benches"]["BENCH_a.json"]["ok"]
    assert diff["benches"]["BENCH_gone.json"]["status"] == "missing"
    assert diff["ok"]                               # missing != regression
    assert "BENCH_a.json" in compare.summarize(diff)


def test_committed_baselines_match_suite_manifest():
    """The committed baseline set is exactly the emitted BENCH file set
    documented in benchmarks/run.py (the drift this PR closes)."""
    from benchmarks.run import SUITE

    baselines = sorted(
        p.name for p in
        (Path(__file__).resolve().parents[1] / "benchmarks"
         / "baselines").glob("BENCH_*.json"))
    assert baselines == sorted(fname for _, _, fname in SUITE)
