"""The repro.api façade: Compiler/Macro, DesignTable queries + caching,
explore() -> DSEReport, and consistency with the legacy dse free functions."""
import warnings

import numpy as np
import pytest

from repro import api
from repro.api import (Compiler, DesignTable, MacroConfig, SelectionPolicy,
                       explore)
from repro.core import gainsight


def small_space():
    return api.design_space(word_sizes=(16, 32), num_words=(32, 64))


# ----------------------------------------------------------------- Compiler
def test_compiler_compile_macro(tmp_path):
    m = Compiler().compile(mem_type="gc_sisi", word_size=16, num_words=32,
                           level_shift=True)
    assert isinstance(m.ppa["f_op_hz"], float) and m.ppa["f_op_hz"] > 0
    assert m.retention_s == m.ppa["retention_s"]
    assert m.family == "si-si"
    assert "module gc_sisi_16x32" in m.verilog()
    assert "library (" in m.lib()
    assert "MACRO gc_sisi_16x32" in m.lef()
    rep = m.write_all(tmp_path)
    assert rep["drc_clean"] and rep["lvs_clean"]
    assert {p.suffix for p in tmp_path.iterdir()} >= {".sp", ".v", ".lib",
                                                      ".lef", ".json"}
    # write_all must reuse the Macro's PPA, not re-characterize
    assert rep["characterization"] is m.ppa


def test_compiler_rejects_unknown_mem_type():
    with pytest.raises(KeyError):
        Compiler(mem_types=("gc_sisi", "nosuch"))
    with pytest.raises(KeyError):
        Compiler().compile(mem_type="nosuch", word_size=16, num_words=16)


# -------------------------------------------------------------- DesignTable
def test_table_roundtrip_and_cache_hit(tmp_path):
    cfgs = small_space()
    t1 = DesignTable.build(cfgs, cache=tmp_path)
    n_sweeps = api.characterize_call_count()
    t2 = DesignTable.build(cfgs, cache=tmp_path)          # second run: cached
    assert api.characterize_call_count() == n_sweeps, \
        "cache hit must not re-run the vmap characterization"
    assert t2.to_configs() == cfgs                        # axis round-trip
    for k in t1.metric_names:
        np.testing.assert_array_equal(t1[k], t2[k])
    assert t1.grid_hash == t2.grid_hash
    # a different grid gets a different cache key
    other = api.design_space(word_sizes=(64,), num_words=(64,))
    assert api.grid_hash(other) != t1.grid_hash


def test_table_save_load_explicit(tmp_path):
    t = DesignTable.from_configs(small_space())
    path = t.save(tmp_path / "t.npz")
    t2 = DesignTable.load(path)
    assert len(t2) == len(t)
    np.testing.assert_array_equal(t["f_op_hz"], t2["f_op_hz"])
    assert list(t2["mem_type"]) == list(t["mem_type"])


def test_feasible_pareto_chain_matches_legacy():
    from repro.core import dse
    cfgs = small_space()
    table = DesignTable.from_configs(cfgs)
    f_hz, lt = 1.0e9, 1e-5
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        res = dse.evaluate_space(cfgs)
        mask = dse.feasible_mask(res, f_hz, lt)
    chain = table.feasible(f_hz, lt)
    assert len(chain) == int(mask.sum())
    assert chain.to_configs() == [c for c, m in zip(cfgs, mask) if m]

    chain = chain.with_column("p_static_w",
                              chain["p_leak_w"] + chain["p_refresh_w"])
    pts = np.stack([chain["area_um2"], chain["p_static_w"],
                    chain["t_read_s"]], axis=1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy_front = dse.pareto_front(pts)
    front = chain.pareto("area_um2", "p_static_w", "t_read_s")
    assert len(front) == int(legacy_front.sum())
    assert front.to_configs() == [c for c, m in zip(chain.to_configs(),
                                                    legacy_front) if m]


def test_table_best_and_maximize():
    table = DesignTable.from_configs(small_space())
    smallest = table.best("area_um2")
    assert smallest.ppa["area_um2"] == pytest.approx(
        float(np.min(table["area_um2"])))
    fastest = table.best("f_op_hz", ascending=False)
    assert fastest.ppa["f_op_hz"] == pytest.approx(
        float(np.max(table["f_op_hz"])))
    # "-col" objective maximizes in pareto()
    front = table.pareto("-retention_s")
    assert float(front["retention_s"][0]) == float(np.max(table["retention_s"]))


def test_table_filter_callable_and_columns():
    table = DesignTable.from_configs(small_space())
    gc = table.filter(lambda t: t["mem_type"] != "sram6t")
    assert set(gc["mem_type"]) <= {"gc_sisi", "gc_ossi"}
    assert set(table.axis_names) == set(DesignTable.AXIS_NAMES)
    assert "f_op_hz" in table and "word_size" in table


# ------------------------------------------------------------------ explore
def test_explore_reproduces_table2_and_hits_cache(tmp_path):
    report = explore(tasks=gainsight.TASKS, cache=tmp_path)
    labels = report.labels()
    for t in gainsight.TASKS:
        exp = gainsight.TABLE2_EXPECTED[t.task_id]
        assert labels[t.task_id]["L1"] == exp["L1"], f"task {t.task_id} L1"
        assert labels[t.task_id]["L2"] == exp["L2"], f"task {t.task_id} L2"
    assert report.matches(gainsight.TABLE2_EXPECTED) == 7

    n_sweeps = api.characterize_call_count()
    report2 = explore(tasks=gainsight.TASKS, cache=tmp_path)
    assert api.characterize_call_count() == n_sweeps, \
        "second explore() on the same grid must hit the DesignTable cache"
    assert report2.labels() == labels


def test_explore_report_structure():
    report = explore(tasks=gainsight.TASKS[:2])
    t1 = report.tasks[0]
    sel = report.selections[t1.task_id]["L1"]
    assert sel.feasible and sel.picks[0].config_idx >= 0
    macro = report.pick_macro(t1.task_id, "L1")
    assert macro.family == sel.picks[0].family
    shmoo = report.shmoo(t1.task_id, "L2")
    assert shmoo.dtype == bool and len(shmoo) == len(report.table)
    assert f"task {t1.task_id}" in report.summary()


def test_explore_policy_preference():
    # SRAM-only preference must never label a level with GCRAM
    report = explore(tasks=gainsight.TASKS[:1],
                     policy=SelectionPolicy(preference=("sram",)))
    for levels in report.labels().values():
        for label in levels.values():
            assert label in ("SRAM", "infeasible")


def test_legacy_select_level_matches_explore():
    from repro.core import dse
    cfgs = api.design_space()
    table = DesignTable.from_configs(cfgs)
    report = explore(space=table, tasks=gainsight.TASKS)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        res = dse.evaluate_space(cfgs)
        for t in gainsight.TASKS:
            l1, picks = dse.select_level(cfgs, res, t.l1)
            assert l1 == report.selections[t.task_id]["L1"].label
            new_picks = report.selections[t.task_id]["L1"].picks
            assert [p["config_idx"] for p in picks] == \
                [p.config_idx for p in new_picks]


# ---------------------------------------------------------------- gainsight
def test_task_req_normalization():
    t = api.as_task_req(gainsight.TASKS[0])
    assert t.task_id == 1 and set(t.levels) == {"L1", "L2"}
    same = api.as_task_req(t)
    assert same is t
    with pytest.raises(TypeError):
        api.as_task_req(42)
