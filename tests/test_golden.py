"""Golden regression snapshot: the nominal-corner physics may not drift.

``tests/golden/table2.json`` freezes (a) the paper's Table-2 selections at
the nominal operating point and (b) the full characterization of a small
fixed config slice, with every metric stored as the exact float64 repr of
the float32 the vmap pipeline produced. These tests diff live results
against the snapshot **bit-for-bit** — an unintended edit to any physics
module fails loudly here instead of silently shifting DSE winners.

After an *intentional* physics change, regenerate with either

    python scripts/update_golden.py
    python -m pytest tests/test_golden.py --update-golden

and commit the new snapshot alongside the change that motivated it.
"""
import json
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "scripts"))

from update_golden import GOLDEN_PATH, SLICE_KW, write_snapshot  # noqa: E402


@pytest.fixture(scope="module")
def golden(request):
    if request.config.getoption("--update-golden"):
        write_snapshot()
    assert GOLDEN_PATH.exists(), \
        "missing tests/golden/table2.json (run scripts/update_golden.py)"
    return json.loads(GOLDEN_PATH.read_text())


def test_table2_selections_match_golden(golden):
    from repro.api import explore
    from repro.core import gainsight
    report = explore(tasks=gainsight.TASKS)
    labels = report.labels()
    for tid, expected in golden["table2"].items():
        assert labels[int(tid)] == expected, f"task {tid} drifted"
    # and the snapshot itself agrees with the paper's ground truth
    for tid, expected in gainsight.TABLE2_EXPECTED.items():
        assert golden["table2"][str(tid)] == expected


def test_characterization_slice_is_bit_for_bit(golden):
    from repro.api import DesignTable, design_space
    slice_kw = {k: tuple(v) for k, v in golden["slice"].items()}
    assert slice_kw == SLICE_KW, \
        "golden slice definition changed; regenerate the snapshot"
    table = DesignTable.from_configs(design_space(**slice_kw))
    assert len(table) == len(golden["characterization"])
    drift = []
    for i, row in enumerate(golden["characterization"]):
        live = table.row(i)
        for k, v in row.items():
            lv = live[k]
            if isinstance(v, float):
                same = float(lv) == v or (np.isnan(v) and np.isnan(float(lv)))
            else:
                same = str(lv) == str(v)
            if not same:
                drift.append(f"row {i} ({row['mem_type']} "
                             f"{row['word_size']}x{row['num_words']}) "
                             f"{k}: golden={v!r} live={lv!r}")
    assert not drift, (
        "characterization drifted from the golden snapshot "
        "(bit-for-bit):\n  " + "\n  ".join(drift[:20])
        + "\nIf the physics change is intentional, regenerate via "
          "scripts/update_golden.py or pytest --update-golden.")


def test_update_golden_roundtrips(tmp_path, golden):
    """The update path rewrites a snapshot identical to a fresh build (so
    --update-golden immediately followed by the diff test passes)."""
    from update_golden import build_snapshot
    snap = build_snapshot()
    assert snap["table2"] == golden["table2"]
    assert snap["characterization"] == golden["characterization"]
