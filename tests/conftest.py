import os
import sys
from pathlib import Path

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py forces 512.
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="regenerate tests/golden/table2.json from the live physics "
             "instead of diffing against it (equivalent to running "
             "scripts/update_golden.py); commit the result only after an "
             "intentional physics change")
