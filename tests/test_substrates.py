"""Data pipeline, optimizer, checkpointing, supervisor, compression."""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is a dev extra: property tests skip where absent, unit tests run
from _hypothesis_compat import given, settings, st

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import get_config, reduce_config
from repro.data.pipeline import SyntheticLMData
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               cosine_schedule)
from repro.parallel.compression import ef_int8_psum_mean, init_residuals
from repro.runtime.supervisor import Supervisor, SupervisorConfig
from repro.train.step import init_train_state, make_train_step

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def tiny_cfg():
    return reduce_config(get_config("internlm2-1.8b")).replace(num_layers=2)


# ----------------------------------------------------------------------- data
def test_data_deterministic_and_resumable():
    cfg = tiny_cfg()
    d1 = SyntheticLMData(cfg, 2, 16, seed=7)
    ref = [d1.next_batch()["tokens"] for _ in range(5)]
    d2 = SyntheticLMData(cfg, 2, 16, seed=7)
    d2.next_batch(), d2.next_batch()
    d2.state.step = 3                      # resume mid-stream
    np.testing.assert_array_equal(d2.next_batch()["tokens"], ref[3])


# ------------------------------------------------------------------ optimizer
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    acfg = AdamWConfig(weight_decay=0.0)
    state = adamw_init(params, acfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(grads, state, params, 0.05, acfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_int8_opt_state_tracks_fp32():
    """int8 moments: single-step drift bounded by quantization resolution and
    the optimizer still minimizes (the property that matters)."""
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    acfg8 = AdamWConfig(quantized=True, weight_decay=0.0)
    acfg32 = AdamWConfig(weight_decay=0.0)
    s32 = adamw_init(params, acfg32)
    s8 = adamw_init(params, acfg8)
    assert isinstance(s8["m"]["w"], dict)          # quantized layout
    p32, s32, _ = adamw_update(g, s32, dict(params), 1e-2, acfg32)
    p8, s8, _ = adamw_update(g, s8, dict(params), 1e-2, acfg8)
    assert float(jnp.max(jnp.abs(p32["w"] - p8["w"]))) < 2e-3
    # and the quantized optimizer converges on a quadratic
    p = {"w": jnp.asarray([4.0, -2.0])}
    s = adamw_init(p, acfg8)
    for _ in range(300):
        p, s, _ = adamw_update({"w": 2 * p["w"]}, s, p, 0.05, acfg8)
    assert float(jnp.abs(p["w"]).max()) < 0.1


@given(st.integers(1, 10_000))
def test_cosine_schedule_bounds(step):
    lr = cosine_schedule(1e-3, warmup=100, total=10_000)
    v = float(lr(jnp.asarray(step)))
    assert 0.0 <= v <= 1e-3 + 1e-9


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    cfg = tiny_cfg()
    params, opt = init_train_state(cfg, jax.random.key(0))
    ck = Checkpointer(tmp_path, keep=2, async_write=False)
    for s in (10, 20, 30):
        ck.save(s, params, opt, {"seed": 7, "step": s})
    assert sorted(ck.steps()) == [20, 30]          # gc keeps last 2
    step, p2, o2, ds = ck.restore(params_template=params, opt_template=opt)
    assert step == 30 and ds["step"] == 30
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_checksum_detects_corruption(tmp_path):
    cfg = tiny_cfg()
    params, opt = init_train_state(cfg, jax.random.key(0))
    ck = Checkpointer(tmp_path, async_write=False)
    ck.save(1, params, opt, {"seed": 0, "step": 1})
    man = json.loads((tmp_path / "step_1" / "manifest.json").read_text())
    man["params_sha256"] = "0" * 64
    (tmp_path / "step_1" / "manifest.json").write_text(json.dumps(man))
    with pytest.raises(IOError):
        ck.restore(params_template=params, opt_template=opt)


def test_checkpoint_reshard_on_restore(tmp_path):
    """Restore places leaves with target-mesh shardings (elastic restart)."""
    from repro.compat import make_mesh, replicated_like
    cfg = tiny_cfg()
    params, opt = init_train_state(cfg, jax.random.key(0))
    ck = Checkpointer(tmp_path, async_write=False)
    ck.save(5, params, opt, {"seed": 0, "step": 5})
    mesh = make_mesh((1,), ("data",))
    _, p2, _, _ = ck.restore(params_template=params, opt_template=opt,
                             shardings=(replicated_like(mesh, params),
                                        replicated_like(mesh, opt)))
    leaf = jax.tree.leaves(p2)[0]
    assert leaf.sharding.mesh.axis_names == ("data",)
    # mesh= alone must reshard too (previously a silently-ignored kwarg)
    _, p3, _, _ = ck.restore(params_template=params, opt_template=opt,
                             mesh=mesh)
    assert jax.tree.leaves(p3)[0].sharding.mesh.axis_names == ("data",)


# ------------------------------------------------------------------ supervisor
def test_supervisor_recovers_from_injected_failures(tmp_path):
    cfg = tiny_cfg()
    lm, step_fn = make_train_step(cfg, base_lr=1e-3, total_steps=40)
    step_fn = jax.jit(step_fn)
    params, opt = init_train_state(cfg, jax.random.key(0))
    data = SyntheticLMData(cfg, 2, 16, seed=3)
    crashed = {"done": False}

    def inject(step):
        if step == 12 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated node failure")

    sup = Supervisor(step_fn, Checkpointer(tmp_path, async_write=False),
                     SupervisorConfig(ckpt_every=5, max_restarts=2),
                     failure_injector=inject)
    params, opt, report = sup.run(params, opt, data, total_steps=20)
    assert report.restarts == 1
    assert report.steps_run >= 20
    assert all(np.isfinite(report.losses))


def test_supervisor_detects_stragglers(tmp_path):
    cfg = tiny_cfg()
    _, step_fn = make_train_step(cfg, base_lr=1e-3, total_steps=40)
    step_fn = jax.jit(step_fn)
    params, opt = init_train_state(cfg, jax.random.key(0))
    data = SyntheticLMData(cfg, 2, 16, seed=3)

    def slow(step):
        return 0.6 if step == 15 else 0.0

    sup = Supervisor(step_fn, Checkpointer(tmp_path, async_write=False),
                     SupervisorConfig(ckpt_every=100, straggler_factor=3.0),
                     straggler_injector=slow)
    _, _, report = sup.run(params, opt, data, total_steps=18)
    assert 15 in report.straggler_events


# ----------------------------------------------------------------- compression
def test_ef_int8_psum_single_axis():
    """On a size-1 axis the compressed mean must equal plain quantization,
    and error feedback must carry the residual exactly."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    mesh = make_mesh((1,), ("data",))
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)}
    r = init_residuals(g)

    def f(g, r):
        return ef_int8_psum_mean(g, r, "data")

    mean, resid = jax.jit(shard_map(f, mesh=mesh, in_specs=(P(), P()),
                                    out_specs=(P(), P())))(g, r)
    np.testing.assert_allclose(np.asarray(mean["w"] + resid["w"]),
                               np.asarray(g["w"]), rtol=1e-6, atol=1e-6)
    # quantization error bounded by scale/2
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(resid["w"]))) <= scale * 0.5 + 1e-7


def test_ef_int8_bias_vanishes_over_steps():
    """Accumulated compressed updates converge to accumulated true updates."""
    rng = np.random.default_rng(1)
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    mesh = make_mesh((1,), ("data",))
    g_seq = [jnp.asarray(rng.normal(size=(16,)), jnp.float32) for _ in range(50)]
    r = {"w": jnp.zeros((16,))}
    acc_c = jnp.zeros((16,))
    acc_t = jnp.zeros((16,))
    f = jax.jit(shard_map(lambda g, r: ef_int8_psum_mean(g, r, "data"),
                          mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P())))
    for g in g_seq:
        mean, r = f({"w": g}, r)
        acc_c = acc_c + mean["w"]
        acc_t = acc_t + g
    # EF guarantees sum of compressed means = sum of true grads - final resid
    np.testing.assert_allclose(np.asarray(acc_c + r["w"]), np.asarray(acc_t),
                               rtol=1e-5, atol=1e-5)
