"""The repro.sim trace-replay simulator: trace construction arithmetic,
refresh-interval parity with the retention solver, collision / expiry-rewrite
arithmetic against closed forms, vmapped-grid bit-exactness vs a
per-composition Python loop, simulate-then-rerank DSE (Table-2 parity,
top-K containment), sim-report caching, and the profiler trace export."""
import warnings

import numpy as np
import pytest

from repro import api
from repro.api import Compiler, DesignTable, SimPolicy, design_space, simulate
from repro.core import bitcells, gainsight, retention
from repro.core.select import Bucket, LevelReq, TaskReq
from repro.hetero import compose, composition_eval_count
from repro.kernels import backend as kbackend
from repro.sim import (DEFAULT_REFRESH_MARGIN, refresh_intervals,
                       sim_eval_count, simulate_traces, task_traces)
from repro.sim.engine import SIM_METRICS
from repro.sim.rerank import composition_idx, sim_cols
from repro.sim.trace import phase_trace


@pytest.fixture(scope="module")
def table():
    return DesignTable.from_configs(design_space())


def _toy_cols(retention_s=1e-4, bits=1024.0, word_bits=32.0, e_read=1e-12,
              e_write=2e-12, f_op=1e9, p_leak=1e-6):
    return {k: np.array([v], np.float64) for k, v in [
        ("bits", bits), ("word_bits", word_bits), ("e_read_j", e_read),
        ("e_write_j", e_write), ("f_op_hz", f_op), ("p_leak_w", p_leak),
        ("retention_s", retention_s)]}


def _one_slot_task(cap_bits=1024, f_hz=1e8, lifetime_s=1e-3):
    return TaskReq("toy", "toy", {
        "L1": LevelReq("L1", cap_bits, (Bucket(1.0, f_hz, lifetime_s),))})


# ------------------------------------------------------------------- refresh
def test_refresh_interval_parity_with_retention_solver(table):
    """Intervals are margin x the SAME retention the transient solver puts in
    the table — elementwise over the grid and directly vs the solver."""
    iv = refresh_intervals(table.metrics)
    np.testing.assert_allclose(
        iv,
        DEFAULT_REFRESH_MARGIN * np.asarray(table["retention_s"], np.float64),
        rtol=0, atol=0)
    rows = np.where((table["mem_type"] == "gc_sisi")
                    & ~table["level_shift"])[0]
    t_solver = float(retention.retention_time(bitcells.BITCELLS["gc_sisi"], 0))
    np.testing.assert_allclose(iv[rows],
                               DEFAULT_REFRESH_MARGIN * t_solver, rtol=1e-6)


def test_retention_grid_constant_is_static():
    """N_STEPS must stay a plain int computed without device work at import
    time (math.log10, not jnp) — and keep its historical value."""
    assert isinstance(retention.N_STEPS, int)
    assert retention.N_STEPS == 480
    assert retention.time_grid().shape[0] == retention.N_STEPS + 1


# -------------------------------------------------------------------- traces
def test_trace_read_volume_matches_requirement():
    """Every phase integrates each slot's reads to f_hz * duration — the
    envelopes shape traffic in time, never change its volume."""
    t = gainsight.TASKS[2]
    for phase in ("prefill", "decode", "train_step"):
        tr = phase_trace(t, phase, duration_s=2e-3, n_bins=48)
        np.testing.assert_allclose(tr.reads.sum(axis=1),
                                   tr.f_req_hz * tr.duration_s, rtol=1e-9)


def test_trace_phase_envelopes():
    task = TaskReq("t", "t", {"L2": LevelReq("L2", 1 << 20, (
        Bucket(0.5, 1e9, 1e-6),        # short-lived (activations)
        Bucket(0.5, 1e9, 10.0)))})     # long-lived  (KV / weights)
    pre = phase_trace(task, "prefill", duration_s=1e-3, n_bins=16)
    dec = phase_trace(task, "decode", duration_s=1e-3, n_bins=16)
    trn = phase_trace(task, "train_step", duration_s=1e-3, n_bins=16)
    # prefill: the long-lived slot fills monotonically; short-lived is flat
    assert np.all(np.diff(pre.occupancy[1]) > 0)
    assert pre.occupancy[1][0] < 0.1 and pre.occupancy[1][-1] > 0.9
    np.testing.assert_allclose(pre.occupancy[0], 1.0)
    # decode: steady state everywhere
    np.testing.assert_allclose(dec.occupancy, 1.0)
    np.testing.assert_allclose(
        dec.reads, np.broadcast_to(dec.reads[:, :1], dec.reads.shape))
    # train-step: residuals triangle up (forward) then down (backward)
    peak = int(np.argmax(trn.occupancy[0]))
    assert 0 < peak < trn.n_bins - 1
    assert np.all(np.diff(trn.occupancy[0][:peak]) > 0)
    assert np.all(np.diff(trn.occupancy[0][peak + 1:]) < 0)
    # backward reads heavier than forward for the residual slot
    assert trn.reads[0][-1] > trn.reads[0][0]
    with pytest.raises(ValueError):
        phase_trace(task, "nosuch")


def test_trace_write_turnover_arithmetic():
    """Decode, flat occupancy: writes are exactly the line-turnover model
    occ * cap * t_bin / lifetime, no phantom first-bin fill."""
    task = _one_slot_task(cap_bits=4096, f_hz=1e8, lifetime_s=5e-4)
    tr = phase_trace(task, "decode", duration_s=1e-3, n_bins=8)
    expect = 1.0 * 4096 * (1e-3 / 8) / 5e-4
    np.testing.assert_allclose(tr.write_bits, expect, rtol=1e-12)


# -------------------------------------------------------- engine arithmetic
def test_collision_and_stall_arithmetic():
    """One slot, one bin, refresh scheduled: recompute ops, utilization,
    stall, collisions, and every energy term by hand."""
    d, life, ret = 1e-3, 1e-2, 1e-4
    cols = _toy_cols(retention_s=ret)
    task = _one_slot_task(cap_bits=1024, f_hz=2e12, lifetime_s=life)
    tr = phase_trace(task, "decode", duration_s=d, n_bins=1)
    out = simulate_traces(cols, np.array([[0]], np.int32), [tr],
                          policy=SimPolicy(refresh=True), backend="xla")
    reads = 2e12 * d
    wops = (1024 * d / life) / 32.0
    nw, interval = 1024 / 32.0, DEFAULT_REFRESH_MARGIN * ret
    refr = 1.0 * nw * d / interval            # tiles=1, occupancy=1
    cap_ops = 1e9 * d
    util = (reads + wops + refr) / cap_ops
    assert util > 1.0                          # the port genuinely saturates
    t_sim = d * util
    assert out["util_peak"][0] == pytest.approx(util, rel=1e-5)
    assert out["t_sim_s"][0] == pytest.approx(t_sim, rel=1e-5)
    assert out["stall_frac"][0] == pytest.approx(util - 1.0, rel=1e-4)
    assert out["collisions"][0] == pytest.approx(
        refr * min((reads + wops) / cap_ops, 1.0), rel=1e-5)
    assert out["e_dyn_j"][0] == pytest.approx(reads * 1e-12 + wops * 2e-12,
                                              rel=1e-5)
    assert out["e_refresh_j"][0] == pytest.approx(refr * 3e-12, rel=1e-5)
    assert out["e_rewrite_j"][0] == 0.0
    assert out["e_leak_j"][0] == pytest.approx(1e-6 * t_sim, rel=1e-5)
    assert out["e_total_j"][0] == pytest.approx(
        out["e_dyn_j"][0] + out["e_refresh_j"][0] + out["e_leak_j"][0],
        rel=1e-6)


def test_expiry_rewrite_arithmetic():
    """Refresh disabled, retention < lifetime: data decays at 1/retention and
    pays overhead-weighted rewrite energy instead of refresh energy."""
    d, life, ret, ovh = 1e-3, 1e-2, 1e-4, 2.0
    cols = _toy_cols(retention_s=ret)
    task = _one_slot_task(cap_bits=1024, f_hz=1e6, lifetime_s=life)
    tr = phase_trace(task, "decode", duration_s=d, n_bins=4)
    out = simulate_traces(cols, np.array([[0]], np.int32), [tr],
                          policy=SimPolicy(refresh=False,
                                           rewrite_overhead=ovh))
    rewr_ops = 1.0 * 1024 * d / ret / 32.0
    assert out["e_rewrite_j"][0] == pytest.approx(rewr_ops * 2e-12 * ovh,
                                                  rel=1e-5)
    assert out["e_refresh_j"][0] == 0.0


def test_refresh_gates_on_retention_vs_lifetime():
    """Retention >= lifetime (the analytic no-refresh feasibility region):
    neither refresh nor rewrites fire, under either scheduling mode."""
    task = _one_slot_task(lifetime_s=1e-5)
    tr = phase_trace(task, "decode", duration_s=1e-3, n_bins=2)
    cols = _toy_cols(retention_s=1e-3)        # outlives the data
    for refresh in (True, False):
        out = simulate_traces(cols, np.array([[0]], np.int32), [tr],
                              policy=SimPolicy(refresh=refresh))
        assert out["e_refresh_j"][0] == 0.0
        assert out["e_rewrite_j"][0] == 0.0
        assert out["collisions"][0] == 0.0


def test_vmapped_grid_bit_exact_vs_python_loop(table):
    """The jit(vmap(scan)) grid path must equal the per-composition Python
    loop over the same scan, bit for bit, across all phases."""
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(table), size=(41, 4)).astype(np.int32)
    idx[5, 2] = -1
    task = TaskReq("x", "x", {
        "L1": LevelReq("L1", 1 << 20, (Bucket(0.6, 1.2e9, 2e-6),
                                       Bucket(0.4, 5e8, 1e-4))),
        "L2": LevelReq("L2", 64 << 20, (Bucket(0.5, 1e9, 1e-3),
                                        Bucket(0.5, 2e9, 3e-6)))})
    traces = task_traces(task, phases=("prefill", "decode", "train_step"))
    cols = sim_cols(table)
    a = simulate_traces(cols, idx, traces, backend="xla")
    b = simulate_traces(cols, idx, traces, backend="interpret")
    for m in SIM_METRICS:
        np.testing.assert_array_equal(a[m], b[m], err_msg=m)
    for phase in a["phases"]:
        for m in SIM_METRICS:
            np.testing.assert_array_equal(a["phases"][phase][m],
                                          b["phases"][phase][m],
                                          err_msg=f"{phase}/{m}")


def test_sentinel_slot_prices_inf(table):
    task = _one_slot_task()
    tr = phase_trace(task, "decode")
    out = simulate_traces(sim_cols(table),
                          np.array([[0], [-1]], np.int32), [tr])
    assert np.isfinite(out["e_total_j"][0])
    assert np.isinf(out["e_total_j"][1]) and np.isinf(out["t_sim_s"][1])
    assert out["collisions"][1] == 0.0
    # the per-phase breakdown honors the same sentinel contract
    assert np.isinf(out["phases"]["decode"]["e_total_j"][1])
    assert np.isfinite(out["phases"]["decode"]["e_total_j"][0])


def test_sim_replay_backend_registration():
    """The grid replay kernel is a first-class backend op: 'sim_replay'
    must stay registered with both the vmapped xla path and the python-loop
    interpret oracle, and dispatch must route a toy grid through the
    registry to the same numbers simulate_traces produces."""
    assert "sim_replay" in kbackend.registered()
    avail = kbackend.available_backends("sim_replay")
    assert "interpret" in avail and "xla" in avail
    assert (kbackend.get_impl("sim_replay", "xla")
            is not kbackend.get_impl("sim_replay", "interpret"))
    task = _one_slot_task()
    tr = phase_trace(task, "decode")
    idx = np.array([[0]], np.int32)
    via_facade = simulate_traces(_toy_cols(), idx, [tr], backend="xla")
    with kbackend.use_backend("interpret"):
        via_registry = simulate_traces(_toy_cols(), idx, [tr])
    for m in SIM_METRICS:
        np.testing.assert_array_equal(via_facade[m], via_registry[m],
                                      err_msg=m)


def test_use_backend_context_overrides_env():
    assert kbackend.resolve_backend("interpret") == "interpret"
    with kbackend.use_backend("interpret"):
        assert kbackend.resolve_backend() == "interpret"
        with kbackend.use_backend("xla"):
            assert kbackend.resolve_backend() == "xla"
        assert kbackend.resolve_backend() == "interpret"
        # an explicit argument still wins over the context
        assert kbackend.resolve_backend("xla") == "xla"
    with pytest.raises(ValueError):
        with kbackend.use_backend("nosuch"):
            pass


# ------------------------------------------------------- simulate-then-rerank
def test_refine_simulate_reproduces_table2(table):
    """Acceptance: the simulated re-rank must not overturn the analytic
    Table-2 winners at default settings — 7/7 through refine="simulate"."""
    c = Compiler()
    for t in gainsight.TASKS:
        rep = c.simulate(t, space=table)
        assert rep.refined == "simulate"
        assert rep.labels() == gainsight.TABLE2_EXPECTED[t.task_id], t.task_id
    assert sum(c.simulate(t, space=table).matches(
        gainsight.TABLE2_EXPECTED[t.task_id]) for t in gainsight.TASKS) == 7


def test_rerank_topk_containment(table):
    """The re-rank permutes the analytic top-K — same composition set, no
    additions, no drops — and stamps sim_* metrics on every entry."""
    t = gainsight.TASKS[6]
    analytic = compose(table, t)
    refined = compose(table, t, refine="simulate")
    assert len(refined.ranked) == len(analytic.ranked)
    key_rows = {tuple(r) for r in composition_idx(analytic)}
    assert {tuple(r) for r in composition_idx(refined)} == key_rows
    for comp in refined.ranked:
        for m in SIM_METRICS:
            assert f"sim_{m}" in comp.metrics
    assert (refined.n_compositions, refined.n_feasible) == \
        (analytic.n_compositions, analytic.n_feasible)
    with pytest.raises(ValueError):
        compose(table, t, refine="nosuch")


def test_simulate_facade_and_policy_validation(table):
    rep = simulate(table, gainsight.TASKS[4])
    assert rep.refined == "simulate"
    assert rep.labels() == gainsight.TABLE2_EXPECTED[5]
    assert rep.best.metrics["sim_e_total_j"] > 0
    via_method = Compiler().simulate(gainsight.TASKS[4], space=table)
    assert via_method.labels() == rep.labels()
    with pytest.raises(ValueError):
        SimPolicy(objective="nosuch")
    with pytest.raises(ValueError):
        SimPolicy(phases=("warmup",))


def test_sim_cache_hits_and_key_sensitivity(tmp_path):
    """A cached simulate() re-runs neither the characterization, the
    analytic scoring, nor the trace replay; changing the task or the sim
    policy misses."""
    c = Compiler()
    t = gainsight.TASKS[1]
    r1 = c.simulate(t, cache=tmp_path)
    n_chz = api.characterize_call_count()
    n_comp = composition_eval_count()
    n_sim = sim_eval_count()
    r2 = c.simulate(t, cache=tmp_path)
    assert api.characterize_call_count() == n_chz
    assert composition_eval_count() == n_comp
    assert sim_eval_count() == n_sim, \
        "simulate() cache hit must not re-run the trace replay"
    assert r2.labels() == r1.labels()
    assert [comp.labels() for comp in r2.ranked] == \
        [comp.labels() for comp in r1.ranked]
    for m in SIM_METRICS:
        assert r2.best.metrics[f"sim_{m}"] == \
            pytest.approx(r1.best.metrics[f"sim_{m}"])
    # different sim policy -> replay re-runs (analytic stays cached)
    c.simulate(t, cache=tmp_path, sim_policy=SimPolicy(n_bins=8))
    assert sim_eval_count() == n_sim + 1
    assert composition_eval_count() == n_comp
    # different task -> everything downstream of the table re-runs
    c.simulate(gainsight.TASKS[3], cache=tmp_path)
    assert sim_eval_count() == n_sim + 2
    assert composition_eval_count() == n_comp + 1
    assert api.characterize_call_count() == n_chz


# ------------------------------------- adaptive refresh / temperature drift
def test_adaptive_refresh_scales_by_write_turnover():
    """Decode, flat occupancy: each bin's writes rewrite turn = wbits/cap of
    the live data, so the adaptive controller must cut refresh energy by
    exactly (1 - turn) — closed form, and strictly cheaper than the fixed
    schedule in a write-heavy phase."""
    d, life, ret, cap = 1e-3, 5e-4, 1e-4, 4096
    cols = _toy_cols(retention_s=ret, bits=4096.0)
    task = _one_slot_task(cap_bits=cap, f_hz=1e6, lifetime_s=life)
    tr = phase_trace(task, "decode", duration_s=d, n_bins=8)
    idx = np.array([[0]], np.int32)
    base = simulate_traces(cols, idx, [tr], policy=SimPolicy(refresh=True))
    adap = simulate_traces(cols, idx, [tr],
                           policy=SimPolicy(refresh=True,
                                            adaptive_refresh=True))
    turn = float(tr.write_bits[0, 0]) / cap          # flat in decode
    assert 0.0 < turn < 1.0
    assert adap["e_refresh_j"][0] == pytest.approx(
        (1.0 - turn) * base["e_refresh_j"][0], rel=1e-5)
    assert adap["e_refresh_j"][0] < base["e_refresh_j"][0]
    # reads/writes/leak untouched by the controller
    assert adap["e_dyn_j"][0] == base["e_dyn_j"][0]
    nw, interval = 4096 / 32.0, DEFAULT_REFRESH_MARGIN * ret
    refr = nw * d / interval
    assert base["e_refresh_j"][0] == pytest.approx(refr * 3e-12, rel=1e-5)


def test_temp_drift_follows_arrhenius_closed_form():
    """A linear 300->300+drift ramp across the window: refresh energy per bin
    scales by 1/rs(T) with rs the solver's Arrhenius factor (Ea = 0.5 eV) —
    recompute the whole scan by hand, and check drift monotonicity."""
    from repro.sim.engine import _EA_OVER_KB_K, _T_NOMINAL_K
    d, life, ret, drift, n = 1e-3, 1e-2, 1e-4, 60.0, 8
    cols = _toy_cols(retention_s=ret)
    task = _one_slot_task(cap_bits=1024, f_hz=1e6, lifetime_s=life)
    tr = phase_trace(task, "decode", duration_s=d, n_bins=n)
    idx = np.array([[0]], np.int32)
    cold = simulate_traces(cols, idx, [tr], policy=SimPolicy(refresh=True))
    hot = simulate_traces(cols, idx, [tr],
                          policy=SimPolicy(refresh=True, temp_drift_k=drift))
    t_bin = d / n
    t_now = _T_NOMINAL_K + drift * (np.arange(n) * t_bin) / d
    rs = np.exp(_EA_OVER_KB_K * (1.0 / t_now - 1.0 / _T_NOMINAL_K))
    nw, interval = 1024 / 32.0, DEFAULT_REFRESH_MARGIN * ret
    e_ref = np.sum(nw * t_bin / (interval * rs)) * 3e-12
    assert hot["e_refresh_j"][0] == pytest.approx(e_ref, rel=1e-4)
    assert hot["e_refresh_j"][0] > cold["e_refresh_j"][0]
    # expiry path: the same ramp accelerates rewrites when refresh is off
    cold_rw = simulate_traces(cols, idx, [tr],
                              policy=SimPolicy(refresh=False))
    hot_rw = simulate_traces(cols, idx, [tr],
                             policy=SimPolicy(refresh=False,
                                              temp_drift_k=drift))
    assert hot_rw["e_rewrite_j"][0] > cold_rw["e_rewrite_j"][0]


def test_cold_boost_scenario_prices_swept_levels(table):
    """The ISSUE scenario end to end: the same GC macro replayed at the base
    point and at the cold-boost (1.2 V, 233 K) sweep block, under the
    adaptive controller + a heating die. The cold block's longer retention
    must cut refresh energy, and xla must stay bit-exact vs interpret."""
    from repro.core import corners
    from repro.hetero import expand
    pts = ((None, None),
           (corners.as_operating_point((1.2, 233.0)), None))
    metrics, fams = expand.expand_metrics(table, table.metrics, pts)
    n = len(table)
    # a GC row that actually refreshes: retention below the slot lifetime
    gc = int(np.where((np.asarray(fams[:n]) != "sram6t")
                      & (np.asarray(metrics["retention_s"][:n]) < 1e-3))[0][0])
    assert metrics["retention_s"][n + gc] > metrics["retention_s"][gc]
    cols = {k: np.asarray(metrics[k]) for k in
            ("bits", "e_read_j", "e_write_j", "f_op_hz", "p_leak_w",
             "retention_s")}
    cols["word_bits"] = np.tile(np.asarray(table["word_size"], np.float64), 2)
    task = _one_slot_task(cap_bits=1 << 20, f_hz=1e8, lifetime_s=1e-3)
    tr = phase_trace(task, "decode", duration_s=1e-3, n_bins=16)
    idx = np.array([[gc], [n + gc]], np.int32)   # base vs cold-boost block
    policy = SimPolicy(refresh=True, adaptive_refresh=True, temp_drift_k=30.0)
    out = simulate_traces(cols, idx, [tr], policy=policy, backend="xla")
    assert np.all(np.isfinite(out["e_total_j"]))
    assert out["e_refresh_j"][1] < out["e_refresh_j"][0]
    ora = simulate_traces(cols, idx, [tr], policy=policy,
                          backend="interpret")
    for m in SIM_METRICS:
        np.testing.assert_array_equal(out[m], ora[m], err_msg=m)


def test_sim_policy_and_refresh_margin_validation():
    """(0, 1] margin enforcement at every python entry point, plus the drift
    sanity bound — jit-safe helpers (refresh_ops) stay unvalidated."""
    from repro.sim.refresh import refresh_interval_s
    for bad in (0.0, -1.0, 1.5, float("inf"), float("nan")):
        with pytest.raises(ValueError, match="margin"):
            refresh_interval_s(np.array([1e-3]), bad)
        with pytest.raises(ValueError, match="margin"):
            refresh_intervals({"retention_s": np.array([1e-3])}, margin=bad)
        with pytest.raises(ValueError, match="margin"):
            SimPolicy(refresh_margin=bad)
    for bad in (float("nan"), float("inf"), -300.0, -350.0):
        with pytest.raises(ValueError, match="temp_drift_k"):
            SimPolicy(temp_drift_k=bad)
    # disabled knobs replay bit-identically to the pre-drift engine defaults
    assert SimPolicy() == SimPolicy(adaptive_refresh=False, temp_drift_k=0.0)


# ------------------------------------------------------------------ profiler
def test_arch_traces_from_synthetic_record():
    """The profiler's trace export: a dry-run record becomes a one-phase
    trace whose envelope matches the shape's kind and whose window follows
    the record's roofline step time."""
    from repro.profiler.traffic import (arch_task, arch_traces,
                                        step_time_estimate)
    rec = {"status": "ok",
           "cost": {"flops_per_device": 1e15, "bytes_per_device": 1e12},
           "collective_bytes_per_device": 1e10}
    traces = arch_traces("qwen3-8b", "decode_32k", rec=rec, n_bins=8)
    assert len(traces) == 1
    tr = traces[0]
    assert tr.phase == "decode" and tr.n_bins == 8
    t_step = step_time_estimate(rec)
    assert tr.duration_s == pytest.approx(4 * max(t_step, 1e-6))
    task = arch_task("qwen3-8b", "decode_32k", rec)
    n_slots = sum(len(lv.buckets) for lv in task.levels.values())
    assert tr.n_slots == n_slots
    np.testing.assert_allclose(tr.reads.sum(axis=1),
                               tr.f_req_hz * tr.duration_s, rtol=1e-9)
    trn = arch_traces("qwen3-8b", "train_4k", rec=rec, n_bins=8)[0]
    assert trn.phase == "train_step"


def test_available_arch_tasks_reports_missing(tmp_path):
    """Empty artifacts: the profiler must say WHAT is missing, not just
    return an empty list."""
    from repro.profiler.traffic import available_arch_tasks
    with pytest.warns(RuntimeWarning, match="dry-run"):
        tasks, missing = available_arch_tasks(
            outdir=str(tmp_path / "nowhere"), return_missing=True)
    assert tasks == []
    assert len(missing) > 0
    assert all(isinstance(a, str) and isinstance(s, str)
               for a, s in missing)
    # default return shape is unchanged for existing callers
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert available_arch_tasks(outdir=str(tmp_path / "nowhere")) == []
