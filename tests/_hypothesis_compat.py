"""Graceful degradation for the optional ``hypothesis`` dev dependency.

``from _hypothesis_compat import given, settings, st`` behaves exactly like
the real hypothesis import when it is installed; when it is not
(``pip install -e .[dev]`` adds it), ``@given(...)`` turns into a per-test
skip marker so the plain unit tests in the same module still run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for hypothesis.strategies: every strategy is a no-op."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        return pytest.mark.skip(
            reason="hypothesis not installed (pip install -e .[dev])")

    class settings:  # noqa: N801 - mirrors hypothesis.settings
        @staticmethod
        def register_profile(*_a, **_k):
            pass

        @staticmethod
        def load_profile(*_a, **_k):
            pass
