"""Graceful degradation for the optional ``hypothesis`` dev dependency.

``from _hypothesis_compat import given, settings, st`` behaves exactly like
the real hypothesis import when it is installed (``pip install -e .[dev]``
adds it). When it is not, ``@given(...)`` degrades to a *bounded-example*
runner instead of a skip: each supported strategy contributes a small
deterministic set of representative draws (endpoints + an interior point),
and the test body runs once per combination (capped). Property tests
therefore still exercise their invariants on every CI/dev box — hypothesis
only adds shrinking and randomized breadth on top.

Strategies the fallback understands: ``st.floats(min, max)``,
``st.integers(min, max)``, ``st.sampled_from(seq)``, ``st.booleans()``,
``st.just(x)``. A test using any *other* strategy skips (as before) rather
than running with made-up inputs.
"""
import inspect
import itertools

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    # hard cap on fallback combinations per test (full cross-products of
    # many-valued strategies would otherwise explode)
    _MAX_FALLBACK_EXAMPLES = 25

    class _Examples:
        """A bounded, deterministic stand-in for one hypothesis strategy."""

        def __init__(self, values):
            self.values = list(values)

    class _St:
        """Stands in for ``hypothesis.strategies``: known strategies return
        bounded example sets; unknown ones return None (-> skip)."""

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_k):
            lo, hi = float(min_value), float(max_value)
            return _Examples([lo, lo + 0.381966 * (hi - lo), hi])

        @staticmethod
        def integers(min_value=0, max_value=100, **_k):
            lo, hi = int(min_value), int(max_value)
            mid = (lo + hi) // 2
            return _Examples(sorted({lo, mid, hi}))

        @staticmethod
        def sampled_from(seq):
            return _Examples(seq)

        @staticmethod
        def booleans():
            return _Examples([False, True])

        @staticmethod
        def just(value):
            return _Examples([value])

        def __getattr__(self, name):
            return lambda *a, **k: None          # unsupported -> skip

    st = _St()

    def given(*arg_strats, **kw_strats):
        strats = list(arg_strats) + list(kw_strats.values())
        if not all(isinstance(s, _Examples) for s in strats):
            return pytest.mark.skip(
                reason="hypothesis not installed and no bounded-example "
                       "fallback for this strategy (pip install -e .[dev])")

        def deco(fn):
            names = list(kw_strats)

            def wrapper():
                combos = itertools.islice(
                    itertools.product(*(s.values for s in strats)),
                    _MAX_FALLBACK_EXAMPLES)
                for combo in combos:
                    pos = combo[:len(arg_strats)]
                    kw = dict(zip(names, combo[len(arg_strats):]))
                    fn(*pos, **kw)

            wrapper.__name__ = fn.__name__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            # hide the example parameters from pytest's fixture resolution
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco

    class settings:  # noqa: N801 - mirrors hypothesis.settings
        def __init__(self, *_a, **_k):
            pass

        def __call__(self, fn):                  # @settings(...) passthrough
            return fn

        @staticmethod
        def register_profile(*_a, **_k):
            pass

        @staticmethod
        def load_profile(*_a, **_k):
            pass
