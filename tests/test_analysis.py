"""The repro.analysis static analyzer: per-rule good/bad/suppressed fixtures
(tmp-tree projects for JP/US/BK, source overlays on the real repo for CK),
baseline + noqa mechanics, exit-code bitmask, docs checks, the CLI, and the
meta-test that the live codebase is clean against the committed baseline.

The two regression guards the issue names explicitly:

* a synthetic field added to ``OperatingPoint`` (the PR-5 bug class) must
  surface as CK01 because ``fingerprint()`` enumerates fields by hand;
* deleting the ``corners_fingerprint`` ingredient from ``api.grid_hash``
  must surface as CK02 + CK03 (the stated acceptance criterion).

The AST tier stays stdlib-only by design, so none of its tests import jax.
The semantic tier (PB/DT/RC, the final section of this file) is the
exception: those checkers trace jaxprs and execute jit sites, so their
tests import jax *inside the test bodies* — collecting this module still
works in a jax-free environment as long as only AST-tier tests run.
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (EXIT_BITS, FAMILIES, RULES, Baseline, Finding,
                            Project, run_analysis)
from repro.analysis import backend_cov, cache_keys, jit_purity, units
from repro.analysis import docs as docs_mod
from repro.analysis.__main__ import main
from repro.analysis.findings import is_suppressed, noqa_rules
from repro.analysis.rules import family_of

ROOT = Path(__file__).resolve().parents[1]


def _read(rel):
    return (ROOT / rel).read_text(encoding="utf-8")


def _overlay(rel, old, new):
    """Project over the real repo with one source mutation injected."""
    src = _read(rel)
    assert old in src, f"anchor drifted in {rel}: {old!r}"
    return Project(ROOT, overlay={rel: src.replace(old, new)})


def _write_tree(root, files):
    for rel, body in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(body), encoding="utf-8")
    return root


# ------------------------------------------------------------------ rules
def test_rule_catalog_shape():
    assert set(EXIT_BITS) == set(FAMILIES)
    # bits are distinct powers of two -> the exit code is a readable bitmask
    assert len({EXIT_BITS[f] for f in FAMILIES}) == len(FAMILIES)
    for rid, entry in RULES.items():
        assert family_of(rid) in FAMILIES, rid
        title, summary = entry
        assert title and summary


# ------------------------------------------------- CK: cache-key coverage
def test_ck_live_repo_clean():
    assert cache_keys.check(Project(ROOT)) == []


def test_ck01_new_operating_point_field_caught():
    """PR-5 bug class: OperatingPoint.fingerprint() enumerates its fields by
    hand, so a new physics knob silently reuses stale caches unless the
    analyzer catches the drift."""
    project = _overlay(
        "src/repro/core/corners.py",
        '    corner: str = "nominal"',
        '    corner: str = "nominal"\n    body_bias_v: float = 0.0')
    rules = {f.rule for f in cache_keys.check(project)}
    assert "CK01" in rules
    msgs = [f.message for f in cache_keys.check(project) if f.rule == "CK01"]
    assert any("body_bias_v" in m for m in msgs)


def test_ck_asdict_keyed_policy_field_is_covered():
    """report_key hashes dataclasses.asdict(policy) — full coverage — so a
    new SelectionPolicy field must NOT flag (no false positive)."""
    project = _overlay(
        "src/repro/core/select.py",
        "    allow_refresh: bool = False\n"
        "    refresh_power_frac: float = 0.1",
        "    allow_refresh: bool = False\n"
        "    refresh_power_frac: float = 0.1\n"
        "    synthetic_knob: float = 1.0")
    assert cache_keys.check(project) == []


def test_ck_grid_hash_corners_removal_caught():
    """Acceptance criterion: deleting the corners ingredient from
    api.grid_hash must be flagged."""
    project = _overlay(
        "src/repro/api.py",
        "    h.update(corners_mod.corners_fingerprint(\n"
        "        corners_mod.as_corners(corners)).encode())\n",
        "")
    found = cache_keys.check(project)
    rules = {f.rule for f in found}
    assert "CK03" in rules       # ingredient corners_fingerprint gone
    assert "CK02" in rules       # parameter `corners` now dead
    assert any("corners_fingerprint" in f.message for f in found)


def test_ck_exit_bit_through_runner():
    project = _overlay(
        "src/repro/core/corners.py",
        '    corner: str = "nominal"',
        '    corner: str = "nominal"\n    body_bias_v: float = 0.0')
    report = run_analysis(ROOT, checks=("CK",), project=project)
    assert report.exit_code == EXIT_BITS["CK"]


# ------------------------------------------------------ JP: jit purity
def _jp_root(tmp_path, body):
    return _write_tree(tmp_path, {
        "src/repro/core/toy.py": "import jax\nimport jax.numpy as jnp\n"
                                 + textwrap.dedent(body)})


def test_jp_clean_fixture(tmp_path):
    root = _jp_root(tmp_path, """
        def good(x):
            y = jnp.sum(x) * 2.0
            return jnp.where(y > 0, y, 0.0)

        good_jit = jax.jit(good)
        """)
    assert jit_purity.check(Project(root)) == []


def test_jp_bad_fixture_all_rules(tmp_path):
    root = _jp_root(tmp_path, """
        def bad(x, opts=[1, 2]):
            y = jnp.sum(x)
            if y > 0:
                z = y * 2
            print(x)
            v = y.item()
            return float(y) + v

        bad_jit = jax.jit(bad, static_argnums=(1,))
        """)
    found = jit_purity.check(Project(root))
    rules = sorted(f.rule for f in found)
    assert "JP01" in rules                   # print
    assert rules.count("JP02") == 2          # .item() and float(traced)
    assert "JP03" in rules                   # if on traced local
    assert "JP04" in rules                   # unhashable static default


def test_jp_unreachable_function_not_linted(tmp_path):
    """Only jit-reachable functions are linted — host-side helpers may
    print and sync freely."""
    root = _jp_root(tmp_path, """
        def host_only(x):
            print(x)
            return float(jnp.sum(x))
        """)
    assert jit_purity.check(Project(root)) == []


def test_jp_type_guard_branch_skipped(tmp_path):
    """isinstance/hasattr branches resolve at trace time — code inside them
    never sees a tracer and must not flag."""
    root = _jp_root(tmp_path, """
        def guarded(x, tp=None):
            if tp is None:
                tp = 1.0
            if isinstance(x, int):
                print("static path")
            return jnp.sum(x) * tp

        guarded_jit = jax.jit(guarded)
        """)
    assert jit_purity.check(Project(root)) == []


def test_jp_reachability_through_call_edges(tmp_path):
    """A violation inside a helper only called from a jitted function is
    still found (BFS over same-package call edges)."""
    root = _jp_root(tmp_path, """
        def helper(y):
            return y.item()

        def entry(x):
            return helper(jnp.sum(x))

        entry_jit = jax.jit(entry)
        """)
    found = jit_purity.check(Project(root))
    assert [f.rule for f in found] == ["JP02"]
    assert "helper" in found[0].message


def test_jp_noqa_suppression_via_runner(tmp_path):
    root = _jp_root(tmp_path, """
        def bad(x):
            return float(jnp.sum(x))  # noqa: JP02

        bad_jit = jax.jit(bad)
        """)
    report = run_analysis(root, checks=("JP",))
    assert report.findings == []
    assert [f.rule for f in report.suppressed] == ["JP02"]
    assert report.exit_code == 0


# ------------------------------------------------------ US: unit suffixes
def _us_root(tmp_path, body):
    # units.TARGETS is a fixed list of physics modules; plant the fixture at
    # one of those paths inside a tmp tree
    return _write_tree(tmp_path, {"src/repro/core/periphery.py":
                                  textwrap.dedent(body)})


def test_us_clean_fixture(tmp_path):
    root = _us_root(tmp_path, """
        C_GATE_PER_UM = 1e-15          # per-unit constant: never suffix-typed

        def stage(width_um, c_load_f, r_drv_ohm):
            area_um2 = width_um * width_um
            t_rc_s = r_drv_ohm * c_load_f
            f_max_hz = 1.0 / t_rc_s
            guard = width_um + 1e-9    # literal wildcard: no unit mix
            return area_um2, t_rc_s, f_max_hz, guard
        """)
    findings = [f for f in units.check(Project(root)) if f.rule != "US01"
                or "guard" not in f.snippet]
    assert [f for f in findings if f.rule in ("US02", "US03")] == []


def test_us_bad_fixture_all_rules(tmp_path):
    root = _us_root(tmp_path, """
        def stage(width_um, t_step_s):
            area = width_um * width_um       # US01: word prefix, no suffix
            t_bad_hz = t_step_s              # US03: suffix vs prefix/RHS
            mix_s = width_um + t_step_s      # US02: um + s
            return area, t_bad_hz, mix_s
        """)
    rules = {f.rule for f in units.check(Project(root))}
    assert {"US01", "US02", "US03"} <= rules


def test_us_inferable_rhs_triggers_us01(tmp_path):
    root = _us_root(tmp_path, """
        def stage(c_load_f, v_swing_v):
            charge = c_load_f * v_swing_v    # inferable coulombs-class unit
            return charge
        """)
    found = units.check(Project(root))
    assert any(f.rule == "US01" and "charge" in f.snippet for f in found)


def test_us_noqa_suppression_via_runner(tmp_path):
    root = _us_root(tmp_path, """
        def stage(width_um):
            area = width_um * width_um  # noqa: US01
            return area
        """)
    report = run_analysis(root, checks=("US",))
    assert report.findings == []
    assert [f.rule for f in report.suppressed] == ["US01"]


def test_us_live_targets_clean():
    assert units.check(Project(ROOT)) == []


# ------------------------------------------- BK: backend registry coverage
_BK_TREE = {
    "src/repro/kernels/toyops.py": """
        from repro.kernels.backend import register

        register("toy_full", tpu=None, interpret=None, xla=None)
        register("toy_naked", tpu=None)
        """,
    "src/repro/configs/models.py": """
        from repro.configs.base import register

        register("toy-model-7b")
        """,
    "tests/test_toy.py": """
        def test_toy_full():
            assert "toy_full"
        """,
}


def test_bk_rules_and_registry_scoping(tmp_path):
    """toy_naked: missing interpret (BK01), missing xla (BK02), untested
    (BK03). toy_full: fully covered. The model-config registry's register()
    is a different contract and must not flag."""
    root = _write_tree(tmp_path, _BK_TREE)
    found = backend_cov.check(Project(root))
    by_rule = {}
    for f in found:
        by_rule.setdefault(f.rule, []).append(f)
    assert set(by_rule) == {"BK01", "BK02", "BK03"}
    for fs in by_rule.values():
        assert len(fs) == 1 and "toy_naked" in fs[0].message
    assert all("configs" not in f.path for f in found)


def test_bk_live_repo_clean():
    assert backend_cov.check(Project(ROOT)) == []


# ------------------------------------------------------------ DC: docs
def test_dc_broken_link_and_anchor(tmp_path):
    _write_tree(tmp_path, {
        "docs/GOOD.md": """
            # Title

            ## Real Section

            [ok](GOOD.md#real-section) [also ok](BAD.md)
            """,
        "docs/BAD.md": """
            [gone](NOPE.md) and [bad anchor](GOOD.md#no-such-section)
            """,
    })
    found = docs_mod.check_links(tmp_path, files=["docs/GOOD.md",
                                                  "docs/BAD.md"])
    rules = sorted(d["rule"] for d in found)
    assert rules == ["DC01", "DC02"]
    assert all(d["path"] == "docs/BAD.md" for d in found)


def test_dc_rule_catalog_must_document_every_rule(tmp_path):
    _write_tree(tmp_path, {"docs/ANALYSIS.md": "only CK01 is described\n"})
    found = docs_mod.check_rule_docs(tmp_path, ["CK01", "US01"])
    assert [d["rule"] for d in found] == ["DC03"]
    assert "US01" in found[0]["message"]


def test_dc_live_docs_clean():
    report = run_analysis(ROOT, checks=(), with_docs=True)
    assert report.findings == [], report.format_text()


# ------------------------------------------------- baseline + noqa mechanics
def test_noqa_parsing():
    assert noqa_rules("x = 1") is None
    assert noqa_rules("x = 1  # noqa") == frozenset()
    assert noqa_rules("x = 1  # noqa: US01") == {"US01"}
    assert noqa_rules("x = 1  # NOQA: us01, jp02") == {"US01", "JP02"}
    f = Finding("US01", "a.py", 1, "m")
    assert is_suppressed(f, "x  # noqa")
    assert is_suppressed(f, "x  # noqa: US01,CK02")
    assert not is_suppressed(f, "x  # noqa: CK02")
    assert not is_suppressed(f, "x")


def test_baseline_roundtrip_and_snippet_matching(tmp_path):
    f1 = Finding("US01", "src/a.py", 10, "msg", snippet="area = w * w")
    f2 = Finding("JP02", "src/b.py", 3, "msg", snippet="v = y.item()")
    path = tmp_path / "baseline.json"
    Baseline.write(path, [f1], {f1.key(): "deliberate: legacy name"})
    b = Baseline.load(path)
    assert b.entries[0]["justification"] == "deliberate: legacy name"

    # snippet-matched: the same finding at a shifted line still matches...
    shifted = Finding("US01", "src/a.py", 99, "msg", snippet="area = w * w")
    active, baselined = b.split([shifted, f2])
    assert active == [f2] and baselined == [shifted]
    # ...an edited line does not (resurfaces for re-review)
    edited = Finding("US01", "src/a.py", 10, "msg", snippet="area = w * h")
    assert b.split([edited])[0] == [edited]
    # entries matching nothing are reported stale
    assert b.stale_entries([f2]) == b.entries


def test_baseline_missing_file_is_empty(tmp_path):
    b = Baseline.load(tmp_path / "nope.json")
    f = Finding("US01", "a.py", 1, "m")
    assert b.split([f]) == ([f], [])
    assert b.stale_entries([]) == []


def test_exit_code_bitmask_composes(tmp_path):
    root = _write_tree(tmp_path, {
        "src/repro/core/toy.py": """
            import jax
            import jax.numpy as jnp

            def bad(x):
                return float(jnp.sum(x))

            bad_jit = jax.jit(bad)
            """,
        "src/repro/core/periphery.py": """
            def stage(width_um):
                area = width_um * width_um
                return area
            """,
    })
    report = run_analysis(root, checks=("JP", "US"))
    assert report.exit_code == EXIT_BITS["JP"] | EXIT_BITS["US"]


# ------------------------------------------------------------------- CLI
def test_cli_json_live_repo_clean(tmp_path, capsys):
    out = tmp_path / "report.json"
    code = main(["--root", str(ROOT), "--docs", "--format=json",
                 "--out", str(out)])
    assert code == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["exit_code"] == 0
    assert payload["counts"]["active"] == 0
    # --out writes the same report for the CI artifact
    assert json.loads(out.read_text())["counts"] == payload["counts"]


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in RULES:
        assert rid in out


def test_cli_nonzero_on_violations_and_write_baseline(tmp_path, capsys):
    root = _write_tree(tmp_path, {"src/repro/core/periphery.py": """
        def stage(width_um):
            area = width_um * width_um
            return area
        """})
    code = main(["--root", str(root), "--rules", "US"])
    capsys.readouterr()
    assert code == EXIT_BITS["US"]
    # snapshotting the findings into the baseline makes the run clean
    assert main(["--root", str(root), "--rules", "US",
                 "--write-baseline"]) == 0
    capsys.readouterr()
    assert main(["--root", str(root), "--rules", "US"]) == 0


def test_cli_rejects_unknown_family():
    with pytest.raises(SystemExit):
        main(["--rules", "ZZ"])


# ------------------------------------------------------------- meta-test
def test_live_repo_clean_against_committed_baseline():
    """The whole analyzer over the real tree: zero active findings against
    the committed baseline, no stale baseline entries."""
    report = run_analysis(ROOT, with_docs=True)
    assert report.findings == [], report.format_text()
    assert report.exit_code == 0
    assert report.stale_baseline == []


# --------------------------------------------------------- prune-baseline
def test_cli_prune_baseline_drops_only_families_that_ran(tmp_path, capsys):
    root = _write_tree(tmp_path, {"src/repro/core/periphery.py": """
        def stage(width):
            return width
        """})
    # a baseline with one stale US entry and one PB entry the US-only run
    # never re-checks
    baseline = root / "analysis_baseline.json"
    baseline.write_text(json.dumps({"entries": [
        {"rule": "US01", "path": "src/repro/core/periphery.py",
         "snippet": "gone = 1", "justification": "stale"},
        {"rule": "PB01", "path": "src/repro/kernels/x.py",
         "snippet": "whatever", "justification": "not re-checked"},
    ]}), encoding="utf-8")
    assert main(["--root", str(root), "--rules", "US",
                 "--prune-baseline"]) == 0
    out = capsys.readouterr().out
    assert "pruned 1 stale" in out
    kept = json.loads(baseline.read_text())["entries"]
    # the US entry is gone, the PB entry survived (its family never ran)
    assert [e["rule"] for e in kept] == ["PB01"]


def test_prune_baseline_keeps_matching_entries(tmp_path, capsys):
    root = _write_tree(tmp_path, {"src/repro/core/periphery.py": """
        def stage(width):
            area = width * width
            return area
        """})
    assert main(["--root", str(root), "--rules", "US",
                 "--write-baseline"]) == 0
    capsys.readouterr()
    assert main(["--root", str(root), "--rules", "US",
                 "--prune-baseline"]) == 0
    assert "pruned 0 stale" in capsys.readouterr().out
    entries = json.loads(
        (root / "analysis_baseline.json").read_text())["entries"]
    assert entries, "live-matching entries must survive a prune"
    assert main(["--root", str(root), "--rules", "US"]) == 0


# --------------------------------------------------- semantic tier: PB
# These tests import jax (inside the bodies); see the module docstring.

def test_pb_live_kernels_prove_clean_on_all_profiles():
    """The tentpole acceptance: every tpu-registered op proves PB01-PB04 on
    at least 3 representative config shapes, zero findings."""
    from repro.analysis.semantic import pb
    findings, stats = pb.verify_all(Project(ROOT))
    assert findings == [], [f.format() for f in findings]
    assert set(stats) == {"attention", "ssm_scan", "retention"}
    for op, clean in stats.items():
        assert clean >= 3, f"{op}: only {clean} profiles proved clean"


def test_pb03_injected_output_race_caught():
    """Collapsing ssm_scan's output d-index onto block 0 makes every
    parallel d-step write the same block: PB03 (+PB02, the other blocks are
    never written)."""
    from repro.analysis.semantic import pb
    project = _overlay(
        "src/repro/kernels/ssm_scan.py",
        "out_specs=pl.BlockSpec((1, chunk, block_d), "
        "lambda b, d, c: (b, c, d)),",
        "out_specs=pl.BlockSpec((1, chunk, block_d), "
        "lambda b, d, c: (b, c, 0)),")
    rules = {f.rule for f in pb.check(project)}
    assert "PB03" in rules and "PB02" in rules, rules


def test_pb01_injected_out_of_bounds_caught():
    """Shifting flash attention's q index by one block walks off the end of
    the operand on the last grid row: PB01."""
    from repro.analysis.semantic import pb
    project = _overlay(
        "src/repro/kernels/flash_attention.py",
        "in_specs=[\n"
        "            pl.BlockSpec((1, block_q, D), "
        "lambda b, i, j: (b, i, 0)),",
        "in_specs=[\n"
        "            pl.BlockSpec((1, block_q, D), "
        "lambda b, i, j: (b, i + 1, 0)),")
    rules = {f.rule for f in pb.check(project)}
    assert "PB01" in rules, rules


def test_pb04_injected_axis_order_swap_caught():
    """Un-permuting ssm_scan's output map to (b, d, c) sends the d axis
    (many blocks) through the chunk dimension (few blocks): PB04."""
    from repro.analysis.semantic import pb
    project = _overlay(
        "src/repro/kernels/ssm_scan.py",
        "out_specs=pl.BlockSpec((1, chunk, block_d), "
        "lambda b, d, c: (b, c, d)),",
        "out_specs=pl.BlockSpec((1, chunk, block_d), "
        "lambda b, d, c: (b, d, c)),")
    rules = {f.rule for f in pb.check(project)}
    assert "PB04" in rules, rules


def test_pb_ssm_grid_ordering_is_intentional_and_locked():
    """ssm_scan's grid is (b, d, c) while its x/y index maps emit
    (b, c, d) — verify on a live capture that this permutation is the
    consistent identity {b->0, d->2, c->1}, so a future 'simplification'
    back to (b, d, c) trips PB04/PB01 instead of silently corrupting."""
    import jax.numpy as jnp
    from repro.analysis.semantic import capture, pb
    from repro.kernels.ssm_scan import ssm_scan_pallas
    B, S, di, n = 2, 128, 256, 8
    x = jnp.zeros((B, S, di), jnp.float32)
    bc = jnp.zeros((B, S, n), jnp.float32)
    with capture.intercept_pallas(ROOT) as caps:
        ssm_scan_pallas(x, x, jnp.zeros((di, n)), bc, bc, jnp.zeros((di,)),
                        block_d=128, chunk=64)
    (cap,) = caps
    assert cap.grid == (B, di // 128, S // 64)
    assert pb.identity_map(cap.out_specs.index_map, cap.grid) == \
        {0: 0, 1: 2, 2: 1}
    assert cap.dimension_semantics == ("parallel", "parallel", "arbitrary")
    assert pb.verify_capture(cap) == []


def test_pb05_unprofiled_tpu_op_caught(monkeypatch):
    from repro.analysis.semantic import pb
    monkeypatch.setattr(
        pb, "KERNEL_SPECS",
        {k: v for k, v in pb.KERNEL_SPECS.items() if k != "attention"})
    findings, stats = pb.verify_all(Project(ROOT))
    assert any(f.rule == "PB05" and "attention" in f.message
               for f in findings)
    assert "attention" not in stats


# --------------------------------------------------- semantic tier: DT
def test_dt_live_entry_points_clean():
    from repro.analysis.semantic import dt
    findings = dt.check(Project(ROOT))
    assert findings == [], [f.format() for f in findings]


def test_dt01_flags_off_policy_dtype():
    import jax.numpy as jnp
    from repro.analysis.semantic import dt
    issues = dt.audit_callable(
        "fixture", lambda x: jnp.sum(x.astype(jnp.float16)),
        (jnp.ones((4,), jnp.float32),))
    assert [i["rule"] for i in issues] == ["DT01"]
    assert "float16" in issues[0]["message"]


def test_dt02_flags_weak_typed_output():
    import jax.numpy as jnp
    from repro.analysis.semantic import dt
    issues = dt.audit_callable(
        "fixture", lambda x: x * 2.0 + 0.0, (3.0,))
    assert any(i["rule"] == "DT02" for i in issues)
    # anchoring the dtype kills the weak type: clean
    fixed = dt.audit_callable(
        "fixture", lambda x: jnp.float32(x) * 2.0,
        (jnp.float32(3.0),))
    assert fixed == []


def test_dt03_flags_narrow_int_accumulation():
    import jax.numpy as jnp
    from repro.analysis.semantic import dt
    issues = dt.audit_callable(
        "fixture", lambda x: jnp.cumsum(x), (jnp.ones((8,), jnp.int16),))
    assert any(i["rule"] == "DT03" for i in issues)


def test_dt04_spec_rot_on_missing_attr(monkeypatch):
    from repro.analysis.semantic import dt
    rotted = dt.DtEntry("ghost", "src/repro/core/characterize.py",
                        "no_such_attr", lambda: ((), {}))
    monkeypatch.setattr(dt, "ENTRIES", (rotted,))
    findings = dt.check(Project(ROOT))
    assert [f.rule for f in findings] == ["DT04"]
    assert "ghost" in findings[0].message


# --------------------------------------------------- semantic tier: RC
def test_rc_budgets_hold_and_repeat_drives_hit_cache():
    """Every budgeted site compiles within budget and a second identical
    drive adds nothing (the deltas measure OUR drives, so this is stable in
    a shared pytest process)."""
    from repro.analysis.semantic import rc
    deltas, broken, errors = rc.audit_sites()
    assert broken == [] and errors == []
    assert set(deltas) == {s.name for s in rc.SITES}
    for site in rc.SITES:
        d1, d2 = deltas[site.name]
        assert d1 <= site.budget, (site.name, d1, site.budget)
        assert d2 == 0, (site.name, d2)


def test_rc01_rc02_fire_on_synthetic_cache_leak(monkeypatch):
    import jax
    import jax.numpy as jnp
    from repro.analysis.semantic import rc

    leaky = jax.jit(lambda x, n: x * n, static_argnums=(1,))
    calls = {"n": 0}

    def drive():
        # a fresh static arg every call: grows the cache on EVERY drive,
        # which is both over-budget (RC01) and repeat-unstable (RC02)
        for _ in range(2):
            calls["n"] += 1
            leaky(jnp.ones(3), calls["n"])

    site = rc.RcSite("leaky", "src/repro/core/characterize.py",
                     "characterize_batch", 1)
    monkeypatch.setattr(rc, "_resolve", lambda s: leaky)
    deltas, broken, errors = rc.audit_sites(sites=(site,), drivers=(drive,))
    assert broken == [] and errors == []
    d1, d2 = deltas["leaky"]
    assert d1 > site.budget      # RC01 condition
    assert d2 > 0                # RC02 condition
    monkeypatch.setattr(rc, "SITES", (site,))
    monkeypatch.setattr(rc, "DRIVERS", (drive,))
    monkeypatch.setattr(rc, "audit_sites",
                        lambda: ({"leaky": (d1, d2)}, [], []))
    rules = [f.rule for f in rc.check(Project(ROOT))
             if f.rule in ("RC01", "RC02")]
    assert rules == ["RC01", "RC02"]


def test_rc03_overlay_jit_site_without_budget_caught(monkeypatch):
    from repro.analysis.semantic import rc
    project = Project(ROOT, overlay={
        "src/repro/core/_rc_probe.py":
            "import jax\n\nprobe = jax.jit(lambda x: x)\n"})
    sites = rc._jit_sites_in_tree(project)
    assert ("src/repro/core/_rc_probe.py", "probe", 3) in sites
    # through the checker (drives stubbed out: RC03 is pure AST)
    monkeypatch.setattr(rc, "audit_sites", lambda: ({}, [], []))
    findings = [f for f in rc.check(project) if f.rule == "RC03"]
    assert len(findings) == 1
    assert findings[0].path == "src/repro/core/_rc_probe.py"
    assert "probe" in findings[0].message


def test_rc04_spec_rot_on_missing_attr(monkeypatch):
    from repro.analysis.semantic import rc
    ghost = rc.RcSite("ghost", "src/repro/core/characterize.py",
                      "no_such_attr", 1)
    monkeypatch.setattr(rc, "SITES", (ghost,))
    monkeypatch.setattr(rc, "DRIVERS", ())
    findings = [f for f in rc.check(Project(ROOT)) if f.rule == "RC04"]
    assert len(findings) == 1 and "ghost" in findings[0].message


# ------------------------------------------- semantic tier: runner/CLI
def test_runner_semantic_families_lazy_and_reported():
    from repro.analysis.runner import SEMANTIC_FAMILIES
    assert SEMANTIC_FAMILIES == ("PB", "DT", "RC")
    # AST-only runs never touch (or report) the semantic families
    report = run_analysis(ROOT, checks=("US",))
    assert report.families_run == ("US",)


def test_exit_bits_cover_semantic_families():
    assert EXIT_BITS["PB"] == 32
    assert EXIT_BITS["DT"] == 64
    assert EXIT_BITS["RC"] == 128
