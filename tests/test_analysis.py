"""The repro.analysis static analyzer: per-rule good/bad/suppressed fixtures
(tmp-tree projects for JP/US/BK, source overlays on the real repo for CK),
baseline + noqa mechanics, exit-code bitmask, docs checks, the CLI, and the
meta-test that the live codebase is clean against the committed baseline.

The two regression guards the issue names explicitly:

* a synthetic field added to ``OperatingPoint`` (the PR-5 bug class) must
  surface as CK01 because ``fingerprint()`` enumerates fields by hand;
* deleting the ``corners_fingerprint`` ingredient from ``api.grid_hash``
  must surface as CK02 + CK03 (the stated acceptance criterion).

No jax import anywhere here — the analyzer is stdlib-only by design.
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (EXIT_BITS, FAMILIES, RULES, Baseline, Finding,
                            Project, run_analysis)
from repro.analysis import backend_cov, cache_keys, jit_purity, units
from repro.analysis import docs as docs_mod
from repro.analysis.__main__ import main
from repro.analysis.findings import is_suppressed, noqa_rules
from repro.analysis.rules import family_of

ROOT = Path(__file__).resolve().parents[1]


def _read(rel):
    return (ROOT / rel).read_text(encoding="utf-8")


def _overlay(rel, old, new):
    """Project over the real repo with one source mutation injected."""
    src = _read(rel)
    assert old in src, f"anchor drifted in {rel}: {old!r}"
    return Project(ROOT, overlay={rel: src.replace(old, new)})


def _write_tree(root, files):
    for rel, body in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(body), encoding="utf-8")
    return root


# ------------------------------------------------------------------ rules
def test_rule_catalog_shape():
    assert set(EXIT_BITS) == set(FAMILIES)
    # bits are distinct powers of two -> the exit code is a readable bitmask
    assert len({EXIT_BITS[f] for f in FAMILIES}) == len(FAMILIES)
    for rid, entry in RULES.items():
        assert family_of(rid) in FAMILIES, rid
        title, summary = entry
        assert title and summary


# ------------------------------------------------- CK: cache-key coverage
def test_ck_live_repo_clean():
    assert cache_keys.check(Project(ROOT)) == []


def test_ck01_new_operating_point_field_caught():
    """PR-5 bug class: OperatingPoint.fingerprint() enumerates its fields by
    hand, so a new physics knob silently reuses stale caches unless the
    analyzer catches the drift."""
    project = _overlay(
        "src/repro/core/corners.py",
        '    corner: str = "nominal"',
        '    corner: str = "nominal"\n    body_bias_v: float = 0.0')
    rules = {f.rule for f in cache_keys.check(project)}
    assert "CK01" in rules
    msgs = [f.message for f in cache_keys.check(project) if f.rule == "CK01"]
    assert any("body_bias_v" in m for m in msgs)


def test_ck_asdict_keyed_policy_field_is_covered():
    """report_key hashes dataclasses.asdict(policy) — full coverage — so a
    new SelectionPolicy field must NOT flag (no false positive)."""
    project = _overlay(
        "src/repro/core/select.py",
        "    allow_refresh: bool = False\n"
        "    refresh_power_frac: float = 0.1",
        "    allow_refresh: bool = False\n"
        "    refresh_power_frac: float = 0.1\n"
        "    synthetic_knob: float = 1.0")
    assert cache_keys.check(project) == []


def test_ck_grid_hash_corners_removal_caught():
    """Acceptance criterion: deleting the corners ingredient from
    api.grid_hash must be flagged."""
    project = _overlay(
        "src/repro/api.py",
        "    h.update(corners_mod.corners_fingerprint(\n"
        "        corners_mod.as_corners(corners)).encode())\n",
        "")
    found = cache_keys.check(project)
    rules = {f.rule for f in found}
    assert "CK03" in rules       # ingredient corners_fingerprint gone
    assert "CK02" in rules       # parameter `corners` now dead
    assert any("corners_fingerprint" in f.message for f in found)


def test_ck_exit_bit_through_runner():
    project = _overlay(
        "src/repro/core/corners.py",
        '    corner: str = "nominal"',
        '    corner: str = "nominal"\n    body_bias_v: float = 0.0')
    report = run_analysis(ROOT, checks=("CK",), project=project)
    assert report.exit_code == EXIT_BITS["CK"]


# ------------------------------------------------------ JP: jit purity
def _jp_root(tmp_path, body):
    return _write_tree(tmp_path, {
        "src/repro/core/toy.py": "import jax\nimport jax.numpy as jnp\n"
                                 + textwrap.dedent(body)})


def test_jp_clean_fixture(tmp_path):
    root = _jp_root(tmp_path, """
        def good(x):
            y = jnp.sum(x) * 2.0
            return jnp.where(y > 0, y, 0.0)

        good_jit = jax.jit(good)
        """)
    assert jit_purity.check(Project(root)) == []


def test_jp_bad_fixture_all_rules(tmp_path):
    root = _jp_root(tmp_path, """
        def bad(x, opts=[1, 2]):
            y = jnp.sum(x)
            if y > 0:
                z = y * 2
            print(x)
            v = y.item()
            return float(y) + v

        bad_jit = jax.jit(bad, static_argnums=(1,))
        """)
    found = jit_purity.check(Project(root))
    rules = sorted(f.rule for f in found)
    assert "JP01" in rules                   # print
    assert rules.count("JP02") == 2          # .item() and float(traced)
    assert "JP03" in rules                   # if on traced local
    assert "JP04" in rules                   # unhashable static default


def test_jp_unreachable_function_not_linted(tmp_path):
    """Only jit-reachable functions are linted — host-side helpers may
    print and sync freely."""
    root = _jp_root(tmp_path, """
        def host_only(x):
            print(x)
            return float(jnp.sum(x))
        """)
    assert jit_purity.check(Project(root)) == []


def test_jp_type_guard_branch_skipped(tmp_path):
    """isinstance/hasattr branches resolve at trace time — code inside them
    never sees a tracer and must not flag."""
    root = _jp_root(tmp_path, """
        def guarded(x, tp=None):
            if tp is None:
                tp = 1.0
            if isinstance(x, int):
                print("static path")
            return jnp.sum(x) * tp

        guarded_jit = jax.jit(guarded)
        """)
    assert jit_purity.check(Project(root)) == []


def test_jp_reachability_through_call_edges(tmp_path):
    """A violation inside a helper only called from a jitted function is
    still found (BFS over same-package call edges)."""
    root = _jp_root(tmp_path, """
        def helper(y):
            return y.item()

        def entry(x):
            return helper(jnp.sum(x))

        entry_jit = jax.jit(entry)
        """)
    found = jit_purity.check(Project(root))
    assert [f.rule for f in found] == ["JP02"]
    assert "helper" in found[0].message


def test_jp_noqa_suppression_via_runner(tmp_path):
    root = _jp_root(tmp_path, """
        def bad(x):
            return float(jnp.sum(x))  # noqa: JP02

        bad_jit = jax.jit(bad)
        """)
    report = run_analysis(root, checks=("JP",))
    assert report.findings == []
    assert [f.rule for f in report.suppressed] == ["JP02"]
    assert report.exit_code == 0


# ------------------------------------------------------ US: unit suffixes
def _us_root(tmp_path, body):
    # units.TARGETS is a fixed list of physics modules; plant the fixture at
    # one of those paths inside a tmp tree
    return _write_tree(tmp_path, {"src/repro/core/periphery.py":
                                  textwrap.dedent(body)})


def test_us_clean_fixture(tmp_path):
    root = _us_root(tmp_path, """
        C_GATE_PER_UM = 1e-15          # per-unit constant: never suffix-typed

        def stage(width_um, c_load_f, r_drv_ohm):
            area_um2 = width_um * width_um
            t_rc_s = r_drv_ohm * c_load_f
            f_max_hz = 1.0 / t_rc_s
            guard = width_um + 1e-9    # literal wildcard: no unit mix
            return area_um2, t_rc_s, f_max_hz, guard
        """)
    findings = [f for f in units.check(Project(root)) if f.rule != "US01"
                or "guard" not in f.snippet]
    assert [f for f in findings if f.rule in ("US02", "US03")] == []


def test_us_bad_fixture_all_rules(tmp_path):
    root = _us_root(tmp_path, """
        def stage(width_um, t_step_s):
            area = width_um * width_um       # US01: word prefix, no suffix
            t_bad_hz = t_step_s              # US03: suffix vs prefix/RHS
            mix_s = width_um + t_step_s      # US02: um + s
            return area, t_bad_hz, mix_s
        """)
    rules = {f.rule for f in units.check(Project(root))}
    assert {"US01", "US02", "US03"} <= rules


def test_us_inferable_rhs_triggers_us01(tmp_path):
    root = _us_root(tmp_path, """
        def stage(c_load_f, v_swing_v):
            charge = c_load_f * v_swing_v    # inferable coulombs-class unit
            return charge
        """)
    found = units.check(Project(root))
    assert any(f.rule == "US01" and "charge" in f.snippet for f in found)


def test_us_noqa_suppression_via_runner(tmp_path):
    root = _us_root(tmp_path, """
        def stage(width_um):
            area = width_um * width_um  # noqa: US01
            return area
        """)
    report = run_analysis(root, checks=("US",))
    assert report.findings == []
    assert [f.rule for f in report.suppressed] == ["US01"]


def test_us_live_targets_clean():
    assert units.check(Project(ROOT)) == []


# ------------------------------------------- BK: backend registry coverage
_BK_TREE = {
    "src/repro/kernels/toyops.py": """
        from repro.kernels.backend import register

        register("toy_full", tpu=None, interpret=None, xla=None)
        register("toy_naked", tpu=None)
        """,
    "src/repro/configs/models.py": """
        from repro.configs.base import register

        register("toy-model-7b")
        """,
    "tests/test_toy.py": """
        def test_toy_full():
            assert "toy_full"
        """,
}


def test_bk_rules_and_registry_scoping(tmp_path):
    """toy_naked: missing interpret (BK01), missing xla (BK02), untested
    (BK03). toy_full: fully covered. The model-config registry's register()
    is a different contract and must not flag."""
    root = _write_tree(tmp_path, _BK_TREE)
    found = backend_cov.check(Project(root))
    by_rule = {}
    for f in found:
        by_rule.setdefault(f.rule, []).append(f)
    assert set(by_rule) == {"BK01", "BK02", "BK03"}
    for fs in by_rule.values():
        assert len(fs) == 1 and "toy_naked" in fs[0].message
    assert all("configs" not in f.path for f in found)


def test_bk_live_repo_clean():
    assert backend_cov.check(Project(ROOT)) == []


# ------------------------------------------------------------ DC: docs
def test_dc_broken_link_and_anchor(tmp_path):
    _write_tree(tmp_path, {
        "docs/GOOD.md": """
            # Title

            ## Real Section

            [ok](GOOD.md#real-section) [also ok](BAD.md)
            """,
        "docs/BAD.md": """
            [gone](NOPE.md) and [bad anchor](GOOD.md#no-such-section)
            """,
    })
    found = docs_mod.check_links(tmp_path, files=["docs/GOOD.md",
                                                  "docs/BAD.md"])
    rules = sorted(d["rule"] for d in found)
    assert rules == ["DC01", "DC02"]
    assert all(d["path"] == "docs/BAD.md" for d in found)


def test_dc_rule_catalog_must_document_every_rule(tmp_path):
    _write_tree(tmp_path, {"docs/ANALYSIS.md": "only CK01 is described\n"})
    found = docs_mod.check_rule_docs(tmp_path, ["CK01", "US01"])
    assert [d["rule"] for d in found] == ["DC03"]
    assert "US01" in found[0]["message"]


def test_dc_live_docs_clean():
    report = run_analysis(ROOT, checks=(), with_docs=True)
    assert report.findings == [], report.format_text()


# ------------------------------------------------- baseline + noqa mechanics
def test_noqa_parsing():
    assert noqa_rules("x = 1") is None
    assert noqa_rules("x = 1  # noqa") == frozenset()
    assert noqa_rules("x = 1  # noqa: US01") == {"US01"}
    assert noqa_rules("x = 1  # NOQA: us01, jp02") == {"US01", "JP02"}
    f = Finding("US01", "a.py", 1, "m")
    assert is_suppressed(f, "x  # noqa")
    assert is_suppressed(f, "x  # noqa: US01,CK02")
    assert not is_suppressed(f, "x  # noqa: CK02")
    assert not is_suppressed(f, "x")


def test_baseline_roundtrip_and_snippet_matching(tmp_path):
    f1 = Finding("US01", "src/a.py", 10, "msg", snippet="area = w * w")
    f2 = Finding("JP02", "src/b.py", 3, "msg", snippet="v = y.item()")
    path = tmp_path / "baseline.json"
    Baseline.write(path, [f1], {f1.key(): "deliberate: legacy name"})
    b = Baseline.load(path)
    assert b.entries[0]["justification"] == "deliberate: legacy name"

    # snippet-matched: the same finding at a shifted line still matches...
    shifted = Finding("US01", "src/a.py", 99, "msg", snippet="area = w * w")
    active, baselined = b.split([shifted, f2])
    assert active == [f2] and baselined == [shifted]
    # ...an edited line does not (resurfaces for re-review)
    edited = Finding("US01", "src/a.py", 10, "msg", snippet="area = w * h")
    assert b.split([edited])[0] == [edited]
    # entries matching nothing are reported stale
    assert b.stale_entries([f2]) == b.entries


def test_baseline_missing_file_is_empty(tmp_path):
    b = Baseline.load(tmp_path / "nope.json")
    f = Finding("US01", "a.py", 1, "m")
    assert b.split([f]) == ([f], [])
    assert b.stale_entries([]) == []


def test_exit_code_bitmask_composes(tmp_path):
    root = _write_tree(tmp_path, {
        "src/repro/core/toy.py": """
            import jax
            import jax.numpy as jnp

            def bad(x):
                return float(jnp.sum(x))

            bad_jit = jax.jit(bad)
            """,
        "src/repro/core/periphery.py": """
            def stage(width_um):
                area = width_um * width_um
                return area
            """,
    })
    report = run_analysis(root, checks=("JP", "US"))
    assert report.exit_code == EXIT_BITS["JP"] | EXIT_BITS["US"]


# ------------------------------------------------------------------- CLI
def test_cli_json_live_repo_clean(tmp_path, capsys):
    out = tmp_path / "report.json"
    code = main(["--root", str(ROOT), "--docs", "--format=json",
                 "--out", str(out)])
    assert code == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["exit_code"] == 0
    assert payload["counts"]["active"] == 0
    # --out writes the same report for the CI artifact
    assert json.loads(out.read_text())["counts"] == payload["counts"]


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in RULES:
        assert rid in out


def test_cli_nonzero_on_violations_and_write_baseline(tmp_path, capsys):
    root = _write_tree(tmp_path, {"src/repro/core/periphery.py": """
        def stage(width_um):
            area = width_um * width_um
            return area
        """})
    code = main(["--root", str(root), "--rules", "US"])
    capsys.readouterr()
    assert code == EXIT_BITS["US"]
    # snapshotting the findings into the baseline makes the run clean
    assert main(["--root", str(root), "--rules", "US",
                 "--write-baseline"]) == 0
    capsys.readouterr()
    assert main(["--root", str(root), "--rules", "US"]) == 0


def test_cli_rejects_unknown_family():
    with pytest.raises(SystemExit):
        main(["--rules", "ZZ"])


# ------------------------------------------------------------- meta-test
def test_live_repo_clean_against_committed_baseline():
    """The whole analyzer over the real tree: zero active findings against
    the committed baseline, no stale baseline entries."""
    report = run_analysis(ROOT, with_docs=True)
    assert report.findings == [], report.format_text()
    assert report.exit_code == 0
    assert report.stale_baseline == []
