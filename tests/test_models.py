"""Per-architecture smoke + decode-consistency tests (reduced configs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, reduce_config
from repro.models import LM


def make_batch(cfg, rng, B=2, S=32):
    if cfg.audio_codebooks:
        return {"codes": rng.integers(0, cfg.vocab_size,
                                      (B, cfg.audio_codebooks, S)).astype(np.int32),
                "cond": rng.normal(size=(B, cfg.cond_len, cfg.cond_dim)).astype(np.float32)}
    if cfg.vision:
        return {"tokens": rng.integers(0, cfg.vocab_size, (B, S - cfg.num_patches)).astype(np.int32),
                "patches": rng.normal(size=(B, cfg.num_patches, cfg.vision_dim)).astype(np.float32)}
    if cfg.meta_tokens:
        return {"tokens": rng.integers(0, cfg.vocab_size, (B, S - cfg.meta_tokens)).astype(np.int32)}
    return {"tokens": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_loss_and_decode(arch):
    """One forward/loss + prefill + decode step on a reduced config: output
    shapes correct, no NaNs."""
    cfg = reduce_config(get_config(arch))
    lm = LM(cfg)
    rng = np.random.default_rng(0)
    params = lm.init(jax.random.key(0))
    batch = make_batch(cfg, rng)
    loss, metrics = jax.jit(lm.loss)(params, batch)
    assert jnp.isfinite(loss), f"{arch} loss not finite"
    assert 1.0 < float(loss) < 20.0
    cache, logits = jax.jit(lambda p, b: lm.prefill(p, b, max_seq=48))(params, batch)
    if cfg.audio_codebooks:
        assert logits.shape == (2, cfg.audio_codebooks, cfg.vocab_size)
        dec = {"tokens": np.zeros((2, cfg.audio_codebooks), np.int32),
               "cond": batch["cond"]}
    else:
        assert logits.shape == (2, cfg.vocab_size)
        dec = {"tokens": np.zeros((2,), np.int32)}
    logits2, cache2 = jax.jit(lm.decode)(params, cache, dec)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["qwen3-8b", "granite-34b", "hymba-1.5b",
                                  "xlstm-125m", "deepseek-v3-671b",
                                  "moonshot-v1-16b-a3b", "musicgen-medium",
                                  "phi-3-vision-4.2b"])
def test_decode_matches_prefill(arch):
    """Cache correctness: prefill(prefix) + N decode steps must produce the
    same final logits as prefill(full sequence). Exercises full KV, MLA
    latent, SWA ring (with wraparound), SSM and xLSTM state caches."""
    cfg = reduce_config(get_config(arch))
    lm = LM(cfg)
    rng = np.random.default_rng(1)
    B, S0, N = 2, 16, 8
    full = make_batch(cfg, rng, B=B, S=(S0 + N + cfg.meta_tokens
                                        + (cfg.num_patches if cfg.vision else 0)))

    def prefix_of(b, n):
        out = {}
        for k, v in b.items():
            if k == "tokens":
                out[k] = v[:, :n]
            elif k == "codes":
                out[k] = v[:, :, :n]
            else:
                out[k] = v
        return out

    max_seq = S0 + N + 4
    prefill = jax.jit(lambda p, b: lm.prefill(p, b, max_seq=max_seq))
    decode = jax.jit(lm.decode)
    params = lm.init(jax.random.key(0))

    cache, logits = prefill(params, prefix_of(full, S0))
    for t in range(S0, S0 + N):
        if cfg.audio_codebooks:
            dec = {"tokens": full["codes"][:, :, t], "cond": full["cond"]}
        else:
            dec = {"tokens": full["tokens"][:, t]}
        logits, cache = decode(params, cache, dec)

    # after consuming tokens [0, S0+N), both paths predict token S0+N
    _, logits_full = prefill(params, prefix_of(full, S0 + N))
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(logits_full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_plan_segments_cover_all_layers():
    from repro.models import build_plan
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        plan = build_plan(cfg)
        layers = sorted(i for seg in plan for i in seg.layers)
        assert layers == list(range(cfg.num_layers)), arch
