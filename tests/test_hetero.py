"""The repro.hetero composition engine: Table-2 parity through the joint
path, per-slot parity with select_level, system-metric arithmetic, caching
(neither the vmap characterization nor the batched scoring re-runs), budgets/
objectives/truncation, and sharded-vs-single-device equivalence."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import api
from repro.api import Compiler, DesignTable, design_space
from repro.core import gainsight
from repro.core.select import (Bucket, LevelReq, SelectionPolicy, TaskReq,
                               select_level)
from repro.hetero import (ComposePolicy, bucket_candidates, compose,
                          composition_eval_count, level_candidates)
from repro.hetero.system import SYSTEM_METRICS, score_grid, tiles_for


@pytest.fixture(scope="module")
def table():
    return DesignTable.from_configs(design_space())


# ------------------------------------------------------------------- parity
def test_compose_reproduces_table2(table):
    """All 7 paper selections, through the joint composition path."""
    for t in gainsight.TASKS:
        rep = compose(table, t)
        exp = gainsight.TABLE2_EXPECTED[t.task_id]
        assert rep.labels() == exp, f"task {t.task_id}: {rep.labels()}"
        assert rep.matches(exp)
    assert sum(compose(table, t).matches(gainsight.TABLE2_EXPECTED[t.task_id])
               for t in gainsight.TASKS) == 7


def test_compose_picks_match_select_level_rows(table):
    """Default policy: the joint winner's per-slot rows equal the greedy
    per-level picks exactly (not just the labels)."""
    metrics, fams = table.metrics, table.families
    for t in gainsight.TASKS:
        rep = compose(table, t)
        for name, level in (("L1", t.l1), ("L2", t.l2)):
            greedy = select_level(metrics, fams, level)
            joint = rep.best.levels[name]
            assert [p.config_idx for p in joint.picks] == \
                [p.config_idx for p in greedy.picks], (t.task_id, name)
            assert joint.label == greedy.label


def test_compose_via_compiler_facade(table):
    rep = Compiler().compose(gainsight.TASKS[4])
    assert rep.labels() == gainsight.TABLE2_EXPECTED[5]
    m = rep.pick_macro("L2", 0)
    assert m.family == rep.best.levels["L2"].picks[0].family
    assert "task 5" in rep.summary()


# ----------------------------------------------------------- system metrics
def test_system_metrics_arithmetic(table):
    """Recompute the winner's system metrics by hand from the table rows."""
    t = gainsight.TASKS[6]                       # 4 slots, 3 families
    rep = compose(table, t)
    b = rep.best
    area = p_static = p_dyn = cap = 0.0
    req_bits = 0.0
    margins = []
    for name, level in (("L1", t.l1), ("L2", t.l2)):
        lc = b.levels[name]
        for pick, tiles, bucket in zip(lc.picks, lc.tiles, level.buckets):
            row = rep.table.row(pick.config_idx)
            need = level.capacity_bits * bucket.frac
            assert tiles == int(np.ceil(need / row["bits"]))
            area += tiles * row["area_um2"]
            p_static += tiles * (row["p_leak_w"] + row["p_refresh_w"])
            p_dyn += row["e_read_j"] * bucket.f_hz
            cap += tiles * row["bits"]
            req_bits += need
            margins.append(row["f_op_hz"] / bucket.f_hz)
    m = b.metrics
    assert m["area_um2"] == pytest.approx(area, rel=1e-5)
    assert m["p_static_w"] == pytest.approx(p_static, rel=1e-5)
    assert m["p_dyn_w"] == pytest.approx(p_dyn, rel=1e-5)
    assert m["p_w"] == pytest.approx(p_static + p_dyn, rel=1e-5)
    assert m["bw_margin"] == pytest.approx(min(margins), rel=1e-5)
    assert m["bw_margin"] >= 1.0                 # feasibility implies margin
    assert m["capacity_bits"] == pytest.approx(cap, rel=1e-5)
    assert m["overprovision"] == pytest.approx(cap / req_bits, rel=1e-5)
    assert m["overprovision"] >= 1.0


def test_candidates_respect_policy_and_order(table):
    metrics, fams = table.metrics, table.families
    b = Bucket(1.0, 0.5e9, 1e-4)
    bc = bucket_candidates(metrics, fams, b, level_name="L1", bucket_index=0,
                           capacity_bits=1e6, mode="all_feasible")
    assert bc.feasible
    ranks = [c.pref_rank for c in bc.candidates]
    assert ranks == sorted(ranks)                # preference-ordered
    sram_only = SelectionPolicy(preference=("sram",))
    bc2 = bucket_candidates(metrics, fams, b, level_name="L1", bucket_index=0,
                            capacity_bits=1e6, policy=sram_only)
    assert {c.family for c in bc2.candidates} == {"sram"}
    lv = LevelReq("L2", 8 * 1024 * 1024, (b, Bucket(1.0, 2.9e9, 1e-4)))
    per_bucket = level_candidates(metrics, fams, lv)
    assert len(per_bucket) == 2
    assert per_bucket[0].capacity_bits == pytest.approx(lv.capacity_bits)


# ------------------------------------------------------------------ caching
def test_compose_cache_skips_vmap_and_scoring(tmp_path):
    t = gainsight.TASKS[2]
    r1 = compose(None, t, cache=tmp_path)
    n_chz, n_eval = api.characterize_call_count(), composition_eval_count()
    r2 = compose(None, t, cache=tmp_path)
    assert api.characterize_call_count() == n_chz, \
        "compose() cache hit must not re-run the vmap characterization"
    assert composition_eval_count() == n_eval, \
        "compose() cache hit must not re-run the batched scoring"
    assert r2.labels() == r1.labels() == gainsight.TABLE2_EXPECTED[3]
    assert [c.labels() for c in r2.ranked] == [c.labels() for c in r1.ranked]
    for m in SYSTEM_METRICS:
        assert r2.best.metrics[m] == pytest.approx(r1.best.metrics[m])
    assert (r2.n_compositions, r2.n_feasible) == (r1.n_compositions,
                                                 r1.n_feasible)
    # a different policy is a different cache entry, not a false hit
    r3 = compose(None, t, cache=tmp_path,
                 compose_policy=ComposePolicy(objective="area"))
    assert composition_eval_count() == n_eval + 1


def test_cache_key_sensitivity(table, tmp_path):
    """The report key must separate tasks and both policies: identical
    re-calls hit, any change misses — proven by the scoring counter."""
    t_a, t_b = gainsight.TASKS[0], gainsight.TASKS[4]
    compose(table, t_a, cache=tmp_path)
    n = composition_eval_count()
    compose(table, t_a, cache=tmp_path)                  # identical: hit
    assert composition_eval_count() == n
    compose(table, t_b, cache=tmp_path)                  # task change: miss
    assert composition_eval_count() == n + 1
    compose(table, t_a, cache=tmp_path,                  # SelectionPolicy
            policy=SelectionPolicy(allow_refresh=True))  # change: miss
    assert composition_eval_count() == n + 2
    compose(table, t_a, cache=tmp_path,                  # ComposePolicy
            compose_policy=ComposePolicy(top_k=3))       # change: miss
    assert composition_eval_count() == n + 3
    # and every variant now hits again without re-scoring
    compose(table, t_b, cache=tmp_path)
    compose(table, t_a, cache=tmp_path,
            policy=SelectionPolicy(allow_refresh=True))
    assert composition_eval_count() == n + 3


# -------------------------------------------------- objectives and budgets
def test_objectives_and_budgets(table):
    t = gainsight.TASKS[0]
    pref = compose(table, t)
    area = compose(table, t, compose_policy=ComposePolicy(objective="area"))
    power = compose(table, t, compose_policy=ComposePolicy(objective="power"))
    assert area.best.metrics["area_um2"] <= pref.best.metrics["area_um2"]
    assert power.best.metrics["p_w"] <= pref.best.metrics["p_w"]
    # a budget below the TRUE min-area design (all_feasible optimum — the
    # budget pin puts that composition in every grid) leaves nothing feasible
    true_min = compose(table, t, compose_policy=ComposePolicy(
        objective="area",
        candidate_mode="all_feasible")).best.metrics["area_um2"]
    rb = compose(table, t, compose_policy=ComposePolicy(
        objective="area", area_budget_um2=0.99 * true_min))
    assert rb.n_feasible == 0 and not rb.best.feasible
    with pytest.raises(ValueError):
        ComposePolicy(objective="nosuch")


def test_all_feasible_optimum_never_worse_than_greedy_reps(table):
    """Objective-aware candidate ordering: caps/trimming must not discard
    the rows a power/area objective is looking for, so the all_feasible
    optimum is always <= the per_family_best one (its candidate superset)."""
    for t in (gainsight.TASKS[0], gainsight.TASKS[6]):
        for objective, metric in (("power", "p_w"), ("area", "area_um2")):
            reps = compose(table, t,
                           compose_policy=ComposePolicy(objective=objective))
            full = compose(table, t, compose_policy=ComposePolicy(
                objective=objective, candidate_mode="all_feasible"))
            assert full.best.metrics[metric] <= \
                reps.best.metrics[metric] * (1 + 1e-6), (t.task_id, objective)


def test_tight_candidate_cap_keeps_the_optimum(table):
    """Candidates are ordered by TILED slot contribution, so an unbudgeted
    power/area optimum survives even a cap of 2 per bucket (raw per-macro
    metrics would put the optimum near the tail — a big macro tiles fewer
    times — and a cap would silently return a several-x-worse design)."""
    for objective, metric in (("power", "p_w"), ("area", "area_um2"),
                              ("balanced", "area_um2")):
        wide = compose(table, gainsight.TASKS[0], compose_policy=ComposePolicy(
            objective=objective, candidate_mode="all_feasible",
            max_candidates_per_bucket=64))
        tight = compose(table, gainsight.TASKS[0],
                        compose_policy=ComposePolicy(
                            objective=objective,
                            candidate_mode="all_feasible",
                            max_candidates_per_bucket=2))
        if objective == "balanced":      # heuristic ordering: no worse than 5%
            assert tight.best.metrics[metric] <= \
                wide.best.metrics[metric] * 1.05
        else:                            # decomposable: cap must be lossless
            assert tight.best.metrics[metric] == pytest.approx(
                wide.best.metrics[metric], rel=1e-9), objective


def test_budget_survives_objective_ordered_caps(table):
    """An area budget just above the min achievable area must stay feasible
    under objective="power" even with a tight per-bucket cap: budgets pin
    their per-slot argmin rows into the grid, so 'nothing fits' can never be
    a cap artifact."""
    t = gainsight.TASKS[0]
    min_area = compose(table, t, compose_policy=ComposePolicy(
        objective="area", candidate_mode="all_feasible")).best.metrics[
        "area_um2"]
    rep = compose(table, t, compose_policy=ComposePolicy(
        objective="power", candidate_mode="all_feasible",
        max_candidates_per_bucket=4, area_budget_um2=1.001 * min_area))
    assert rep.n_feasible > 0 and rep.best.feasible
    assert rep.best.metrics["area_um2"] <= 1.001 * min_area
    # per-bucket caps now surface as a non-exhaustive-grid signal
    assert rep.truncated
    # grid trimming (max_compositions) must not drop the pinned rows either
    trim = compose(table, t, compose_policy=ComposePolicy(
        objective="power", candidate_mode="all_feasible",
        max_compositions=8, area_budget_um2=1.001 * min_area))
    assert trim.n_feasible > 0 and trim.best.feasible
    # ...and the guarantee holds in the default per_family_best mode too,
    # where the min-area row is usually not a greedy family representative
    reps = compose(table, t, compose_policy=ComposePolicy(
        area_budget_um2=1.001 * min_area))
    assert reps.n_feasible > 0 and reps.best.feasible


def test_all_feasible_mode_and_truncation(table):
    t = gainsight.TASKS[2]
    big = compose(table, t, compose_policy=ComposePolicy(
        candidate_mode="all_feasible", max_candidates_per_bucket=12))
    small = compose(table, t)
    assert big.n_compositions > small.n_compositions
    # same winner: extra candidates are all worse under the default objective
    assert big.labels() == small.labels()
    trunc = compose(table, t, compose_policy=ComposePolicy(
        candidate_mode="all_feasible", max_candidates_per_bucket=30,
        max_compositions=100))
    assert trunc.n_compositions <= 100 and trunc.truncated


def test_infeasible_bucket_gets_sentinel_label(table):
    impossible = TaskReq("x", "impossible", {
        "L1": LevelReq("L1", 8 * 1024, (Bucket(1.0, 1e13, 1e3),))})
    rep = compose(table, impossible)
    assert rep.labels() == {"L1": "infeasible"}
    assert not rep.best.feasible and rep.n_feasible == 0
    with pytest.raises(LookupError):
        rep.pick_macro("L1", 0)


# ----------------------------------------------------------------- sharding
def test_sharded_scoring_matches_inprocess(table):
    """sharded=True on the current host (any device count) must be exact."""
    if jax.device_count() == 1:
        pytest.skip("1-device host: in-process sharding is a bypass; "
                    "the subprocess test covers the real path")
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(table), size=(257, 4)).astype(np.int32)
    cap, f = [1e5, 2e5, 4e5, 1e6], [1e9, 5e8, 2e9, 1e9]
    a = score_grid(table.metrics, idx, cap, f, sharded=False)
    b = score_grid(table.metrics, idx, cap, f, sharded=True)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


_SHARDED_EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np, jax
sys.path.insert(0, "src")
assert jax.device_count() == 8
from repro.api import DesignTable, design_space
from repro.core import gainsight
from repro.hetero import compose
from repro.hetero.system import score_grid

table = DesignTable.from_configs(design_space())
rng = np.random.default_rng(0)
idx = rng.integers(0, len(table), size=(1003, 4)).astype(np.int32)
cap, f = [1e5, 2e5, 4e5, 1e6], [1e9, 5e8, 2e9, 1e9]
a = score_grid(table.metrics, idx, cap, f, sharded=False)
b = score_grid(table.metrics, idx, cap, f, sharded=True)
exact = all(bool(np.array_equal(a[k], b[k])) for k in a)
r0 = compose(table, gainsight.TASKS[6], sharded=False)
r1 = compose(table, gainsight.TASKS[6], sharded=True)
print(json.dumps({"exact": exact, "labels_equal": r0.labels() == r1.labels(),
                  "table2": r1.labels() ==
                  gainsight.TABLE2_EXPECTED[7]}))
"""


def test_sharded_equals_single_device_8dev(tmp_path):
    """8-virtual-device shard_map scoring == single device, bit exact
    (subprocess: the device count must be set before jax initializes)."""
    script = tmp_path / "sharded_equiv.py"
    script.write_text(_SHARDED_EQUIV_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True,
                         cwd=str(Path(__file__).resolve().parents[1]),
                         env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res == {"exact": True, "labels_equal": True, "table2": True}


# ---------------------------------------------------------------- internals
def test_tiles_for_matches_kernel(table):
    idx = np.array([[0, 5], [-1, 7]], np.int32)
    cap = np.array([1e6, 3e5])
    tiles = tiles_for(table.metrics, idx, cap)
    bits = np.asarray(table.metrics["bits"])
    assert tiles[0, 0] == int(np.ceil(1e6 / bits[0]))
    assert tiles[1, 0] == 0                       # sentinel slot: no tiles
    assert tiles[1, 1] == int(np.ceil(3e5 / bits[7]))
