"""Distribution: pspec rules, hint safety, and an 8-virtual-device
equivalence run (subprocess: device count must be set before jax init)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, reduce_config
from repro.models import LM
from repro.parallel.sharding import param_pspec_tree


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_param_pspecs_always_divisible():
    """Every sharded dim must divide the mesh extent (rule fallback works)."""
    mesh = _FakeMesh()
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        lm = LM(cfg)
        shapes = jax.eval_shape(lm.init, jax.random.key(0))
        specs = param_pspec_tree(mesh, shapes)
        flat_sh = jax.tree.leaves(shapes)
        flat_sp = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda x: hasattr(x, "index"))[0]
        ext = {"data": 16, "model": 16, ("pod", "data"): 32}
        for leaf, spec in zip(flat_sh, flat_sp):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                e = 16 if isinstance(ax, str) else 16 * 16
                assert dim % e == 0, (arch, leaf.shape, tuple(spec))


def test_hint_is_noop_without_mesh():
    from repro.parallel.sharding import hint
    x = jnp.ones((8, 8))
    y = hint(x, "D", "M")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_maybe_shard_drops_axes_missing_from_mesh():
    """A spec naming an axis the active mesh lacks must replicate, not raise
    (the mesh-agnostic contract model code relies on)."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh
    from repro.parallel.sharding import maybe_shard
    x = jnp.ones((8, 8))
    np.testing.assert_array_equal(np.asarray(maybe_shard(x, P(None, "model"))),
                                  np.asarray(x))      # no mesh: identity
    with make_mesh((1,), ("data",)):                  # data-only mesh
        y = maybe_shard(x, P(("pod", "data"), "model"))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


_EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, "src")
from repro.compat import make_mesh
from repro.configs import get_config, reduce_config
from repro.data.pipeline import SyntheticLMData
from repro.parallel.sharding import batch_pspec_tree, param_pspec_tree, to_named
from repro.train.step import init_train_state, make_train_step

cfg = reduce_config(get_config("internlm2-1.8b")).replace(
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=8, head_dim=8, d_ff=128)
_, step = make_train_step(cfg, base_lr=1e-3)
params, opt = init_train_state(cfg, jax.random.key(0))
data = SyntheticLMData(cfg, 8, 16, seed=9)
batch = data.next_batch()

# 1-device reference
l_ref = float(jax.jit(step)(params, opt, batch, 0)[2]["loss"])

# 2x4 mesh ("data","model") sharded run
mesh = make_mesh((2, 4), ("data", "model"))
params_sd = jax.eval_shape(lambda: params)
psh = to_named(mesh, param_pspec_tree(mesh, params))
bsh = to_named(mesh, batch_pspec_tree(mesh, batch))
with mesh:
    f = jax.jit(step, in_shardings=(psh, None, bsh, None))
    l_sh = float(f(params, opt, batch, 0)[2]["loss"])
print(json.dumps({"ref": l_ref, "sharded": l_sh}))
"""


def test_sharded_loss_matches_single_device(tmp_path):
    """Same step, same data: 8-virtual-device GSPMD result == 1-device."""
    script = tmp_path / "equiv.py"
    script.write_text(_EQUIV_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, cwd=str(Path(__file__).resolve().parents[1]),
                         env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["ref"] - res["sharded"]) < 2e-2, res


def test_dryrun_artifacts_complete_and_clean():
    """Deliverable (e): every (arch x applicable shape x mesh) compiled."""
    outdir = Path("artifacts/dryrun")
    if not outdir.exists():
        pytest.skip("dry-run not generated in this environment")
    from repro.configs import SHAPES, applicable_shapes
    missing, failed = [], []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        live = {s.name for s in applicable_shapes(cfg)}
        for shape in SHAPES:
            for mesh in ("pod16x16", "pod2x16x16"):
                p = outdir / f"{arch}__{shape}__{mesh}.json"
                if not p.exists():
                    missing.append(p.name)
                    continue
                rec = json.loads(p.read_text())
                if shape in live and rec["status"] != "ok":
                    failed.append((p.name, rec.get("error", "")[:100]))
                if shape not in live and rec["status"] != "skipped":
                    failed.append((p.name, "expected skip"))
    assert not missing, missing[:5]
    assert not failed, failed[:5]
