"""repro.compat must work under BOTH jax API spellings.

The spelling the pinned jax does not provide is simulated by monkeypatching
the live jax modules, so both code paths stay covered regardless of which
jax is installed — the layer cannot silently rot when jax upgrades.
Also covers the kernel backend registry (repro.kernels.backend).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.kernels import backend


# ------------------------------------------------------------ version parsing
def test_jax_version_is_int_triple():
    v = compat.jax_version()
    assert len(v) == 3 and all(isinstance(p, int) for p in v)
    assert v >= (0, 4, 0)


# ------------------------------------------------------- tpu_compiler_params
def test_compiler_params_native_spelling():
    from jax.experimental.pallas import tpu as pltpu
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    assert isinstance(params, cls)
    assert tuple(params.dimension_semantics) == ("parallel", "arbitrary")


def test_compiler_params_new_spelling(monkeypatch):
    from jax.experimental.pallas import tpu as pltpu

    class FakeNew:
        def __init__(self, **kw):
            self.kw = kw

    monkeypatch.setattr(pltpu, "CompilerParams", FakeNew, raising=False)
    p = compat.tpu_compiler_params(dimension_semantics=("parallel",))
    assert isinstance(p, FakeNew)
    assert p.kw == {"dimension_semantics": ("parallel",)}


def test_compiler_params_old_spelling(monkeypatch):
    from jax.experimental.pallas import tpu as pltpu

    class FakeOld:
        def __init__(self, **kw):
            self.kw = kw

    monkeypatch.delattr(pltpu, "CompilerParams", raising=False)
    monkeypatch.setattr(pltpu, "TPUCompilerParams", FakeOld, raising=False)
    p = compat.tpu_compiler_params(dimension_semantics=("arbitrary",))
    assert isinstance(p, FakeOld)
    assert p.kw == {"dimension_semantics": ("arbitrary",)}


def test_compiler_params_dict_fallback(monkeypatch):
    from jax.experimental.pallas import tpu as pltpu
    monkeypatch.delattr(pltpu, "CompilerParams", raising=False)
    monkeypatch.delattr(pltpu, "TPUCompilerParams", raising=False)
    p = compat.tpu_compiler_params(dimension_semantics=("parallel",))
    assert p == {"mosaic": {"dimension_semantics": ("parallel",)}}


# ------------------------------------------------------------------ make_mesh
def test_make_mesh_native():
    mesh = compat.make_mesh((1,), ("data",))
    assert mesh.axis_names == ("data",)
    assert mesh.shape["data"] == 1


def test_make_mesh_new_spelling_passes_axis_types(monkeypatch):
    """When jax grows AxisType + the axis_types kwarg, compat must pass it."""
    recorded = {}

    class FakeAxisType:
        Auto = "auto-member"
        Explicit = "explicit-member"

    def fake_make_mesh(axis_shapes, axis_names, *, devices=None,
                       axis_types=None):
        recorded.update(shapes=axis_shapes, names=axis_names,
                        axis_types=axis_types)
        return "fake-mesh"

    monkeypatch.setattr(jax.sharding, "AxisType", FakeAxisType, raising=False)
    monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
    assert compat.make_mesh((2, 4), ("data", "model")) == "fake-mesh"
    assert recorded == {"shapes": (2, 4), "names": ("data", "model"),
                        "axis_types": ("auto-member", "auto-member")}
    assert compat.make_mesh((1,), ("x",), kind="explicit") == "fake-mesh"
    assert recorded["axis_types"] == ("explicit-member",)


def test_make_mesh_old_signature_drops_axis_types(monkeypatch):
    """An old-style jax.make_mesh (no axis_types kwarg) must not receive one
    even when the AxisType enum exists."""

    class FakeAxisType:
        Auto = "auto-member"

    def fake_make_mesh(axis_shapes, axis_names, *, devices=None):
        return ("fake-mesh", axis_shapes, axis_names)

    monkeypatch.setattr(jax.sharding, "AxisType", FakeAxisType, raising=False)
    monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
    assert compat.make_mesh((1,), ("data",))[0] == "fake-mesh"


def test_make_mesh_prehistoric_fallback(monkeypatch):
    """Without jax.make_mesh at all, devices are arranged by hand."""
    monkeypatch.delattr(jax, "make_mesh", raising=False)
    mesh = compat.make_mesh((1,), ("data",))
    assert isinstance(mesh, jax.sharding.Mesh)
    assert mesh.axis_names == ("data",)


def test_axis_types_none_when_enum_missing(monkeypatch):
    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
    assert compat.axis_types("auto", 2) is None
    assert compat.axis_types(None, 2) is None


# ------------------------------------------------------------------ shard_map
def test_shard_map_executes():
    mesh = compat.make_mesh((1,), ("data",))
    f = compat.shard_map(lambda x: jax.lax.psum(x, "data"), mesh=mesh,
                         in_specs=P(), out_specs=P())
    out = jax.jit(f)(jnp.ones((4,)))
    np.testing.assert_allclose(np.asarray(out), np.ones(4))


def test_shard_map_new_spelling_maps_check_rep_to_check_vma(monkeypatch):
    recorded = {}

    def fake_shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        recorded.update(mesh=mesh, check_vma=check_vma)
        return f

    monkeypatch.setattr(jax, "shard_map", fake_shard_map, raising=False)
    fn = compat.shard_map(lambda x: x, mesh="m", in_specs=(), out_specs=(),
                          check_rep=False)
    assert fn(3) == 3
    assert recorded == {"mesh": "m", "check_vma": False}


# --------------------------------------------------------------- current_mesh
def test_current_mesh_tracks_context():
    assert compat.current_mesh() is None
    mesh = compat.make_mesh((1,), ("data",))
    with mesh:
        got = compat.current_mesh()
        assert got is not None and got.axis_names == ("data",)
    assert compat.current_mesh() is None


# ------------------------------------------------------ sharding constructors
def test_named_sharding_accepts_parts_and_spec():
    mesh = compat.make_mesh((1,), ("data",))
    a = compat.named_sharding(mesh, "data", None)
    b = compat.named_sharding(mesh, P("data", None))
    assert a.spec == b.spec == P("data", None)


def test_replicated_like_mirrors_tree():
    mesh = compat.make_mesh((1,), ("data",))
    tree = {"a": jnp.ones((2,)), "b": {"c": jnp.ones((3,))}}
    sh = compat.replicated_like(mesh, tree)
    assert set(sh) == {"a", "b"}
    assert sh["b"]["c"].spec == P()


# ----------------------------------------------------------- backend registry
def test_backend_registry_has_all_ops():
    import repro.kernels.ops  # noqa: F401  (registration side effect)
    assert {"attention", "ssm_scan", "retention"} <= set(backend.registered())
    for op in ("attention", "ssm_scan", "retention"):
        assert backend.available_backends(op) == ("tpu", "interpret", "xla")


def test_backend_resolution_order(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert backend.resolve_backend("interpret") == "interpret"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    assert backend.resolve_backend() == "interpret"
    monkeypatch.delenv("REPRO_KERNEL_BACKEND")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert backend.resolve_backend() == "interpret"
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    expected = "tpu" if jax.default_backend() == "tpu" else "xla"
    assert backend.resolve_backend() == expected
    with pytest.raises(ValueError):
        backend.resolve_backend("cuda")
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "cuda")
    with pytest.raises(ValueError):
        backend.resolve_backend()


def test_backend_dispatch_agrees_across_backends(monkeypatch):
    """attention via xla and interpret backends must agree numerically."""
    import repro.kernels.ops as ops
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    rng = np.random.default_rng(0)
    q, k, v = (jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
               for _ in range(3))
    y_xla = backend.dispatch("attention", q, k, v, causal=True, backend="xla")
    y_int = backend.dispatch("attention", q, k, v, causal=True,
                             backend="interpret")
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_int),
                               rtol=2e-5, atol=2e-5)
    # the public entry point honors the env override
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "xla")
    np.testing.assert_allclose(np.asarray(ops.attention(q, k, v)),
                               np.asarray(y_xla), rtol=0, atol=0)


def test_backend_missing_impl_falls_back_to_xla(monkeypatch):
    backend.register("_probe_op", xla=lambda x: x + 1)
    try:
        assert backend.dispatch("_probe_op", 1, backend="interpret") == 2
        assert backend.dispatch("_probe_op", 1, backend="tpu") == 2
        with pytest.raises(KeyError):
            backend.dispatch("_unregistered_op", 1)
        with pytest.raises(ValueError):
            backend.register("_probe_op", cuda=lambda x: x)
    finally:
        backend._REGISTRY.pop("_probe_op", None)
