"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

UT = 0.02585
_NEG = -0.7 * jnp.finfo(jnp.float32).max


# ---------------------------------------------------------------------------
# retention transient (oracle for retention_kernel)
# ---------------------------------------------------------------------------
# packed config rows: [vt, n, ispec, eta, i_floor, jg_coef, c_sn, w, v0, v_min]
N_FIELDS = 10


def _F(u):
    sp = jnp.where(u > 40.0, u / 2.0, jnp.log1p(jnp.exp(jnp.minimum(u / 2.0, 40.0))))
    return sp * sp


def _leak(p, v):
    vt, n, ispec, eta, i_floor, jg, c_sn, w = (p[..., i] for i in range(8))
    vt_eff = vt - eta * v
    nut = n * UT
    i_ch = ispec * (_F((0.0 - vt_eff) / nut) - _F((0.0 - vt_eff - n * v) / nut))
    return (jnp.maximum(i_ch, 0.0) + i_floor) * w + jg * v


def retention_ref(params, ts):
    """params (B, 10), ts (N+1,) log grid -> retention times (B,).

    RK4 + first-crossing with log-linear interpolation (same discretization
    as the Pallas kernel)."""
    v = params[:, 8]
    v_min = params[:, 9]
    c_sn = params[:, 6]

    def f(v):
        return -_leak(params, jnp.maximum(v, 0.0)) / jnp.maximum(c_sn, 1e-18)

    def step(carry, i):
        v, t_ret, found = carry
        dt = ts[i + 1] - ts[i]
        k1 = f(v)
        k2 = f(v + 0.5 * dt * k1)
        k3 = f(v + 0.5 * dt * k2)
        k4 = f(v + dt * k3)
        v_new = jnp.clip(v + dt / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4), 0.0, 2.0)
        crossed = (v_new < v_min) & (~found)
        frac = jnp.clip((v - v_min) / jnp.maximum(v - v_new, 1e-9), 0.0, 1.0)
        t_cross = jnp.exp(jnp.log(ts[i]) + frac *
                          (jnp.log(ts[i + 1]) - jnp.log(ts[i])))
        t_ret = jnp.where(crossed, t_cross, t_ret)
        return (v_new, t_ret, found | crossed), None

    n = ts.shape[0] - 1
    init = (v, jnp.full_like(v, ts[-1]), v < v_min)
    (v, t_ret, found), _ = jax.lax.scan(step, init, jnp.arange(n))
    return t_ret


# ---------------------------------------------------------------------------
# flash attention forward (oracle)
# ---------------------------------------------------------------------------


def attention_ref(q, k, v, causal=True, scale=None):
    """q,k,v (B,H,S,D) -> (B,H,S,D), fp32 softmax."""
    B, H, S, D = q.shape
    scale = scale or 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, k.shape[2]), bool))
        s = jnp.where(mask, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# selective scan (oracle)
# ---------------------------------------------------------------------------


def ssm_scan_ref(x, dt, A, Bc, Cc, D, h0):
    """Sequential reference. x/dt (B,S,di); Bc/Cc (B,S,n); A (di,n); D (di,);
    h0 (B,di,n) -> (y (B,S,di), h_final)."""

    def step(h, xs):
        x_t, dt_t, b_t, c_t = xs
        a = jnp.exp(dt_t[..., None] * A)
        h = a * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + D * x_t
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (x, dt, Bc, Cc))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h
