"""Pallas TPU kernel: flash-attention forward (serving prefill hot-spot).

Grid (B*H, nq, nk) with the kv dimension innermost/"arbitrary": the online
softmax state (m, l, acc) lives in VMEM scratch across kv iterations and the
output tile is emitted on the last kv step. Causal masking is applied at
block granularity (off-diagonal blocks need no mask; blocks strictly above
the diagonal are skipped with @pl.when).

Tile sizes default to (128, 128) q x kv — MXU-aligned for head_dim >= 64.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

_NEG = -0.7 * jnp.finfo(jnp.float32).max


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, block_q, block_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], _NEG)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                              # (bq, d)
        k = k_ref[0]                              # (bk, d)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, _NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _emit():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    interpret=False):
    """q,k,v (B,H,S,D) -> (B,H,S,D). Forward-only (serving path)."""
    B, H, S, D = q.shape
    Sk = k.shape[2]
    # D is a static shape int: host math, no device round-trip (the previous
    # float(jnp.sqrt(...)) forced a sync before the kernel even launched)
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)
    assert S % block_q == 0 and Sk % block_k == 0, "pad sequence to block size"
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, Sk, D)
    vf = v.reshape(B * H, Sk, D)
    grid = (B * H, S // block_q, Sk // block_k)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
