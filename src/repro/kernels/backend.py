"""Kernel backend dispatch: one registry instead of per-call mode strings.

Every compute hot-spot registers up to three implementations:

  "tpu"       — native ``pallas_call`` (requires a TPU device)
  "interpret" — the same Pallas kernel through the interpreter (any device;
                what the test suite exercises)
  "xla"       — the pure jax.numpy oracle from ``kernels/ref.py``

Selection order (``resolve_backend``):

  1. explicit ``backend=`` argument
  2. a ``use_backend(...)`` context override (innermost wins)
  3. ``REPRO_KERNEL_BACKEND`` env var ("tpu" / "interpret" / "xla")
  4. legacy ``REPRO_PALLAS_INTERPRET=1`` (kept for existing launch scripts)
  5. "tpu" when ``jax.default_backend()`` is a TPU, else "xla"

A resolved backend with no registered implementation falls back to "xla",
so ops stay callable on CPU even when only the reference path exists.
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from typing import Callable, Dict, Optional, Tuple

import jax

BACKENDS = ("tpu", "interpret", "xla")
_FALLBACK = {"tpu": ("tpu", "xla"),
             "interpret": ("interpret", "xla"),
             "xla": ("xla",)}

_REGISTRY: Dict[str, Dict[str, Callable]] = {}

# stack of use_backend() overrides (innermost last); beats the env vars but
# not an explicit backend= argument
_OVERRIDES: list = []


@contextmanager
def use_backend(backend: str):
    """Force ``resolve_backend()`` to ``backend`` inside the block.

    Tests and benchmarks use this to pin every dispatched op (e.g. the
    simulator's interpret-vs-xla equivalence proof) without threading a
    ``backend=`` argument through call stacks or mutating the process env.
    Nested blocks: the innermost wins; an explicit ``backend=`` argument
    still takes precedence.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; valid: {BACKENDS}")
    _OVERRIDES.append(backend)
    try:
        yield
    finally:
        _OVERRIDES.pop()


def register(name: str, **impls: Callable) -> None:
    """Register (or extend) the per-backend implementations of one op."""
    unknown = set(impls) - set(BACKENDS)
    if unknown:
        raise ValueError(
            f"unknown backend(s) {sorted(unknown)} for op {name!r}; "
            f"valid: {BACKENDS}")
    _REGISTRY.setdefault(name, {}).update(impls)


def registered() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def available_backends(name: str) -> Tuple[str, ...]:
    impls = _REGISTRY.get(name, {})
    return tuple(b for b in BACKENDS if b in impls)


def impl_map(name: str) -> Dict[str, Callable]:
    """Copy of one op's backend->implementation mapping. Introspection hook
    for the semantic analyzer (PB profiles every op with a 'tpu' impl) and
    the backend-divergence test sweep; mutating the copy does not touch the
    registry."""
    return dict(_REGISTRY.get(name, {}))


def resolve_backend(explicit: Optional[str] = None) -> str:
    if explicit is not None:
        if explicit not in BACKENDS:
            raise ValueError(f"unknown backend {explicit!r}; valid: {BACKENDS}")
        return explicit
    if _OVERRIDES:
        return _OVERRIDES[-1]
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        if env not in BACKENDS:
            raise ValueError(
                f"REPRO_KERNEL_BACKEND={env!r} invalid; valid: {BACKENDS}")
        return env
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return "interpret"
    return "tpu" if jax.default_backend() == "tpu" else "xla"


def get_impl(name: str, backend: Optional[str] = None) -> Callable:
    impls = _REGISTRY.get(name)
    if impls is None:
        raise KeyError(f"no kernel registered under {name!r}; "
                       f"registered: {registered()}")
    resolved = resolve_backend(backend)
    for candidate in _FALLBACK[resolved]:
        if candidate in impls:
            from repro import obs                # lazy: kernels load early
            obs.counter(f"kernels.dispatch.{name}.{candidate}").inc()
            return impls[candidate]
    raise KeyError(f"op {name!r} has no implementation for backend "
                   f"{resolved!r} and no xla fallback")


def dispatch(name: str, *args, backend: Optional[str] = None, **kwargs):
    return get_impl(name, backend)(*args, **kwargs)
