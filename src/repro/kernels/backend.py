"""Kernel backend dispatch: one registry instead of per-call mode strings.

Every compute hot-spot registers up to three implementations:

  "tpu"       — native ``pallas_call`` (requires a TPU device)
  "interpret" — the same Pallas kernel through the interpreter (any device;
                what the test suite exercises)
  "xla"       — the pure jax.numpy oracle from ``kernels/ref.py``

Selection order (``resolve_backend``):

  1. explicit ``backend=`` argument
  2. ``REPRO_KERNEL_BACKEND`` env var ("tpu" / "interpret" / "xla")
  3. legacy ``REPRO_PALLAS_INTERPRET=1`` (kept for existing launch scripts)
  4. "tpu" when ``jax.default_backend()`` is a TPU, else "xla"

A resolved backend with no registered implementation falls back to "xla",
so ops stay callable on CPU even when only the reference path exists.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, Optional, Tuple

import jax

BACKENDS = ("tpu", "interpret", "xla")
_FALLBACK = {"tpu": ("tpu", "xla"),
             "interpret": ("interpret", "xla"),
             "xla": ("xla",)}

_REGISTRY: Dict[str, Dict[str, Callable]] = {}


def register(name: str, **impls: Callable) -> None:
    """Register (or extend) the per-backend implementations of one op."""
    unknown = set(impls) - set(BACKENDS)
    if unknown:
        raise ValueError(
            f"unknown backend(s) {sorted(unknown)} for op {name!r}; "
            f"valid: {BACKENDS}")
    _REGISTRY.setdefault(name, {}).update(impls)


def registered() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def available_backends(name: str) -> Tuple[str, ...]:
    impls = _REGISTRY.get(name, {})
    return tuple(b for b in BACKENDS if b in impls)


def resolve_backend(explicit: Optional[str] = None) -> str:
    if explicit is not None:
        if explicit not in BACKENDS:
            raise ValueError(f"unknown backend {explicit!r}; valid: {BACKENDS}")
        return explicit
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        if env not in BACKENDS:
            raise ValueError(
                f"REPRO_KERNEL_BACKEND={env!r} invalid; valid: {BACKENDS}")
        return env
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return "interpret"
    return "tpu" if jax.default_backend() == "tpu" else "xla"


def get_impl(name: str, backend: Optional[str] = None) -> Callable:
    impls = _REGISTRY.get(name)
    if impls is None:
        raise KeyError(f"no kernel registered under {name!r}; "
                       f"registered: {registered()}")
    for candidate in _FALLBACK[resolve_backend(backend)]:
        if candidate in impls:
            return impls[candidate]
    raise KeyError(f"op {name!r} has no implementation for backend "
                   f"{resolve_backend(backend)!r} and no xla fallback")


def dispatch(name: str, *args, backend: Optional[str] = None, **kwargs):
    return get_impl(name, backend)(*args, **kwargs)
