"""Pallas TPU kernel: chunked selective-scan (hymba SSM heads / mamba-style).

Grid (B, di_blocks, n_chunks) with the chunk dimension innermost and
"arbitrary": the recurrent state h (di_blk, n) lives in VMEM scratch across
chunk iterations; within a chunk the T timesteps run as a fori_loop of
VPU-width (di_blk, n) updates. HBM traffic per program = the (T, di_blk)
x/dt tiles + (T, n) B/C tiles + (T, di_blk) y tile out — the sequential
dependency never leaves VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

BLOCK_D = 512
CHUNK_T = 128


def _ssm_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_scr, *,
                chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr[...])

    a_log = a_ref[...]                      # (di_blk, n)
    d_coef = d_ref[...]                     # (1, di_blk)

    def body(t, h):
        x_t = x_ref[0, t, :]                # (di_blk,)
        dt_t = dt_ref[0, t, :]
        b_t = b_ref[0, t, :]                # (n,)
        c_t = c_ref[0, t, :]
        a = jnp.exp(dt_t[:, None] * a_log)  # (di_blk, n)
        h = a * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=-1) + d_coef[0] * x_t
        y_ref[0, t, :] = y
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, body, h_scr[...])


def ssm_scan_pallas(x, dt, A, Bc, Cc, D, *, block_d=BLOCK_D, chunk=CHUNK_T,
                    interpret=False):
    """x/dt (B,S,di) fp32; Bc/Cc (B,S,n); A (di,n); D (di,) -> y (B,S,di).

    h0 = 0 (training/prefill path; decode uses the single-step jnp update)."""
    B, S, di = x.shape
    n = A.shape[1]
    block_d = min(block_d, di)
    chunk = min(chunk, S)
    assert di % block_d == 0 and S % chunk == 0, "pad di/S to block size"
    grid = (B, di // block_d, S // chunk)
    y = pl.pallas_call(
        functools.partial(_ssm_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, n), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((block_d, n), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, block_d), lambda b, d, c: (0, d)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x.astype(jnp.float32), dt.astype(jnp.float32), Bc.astype(jnp.float32),
      Cc.astype(jnp.float32), A.astype(jnp.float32),
      D[None, :].astype(jnp.float32))
    return y
