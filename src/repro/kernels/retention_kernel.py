"""Pallas TPU kernel: batched transient retention simulation.

This is OpenGCRAM's characterization hot loop — one SPICE transient per
(device x VT x cap x sizing) configuration, embarrassingly parallel across
the design space. The TPU mapping tiles 128 configurations per program into
VMEM and runs the full RK4 log-grid integration on the VPU; HBM traffic is
one (10,128) parameter tile in + one (1,128) retention vector out, so the
kernel is compute-bound by design.

Layout: params (10, B) fp32, B padded to a multiple of 128. Time grid is a
small (1, N+1) VMEM-resident input shared by every program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params
from repro.kernels.ref import N_FIELDS, UT

BLOCK_B = 128


def _F(u):
    sp = jnp.where(u > 40.0, u / 2.0, jnp.log1p(jnp.exp(jnp.minimum(u / 2.0, 40.0))))
    return sp * sp


def _retention_kernel(params_ref, ts_ref, out_ref, *, n_steps):
    p = params_ref[...]                      # (10, BLOCK_B)
    ts = ts_ref[...]                         # (1, n_steps+1)
    vt, n, ispec, eta, i_floor, jg, c_sn, w = (p[i] for i in range(8))
    v0, v_min = p[8], p[9]

    def leak(v):
        vt_eff = vt - eta * v
        nut = n * UT
        i_ch = ispec * (_F((0.0 - vt_eff) / nut) - _F((0.0 - vt_eff - n * v) / nut))
        return (jnp.maximum(i_ch, 0.0) + i_floor) * w + jg * v

    def f(v):
        return -leak(jnp.maximum(v, 0.0)) / jnp.maximum(c_sn, 1e-18)

    def body(i, carry):
        v, t_ret, found = carry
        t0 = ts[0, i]
        t1 = ts[0, i + 1]
        dt = t1 - t0
        k1 = f(v)
        k2 = f(v + 0.5 * dt * k1)
        k3 = f(v + 0.5 * dt * k2)
        k4 = f(v + dt * k3)
        v_new = jnp.clip(v + dt / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4), 0.0, 2.0)
        crossed = (v_new < v_min) & (~found)
        frac = jnp.clip((v - v_min) / jnp.maximum(v - v_new, 1e-9), 0.0, 1.0)
        t_cross = jnp.exp(jnp.log(t0) + frac * (jnp.log(t1) - jnp.log(t0)))
        t_ret = jnp.where(crossed, t_cross, t_ret)
        return v_new, t_ret, found | crossed

    init = (v0, jnp.full_like(v0, ts[0, n_steps]), v0 < v_min)
    _, t_ret, _ = jax.lax.fori_loop(0, n_steps, body, init)
    out_ref[...] = t_ret[None, :]


def retention_pallas(params, ts, *, interpret=False):
    """params (B, 10) fp32, ts (N+1,) -> (B,) retention seconds."""
    B = params.shape[0]
    pad = (-B) % BLOCK_B
    p = jnp.pad(params, ((0, pad), (0, 0)),
                constant_values=1.0).T.astype(jnp.float32)   # (10, B')
    Bp = B + pad
    n_steps = ts.shape[0] - 1
    out = pl.pallas_call(
        functools.partial(_retention_kernel, n_steps=n_steps),
        grid=(Bp // BLOCK_B,),
        in_specs=[
            pl.BlockSpec((N_FIELDS, BLOCK_B), lambda i: (0, i)),
            pl.BlockSpec((1, n_steps + 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_B), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Bp), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(p, ts[None, :].astype(jnp.float32))
    return out[0, :B]
