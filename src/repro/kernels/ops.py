"""Backend dispatch for the Pallas kernels.

On TPU the pallas_call path runs natively; on CPU (this container, including
the 512-device dry-run) the pure-jnp oracle runs instead so the AOT compile
stays tractable. Set REPRO_PALLAS_INTERPRET=1 to force the kernels through
the Pallas interpreter (tests do this per-call instead).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.retention_kernel import retention_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas


def _use_pallas():
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return "interpret"
    return "tpu" if jax.default_backend() == "tpu" else None


def attention(q, k, v, *, causal=True):
    mode = _use_pallas()
    if mode == "tpu":
        return _flash_pallas(q, k, v, causal=causal)
    if mode == "interpret":
        return _flash_pallas(q, k, v, causal=causal, interpret=True)
    return ref.attention_ref(q, k, v, causal=causal)


def ssm_scan(x, dt, A, Bc, Cc, D):
    mode = _use_pallas()
    if mode == "tpu":
        return ssm_scan_pallas(x, dt, A, Bc, Cc, D)
    if mode == "interpret":
        return ssm_scan_pallas(x, dt, A, Bc, Cc, D, interpret=True)
    B = x.shape[0]
    h0 = jnp.zeros((B, A.shape[0], A.shape[1]), jnp.float32)
    return ref.ssm_scan_ref(x, dt, A, Bc, Cc, D, h0)[0]


def retention_batch(params, ts):
    mode = _use_pallas()
    if mode == "tpu":
        return retention_pallas(params, ts)
    if mode == "interpret":
        return retention_pallas(params, ts, interpret=True)
    return ref.retention_ref(params, ts)
