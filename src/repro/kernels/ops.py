"""Public kernel entry points, dispatched through ``repro.kernels.backend``.

On TPU the pallas_call path runs natively; on CPU (this container, including
the 512-device dry-run) the pure-jnp oracle runs instead so the AOT compile
stays tractable.  Backend selection is centralized in
``backend.resolve_backend`` (``REPRO_KERNEL_BACKEND`` env var, legacy
``REPRO_PALLAS_INTERPRET=1``, else device-based).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from repro.kernels import backend as _backend
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.retention_kernel import retention_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas


def _ssm_scan_ref(x, dt, A, Bc, Cc, D):
    h0 = jnp.zeros((x.shape[0], A.shape[0], A.shape[1]), jnp.float32)
    return ref.ssm_scan_ref(x, dt, A, Bc, Cc, D, h0)[0]


_backend.register(
    "attention",
    tpu=_flash_pallas,
    interpret=functools.partial(_flash_pallas, interpret=True),
    xla=ref.attention_ref,
)
_backend.register(
    "ssm_scan",
    tpu=ssm_scan_pallas,
    interpret=functools.partial(ssm_scan_pallas, interpret=True),
    xla=_ssm_scan_ref,
)
_backend.register(
    "retention",
    tpu=retention_pallas,
    interpret=functools.partial(retention_pallas, interpret=True),
    xla=ref.retention_ref,
)


def attention(q, k, v, *, causal=True, backend=None):
    return _backend.dispatch("attention", q, k, v, causal=causal,
                             backend=backend)


def ssm_scan(x, dt, A, Bc, Cc, D, *, backend=None):
    return _backend.dispatch("ssm_scan", x, dt, A, Bc, Cc, D, backend=backend)


def retention_batch(params, ts, *, backend=None):
    return _backend.dispatch("retention", params, ts, backend=backend)
