import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count on init.

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

For each cell this produces artifacts/dryrun/<arch>__<shape>__<mesh>.json with
  - per-device memory analysis (argument/output/temp bytes)
  - per-device cost analysis (HLO flops, bytes accessed)
  - collective traffic parsed from the partitioned HLO (per collective kind)
  - MODEL_FLOPS (6·N·D or 2·N·D with N_active for MoE)
which benchmarks/roofline_table.py turns into the three roofline terms.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import re
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.compat import named_sharding
from repro.configs import SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.models import LM
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel.sharding import (batch_pspec_tree, cache_pspec_tree,
                                     opt_pspec_tree, param_pspec_tree, to_named)
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.train.step import make_train_step

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _bf16_legalization_bytes(hlo_text: str, min_bytes: int = 1 << 28) -> int:
    """Estimate fp32 twin buffers created by CPU bf16 legalization: for every
    large bf16 shape that also occurs as an f32 buffer, count the f32 copy."""
    shapes = set(_SHAPE_RE.findall(hlo_text))
    bf16 = {dims for dt, dims in shapes if dt == "bf16"}
    total = 0
    for dt, dims in shapes:
        if dt == "f32" and dims in bf16:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            if n * 4 >= min_bytes:
                total += n * 4
    return total


_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"\bwhile\(")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_REF_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")


def _split_computations(hlo_text: str):
    """name -> body lines. A computation header is a column-0 line ending in
    '{' (params may contain nested parens, so parse only the leading token)."""
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        if line and line[0] not in " \t}" and line.rstrip().endswith("{"):
            tok = line.strip()
            if tok.startswith("ENTRY"):
                tok = tok[len("ENTRY"):].strip()
            name = tok.split("(", 1)[0].split(" ", 1)[0].strip().lstrip("%")
            if name in ("HloModule",) or not name:
                cur = None
                continue
            cur = name
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
    return comps


def collective_stats(hlo_text: str):
    """Per-device collective traffic by kind, with `while` trip-count
    multiplication: a collective inside a scanned layer body executes
    trip-count times per step, but appears once in the HLO text. Trip counts
    are recovered from the loop-condition constants."""
    comps = _split_computations(hlo_text)

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for line in comps.get(cond_name, ())
                  for c in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    from functools import lru_cache

    @lru_cache(maxsize=None)
    def totals(comp_name: str):
        stats: dict = {}
        for line in comps.get(comp_name, ()):
            m = _COLL_RE.search(line)
            if m:
                b = _shape_bytes(m.group(1))
                st = stats.setdefault(m.group(2), {"count": 0, "bytes": 0})
                st["count"] += 1
                st["bytes"] += b
            if _WHILE_RE.search(line):
                c = _COND_RE.search(line)
                b = _BODY_RE.search(line)
                if b:
                    trips = trip_count(c.group(1)) if c else 1
                    for kind, sub in totals(b.group(1)).items():
                        st = stats.setdefault(kind, {"count": 0, "bytes": 0})
                        st["count"] += sub["count"] * trips
                        st["bytes"] += sub["bytes"] * trips
                continue
            for ref in _REF_RE.findall(line):
                for kind, sub in totals(ref).items():
                    st = stats.setdefault(kind, {"count": 0, "bytes": 0})
                    st["count"] += sub["count"]
                    st["bytes"] += sub["bytes"]
        return stats

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            tok = line[len("ENTRY"):].strip()
            entry = tok.split("(", 1)[0].split(" ", 1)[0].strip().lstrip("%")
            break
    if entry is None or entry not in comps:
        # fallback: flat count (no trip multiplication)
        stats = {}
        for m in _COLL_RE.finditer(hlo_text):
            st = stats.setdefault(m.group(2), {"count": 0, "bytes": 0})
            st["count"] += 1
            st["bytes"] += _shape_bytes(m.group(1))
        return stats
    # deep-copy out of the lru_cache
    return json.loads(json.dumps(totals(entry)))


def model_flops_params(cfg, params_sd):
    """(N_total, N_active): parameter counts; MoE scales routed experts by
    top_k/num_experts."""
    flat = jax.tree_util.tree_flatten_with_path(params_sd)[0]
    total = 0
    expert = 0
    for path, leaf in flat:
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        if "moe" in keys and keys[-1] in ("wg", "wu", "wd") and "shared" not in keys:
            expert += n
    if cfg.moe and cfg.num_experts:
        frac = cfg.top_k / cfg.num_experts
        active = total - expert + expert * frac
    else:
        active = total
    return int(total), int(active)


def _scalar_sh(mesh):
    return named_sharding(mesh)


def build_cell(cfg, shape, mesh, opt_level: int = 0):
    """Returns (jitted_fn, arg_specs) ready for .lower().

    opt_level 0 = paper-faithful baseline sharding; >=1 enables the §Perf
    optimizations (inference param replication, shard_map MoE via
    REPRO_MOE_SHARDMAP)."""
    lm = LM(cfg)
    quant = cfg.name == "deepseek-v3-671b"
    acfg = AdamWConfig(quantized=quant)
    # shard_map MoE only applies to training (inference spreads experts over
    # model x data, where the psum("model") combine wouldn't reach them)
    if opt_level >= 2 and shape.kind == "train":
        os.environ["REPRO_MOE_SHARDMAP"] = "1"
    else:
        os.environ.pop("REPRO_MOE_SHARDMAP", None)
    params_sd = jax.eval_shape(lm.init, jax.random.key(0))
    # --opt >= 1: inference cells replicate params over "data" (no optimizer
    # state to shard -> FSDP gathering is pure waste). Baseline (--opt 0)
    # keeps the uniform train-style sharding.
    pmode = "train" if (shape.kind == "train" or opt_level < 1) else "infer"
    psh = to_named(mesh, param_pspec_tree(mesh, params_sd, mode=pmode))
    batch_sd = lm.input_specs(shape)
    bsh = to_named(mesh, batch_pspec_tree(mesh, batch_sd))

    if shape.kind == "train":
        mb = int(os.environ.get("REPRO_MICROBATCH", "0")) or None
        _, step = make_train_step(cfg, acfg=acfg, microbatch=mb)
        opt_sd = jax.eval_shape(partial(adamw_init, acfg=acfg), params_sd)
        osh = to_named(mesh, opt_pspec_tree(mesh, params_sd, opt_sd))
        f = jax.jit(step,
                    in_shardings=(psh, osh, bsh, _scalar_sh(mesh)),
                    out_shardings=(psh, osh, None),
                    donate_argnums=(0, 1))
        args = (params_sd, opt_sd, batch_sd,
                jax.ShapeDtypeStruct((), jnp.int32))
        return f, args

    if shape.kind == "prefill":
        _, prefill = make_prefill_step(cfg, max_seq=shape.seq_len)
        cache_sd, logits_sd = jax.eval_shape(prefill, params_sd, batch_sd)
        csh = to_named(mesh, cache_pspec_tree(mesh, cache_sd, cfg))
        lsh = to_named(mesh, batch_pspec_tree(mesh, logits_sd))
        f = jax.jit(prefill, in_shardings=(psh, bsh),
                    out_shardings=(csh, lsh))
        return f, (params_sd, batch_sd)

    # decode: one token against a cache of seq_len
    _, decode = make_decode_step(cfg)
    cache_sd = jax.eval_shape(
        partial(lm.init_cache, shape.global_batch, shape.seq_len))
    csh = to_named(mesh, cache_pspec_tree(mesh, cache_sd, cfg))
    logits_sd, _ = jax.eval_shape(decode, params_sd, cache_sd, batch_sd)
    lsh = to_named(mesh, batch_pspec_tree(mesh, logits_sd))
    f = jax.jit(decode, in_shardings=(psh, csh, bsh),
                out_shardings=(lsh, csh), donate_argnums=(1,))
    return f, (params_sd, cache_sd, batch_sd)


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: Path,
             save_hlo: bool = False, opt_level: int = 0):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = outdir / f"{arch}__{shape_name}__{mesh_name}.json"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "seq_len": shape.seq_len,
           "global_batch": shape.global_batch}

    if shape.name == "long_500k" and not cfg.supports_long_context:
        rec["status"] = "skipped"
        rec["reason"] = "full-attention arch; long_500k requires sub-quadratic attention (DESIGN.md §4)"
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[dryrun] SKIP {arch} {shape_name} ({mesh_name})")
        return rec

    rec["opt_level"] = opt_level
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        f, args = build_cell(cfg, shape, mesh, opt_level)
        lowered = f.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {"flops_per_device": float(ca.get("flops", 0.0)),
                       "bytes_per_device": float(ca.get("bytes accessed", 0.0))}
        hlo = compiled.as_text()
        rec["collectives"] = collective_stats(hlo)
        rec["collective_bytes_per_device"] = sum(
            v["bytes"] for v in rec["collectives"].values())
        # XLA:CPU legalizes bf16 through fp32 (no native bf16): large bf16
        # buffers acquire a same-shape fp32 twin that would NOT exist on the
        # TPU backend. Report a corrected estimate alongside the raw number.
        corr = _bf16_legalization_bytes(hlo)
        rec["memory"]["bf16_legalization_bytes"] = corr
        rec["memory"]["peak_bytes_tpu_estimate"] = max(
            0, rec["memory"]["peak_bytes_per_device"] - corr)
        if save_hlo:
            (outdir / f"{arch}__{shape_name}__{mesh_name}.hlo.txt").write_text(hlo)

    params_sd = jax.eval_shape(LM(cfg).init, jax.random.key(0))
    n_total, n_active = model_flops_params(cfg, params_sd)
    tokens = shape.global_batch * (shape.seq_len if shape.kind in ("train", "prefill")
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    rec["params_total"] = n_total
    rec["params_active"] = n_active
    rec["model_flops_global"] = mult * n_active * tokens
    rec["timing"] = {"lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2)}
    rec["status"] = "ok"
    out_path.write_text(json.dumps(rec, indent=2))
    print(f"[dryrun] OK {arch} {shape_name} ({mesh_name}) "
          f"compile={t_compile:.1f}s flops/dev={rec['cost']['flops_per_device']:.3g} "
          f"peak={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
          f"coll={rec['collective_bytes_per_device']/2**20:.1f}MiB")
    return rec


def memory_dse_annotate(cells, outdir: Path):
    """One ``repro.api.explore`` call over every successful cell: derive the
    GainSight-analog L1/L2 requirements from each dry-run record and stamp
    the selected heterogeneous memory mix back into its JSON."""
    from repro.api import SelectionPolicy, explore
    from repro.profiler.traffic import arch_task

    tasks, paths = [], {}
    for rec, out_path in cells:
        if rec.get("status") != "ok":
            continue
        t = arch_task(rec["arch"], rec["shape"], rec)
        tasks.append(t)
        paths[t.task_id] = (rec, out_path)
    if not tasks:
        return
    report = explore(tasks=tasks,
                     policy=SelectionPolicy(allow_refresh=True),
                     cache=outdir / "dse_cache")
    for tid, levels in report.labels().items():
        rec, out_path = paths[tid]
        rec["memory_dse"] = levels
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[dryrun] DSE {tid}: L1={levels['L1']} L2={levels['L2']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--opt", type=int, default=0,
                    help="0=baseline sharding, >=1 perf-optimized")
    ap.add_argument("--dse", action="store_true",
                    help="annotate each compiled cell with its heterogeneous "
                         "L1/L2 memory pick (repro.api.explore)")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    if args.opt >= 2:
        os.environ["REPRO_MOE_SHARDMAP"] = "1"
    if args.opt >= 3:
        # refuted for prefill (see EXPERIMENTS.md §Perf): kept as an explicit
        # opt level so the negative result stays reproducible
        os.environ["REPRO_SEQ_SHARDED"] = "1"
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for arch in list_archs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    failures = 0
    done = []
    for arch, shape in cells:
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        out_path = outdir / f"{arch}__{shape}__{mesh_name}.json"
        if args.skip_existing and out_path.exists():
            rec = json.loads(out_path.read_text())
            if rec.get("status") in ("ok", "skipped"):
                done.append((rec, out_path))
                continue
        try:
            rec = run_cell(arch, shape, args.multi_pod, outdir,
                           save_hlo=args.save_hlo, opt_level=args.opt)
            done.append((rec, out_path))
        except Exception as e:  # record failure, keep sweeping
            failures += 1
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()[-4000:]}
            out_path.write_text(json.dumps(rec, indent=2))
            print(f"[dryrun] FAIL {arch} {shape} ({mesh_name}): {e!r}")
    if args.dse:
        memory_dse_annotate(done, outdir)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
