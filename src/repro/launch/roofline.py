"""Roofline analysis (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), TPU-v5e-class constants:
    compute    = FLOPs / (chips * 197 TFLOP/s)
    memory     = HBM bytes / (chips * 819 GB/s)
    collective = collective bytes / (chips * 50 GB/s per link)

FLOPs/HBM bytes come from an ANALYTIC cost model (below): XLA:CPU's
cost_analysis() counts `while` bodies once (not x trip count), so the raw
HLO numbers undercount scanned-layer work; they are recorded in the dry-run
JSON for reference. Collective bytes DO come from the compiled HLO, with
trip-count multiplication (dryrun.collective_stats).

MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference) is reported
next to the executed-FLOPs estimate; their ratio exposes remat recompute and
blocked-attention masking waste.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Optional

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
LINK_BW = 50e9             # B/s / link


def _attn_dims(cfg):
    if cfg.mla:
        return cfg.qk_nope_dim + cfg.qk_rope_dim, cfg.v_head_dim
    return cfg.head_dim, cfg.head_dim


def analytic_cost(arch: str, shape_name: str, params_total: int,
                  params_active: int) -> Dict[str, float]:
    """Global executed FLOPs + HBM bytes for one cell (whole mesh)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    L, H = cfg.num_layers, cfg.num_heads
    dqk, dv = _attn_dims(cfg)
    di = cfg.d_model * cfg.ssm_expand
    n_full = len(cfg.full_attn_every) if cfg.full_attn_every else (
        L if cfg.family not in ("ssm",) else 0)
    n_swa = L - n_full if cfg.family == "hybrid" else 0
    if cfg.family == "hybrid":
        n_full = len(cfg.full_attn_every)

    tokens = B * S if shape.kind in ("train", "prefill") else B
    matmul_fwd = 2.0 * params_active * tokens

    if shape.kind in ("train", "prefill"):
        # blocked attention computes full (not triangular) S^2 per layer
        attn = 2.0 * B * S * S * H * (dqk + dv) * n_full
        attn += 2.0 * B * S * min(cfg.window, S) * H * (dqk + dv) * n_swa
        ssm = 6.0 * B * S * di * cfg.ssm_state * (L if cfg.family in
                                                  ("hybrid",) else 0)
        if cfg.family == "ssm":
            dh = 2 * cfg.d_model // cfg.num_heads
            ssm = 4.0 * B * S * cfg.num_heads * dh * dh * L  # mLSTM C update
        fwd = matmul_fwd + attn + ssm
        if shape.kind == "train":
            # fwd + backward(2x) + full-remat recompute (+1 fwd)
            flops = 4.0 * fwd
        else:
            flops = fwd
    else:  # decode: one token against an S-length cache
        cache_len = S
        if cfg.family == "hybrid":
            attn = 2.0 * B * (cache_len * n_full + min(cfg.window, cache_len)
                              * n_swa) * H * (dqk + dv)
        elif cfg.family == "ssm":
            dh = 2 * cfg.d_model // cfg.num_heads
            attn = 4.0 * B * cfg.num_heads * dh * dh * L
        elif cfg.mla:
            # absorbed decode: scores + output against the latent cache
            attn = 2.0 * B * cache_len * H * (cfg.kv_lora_rank * 2
                                              + cfg.qk_rope_dim) \
                + 2.0 * B * H * (cfg.qk_nope_dim * cfg.kv_lora_rank
                                 + cfg.kv_lora_rank * cfg.v_head_dim)
        else:
            attn = 2.0 * B * cache_len * H * (dqk + dv) * 1.0
            attn *= L
        if cfg.family not in ("ssm", "hybrid") and not cfg.mla:
            pass
        elif cfg.mla:
            attn *= L
        flops = matmul_fwd + attn
        if cfg.family == "hybrid":
            flops += 6.0 * B * di * cfg.ssm_state * L

    # --- HBM bytes ---------------------------------------------------------
    p_bytes = 2.0 * params_active          # bf16 stream of active params
    d = cfg.d_model
    if shape.kind == "train":
        # params fwd + bwd + grads + fp32 opt m/v read+write + param write
        hbm = 2.0 * params_total * 2 + 2.0 * params_total \
            + 16.0 * params_total + 2.0 * params_total
        hbm += 2.0 * 2 * B * S * d * L * 2     # residual stash write+read (bf16)
        hbm += 2.0 * B * S * d * L * 6         # layer activations traffic (est.)
    elif shape.kind == "prefill":
        hbm = p_bytes + 2.0 * B * S * d * L * 4
        if not (cfg.family == "ssm"):
            kv_unit = (cfg.kv_lora_rank + cfg.qk_rope_dim) if cfg.mla \
                else 2 * cfg.num_kv_heads * cfg.head_dim
            hbm += 2.0 * B * S * kv_unit * L   # cache write
    else:
        hbm = p_bytes
        if cfg.family == "ssm":
            dh = 2 * cfg.d_model // cfg.num_heads
            hbm += 4.0 * B * cfg.num_heads * dh * dh * L
        elif cfg.family == "hybrid":
            hbm += 2.0 * B * (min(cfg.window, S) * 2 * cfg.num_kv_heads
                              * cfg.head_dim * (L - len(cfg.full_attn_every))
                              + S * 2 * cfg.num_kv_heads * cfg.head_dim
                              * len(cfg.full_attn_every))
            hbm += 4.0 * B * di * cfg.ssm_state * L
        elif cfg.mla:
            hbm += 2.0 * B * S * (cfg.kv_lora_rank + cfg.qk_rope_dim) * L
        else:
            hbm += 2.0 * B * S * 2 * cfg.num_kv_heads * cfg.head_dim * L
    return {"flops_global": flops, "hbm_bytes_global": hbm}


def roofline_row(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    chips = 512 if rec["mesh"] == "pod2x16x16" else 256
    ana = analytic_cost(rec["arch"], rec["shape"], rec["params_total"],
                        rec["params_active"])
    t_compute = ana["flops_global"] / (chips * PEAK_FLOPS)
    t_memory = ana["hbm_bytes_global"] / (chips * HBM_BW)
    t_coll = rec["collective_bytes_per_device"] / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    t_bound = terms[bottleneck]
    mfu_bound = (ana["flops_global"] / (chips * PEAK_FLOPS)) / max(t_bound, 1e-30)
    useful = rec["model_flops_global"] / max(ana["flops_global"], 1.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "bottleneck": bottleneck,
        "roofline_fraction": mfu_bound,
        "model_flops": rec["model_flops_global"],
        "executed_flops": ana["flops_global"],
        "useful_flops_ratio": useful,
        "hlo_flops_per_device_raw": rec["cost"]["flops_per_device"],
        "peak_gib_per_device": rec["memory"]["peak_bytes_per_device"] / 2**30,
        "peak_gib_tpu_estimate": rec["memory"].get(
            "peak_bytes_tpu_estimate", rec["memory"]["peak_bytes_per_device"]) / 2**30,
    }


def load_table(outdir="artifacts/dryrun", mesh="pod16x16"):
    rows = []
    for p in sorted(Path(outdir).glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        row = roofline_row(rec)
        if row:
            rows.append(row)
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    rows = load_table(args.out, args.mesh)
    hdr = (f"{'arch':22s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'bound':>10s} {'roofline%':>9s} {'useful%':>8s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:22s} {r['shape']:12s} {r['t_compute_s']:9.2e} "
              f"{r['t_memory_s']:9.2e} {r['t_collective_s']:9.2e} "
              f"{r['bottleneck']:>10s} {100*r['roofline_fraction']:8.1f}% "
              f"{100*r['useful_flops_ratio']:7.1f}%")


if __name__ == "__main__":
    main()
