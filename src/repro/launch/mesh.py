"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
Mesh construction goes through ``repro.compat.make_mesh`` so the axis-type
annotation degrades gracefully across the jax 0.4.x → 0.7.x drift.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))
