"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
