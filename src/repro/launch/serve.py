"""Serving launcher: batched generation with the production cache stack.

    PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --reduced \
        --requests 4 --prompt-len 12 --steps 24
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models import LM
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=None)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    eng = Engine(cfg, params, max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    if cfg.audio_codebooks:
        batch = {"codes": rng.integers(0, cfg.vocab_size,
                                       (args.requests, cfg.audio_codebooks,
                                        args.prompt_len)).astype(np.int32),
                 "cond": rng.normal(size=(args.requests, cfg.cond_len,
                                          cfg.cond_dim)).astype(np.float32)}
    else:
        batch = {"tokens": rng.integers(0, cfg.vocab_size,
                                        (args.requests,
                                         args.prompt_len)).astype(np.int32)}
    t0 = time.time()
    out = eng.generate(batch, steps=args.steps, temperature=args.temperature)
    dt = time.time() - t0
    print(f"{args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
