"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 100 --batch 8 --seq 64

On a real TPU fleet this process runs per-host under the same mesh the
dry-run validated (launch/mesh.py); on CPU it drives the reduced configs
end-to-end with the full substrate: sharded step, checkpointing, supervisor
with restart + straggler detection, resumable data.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import get_config, reduce_config
from repro.data.pipeline import SyntheticLMData
from repro.runtime.supervisor import Supervisor, SupervisorConfig
from repro.train.step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="artifacts/launch_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    lm, step = make_train_step(cfg, base_lr=args.lr, warmup=20,
                               total_steps=args.steps,
                               microbatch=args.microbatch)
    step = jax.jit(step, donate_argnums=(0, 1))
    params, opt = init_train_state(cfg, jax.random.key(0))
    data = SyntheticLMData(cfg, args.batch, args.seq, seed=0)
    ck = Checkpointer(args.ckpt_dir, keep=2)
    start = 0
    if args.resume and ck.latest_step() is not None:
        start, params, opt, dstate = ck.restore(params_template=params,
                                                opt_template=opt)
        data.state.seed, data.state.step = dstate["seed"], dstate["step"]
        print(f"resumed from step {start}")

    sup = Supervisor(step, ck, SupervisorConfig(ckpt_every=args.ckpt_every))
    params, opt, report = sup.run(params, opt, data, total_steps=args.steps,
                                  start_step=start)
    print(f"arch={args.arch} steps={report.steps_run} "
          f"restarts={report.restarts} stragglers={len(report.straggler_events)}")
    print(f"loss first10={np.mean(report.losses[:10]):.4f} "
          f"last10={np.mean(report.losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
