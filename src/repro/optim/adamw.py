"""Sharded AdamW with optional int8-quantized moments.

States mirror the parameter pytree (so they inherit the parameter sharding =
ZeRO-style over the FSDP axis). The int8 mode stores m/v as int8 with
per-tensor-row fp32 scales — 4x smaller optimizer memory, which is what lets
deepseek-v3-671b fit a 16 GB/chip pod (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantized: bool = False


def _q8(x):
    """int8 quantize along the last axis. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q, scale):
    return q.astype(jnp.float32) * scale


def adamw_init(params, acfg: AdamWConfig = AdamWConfig()):
    def zeros_like_moment(p):
        if acfg.quantized and p.ndim >= 1 and p.size >= 1024:
            q = jnp.zeros(p.shape, jnp.int8)
            s = jnp.zeros(p.shape[:-1] + (1,), jnp.float32)
            return {"q": q, "scale": s}
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree.map(zeros_like_moment, params),
        "v": jax.tree.map(zeros_like_moment, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _load(moment, kind="m"):
    if isinstance(moment, dict):
        x = _dq8(moment["q"], moment["scale"])
        return x * x if kind == "v" else x
    return moment


def _store(val, like, kind="m"):
    if isinstance(like, dict):
        # v is quantized in sqrt-domain: Adam consumes sqrt(v), so this puts
        # the int8 resolution where it matters (bitsandbytes-style trick)
        q, s = _q8(jnp.sqrt(jnp.maximum(val, 0.0)) if kind == "v" else val)
        return {"q": q, "scale": s}
    return val


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(grads, state, params, lr, acfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, acfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    count = state["count"] + 1
    c1 = 1.0 - acfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - acfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m_st, v_st):
        g = g.astype(jnp.float32) * scale
        m = acfg.b1 * _load(m_st, "m") + (1 - acfg.b1) * g
        v = acfg.b2 * _load(v_st, "v") + (1 - acfg.b2) * g * g
        mhat = m / c1
        vhat = v / c2
        step = mhat / (jnp.sqrt(vhat) + acfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            step = step + acfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, _store(m, m_st, "m"), _store(v, v_st, "v")

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}, gnorm


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.asarray(
            step, jnp.float32)
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * w * cos
    return lr
