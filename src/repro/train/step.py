"""Train-step factory: loss + grad + AdamW + MoE aux-free bias update.

``make_train_step(cfg)`` returns a pure function
    step(params, opt_state, batch, stepno) -> (params, opt_state, metrics)
suitable for jit with donated (params, opt_state).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import LM, build_plan
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule

MOE_BIAS_LR = 1e-3


def update_moe_bias(cfg, params, load):
    """DeepSeek aux-loss-free balancing: nudge routing bias against load.

    ``load`` (Lmoe, E) stacked over moe segments in plan order."""
    plan = build_plan(cfg)
    row = 0
    params = dict(params)
    for seg in plan:
        if seg.kind != "moe":
            continue
        Ls = len(seg.layers)
        seg_load = load[row: row + Ls]
        row += Ls
        seg_p = dict(params[seg.name])
        moe_p = dict(seg_p["moe"])
        mean = jnp.mean(seg_load, axis=-1, keepdims=True)
        moe_p["bias"] = moe_p["bias"] + MOE_BIAS_LR * jnp.sign(mean - seg_load)
        seg_p["moe"] = moe_p
        params[seg.name] = seg_p
    return params


def make_train_step(cfg, *, base_lr=3e-4, warmup=200, total_steps=10_000,
                    acfg: AdamWConfig = AdamWConfig(), remat="full",
                    microbatch: int | None = None):
    lm = LM(cfg)
    lr_fn = cosine_schedule(base_lr, warmup, total_steps)

    def loss_fn(params, batch):
        return lm.loss(params, batch, remat=remat)

    def grads_of(params, batch):
        if microbatch is None:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        # gradient accumulation over microbatches via scan
        B = jax.tree.leaves(batch)[0].shape[0]
        n = B // microbatch
        mb = jax.tree.map(
            lambda x: x.reshape(n, microbatch, *x.shape[1:]), batch)

        def acc(carry, b):
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
            carry = jax.tree.map(jnp.add, carry, g)
            return carry, (l, m)

        # zeros_like keeps the parameter sharding on the fp32 accumulator
        # (a bare jnp.zeros leaves GSPMD free to replicate 100s of GB)
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                             params)
        gsum, (ls, ms) = jax.lax.scan(acc, zeros, mb)
        grads = jax.tree.map(lambda g: g / n, gsum)
        metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        return (jnp.mean(ls), metrics), grads

    def step(params, opt_state, batch, stepno):
        (loss, metrics), grads = grads_of(params, batch)
        lr = lr_fn(stepno)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params, lr, acfg)
        if "moe_load" in metrics:
            params = update_moe_bias(cfg, params, metrics["moe_load"])
            metrics = {**metrics,
                       "moe_balance": jnp.std(jnp.mean(metrics["moe_load"], 0))}
            metrics.pop("moe_load")
        metrics = {**metrics, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return lm, step


def init_train_state(cfg, key, acfg: AdamWConfig = AdamWConfig()):
    lm = LM(cfg)
    params = lm.init(key)
    opt = adamw_init(params, acfg)
    return params, opt
