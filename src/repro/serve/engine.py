"""Serving: prefill/decode step factories + a minimal batched engine.

The step factories are what the dry-run lowers for the ``prefill_*`` /
``decode_*`` / ``long_*`` cells; the Engine is the runnable CPU-scale
serving loop used by examples/serve_lm.py.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import LM

# per-step serving telemetry (repro.obs): dispatch counts always, wall-time
# histograms [s] + spans when tracing is enabled
_C_PREFILL = obs.counter("serve.prefill_calls")
_C_DECODE = obs.counter("serve.decode_steps")
_H_PREFILL_S = obs.histogram("serve.prefill_s")
_H_DECODE_S = obs.histogram("serve.decode_step_s")
_H_SAMPLE_S = obs.histogram("serve.sample_s")


def make_prefill_step(cfg, max_seq: Optional[int] = None):
    lm = LM(cfg)

    def prefill(params, batch):
        return lm.prefill(params, batch, max_seq=max_seq)

    return lm, prefill


def make_decode_step(cfg):
    lm = LM(cfg)

    def decode(params, cache, batch):
        logits, cache = lm.decode(params, cache, batch)
        return logits, cache

    return lm, decode


def sample_greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_temperature(key, logits, temperature=0.8):
    return jax.random.categorical(key, logits.astype(jnp.float32) / temperature,
                                  axis=-1).astype(jnp.int32)


class Engine:
    """Batched greedy/temperature generation (CPU-scale reference loop)."""

    def __init__(self, cfg, params, max_seq=256):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.lm, prefill = make_prefill_step(cfg, max_seq=max_seq)
        _, decode = make_decode_step(cfg)
        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    def generate(self, batch: Dict[str, Any], steps: int, temperature=None,
                 seed=0):
        t0 = time.perf_counter()
        with obs.span("serve.prefill", probe=self._prefill,
                      batch=int(jax.tree.leaves(batch)[0].shape[0])):
            cache, logits = self._prefill(self.params, batch)
        _C_PREFILL.inc()
        _H_PREFILL_S.observe(time.perf_counter() - t0)
        key = jax.random.key(seed)
        outs = []
        cond = batch.get("cond")
        for i in range(steps):
            # sampling is its own span/histogram: the decode span measures
            # only the model decode dispatch, not the sampler or the
            # np.asarray(tok) host sync that lands between them
            t0 = time.perf_counter()
            with obs.span("serve.sample", step=i):
                if temperature is None:
                    tok = sample_greedy(logits)
                else:
                    key, sk = jax.random.split(key)
                    tok = sample_temperature(sk, logits, temperature)
            _H_SAMPLE_S.observe(time.perf_counter() - t0)
            outs.append(np.asarray(tok))  # host sync, outside both spans
            dec_batch = {"tokens": tok}
            if cond is not None:
                dec_batch["cond"] = cond
            t0 = time.perf_counter()
            with obs.span("serve.decode_step", probe=self._decode, step=i):
                logits, cache = self._decode(self.params, cache, dec_batch)
            _C_DECODE.inc()
            _H_DECODE_S.observe(time.perf_counter() - t0)
        return np.stack(outs, axis=1)  # (B, steps[, nq])
