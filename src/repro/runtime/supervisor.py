"""Fault-tolerant training supervisor.

Design point for 1000+ nodes (DESIGN.md §6), exercised here at CPU scale:
  * periodic atomic checkpoints (async writer)
  * bounded-retry restart-from-latest on step failure (failure injection for
    tests: any exception type, any step)
  * straggler watchdog: step time > `straggler_factor` x rolling median
    triggers a mitigation callback (at scale: re-shard away from the slow
    host; here: recorded + surfaced in metrics)
  * elastic restart: restore onto a different mesh via Checkpointer's
    reshard-on-restore.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import Checkpointer


@dataclass
class SupervisorConfig:
    ckpt_every: int = 50
    max_restarts: int = 3
    straggler_factor: float = 3.0
    heartbeat_every: int = 1


@dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    straggler_events: List[int] = field(default_factory=list)
    losses: List[float] = field(default_factory=list)
    heartbeats: List[float] = field(default_factory=list)


class Supervisor:
    def __init__(self, step_fn: Callable, ckpt: Checkpointer,
                 cfg: SupervisorConfig = SupervisorConfig(),
                 failure_injector: Optional[Callable[[int], None]] = None,
                 straggler_injector: Optional[Callable[[int], float]] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.cfg = cfg
        self.failure_injector = failure_injector
        self.straggler_injector = straggler_injector
        self.report = SupervisorReport()

    def run(self, params, opt_state, data, total_steps: int, start_step: int = 0):
        """Run to `total_steps` with restart-on-failure. Returns
        (params, opt_state, report)."""
        step = start_step
        restarts = 0
        times: List[float] = []
        while step < total_steps:
            try:
                t0 = time.time()
                if self.failure_injector is not None:
                    self.failure_injector(step)
                if self.straggler_injector is not None:
                    time.sleep(self.straggler_injector(step))
                batch = data.next_batch()
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch, step)
                loss = float(jax.device_get(metrics["loss"]))
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                dt = time.time() - t0
                times.append(dt)
                med = float(np.median(times[-20:]))
                if len(times) > 5 and dt > self.cfg.straggler_factor * med:
                    self.report.straggler_events.append(step)
                self.report.losses.append(loss)
                self.report.heartbeats.append(time.time())
                self.report.steps_run += 1
                step += 1
                if step % self.cfg.ckpt_every == 0 or step == total_steps:
                    self.ckpt.save(step, params, opt_state,
                                   data.state.to_dict())
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception:
                restarts += 1
                self.report.restarts = restarts
                if restarts > self.cfg.max_restarts:
                    raise
                # restore from the latest good checkpoint (or step 0 state)
                latest = self.ckpt.latest_step()
                if latest is not None:
                    step, params, opt_state, dstate = self.ckpt.restore(
                        params_template=params, opt_template=opt_state)
                    data.state.seed = dstate["seed"]
                    data.state.step = dstate["step"]
                else:
                    step = start_step
        self.ckpt.wait()
        return params, opt_state, self.report
