"""Error-feedback int8 gradient all-reduce (DP traffic compression).

Wire format per tensor: int8 payload + one fp32 scale (shared across the
replica group via a tiny max-psum), int32 accumulation on receive — 4x less
DP bandwidth than bf16 grads, 8x less than fp32. The quantization error is
carried in a residual buffer and re-injected next step (error feedback), so
convergence matches uncompressed SGD/Adam to first order.

Usage (inside shard_map over the data axis):
    (g_mean, new_resid) = ef_int8_psum_mean(g_local, resid, axis_name="data")
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def ef_int8_psum_mean(g, resid, axis_name: str):
    """Per-leaf error-feedback int8 all-reduce-mean. g/resid: same pytree."""
    n = jax.lax.psum(jnp.ones(()), axis_name)

    def one(g_leaf, r_leaf):
        x = g_leaf.astype(jnp.float32) + r_leaf
        amax_local = jnp.max(jnp.abs(x))
        amax = jax.lax.pmax(amax_local, axis_name)       # shared scale
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = total.astype(jnp.float32) * scale / n
        new_resid = x - q.astype(jnp.float32) * scale    # error feedback
        return mean, new_resid

    flat_g, treedef = jax.tree_util.tree_flatten(g)
    flat_r = treedef.flatten_up_to(resid)
    out = [one(a, b) for a, b in zip(flat_g, flat_r)]
    mean = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_resid = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return mean, new_resid


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_compressed_grad_fn(loss_fn, mesh, axis_name="data"):
    """Wrap a per-replica loss into a shard_map'd compressed-DP gradient fn.

    Returns grad_fn(params, batch, resid) -> (loss_mean, grads_mean, resid').
    Params are replicated across `axis_name`; batch is sharded on dim 0."""
    from repro.compat import shard_map

    def local(params, batch, resid):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        g_mean, new_resid = ef_int8_psum_mean(grads, resid, axis_name)
        return jax.lax.pmean(loss, axis_name), g_mean, new_resid

    def grad_fn(params, batch, resid):
        batch_spec = jax.tree.map(lambda _: P(axis_name), batch)
        rep = jax.tree.map(lambda _: P(), params)
        return shard_map(
            local, mesh=mesh,
            in_specs=(rep, batch_spec, rep),
            out_specs=(P(), rep, rep),
        )(params, batch, resid)

    return grad_fn
