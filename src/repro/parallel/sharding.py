"""Sharding rules: map every parameter / cache / batch leaf to a PartitionSpec.

Axes:
  "data"  — batch + FSDP (ZeRO-style parameter/optimizer sharding)
  "model" — tensor parallel (heads / d_ff / experts / vocab) and
            sequence-parallel decode caches (context parallelism)
  "pod"   — multi-pod extension of the data axis

Rules are keyed by leaf *name*; stacked layer segments add one leading layer
axis which is handled generically (rank = rule rank + 1 -> prepend None).
Any axis whose dimension is not divisible by the mesh extent is dropped
(replicated) — this is what makes one rule table work across all 10 archs
(e.g. kv heads 1/5/8 stay replicated under 16-way TP, the standard practice).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import current_mesh, named_sharding

# rule tables: leaf name -> per-dim axis names (before the stacked-layer dim).
# "D" = data/FSDP axis, "M" = model/TP axis, None = replicated.
_PARAM_RULES: Dict[str, Tuple] = {
    # embeddings / heads
    "embed": ("M", "D"),          # (V, d) vocab-parallel; audio (nq,V,d) handled by rank pad
    "head": ("D", "M"),
    "heads": (None, "D", "M"),
    "meta": (None, None),
    # attention
    "wq": ("D", "M", None),
    "wk": ("D", "M", None),
    "wv": ("D", "M", None),
    "wo": ("M", None, "D"),
    "q_norm": (None,), "k_norm": (None,),
    # mlp
    "wg": ("D", "M"), "wu": ("D", "M"), "wi": ("D", "M"), "wd": ("M", "D"),
    # moe
    "router": ("D", None), "bias": (None,),
    # mla
    "w_dq": ("D", "M"), "w_uq": ("D", "M", None),
    "w_dkv": ("D", "M"), "w_kr": ("D", None),
    "w_uk": ("D", "M", None), "w_uv": ("D", "M", None),
    # ssm
    "w_in": ("D", "M"), "conv_w": (None, "M"), "conv_b": ("M",),
    "w_dt1": ("M", None), "w_dt2": (None, "M"),
    "w_B": ("M", None), "w_C": ("M", None),
    "A_log": ("M", None), "D": ("M",), "b_dt": ("M",),
    "w_out": ("M", "D"),
    # xlstm
    "w_up": ("D", "M"), "w_z": ("D", "M"),
    "w_if": ("M", None), "b_if": (None,),
    "r_g": (None, "M", None), "w_g": ("D", "M"), "b_g": (None,),
    "w_down": ("M", "D"),
    # multimodal
    "w1": (None, "M"), "w2": ("M", "D"),
    "cond_proj": (None, "M"),
    "proj": ("D", "M"),
    # mixers / norms (1-D handled by fallback too)
    "mix_a": (None,), "mix_s": (None,),
}

# MoE expert tensors override the generic mlp names when under a "moe" subtree:
_MOE_RULES: Dict[str, Tuple] = {
    "wg": ("M", "D", None),       # (E, d, f): experts -> EP on model axis
    "wu": ("M", "D", None),
    "wd": ("M", None, "D"),
}


def _axis(mesh: Mesh, tag):
    """Map rule tag to mesh axis name(s)."""
    if tag == "D":
        return ("pod", "data") if "pod" in mesh.axis_names else "data"
    if tag == "M":
        return "model"
    if tag == "E":      # expert dim: spread over model x data (full EP)
        return ("model", "data")
    return None


def _extent(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _spec_for(mesh: Mesh, rule: Tuple, shape: Tuple[int, ...]) -> P:
    if len(shape) == len(rule) + 1:          # stacked layer segment
        rule = (None,) + rule
    if len(shape) != len(rule):
        rule = (None,) * len(shape)
    out = []
    for dim, tag in zip(shape, rule):
        ax = _axis(mesh, tag)
        if ax is not None and dim % _extent(mesh, ax) == 0 and dim > 0:
            out.append(ax)
        elif tag == "E" and dim % mesh.shape["model"] == 0 and dim > 0:
            out.append("model")           # fewer experts than chips: EP=TP
        else:
            out.append(None)
    return P(*out)


def param_pspec_tree(mesh: Mesh, params_shapes, mode: str = "train") -> Any:
    """PartitionSpec pytree for a params pytree (of arrays or SDStructs).

    mode="train": FSDP ("D" tags shard over data) + TP.
    mode="infer": replicate over data, shard over model only — serving has no
    optimizer state, so ZeRO-style gathering is pure collective waste
    (§Perf iteration: removes the per-layer weight all-gathers from
    prefill/decode entirely)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        name = keys[-1] if keys else None
        in_moe = "moe" in keys[:-1] or "shared" in keys[:-1]
        rule = None
        if in_moe and name in _MOE_RULES and "shared" not in keys[:-1]:
            rule = _MOE_RULES[name]
        elif name in _PARAM_RULES:
            rule = _PARAM_RULES[name]
        else:
            rule = (None,) * len(leaf.shape)
        if mode == "infer":
            rule = tuple(None if t == "D" else t for t in rule)
            if in_moe and name in _MOE_RULES and "shared" not in keys[:-1]:
                # replicating 100s-of-GB expert tables over "data" would blow
                # HBM: spread the expert dim over model x data instead
                rule = ("E",) + rule[1:]
        specs.append(_spec_for(mesh, rule, tuple(leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def batch_pspec_tree(mesh: Mesh, batch_shapes) -> Any:
    d = _axis(mesh, "D")

    def one(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) >= 1 and shape[0] % _extent(mesh, d) == 0:
            spec[0] = d
        return P(*spec)

    return jax.tree.map(one, batch_shapes)


def cache_pspec_tree(mesh: Mesh, cache_shapes, cfg) -> Any:
    """Decode caches: batch on data; long sequence dims on model
    (sequence-parallel / context-parallel decode); feature dims on model where
    divisible."""
    m = _axis(mesh, "M")
    d = _axis(mesh, "D")
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    specs = []
    for path, leaf in flat:
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        name = keys[-1] if isinstance(keys[-1], str) else (
            keys[-2] if len(keys) > 1 and isinstance(keys[-2], str) else None)
        shape = tuple(leaf.shape)
        spec = [None] * len(shape)
        if len(shape) == 0 or leaf.dtype == jax.numpy.int32 and len(shape) <= 2:
            specs.append(P(*spec))
            continue
        # leading dims: (Ls, B, ...) — layer axis replicated, batch on data
        if len(shape) >= 2 and shape[1] % _extent(mesh, d) == 0:
            spec[1] = d
        elif len(shape) >= 1 and shape[0] % _extent(mesh, d) == 0 and len(shape) <= 3:
            pass  # states like (Ls,B,..) with tiny B: replicate
        # sequence-parallel: big 3rd dim (cache length) on model
        if len(shape) >= 3 and shape[2] >= 4096 and shape[2] % _extent(mesh, m) == 0:
            spec[2] = m
        elif len(shape) >= 3:
            # feature dims on model if divisible (ssm di, xlstm dh, latent r)
            for i in range(2, len(shape)):
                if shape[i] % _extent(mesh, m) == 0 and shape[i] >= 2 * _extent(mesh, m):
                    spec[i] = m
                    break
        specs.append(P(*spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_pspec_tree(mesh: Mesh, params_shapes, opt_shapes) -> Any:
    """Optimizer-state specs mirror parameter specs (ZeRO via FSDP axis).

    Handles the int8-quantized moment layout {"q": ..., "scale": ...} where
    the scale drops the last (reduced) axis."""
    pspecs = param_pspec_tree(mesh, params_shapes)
    flat_p = {tuple(_key(k) for k in path): spec
              for path, spec in jax.tree_util.tree_flatten_with_path(
                  pspecs, is_leaf=lambda x: isinstance(x, P))[0]}

    def build(moment_tree):
        flat, treedef = jax.tree_util.tree_flatten_with_path(moment_tree)
        out = []
        for path, leaf in flat:
            keys = tuple(_key(k) for k in path)
            if keys and keys[-1] in ("q", "scale"):
                base = flat_p.get(keys[:-1], P())
                if keys[-1] == "scale":
                    out.append(P(*(list(base) + [None]))
                               if len(base) < len(leaf.shape) else
                               P(*(list(base)[:-1] + [None])))
                else:
                    out.append(base)
            else:
                out.append(flat_p.get(keys, P()))
        return jax.tree_util.tree_unflatten(treedef, out)

    return {"m": build(opt_shapes["m"]), "v": build(opt_shapes["v"]),
            "count": P()}


def _key(k):
    return getattr(k, "key", getattr(k, "name", None))


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: named_sharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def maybe_shard(x, spec: P):
    """with_sharding_constraint if a mesh is active, else identity (so model
    code can be mesh-agnostic for CPU smoke tests). Spec entries naming axes
    the active mesh does not have are dropped (replicated), so the same spec
    works on data-only and data x model meshes."""
    env_mesh = current_mesh()
    if env_mesh is None:
        return x
    names = set(env_mesh.axis_names)

    def keep(entry):
        if isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in names)
            return kept or None
        return entry if entry in names else None

    spec = P(*(keep(e) if e is not None else None for e in spec))
    return jax.lax.with_sharding_constraint(x, named_sharding(env_mesh, spec))


def hint(x, *tags):
    """Sharding hint with symbolic tags: "D" (batch/FSDP axes), "M" (model),
    None. Tags on non-divisible dims are dropped; no-op without an active
    mesh. This is how model code pins activation shardings (e.g. keeping the
    batch dim on "data" inside attention) without knowing the mesh."""
    env_mesh = current_mesh()
    if env_mesh is None or len(tags) != x.ndim:
        return x
    spec = []
    for dim, tag in zip(x.shape, tags):
        ax = _axis(env_mesh, tag)
        if ax is not None and dim % _extent(env_mesh, ax) == 0 and dim > 0:
            spec.append(ax)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, named_sharding(env_mesh, P(*spec)))
