"""Device-parallel evaluation of batched grids (leading-axis sharding).

``shard_leading`` runs a batched pure function with its first argument's
leading axis split across every visible device via ``repro.compat.make_mesh``
+ ``repro.compat.shard_map``; remaining arguments are replicated. The grid is
padded to a device-count multiple and un-padded on the way out, so callers
never see the device count. On a 1-device host it degrades to a plain call —
the result is bit-identical either way (same kernel, same math, only the
placement differs), which is what lets the hetero composition tests assert
sharded == single-device.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map

GRID_AXIS = "grid"


def pad_to_multiple(x, multiple: int):
    """Pad ``x``'s leading axis up to a multiple of ``multiple`` by repeating
    its first row (values are discarded by the caller's un-pad slice).

    Returns ``(padded, original_length)``."""
    n = x.shape[0]
    if multiple <= 1 or n % multiple == 0:
        return x, n
    pad = multiple - n % multiple
    fill = jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])
    return jnp.concatenate([x, fill], axis=0), n


def shard_leading(fn, x, *rest, devices: Optional[Sequence] = None,
                  axis_name: str = GRID_AXIS):
    """Evaluate ``fn(x, *rest)`` with ``x``'s leading axis sharded.

    ``fn``     pure, shape-polymorphic over the leading axis of ``x``; every
               output leaf must carry that leading axis.
    ``x``      the grid array, shape ``(J, ...)``.
    ``rest``   broadcast (replicated) arguments — arrays or pytrees.
    ``devices`` defaults to ``jax.devices()``; with one device the call is a
               plain ``fn(x, *rest)``.

    Returns ``fn``'s output with every leaf un-padded back to length ``J``.
    """
    devs = list(devices) if devices is not None else jax.devices()
    n_dev = len(devs)
    if n_dev <= 1:
        return fn(x, *rest)
    mesh = make_mesh((n_dev,), (axis_name,), devices=devs)
    xp, n = pad_to_multiple(jnp.asarray(x), n_dev)
    sharded = shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis_name),) + (P(),) * len(rest),
        out_specs=P(axis_name), check_rep=False)
    out = sharded(xp, *rest)
    return jax.tree.map(lambda leaf: leaf[:n], out)
