"""Device-parallel evaluation of batched grids (1D and 2D sharding).

``shard_leading`` runs a batched pure function with its first argument's
leading axis split across every visible device via ``repro.compat.make_mesh``
+ ``repro.compat.shard_map``; remaining arguments are replicated. The grid is
padded to a device-count multiple and un-padded on the way out, so callers
never see the device count. On a 1-device host it degrades to a plain call —
the result is bit-identical either way (same kernel, same math, only the
placement differs), which is what lets the hetero composition tests assert
sharded == single-device.

``shard2d`` generalizes this to a 2D device mesh for doubly-batched work
(e.g. compositions × operating corners): the first argument's leading axis
shards over one mesh axis and the second argument's over the other, with the
device count factorized between them. Same contract: padded in, un-padded
out, bit-identical to the unsharded call.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.compat import make_mesh, shard_map

GRID_AXIS = "grid"
CORNER_AXIS = "corner"

# multi-device dispatches (repro.obs registry); single-device calls take the
# plain-call fast path and are deliberately not counted as "sharded"
_C_SHARD = obs.counter("parallel.shard_calls")


def pad_to_multiple(x, multiple: int):
    """Pad ``x``'s leading axis up to a multiple of ``multiple`` by repeating
    its first row (values are discarded by the caller's un-pad slice).

    Returns ``(padded, original_length)``."""
    n = x.shape[0]
    if multiple <= 1 or n % multiple == 0:
        return x, n
    pad = multiple - n % multiple
    fill = jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])
    return jnp.concatenate([x, fill], axis=0), n


def shard_leading(fn, x, *rest, devices: Optional[Sequence] = None,
                  axis_name: str = GRID_AXIS):
    """Evaluate ``fn(x, *rest)`` with ``x``'s leading axis sharded.

    ``fn``     pure, shape-polymorphic over the leading axis of ``x``; every
               output leaf must carry that leading axis.
    ``x``      the grid array, shape ``(J, ...)``.
    ``rest``   broadcast (replicated) arguments — arrays or pytrees.
    ``devices`` defaults to ``jax.devices()``; with one device the call is a
               plain ``fn(x, *rest)``.

    Returns ``fn``'s output with every leaf un-padded back to length ``J``.
    """
    devs = list(devices) if devices is not None else jax.devices()
    n_dev = len(devs)
    if n_dev <= 1:
        return fn(x, *rest)
    with obs.span("parallel.shard", mesh="1d", n_dev=n_dev):
        _C_SHARD.inc()
        mesh = make_mesh((n_dev,), (axis_name,), devices=devs)
        xp, n = pad_to_multiple(jnp.asarray(x), n_dev)
        sharded = shard_map(
            fn, mesh=mesh,
            in_specs=(P(axis_name),) + (P(),) * len(rest),
            out_specs=P(axis_name), check_rep=False)
        out = sharded(xp, *rest)
        return jax.tree.map(lambda leaf: leaf[:n], out)


def _factor_devices(n_dev: int, minor_n: int) -> Tuple[int, int]:
    """Split ``n_dev`` into ``(major_ways, minor_ways)``: the minor axis gets
    the largest divisor of ``n_dev`` not exceeding its extent ``minor_n`` (no
    point cutting a 2-corner axis 8 ways), the major axis the rest."""
    minor_ways = max(d for d in range(1, n_dev + 1)
                     if n_dev % d == 0 and d <= max(minor_n, 1))
    return n_dev // minor_ways, minor_ways


def shard2d(fn, x, y, *rest, devices: Optional[Sequence] = None,
            axis_names: Tuple[str, str] = (GRID_AXIS, CORNER_AXIS)):
    """Evaluate ``fn(x, y, *rest)`` on a 2D device mesh.

    ``fn``     pure; shape-polymorphic over the leading axis of every ``x``
               leaf and of every ``y`` leaf; every output leaf must carry
               ``(y_leading, x_leading)`` as its first two axes.
    ``x``      array or pytree whose leaves share leading extent ``J`` —
               sharded over mesh axis ``axis_names[0]``.
    ``y``      array or pytree whose leaves share leading extent ``C`` —
               sharded over mesh axis ``axis_names[1]``.
    ``rest``   broadcast (replicated) arguments.
    ``devices`` defaults to ``jax.devices()``; the device count factorizes
               across the two axes (minor ``y`` axis first, capped at ``C``);
               with one device the call is a plain ``fn(x, y, *rest)``.

    Both leading axes are padded to mesh-shape multiples and un-padded on the
    way out, so results are bit-identical to the unsharded call.
    """
    devs = list(devices) if devices is not None else jax.devices()
    n_dev = len(devs)
    if n_dev <= 1:
        return fn(x, y, *rest)
    with obs.span("parallel.shard", mesh="2d", n_dev=n_dev):
        _C_SHARD.inc()
        n_x = jax.tree.leaves(x)[0].shape[0]
        n_y = jax.tree.leaves(y)[0].shape[0]
        ways_x, ways_y = _factor_devices(n_dev, n_y)
        ax_x, ax_y = axis_names
        mesh = make_mesh((ways_x, ways_y), (ax_x, ax_y), devices=devs)
        xp = jax.tree.map(
            lambda leaf: pad_to_multiple(jnp.asarray(leaf), ways_x)[0], x)
        yp = jax.tree.map(
            lambda leaf: pad_to_multiple(jnp.asarray(leaf), ways_y)[0], y)
        sharded = shard_map(
            fn, mesh=mesh,
            in_specs=(P(ax_x), P(ax_y)) + (P(),) * len(rest),
            out_specs=P(ax_y, ax_x), check_rep=False)
        out = sharded(xp, yp, *rest)
        return jax.tree.map(lambda leaf: leaf[:n_y, :n_x], out)
