"""Render a stage-time / counter table from a trace file or live state.

``python -m repro.obs report trace.json`` aggregates the span events —
calls, total/mean/max wall time [ms], compile events (spans that paid a
``new_traces`` jit compilation), errors — and appends the counter /
gauge / histogram snapshot. Works on both export formats.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence


def aggregate(events: Sequence[Dict]) -> Dict[str, Dict[str, float]]:
    """Per-span-name rollup of the raw events."""
    agg: Dict[str, Dict[str, float]] = {}
    for e in events:
        row = agg.setdefault(e["name"], {
            "calls": 0, "total_ms": 0.0, "max_ms": 0.0,
            "compiles": 0, "new_traces": 0, "errors": 0})
        dur_ms = float(e.get("dur", 0.0)) / 1e3
        row["calls"] += 1
        row["total_ms"] += dur_ms
        row["max_ms"] = max(row["max_ms"], dur_ms)
        args = e.get("args") or {}
        if args.get("new_traces"):
            row["compiles"] += 1
            row["new_traces"] += int(args["new_traces"])
        if "error" in args:
            row["errors"] += 1
    for row in agg.values():
        row["mean_ms"] = row["total_ms"] / row["calls"] if row["calls"] else 0
    return agg


def render(events: Optional[Sequence[Dict]] = None,
           metrics: Optional[Dict] = None) -> str:
    """The report text (defaults: live tracer/registry state)."""
    if events is None:
        from repro.obs import trace
        events = trace.events()
    if metrics is None:
        from repro.obs import metrics as metrics_mod
        metrics = metrics_mod.REGISTRY.snapshot()
    lines: List[str] = []
    agg = aggregate(events)
    if agg:
        lines.append(f"{'span':34s} {'calls':>6s} {'total_ms':>10s} "
                     f"{'mean_ms':>10s} {'max_ms':>10s} {'compiles':>8s} "
                     f"{'errors':>6s}")
        for name, r in sorted(agg.items(), key=lambda kv: -kv[1]["total_ms"]):
            lines.append(f"{name:34s} {r['calls']:6d} {r['total_ms']:10.3f} "
                         f"{r['mean_ms']:10.3f} {r['max_ms']:10.3f} "
                         f"{r['compiles']:8d} {r['errors']:6d}")
    else:
        lines.append("no span events (tracing was off, or nothing ran)")
    counters = metrics.get("counters") or {}
    if counters:
        lines.append("")
        lines.append(f"{'counter':46s} {'value':>12s}")
        for name, v in sorted(counters.items()):
            lines.append(f"{name:46s} {v:12d}")
    gauges = metrics.get("gauges") or {}
    if gauges:
        lines.append("")
        lines.append(f"{'gauge':46s} {'value':>12s}")
        for name, v in sorted(gauges.items()):
            lines.append(f"{name:46s} {v:12.4g}")
    hists = metrics.get("histograms") or {}
    if hists:
        lines.append("")
        lines.append(f"{'histogram':34s} {'count':>6s} {'mean':>12s} "
                     f"{'min':>12s} {'max':>12s}")
        for name, h in sorted(hists.items()):
            lines.append(
                f"{name:34s} {h['count']:6d} {h['mean']:12.4g} "
                f"{(h['min'] if h['min'] is not None else 0):12.4g} "
                f"{(h['max'] if h['max'] is not None else 0):12.4g}")
    return "\n".join(lines)


def render_file(path) -> str:
    """The report text for a written trace file (either export format)."""
    from repro.obs import export
    events, metrics = export.read(path)
    return render(events, metrics)
