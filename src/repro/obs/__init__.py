"""repro.obs — structured tracing + metrics for the DSE pipeline.

Two halves (see docs/OBSERVABILITY.md for the catalog and contracts):

- ``trace``: gated context-manager spans (``REPRO_TRACE=out.json`` /
  ``Compiler(telemetry=True)`` / ``enabled_scope``). Off by default and
  provably free: no events, no timestamps, bit-identical numerics.
- ``metrics``: always-on counters/gauges/histograms — the registry the
  cache-proof counters (characterize/compose/sim eval counts) live on.

Stdlib-only: importing or using repro.obs can never add a jax dependency,
a jit site, or a trace-cache entry to the instrumented hot paths.
"""
from repro.obs.metrics import (  # noqa: F401
    REGISTRY, Counter, Gauge, Histogram, Registry, counter, gauge,
    histogram, snapshot, value,
)
from repro.obs.trace import (  # noqa: F401
    clear, disable, enable, enabled, enabled_scope, events, span, write,
)

__all__ = [
    "span", "enabled", "enable", "disable", "enabled_scope",
    "events", "clear", "write",
    "counter", "gauge", "histogram", "value", "snapshot",
    "REGISTRY", "Registry", "Counter", "Gauge", "Histogram",
]
