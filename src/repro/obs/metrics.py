"""Process-global metrics registry: counters, gauges, histograms.

Counters are **always on** — they are plain Python int increments with no
numeric effect on any pipeline output, which is what lets the cache-proof
counters (``api.characterize_call_count``, ``hetero.composition_eval_count``,
``sim.sim_eval_count``) live here without an enable flag. Spans
(``repro.obs.trace``) are the gated, timestamp-bearing half.

Naming follows the repo's unit-suffix convention (the US analyzer family):
a metric carrying a physical unit ends in its suffix (``serve.prefill_s``
is seconds); bare counts (``hetero.cache_hits``) carry none. The full
catalog lives in ``repro.obs.catalog`` and is documentation-gated by the
DC04 analyzer rule.

Stdlib-only, thread-safe at the registry level (creation under a lock;
int/float updates ride the GIL like the pre-existing module counters did).
"""
from __future__ import annotations

import threading
from typing import Dict, Optional


class Counter:
    """Monotonic event count."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-written value (e.g. a configured size)."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming summary: count / total / min / max (mean derived)."""
    __slots__ = ("name", "count", "total", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class Registry:
    """Name → instrument map; ``get-or-create`` accessors are idempotent."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram(name)
            return h

    def value(self, name: str, default: int = 0) -> int:
        """A counter's current value (``default`` if never created)."""
        with self._lock:
            c = self._counters.get(name)
            return c.value if c is not None else default

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Plain-dict view of every instrument (JSON-ready)."""
        with self._lock:
            return {
                "counters": {n: c.value for n, c in self._counters.items()},
                "gauges": {n: g.value for n, g in self._gauges.items()},
                "histograms": {
                    n: {"count": h.count, "total": h.total, "min": h.min,
                        "max": h.max, "mean": h.mean}
                    for n, h in self._hists.items()},
            }

    def reset(self) -> None:
        """Zero every instrument, keeping registered names alive (so
        pre-registered catalog metrics still appear in snapshots)."""
        with self._lock:
            for c in self._counters.values():
                c.value = 0
            for g in self._gauges.values():
                g.value = 0.0
            for h in self._hists.values():
                h.count, h.total, h.min, h.max = 0, 0.0, None, None


REGISTRY = Registry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)


def value(name: str, default: int = 0) -> int:
    return REGISTRY.value(name, default)


def snapshot() -> Dict[str, Dict[str, object]]:
    return REGISTRY.snapshot()


def reset(_unused: Optional[object] = None) -> None:
    REGISTRY.reset()
