"""CLI: ``python -m repro.obs report trace.json`` → stage-time table."""
from __future__ import annotations

import argparse
import sys

from repro.obs import report as report_mod


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="repro.obs trace tooling (see docs/OBSERVABILITY.md)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p_report = sub.add_parser(
        "report", help="render a stage-time/counter table from a trace file")
    p_report.add_argument("trace", help="trace file (.json Chrome format "
                                        "or .jsonl event log)")
    args = ap.parse_args(argv)
    if args.cmd == "report":
        print(report_mod.render_file(args.trace))
    return 0


if __name__ == "__main__":
    sys.exit(main())
