"""The observability catalog: every span and metric name, as pure data.

Stdlib-only and free of intra-package imports on purpose — like
``repro.analysis.rules`` this file is loaded standalone via importlib by
``scripts/check_docs.py`` and the DC04 analyzer rule, which require every
name below to be documented in ``docs/OBSERVABILITY.md``. Instrumented
modules do NOT import this file; it is the audit surface, not the API.

``kernels.dispatch.<op>.<backend>`` is a *pattern* entry: the dispatch
counter family is keyed per (op, backend) pair at runtime and ``covers()``
matches any concrete name against it.
"""
from __future__ import annotations

# span name -> (where it is emitted, what it measures)
SPANS = {
    "api.compile": ("repro.api.Compiler.compile",
                    "single-macro characterization (one config, no vmap)"),
    "api.characterize": ("repro.api.DesignTable.from_configs",
                         "vmap characterization sweep over the config grid "
                         "(nominal or corner-batched)"),
    "api.table_build": ("repro.api.DesignTable.build",
                        "table construction incl. the npz cache consult"),
    "api.explore": ("repro.api.explore",
                    "independent per-level DSE over all tasks"),
    "hetero.compose": ("repro.hetero.compose.compose",
                       "one joint composition call end to end "
                       "(cache consult, candidates, search, materialize)"),
    "hetero.search": ("repro.hetero.compose.compose",
                      "the grid ranking stage: exhaustive cross-product or "
                      "branch-and-bound enumeration"),
    "hetero.expand": ("repro.hetero.compose.compose",
                      "operating-point expansion: per-(vdd point x refresh "
                      "margin) metric blocks for the vdd_sweep search axis"),
    "hetero.score": ("repro.hetero.system.score_grid[_corners]",
                     "one batched composition-scoring dispatch "
                     "(probe: the score jit — new_traces on first compile)"),
    "sim.replay": ("repro.sim.engine.simulate_traces",
                   "batched trace replay over all phases of one call"),
    "sim.replay_phase": ("repro.sim.engine.simulate_traces",
                         "one phase's vmapped scan dispatch "
                         "(probe: the sim-grid jit)"),
    "sim.rerank": ("repro.sim.rerank.simulate_report",
                   "simulate-then-rerank refinement incl. the sim cache "
                   "consult"),
    "parallel.shard": ("repro.parallel.grid.shard_leading/shard2d",
                       "device-mesh setup + sharded dispatch (multi-device "
                       "hosts only; single-device calls are plain)"),
    "serve.prefill": ("repro.serve.engine.Engine.generate",
                      "the prefill dispatch of one generate() call"),
    "serve.sample": ("repro.serve.engine.Engine.generate",
                     "host-side token sampling for one decode step"),
    "serve.decode_step": ("repro.serve.engine.Engine.generate",
                          "one decode step's model decode dispatch (sampling "
                          "and the host sync are outside this span)"),
}

# metric name -> (kind, what it counts/measures)
METRICS = {
    "api.characterize_calls": (
        "counter", "vmap characterization sweeps executed "
        "(backs api.characterize_call_count — cache hits leave it flat)"),
    "api.table_cache_hits": (
        "counter", "DesignTable.build npz cache hits"),
    "api.table_cache_misses": (
        "counter", "DesignTable.build npz cache misses (cache consulted, "
        "table re-characterized)"),
    "hetero.compose_evals": (
        "counter", "batched composition scoring sweeps "
        "(backs hetero.composition_eval_count)"),
    "hetero.cache_hits": (
        "counter", "composition-report npz cache hits in compose()"),
    "hetero.cache_misses": (
        "counter", "composition-report npz cache misses in compose()"),
    "hetero.search_nodes": (
        "counter", "lattice nodes actually scored by branch_and_bound"),
    "hetero.search_batches": (
        "counter", "fixed-shape scoring batches branch_and_bound flushed"),
    "hetero.search_pruned": (
        "counter", "compositions proven prunable by the bound "
        "(full cross-product size minus nodes scored)"),
    "hetero.expanded_points": (
        "counter", "virtual (operating point x refresh margin) metric "
        "blocks built for vdd_sweep/refresh_margin_sweep searches"),
    "sim.replay_calls": (
        "counter", "batched trace-replay sweeps "
        "(backs sim.sim_eval_count — a sim-cache hit leaves it flat)"),
    "sim.cache_hits": (
        "counter", "sim-report npz cache hits in simulate_report()"),
    "sim.cache_misses": (
        "counter", "sim-report npz cache misses in simulate_report()"),
    "kernels.dispatch.<op>.<backend>": (
        "counter", "kernel-registry dispatches per (op, resolved backend), "
        "e.g. kernels.dispatch.sim_replay.xla"),
    "parallel.shard_calls": (
        "counter", "sharded (multi-device) grid dispatches"),
    "serve.prefill_calls": (
        "counter", "Engine.generate prefill dispatches"),
    "serve.decode_steps": (
        "counter", "Engine.generate decode steps"),
    "serve.prefill_s": (
        "histogram", "wall time of each prefill dispatch [s]"),
    "serve.decode_step_s": (
        "histogram", "wall time of each decode step's model dispatch [s]"),
    "serve.sample_s": (
        "histogram", "wall time of host-side sampling per decode step [s]"),
}


def covers(name: str) -> bool:
    """Is a concrete runtime span/metric name covered by the catalog?
    Exact entries match literally; entries containing ``<`` are prefix
    patterns (everything before the first ``<`` must prefix ``name``)."""
    if name in SPANS or name in METRICS:
        return True
    for entry in (*SPANS, *METRICS):
        head = entry.split("<", 1)[0]
        if "<" in entry and name.startswith(head):
            return True
    return False
