"""Trace exports: Chrome trace-event JSON (Perfetto-loadable) and JSONL.

Chrome format (``.json``): one ``{"traceEvents": [...], "otherData": ...}``
object — "X" (complete) events for spans with µs timestamps/durations, and
"C" (counter) events for every registry counter at the trace end so the
counters render as tracks in Perfetto/``chrome://tracing``. The full
metrics snapshot also rides verbatim in ``otherData["metrics"]``.

JSONL format (``.jsonl``): one JSON object per line — ``{"type": "span",
...event...}`` per span plus a final ``{"type": "metrics", ...}`` record.
Grep/stream-friendly; round-trips through ``read()`` losslessly.

``read()`` sniffs the format and returns ``(events, metrics)`` for either.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

SCHEMA_VERSION = 1


def _resolve(events, metrics):
    if events is None:
        from repro.obs import trace
        events = trace.events()
    if metrics is None:
        from repro.obs import metrics as metrics_mod
        metrics = metrics_mod.REGISTRY.snapshot()
    return events, metrics


def chrome_trace(events: Optional[Sequence[Dict]] = None,
                 metrics: Optional[Dict] = None) -> Dict[str, object]:
    """Build the Chrome trace-event object (defaults: live tracer state)."""
    events, metrics = _resolve(events, metrics)
    pid = os.getpid()
    out: List[Dict[str, object]] = []
    ts_end = 0.0
    for e in events:
        ts, dur = float(e.get("ts", 0.0)), float(e.get("dur", 0.0))
        ts_end = max(ts_end, ts + dur)
        out.append({
            "name": e["name"], "cat": e.get("cat", "repro"), "ph": "X",
            "ts": round(ts, 3), "dur": round(dur, 3),
            "pid": pid, "tid": e.get("tid", 0),
            "args": dict(e.get("args", {}), depth=e.get("depth", 0)),
        })
    for name, value in sorted((metrics.get("counters") or {}).items()):
        out.append({"name": name, "cat": "metrics", "ph": "C",
                    "ts": round(ts_end, 3), "pid": pid, "tid": 0,
                    "args": {"value": value}})
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"schema": SCHEMA_VERSION, "metrics": metrics}}


def write_chrome(path, events: Optional[Sequence[Dict]] = None,
                 metrics: Optional[Dict] = None) -> str:
    payload = chrome_trace(events, metrics)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(payload, f, default=str)
    return str(path)


def write_jsonl(path, events: Optional[Sequence[Dict]] = None,
                metrics: Optional[Dict] = None) -> str:
    events, metrics = _resolve(events, metrics)
    with open(path, "w", encoding="utf-8") as f:
        for e in events:
            f.write(json.dumps({"type": "span", **e}, default=str) + "\n")
        f.write(json.dumps({"type": "metrics", "schema": SCHEMA_VERSION,
                            "metrics": metrics}, default=str) + "\n")
    return str(path)


def write(path, events: Optional[Sequence[Dict]] = None,
          metrics: Optional[Dict] = None) -> str:
    """Write by suffix: ``.jsonl`` → JSON-lines, else Chrome trace JSON."""
    if str(path).endswith(".jsonl"):
        return write_jsonl(path, events, metrics)
    return write_chrome(path, events, metrics)


def read(path) -> Tuple[List[Dict[str, object]], Dict[str, object]]:
    """Load either export format back into ``(span events, metrics)``."""
    with open(path, "r", encoding="utf-8") as f:
        text = f.read()
    stripped = text.lstrip()
    if stripped.startswith("{") and "\"traceEvents\"" in stripped[:200]:
        payload = json.loads(text)
        events = []
        for e in payload.get("traceEvents", []):
            if e.get("ph") != "X":
                continue
            args = dict(e.get("args", {}))
            depth = args.pop("depth", 0)
            events.append({"name": e["name"], "cat": e.get("cat", "repro"),
                           "ph": "X", "ts": e.get("ts", 0.0),
                           "dur": e.get("dur", 0.0), "tid": e.get("tid", 0),
                           "depth": depth, "args": args})
        metrics = (payload.get("otherData") or {}).get("metrics") or {}
        return events, metrics
    events, metrics = [], {}
    for line in text.splitlines():
        if not line.strip():
            continue
        rec = json.loads(line)
        kind = rec.pop("type", "span")
        if kind == "metrics":
            metrics = rec.get("metrics", {})
        else:
            events.append(rec)
    return events, metrics
