"""Zero-dependency span tracer: wall-clock spans with jit-compile deltas.

``span("hetero.score", probe=_score_jit, J=4096)`` is a context manager
that records one trace event — name, category, start timestamp and
duration [µs], nesting depth, thread id, and arbitrary JSON-serializable
``args``. When tracing is *disabled* (the default) ``span()`` returns a
shared no-op singleton: no allocation, no timestamp read, no lock — the
instrumented hot paths pay one module-global boolean check.

Contract highlights (docs/OBSERVABILITY.md spells out the full catalog):

- **exception safety**: a span body that raises still closes its event
  (the exception type lands in ``args["error"]``) and the exception
  propagates unchanged — tracing never swallows errors.
- **compile-vs-execute split**: pass ``probe=<jitted fn>`` and the span
  diffs the function's ``_cache_size()`` across its body; a nonzero delta
  lands in ``args["new_traces"]``, so a trace shows exactly which call
  paid a compilation. The probe is read, never wrapped — the jit cache
  key and trace count of the probed function are untouched.
- **nesting**: per-thread depth is recorded on every event, so exporters
  can reconstruct the span tree without parent pointers.
- **activation**: ``REPRO_TRACE=out.json`` in the environment enables
  tracing at import and writes the Chrome-trace file at process exit;
  ``enabled_scope(True)`` / ``enable()`` do the same programmatically
  (``repro.api.Compiler(telemetry=True)`` wraps its calls in a scope).

Everything here is stdlib-only: no jax, no numpy — the tracer itself can
never add a jit trace-cache entry (RC budgets) or touch numerics.
"""
from __future__ import annotations

import atexit
import os
import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Optional

# process epoch: event timestamps are µs since this module was imported
_T0 = time.perf_counter()

_lock = threading.Lock()
_events: List[Dict[str, object]] = []
_enabled = False
_out_path: Optional[str] = None
_tls = threading.local()


def enabled() -> bool:
    """Is span recording currently on?"""
    return _enabled


def enable(path: Optional[str] = None) -> None:
    """Turn span recording on; ``path`` (optional) is where ``write()`` /
    the atexit flush will put the Chrome-trace file."""
    global _enabled, _out_path
    if path is not None:
        _out_path = str(path)
    _enabled = True


def disable() -> None:
    """Turn span recording off (already-recorded events are kept)."""
    global _enabled
    _enabled = False


@contextmanager
def enabled_scope(on: bool = True):
    """Force tracing on (or off) inside the block, restoring the previous
    state on exit — the scope ``Compiler(telemetry=True)`` uses."""
    global _enabled
    prev = _enabled
    _enabled = bool(on)
    try:
        yield
    finally:
        _enabled = prev


def _probe_size(probe) -> Optional[int]:
    """Trace-cache size of a jitted callable, via the same ``_cache_size()``
    API the RC analyzer budgets; None when the probe has no such API."""
    size = getattr(probe, "_cache_size", None)
    if callable(size):
        try:
            return int(size())
        except Exception:
            return None
    return None


class _NullSpan:
    """Shared do-nothing span returned while tracing is disabled."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):
        return self


_NULL = _NullSpan()


class Span:
    """One live span (use via ``span(...)``, not directly)."""
    __slots__ = ("name", "cat", "args", "_probe", "_t0", "_cache0", "_depth")

    def __init__(self, name: str, cat: str, probe, args: Dict[str, object]):
        self.name = name
        self.cat = cat
        self.args = args
        self._probe = probe

    def set(self, **kw):
        """Attach extra args mid-span (e.g. results known only at the end)."""
        self.args.update(kw)
        return self

    def __enter__(self):
        self._depth = getattr(_tls, "depth", 0)
        _tls.depth = self._depth + 1
        self._cache0 = _probe_size(self._probe) \
            if self._probe is not None else None
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        _tls.depth = self._depth
        args = dict(self.args)
        if self._cache0 is not None:
            c1 = _probe_size(self._probe)
            if c1 is not None and c1 != self._cache0:
                args["new_traces"] = c1 - self._cache0
        if exc_type is not None:
            args["error"] = exc_type.__name__
        event = {
            "name": self.name,
            "cat": self.cat,
            "ph": "X",
            "ts": (self._t0 - _T0) * 1e6,       # µs since process epoch
            "dur": (t1 - self._t0) * 1e6,       # µs
            "tid": threading.get_ident() & 0xFFFFFFFF,
            "depth": self._depth,
            "args": args,
        }
        with _lock:
            _events.append(event)
        return False                             # never swallow the exception


def span(name: str, cat: str = "repro", probe=None, **args):
    """Context manager recording one trace event (no-op when disabled).

    ``probe``: optional jitted callable whose ``_cache_size()`` delta across
    the span body is reported as ``args["new_traces"]``.
    """
    if not _enabled:
        return _NULL
    return Span(name, cat, probe, args)


def events() -> List[Dict[str, object]]:
    """Snapshot (copy) of every recorded event so far."""
    with _lock:
        return list(_events)


def clear() -> None:
    """Drop all recorded events (the enabled flag is untouched)."""
    with _lock:
        _events.clear()


def write(path: Optional[str] = None) -> Optional[str]:
    """Flush recorded events + the metrics snapshot to ``path`` (or the
    ``REPRO_TRACE``/``enable(path=...)`` destination). Format by suffix:
    ``.jsonl`` → JSON-lines, anything else → Chrome trace-event JSON.
    Returns the path written, or None if there was nowhere to write."""
    from repro.obs import export, metrics
    dest = path or _out_path
    if dest is None:
        return None
    export.write(dest, events(), metrics.REGISTRY.snapshot())
    return dest


def _flush_at_exit() -> None:
    if _out_path is not None and (_events or _enabled):
        try:
            write()
        except Exception:                        # never break interpreter exit
            pass


atexit.register(_flush_at_exit)

_env_path = os.environ.get("REPRO_TRACE")
if _env_path:
    enable(_env_path)
