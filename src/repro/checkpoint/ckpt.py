"""Checkpointing: atomic, async-capable, reshard-on-restore.

Format: one .npz with path-flattened leaves + a JSON manifest (step, data
state, tree structure, checksums). Writes go to a tmp dir + os.replace so a
crash mid-write never corrupts the latest checkpoint. `restore(..., mesh=)`
re-device_puts every leaf with the target mesh's shardings — this is what
lets a 512-chip checkpoint restart on a 256-chip mesh after a pod loss
(elastic downscale; see runtime.supervisor).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.compat import replicated_like

SEP = "|"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(template, arrays: Dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = SEP.join(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                       for k in path)
        arr = arrays[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory, keep: int = 3, async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, params, opt_state, data_state: Dict[str, Any],
             block: bool = False):
        params_np = _flatten(jax.device_get(params))
        opt_np = _flatten(jax.device_get(opt_state))
        self.wait()

        def _write():
            tmp = self.dir / f".tmp_step_{step}"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "params.npz", **params_np)
            np.savez(tmp / "opt.npz", **opt_np)
            digest = hashlib.sha256()
            for k in sorted(params_np):
                digest.update(params_np[k].tobytes())
            manifest = {
                "step": step,
                "data_state": data_state,
                "time": time.time(),
                "params_sha256": digest.hexdigest(),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)                    # atomic publish
            self._gc()

        if self.async_write and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if (p / "manifest.json").exists()]

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return max(steps) if steps else None

    def restore(self, step: Optional[int] = None, *, params_template=None,
                opt_template=None, mesh=None, shardings=None):
        """Returns (step, params, opt_state, data_state). With `shardings`
        (pytrees of NamedSharding for the *target* mesh) leaves are placed
        sharded — reshard-on-restore. Passing `mesh=` alone replicates every
        leaf onto the target mesh (the elastic-downscale default)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        params_np = dict(np.load(d / "params.npz"))
        opt_np = dict(np.load(d / "opt.npz"))
        digest = hashlib.sha256()
        for k in sorted(params_np):
            digest.update(params_np[k].tobytes())
        if digest.hexdigest() != manifest["params_sha256"]:
            raise IOError(f"checkpoint step_{step} failed checksum")
        params = _unflatten_into(params_template, params_np) \
            if params_template is not None else params_np
        opt = _unflatten_into(opt_template, opt_np) \
            if opt_template is not None else opt_np
        if shardings is None and mesh is not None:
            shardings = (replicated_like(mesh, params),
                         replicated_like(mesh, opt))
        if shardings is not None:
            p_sh, o_sh = shardings
            params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, p_sh)
            opt = jax.tree.map(lambda x, s: jax.device_put(x, s), opt, o_sh)
        return manifest["step"], params, opt, manifest["data_state"]
