"""npz caching of composition reports, alongside the DesignTable cache.

``compose(cache=dir)`` stores its ranked result as ``hetero_<key>.npz`` in
the same directory the DesignTable npz lives in. The key fingerprints
everything that determines the outcome:

  - the table's ``grid_hash`` (config grid + physics-source fingerprint, so
    any edit to the characterization models invalidates hetero caches too),
  - the task's full numeric requirement (per-level capacity [bits] and
    per-bucket (frac, f_hz [Hz], lifetime_s [s])),
  - every ``SelectionPolicy`` and ``ComposePolicy`` field.

A cache hit reconstructs the ``CompositionReport`` from the stored row
indices + system metrics without re-running either the vmap characterization
or the batched composition scoring (both proved by the call counters
``repro.api.characterize_call_count`` / ``repro.hetero.composition_eval_count``).

Simulated re-rank reports (``compose(refine="simulate")``, ``repro.sim``)
cache beside these as ``sim_<key>.npz``: the key extends the analytic report
key with every ``SimPolicy`` field and the content fingerprints of the
replayed traces, and a hit skips the batched trace replay too (proof
counter: ``repro.sim.engine.sim_eval_count``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import warnings
from pathlib import Path
from typing import Optional, Union

import numpy as np

from repro.core.select import SelectionPolicy, TaskReq
from repro.hetero.system import SYSTEM_METRICS, tiles_for

_HETERO_SCHEMA = 5     # 2: truncated also reflects per-bucket caps; budgets
#                         pin per-slot argmin rows into the grid
#                      3: robust (worst-corner) mode keyed into the report
#                      4: N-level/SystemBudget/search fields on ComposePolicy
#                         (key-breaking) + search/n_space persisted in meta
#                      5: vdd_sweep/refresh_margin_sweep on ComposePolicy
#                         (key-breaking); persisted idx may be VIRTUAL rows
#                         of the expanded grid (block * n_base + base)


def _task_fingerprint(task: TaskReq) -> dict:
    return {
        "task_id": repr(task.task_id),
        "name": task.name,
        "levels": {
            name: {"capacity_bits": int(level.capacity_bits),
                   "buckets": [[float(b.frac), float(b.f_hz),
                                float(b.lifetime_s)] for b in level.buckets]}
            for name, level in task.levels.items()},
    }


def report_key(grid_hash: str, task: TaskReq, policy: SelectionPolicy,
               compose_policy, robust=None) -> str:
    """16-hex cache key over (table grid, task requirement, both policies,
    robust mode). The grid hash already covers the operating corners, so a
    different ``corners=`` list misses; ``robust`` distinguishes worst-case
    rankings of the same table."""
    payload = json.dumps({
        "schema": _HETERO_SCHEMA,
        "grid": grid_hash,
        "task": _task_fingerprint(task),
        "policy": dataclasses.asdict(policy),
        "compose": dataclasses.asdict(compose_policy),
        "robust": robust,
    }, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def _path(cache_dir: Union[str, Path], key: str) -> Path:
    return Path(cache_dir) / f"hetero_{key}.npz"


def save_report(cache_dir: Union[str, Path], report, top_idx: np.ndarray
                ) -> Path:
    """Persist the ranked compositions of ``report`` (row-index matrix
    ``top_idx`` of shape (top_k, n_slots) + per-composition metrics)."""
    key = report_key(report.table.grid_hash, report.task, report.policy,
                     report.compose_policy, robust=report.robust)
    path = _path(cache_dir, key)
    path.parent.mkdir(parents=True, exist_ok=True)
    meta = {"schema": _HETERO_SCHEMA, "key": key,
            "n_compositions": report.n_compositions,
            "n_feasible": report.n_feasible,
            "truncated": report.truncated,
            "search": report.search,
            # python int end-to-end (json has no width limit; int64 wraps at
            # 64-candidate slots past ~10 levels)
            "n_space": int(report.n_space)}
    payload = {
        "idx": np.asarray(top_idx, np.int32),
        "rank": np.array([c.pref_rank for c in report.ranked], np.int64),
        "feasible": np.array([c.feasible for c in report.ranked], bool),
    }
    for m in SYSTEM_METRICS:
        payload[f"metric_{m}"] = np.array(
            [c.metrics[m] for c in report.ranked], np.float64)
    np.savez(path, __meta__=json.dumps(meta), **payload)
    return path


def load_report(cache_dir: Union[str, Path], table, task: TaskReq,
                policy: SelectionPolicy, compose_policy,
                robust=None) -> Optional[object]:
    """Reconstruct a cached ``CompositionReport`` for these exact inputs, or
    None on miss / unreadable file (the caller then recomputes and re-saves).
    """
    from repro.hetero import expand as expand_mod
    from repro.hetero.compose import CompositionReport, _materialize
    key = report_key(table.grid_hash, task, policy, compose_policy,
                     robust=robust)
    path = _path(cache_dir, key)
    if not path.exists():
        return None
    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
            if meta.get("schema") != _HETERO_SCHEMA:
                raise ValueError(f"cache schema {meta.get('schema')} != "
                                 f"{_HETERO_SCHEMA}")
            idx = z["idx"]
            rank = z["rank"]
            feasible = z["feasible"]
            metric_rows = {m: z[f"metric_{m}"] for m in SYSTEM_METRICS}
    except Exception as e:
        warnings.warn(f"ignoring unreadable hetero cache {path}: {e}",
                      RuntimeWarning, stacklevel=2)
        return None
    cap_bits = np.array([level.capacity_bits * b.frac
                         for level in task.levels.values()
                         for b in level.buckets], np.float64)
    if idx.shape[1] != len(cap_bits):
        warnings.warn(f"ignoring hetero cache {path}: slot count "
                      f"{idx.shape[1]} != task's {len(cap_bits)}",
                      RuntimeWarning, stacklevel=2)
        return None
    # persisted rows may be virtual (vdd-swept) indices: tiling depends only
    # on the op-invariant "bits" column, so fold back to physical rows for
    # tiles_for and let _materialize decode the (block, base) split itself
    points = expand_mod.expansion_points(compose_policy)
    tiles = tiles_for(table.metrics, expand_mod.to_base(idx, len(table)),
                      cap_bits)
    ranked = tuple(
        _materialize(table, task, idx[k], tiles[k],
                     {m: float(metric_rows[m][k]) for m in SYSTEM_METRICS},
                     int(rank[k]), bool(feasible[k]), points=points)
        for k in range(idx.shape[0]))
    return CompositionReport(table=table, task=task, policy=policy,
                             compose_policy=compose_policy, ranked=ranked,
                             n_compositions=int(meta["n_compositions"]),
                             n_feasible=int(meta["n_feasible"]),
                             truncated=bool(meta["truncated"]),
                             search=str(meta["search"]),
                             n_space=int(meta["n_space"]),
                             robust=robust)


# ---------------------------------------------------------------------------
# simulated re-rank reports (repro.sim)
# ---------------------------------------------------------------------------

_SIM_SCHEMA = 1


def sim_report_key(base_key: str, sim_policy, trace_fps) -> str:
    """16-hex cache key of one simulated re-rank: the analytic report key
    (``report_key``) extended with every ``SimPolicy`` field and the content
    fingerprints of the replayed traces — a different task, either policy,
    or trace shape all miss."""
    payload = json.dumps({
        "schema": _SIM_SCHEMA,
        "base": base_key,
        "sim": dataclasses.asdict(sim_policy),
        "traces": list(trace_fps),
    }, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def _sim_path(cache_dir: Union[str, Path], key: str) -> Path:
    return Path(cache_dir) / f"sim_{key}.npz"


def save_sim_report(cache_dir: Union[str, Path], key: str,
                    order: np.ndarray, metrics, per_phase) -> Path:
    """Persist one simulated re-rank: the best-first permutation of the
    analytic ranked list + per-composition simulated metrics (combined and
    per phase), aligned to the ANALYTIC order so a hit can re-apply them to
    the reconstructed analytic report."""
    path = _sim_path(cache_dir, key)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = {"order": np.asarray(order, np.int64)}
    for m, v in metrics.items():
        payload[f"metric_{m}"] = np.asarray(v, np.float64)
    for phase, ms in per_phase.items():
        for m, v in ms.items():
            payload[f"phase_{phase}_{m}"] = np.asarray(v, np.float64)
    meta = {"schema": _SIM_SCHEMA, "key": key,
            "phases": list(per_phase)}
    np.savez(path, __meta__=json.dumps(meta), **payload)
    return path


def load_sim_report(cache_dir: Union[str, Path], key: str,
                    n_ranked: int) -> Optional[dict]:
    """Load one simulated re-rank for this exact key, or None on miss /
    unreadable / shape-mismatched file. Returns ``{"order", "metrics",
    "phases"}`` with numpy payloads."""
    path = _sim_path(cache_dir, key)
    if not path.exists():
        return None
    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
            if meta.get("schema") != _SIM_SCHEMA:
                raise ValueError(f"cache schema {meta.get('schema')} != "
                                 f"{_SIM_SCHEMA}")
            order = z["order"]
            metrics = {k[7:]: z[k] for k in z.files
                       if k.startswith("metric_")}
            phases: dict = {}
            for phase in meta.get("phases", ()):
                phases[phase] = {k[len(f"phase_{phase}_"):]: z[k]
                                 for k in z.files
                                 if k.startswith(f"phase_{phase}_")}
    except Exception as e:
        warnings.warn(f"ignoring unreadable sim cache {path}: {e}",
                      RuntimeWarning, stacklevel=2)
        return None
    if order.shape[0] != n_ranked:
        warnings.warn(f"ignoring sim cache {path}: ranked count "
                      f"{order.shape[0]} != report's {n_ranked}",
                      RuntimeWarning, stacklevel=2)
        return None
    return {"order": order, "metrics": metrics, "phases": phases}
