"""npz caching of composition reports, alongside the DesignTable cache.

``compose(cache=dir)`` stores its ranked result as ``hetero_<key>.npz`` in
the same directory the DesignTable npz lives in. The key fingerprints
everything that determines the outcome:

  - the table's ``grid_hash`` (config grid + physics-source fingerprint, so
    any edit to the characterization models invalidates hetero caches too),
  - the task's full numeric requirement (per-level capacity [bits] and
    per-bucket (frac, f_hz [Hz], lifetime_s [s])),
  - every ``SelectionPolicy`` and ``ComposePolicy`` field.

A cache hit reconstructs the ``CompositionReport`` from the stored row
indices + system metrics without re-running either the vmap characterization
or the batched composition scoring (both proved by the call counters
``repro.api.characterize_call_count`` / ``repro.hetero.composition_eval_count``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import warnings
from pathlib import Path
from typing import Optional, Union

import numpy as np

from repro.core.select import SelectionPolicy, TaskReq
from repro.hetero.system import SYSTEM_METRICS, tiles_for

_HETERO_SCHEMA = 2     # 2: truncated also reflects per-bucket caps; budgets
#                         pin per-slot argmin rows into the grid


def _task_fingerprint(task: TaskReq) -> dict:
    return {
        "task_id": repr(task.task_id),
        "name": task.name,
        "levels": {
            name: {"capacity_bits": int(level.capacity_bits),
                   "buckets": [[float(b.frac), float(b.f_hz),
                                float(b.lifetime_s)] for b in level.buckets]}
            for name, level in task.levels.items()},
    }


def report_key(grid_hash: str, task: TaskReq, policy: SelectionPolicy,
               compose_policy) -> str:
    """16-hex cache key over (table grid, task requirement, both policies)."""
    payload = json.dumps({
        "schema": _HETERO_SCHEMA,
        "grid": grid_hash,
        "task": _task_fingerprint(task),
        "policy": dataclasses.asdict(policy),
        "compose": dataclasses.asdict(compose_policy),
    }, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def _path(cache_dir: Union[str, Path], key: str) -> Path:
    return Path(cache_dir) / f"hetero_{key}.npz"


def save_report(cache_dir: Union[str, Path], report, top_idx: np.ndarray
                ) -> Path:
    """Persist the ranked compositions of ``report`` (row-index matrix
    ``top_idx`` of shape (top_k, n_slots) + per-composition metrics)."""
    key = report_key(report.table.grid_hash, report.task, report.policy,
                     report.compose_policy)
    path = _path(cache_dir, key)
    path.parent.mkdir(parents=True, exist_ok=True)
    meta = {"schema": _HETERO_SCHEMA, "key": key,
            "n_compositions": report.n_compositions,
            "n_feasible": report.n_feasible,
            "truncated": report.truncated}
    payload = {
        "idx": np.asarray(top_idx, np.int32),
        "rank": np.array([c.pref_rank for c in report.ranked], np.int64),
        "feasible": np.array([c.feasible for c in report.ranked], bool),
    }
    for m in SYSTEM_METRICS:
        payload[f"metric_{m}"] = np.array(
            [c.metrics[m] for c in report.ranked], np.float64)
    np.savez(path, __meta__=json.dumps(meta), **payload)
    return path


def load_report(cache_dir: Union[str, Path], table, task: TaskReq,
                policy: SelectionPolicy, compose_policy) -> Optional[object]:
    """Reconstruct a cached ``CompositionReport`` for these exact inputs, or
    None on miss / unreadable file (the caller then recomputes and re-saves).
    """
    from repro.hetero.compose import CompositionReport, _materialize
    key = report_key(table.grid_hash, task, policy, compose_policy)
    path = _path(cache_dir, key)
    if not path.exists():
        return None
    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
            if meta.get("schema") != _HETERO_SCHEMA:
                raise ValueError(f"cache schema {meta.get('schema')} != "
                                 f"{_HETERO_SCHEMA}")
            idx = z["idx"]
            rank = z["rank"]
            feasible = z["feasible"]
            metric_rows = {m: z[f"metric_{m}"] for m in SYSTEM_METRICS}
    except Exception as e:
        warnings.warn(f"ignoring unreadable hetero cache {path}: {e}",
                      RuntimeWarning, stacklevel=2)
        return None
    cap_bits = np.array([level.capacity_bits * b.frac
                         for level in task.levels.values()
                         for b in level.buckets], np.float64)
    if idx.shape[1] != len(cap_bits):
        warnings.warn(f"ignoring hetero cache {path}: slot count "
                      f"{idx.shape[1]} != task's {len(cap_bits)}",
                      RuntimeWarning, stacklevel=2)
        return None
    tiles = tiles_for(table.metrics, idx, cap_bits)
    ranked = tuple(
        _materialize(table, task, idx[k], tiles[k],
                     {m: float(metric_rows[m][k]) for m in SYSTEM_METRICS},
                     int(rank[k]), bool(feasible[k]))
        for k in range(idx.shape[0]))
    return CompositionReport(table=table, task=task, policy=policy,
                             compose_policy=compose_policy, ranked=ranked,
                             n_compositions=int(meta["n_compositions"]),
                             n_feasible=int(meta["n_feasible"]),
                             truncated=bool(meta["truncated"]))
