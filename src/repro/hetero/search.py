"""Branch-and-bound composition search over the per-slot candidate lattice.

The exhaustive path in ``repro.hetero.compose`` materializes the full
cross-product of per-(level, bucket) candidates — fine for two levels, but an
N-level hierarchy explodes combinatorially (`64^11` compositions overflows
int64). This module enumerates the SAME space best-first instead, exploiting
the property the candidate machinery already maintains: every ranking
objective's **primary key decomposes into per-slot contributions** —

  - "preference":  Σ per-slot preference rank (integer-exact),
  - "power":       Σ tiled slot power  (``tiles·(leak+refresh) + e_read·f``),
  - "area":        Σ tiled slot area   (``tiles·area``),
  - "balanced":    Σ slot (area/a0 + power/p0) with the analytic per-slot
                   normalizers of ``balanced_norms``.

Algorithm: sort each slot's candidates ascending by contribution; a lattice
node is a per-slot position vector whose bound is the exact float64 sum of
its contributions. Nodes come off a min-heap in non-decreasing bound order
(every successor increments one slot position, and sorted contributions make
bounds monotone along lattice edges), get batch-scored through the SAME
``score_grid`` kernel as the exhaustive path (fixed-size padded batches — one
trace-cache entry), and feasibility (sentinel slots + the active
``SystemBudget`` rails) is checked on the scored float32 metrics.

Stop rule / optimality proof: once ``top_k`` feasible compositions are in
hand, the search stops when the heap minimum exceeds the kth-best feasible
bound plus a slack covering float32-scoring vs float64-bound rounding
(preference is integer-exact, slack 0.5). Monotonicity guarantees every
composition with bound ≤ cutoff was already enumerated, so nothing that
could rank in the top k under the objective's primary key — including all
primary-key ties, which the caller's secondary keys then order — is ever
pruned. If the node budget (``ComposePolicy.max_compositions``) runs out
first the result is flagged truncated, exactly like a trimmed exhaustive
grid. ``compose`` falls back to the exhaustive grid below
``ComposePolicy.search_threshold`` where a single batched scoring sweep is
cheaper than the heap walk.
"""
from __future__ import annotations

import heapq
import math
from typing import Dict, List, Mapping, Sequence, Tuple

import numpy as np

from repro import obs
from repro.hetero.candidates import BucketCandidates
from repro.hetero.system import SYSTEM_METRICS, SystemBudget, score_grid

# search statistics (repro.obs registry): nodes actually scored, fixed-shape
# batches flushed, and compositions the bound proof never had to score
_C_NODES = obs.counter("hetero.search_nodes")
_C_BATCHES = obs.counter("hetero.search_batches")
_C_PRUNED = obs.counter("hetero.search_pruned")

# relative slack on the branch-and-bound cutoff: the float64 bound of a
# composition and its float32 kernel score agree to ~1e-6 relative per slot;
# 1e-4 is orders of magnitude of headroom without enumerating the world
_CUTOFF_REL_SLACK = 1e-4


def slot_contributions(slots: Sequence[BucketCandidates],
                       metrics: Mapping[str, np.ndarray]
                       ) -> Tuple[List[np.ndarray], List[np.ndarray]]:
    """Per-slot float64 (area [µm²], power [W]) contribution of every
    candidate to the system score — exactly what ``score_kernel`` sums:
    ``ceil(cap_bits/bits)·metric`` plus ``e_read_j·f_hz`` dynamic power.
    Sentinel candidates (``config_idx < 0``) contribute +inf (the kernel
    prices sentinel slots at +inf); NaN metrics also map to +inf so the
    enumeration order stays total."""
    bits = np.maximum(np.asarray(metrics["bits"], np.float64), 1.0)
    row_area_um2 = np.asarray(metrics["area_um2"], np.float64)
    row_p_static_w = (np.asarray(metrics["p_leak_w"], np.float64)
                      + np.asarray(metrics["p_refresh_w"], np.float64))
    row_e_read_j = np.asarray(metrics["e_read_j"], np.float64)
    area_per_slot: List[np.ndarray] = []
    power_per_slot: List[np.ndarray] = []
    for bc in slots:
        area_c = np.empty(len(bc.candidates), np.float64)
        power_c = np.empty(len(bc.candidates), np.float64)
        for i, cand in enumerate(bc.candidates):
            if cand.config_idx < 0:
                area_c[i] = power_c[i] = np.inf
                continue
            tiles = np.ceil(bc.capacity_bits / bits[cand.config_idx])
            area_c[i] = tiles * row_area_um2[cand.config_idx]
            power_c[i] = (tiles * row_p_static_w[cand.config_idx]
                          + row_e_read_j[cand.config_idx] * bc.bucket.f_hz)
        area_per_slot.append(np.where(np.isnan(area_c), np.inf, area_c))
        power_per_slot.append(np.where(np.isnan(power_c), np.inf, power_c))
    return area_per_slot, power_per_slot


def balanced_norms(slots: Sequence[BucketCandidates],
                   metrics: Mapping[str, np.ndarray]) -> Tuple[float, float]:
    """Analytic normalizers (a0 [µm²], p0 [W]) for the "balanced" objective:
    the sum over slots of the minimum candidate contribution — a lower bound
    on any composition's system area / power. Being a function of the
    candidate lists alone (not of which grid subset got scored), the balanced
    ranking is identical between the exhaustive and branch-and-bound paths.
    Slots with only the sentinel contribute nothing (their +inf would drown
    the normalizer)."""
    area_per_slot, power_per_slot = slot_contributions(slots, metrics)
    a0 = sum(float(np.min(a)) for a in area_per_slot if np.isfinite(a).any())
    p0 = sum(float(np.min(p)) for p in power_per_slot if np.isfinite(p).any())
    return max(a0, 1e-30), max(p0, 1e-30)


def _primary_contribs(slots: Sequence[BucketCandidates],
                      metrics: Mapping[str, np.ndarray],
                      objective: str) -> List[np.ndarray]:
    """Per-slot float64 contribution of each candidate to the objective's
    PRIMARY ranking key (the quantity the bound sums)."""
    if objective == "preference":
        return [np.array([float(c.pref_rank) for c in bc.candidates],
                         np.float64) for bc in slots]
    area_per_slot, power_per_slot = slot_contributions(slots, metrics)
    if objective == "power":
        return power_per_slot
    if objective == "area":
        return area_per_slot
    if objective == "balanced":
        a0, p0 = balanced_norms(slots, metrics)
        return [a / a0 + p / p0
                for a, p in zip(area_per_slot, power_per_slot)]
    raise ValueError(f"unknown objective {objective!r}")


def branch_and_bound(slots: Sequence[BucketCandidates],
                     metrics: Mapping[str, np.ndarray],
                     cap_bits: np.ndarray, f_req: np.ndarray,
                     objective: str, budget: SystemBudget,
                     *, top_k: int = 8, max_nodes: int = 200_000,
                     batch: int = 512, sharded: bool = False):
    """Best-first enumeration of the composition lattice (module docstring).

    Returns ``(idx (n,S) int32, pos (n,S) int64, rank_sum (n,) int64,
    scores {metric: (n,) float32}, truncated, n_scored)`` — the scored subset
    in enumeration order, ready for the caller's ``_order`` ranking.
    ``pos`` holds each composition's position in the ORIGINAL candidate
    lists, so metric-tie ordering matches the exhaustive grid exactly.
    """
    lists = [bc.candidates for bc in slots]
    n_slots = len(lists)
    contribs = _primary_contribs(slots, metrics, objective)
    # ascending contribution order per slot; stable so equal-contribution
    # candidates keep their (deterministic) list order
    sort_of = [np.argsort(c, kind="stable") for c in contribs]
    sorted_c = [c[o] for c, o in zip(contribs, sort_of)]
    top_k = max(top_k, 1)
    batch = max(batch, 1)

    def bound_of(node: Tuple[int, ...]) -> float:
        # recomputed from scratch: incremental updates would turn the +inf
        # sentinel contributions into inf-inf = NaN
        return float(sum(sorted_c[s][p] for s, p in enumerate(node)))

    slack = 0.5 if objective == "preference" else None

    def cutoff(kth_bound: float) -> float:
        if slack is not None:
            return kth_bound + slack
        return kth_bound + max(abs(kth_bound) * _CUTOFF_REL_SLACK, 1e-12)

    root = (0,) * n_slots
    heap: List[Tuple[float, Tuple[int, ...]]] = [(bound_of(root), root)]
    seen = {root}
    feas_bounds: List[float] = []       # max-heap (negated), size ≤ top_k
    pending: List[Tuple[float, Tuple[int, ...]]] = []
    out_idx: List[np.ndarray] = []
    out_pos: List[np.ndarray] = []
    out_rank: List[np.ndarray] = []
    out_scores: Dict[str, List[np.ndarray]] = {m: [] for m in SYSTEM_METRICS}
    n_scored = 0
    truncated = False

    def flush() -> None:
        nonlocal n_scored
        if not pending:
            return
        n = len(pending)
        idx_np = np.empty((batch, n_slots), np.int32)
        pos_np = np.empty((batch, n_slots), np.int64)
        rank_np = np.zeros(batch, np.int64)
        for j, (_, node) in enumerate(pending):
            for s, p_sorted in enumerate(node):
                p_orig = int(sort_of[s][p_sorted])
                cand = lists[s][p_orig]
                idx_np[j, s] = cand.config_idx
                pos_np[j, s] = p_orig
                rank_np[j] += cand.pref_rank
        idx_np[n:] = idx_np[0]          # pad to the fixed batch shape so the
        #                                 jit kernel compiles exactly once
        scores = score_grid(metrics, idx_np, cap_bits, f_req, sharded=sharded)
        _C_BATCHES.inc()
        feas = np.all(idx_np[:n] >= 0, axis=1) & budget.feasible(
            {m: scores[m][:n] for m in SYSTEM_METRICS})
        for j in np.where(feas)[0]:
            b = pending[j][0]
            if len(feas_bounds) < top_k:
                heapq.heappush(feas_bounds, -b)
            elif b < -feas_bounds[0]:
                heapq.heappushpop(feas_bounds, -b)
        out_idx.append(idx_np[:n].copy())
        out_pos.append(pos_np[:n].copy())
        out_rank.append(rank_np[:n].copy())
        for m in SYSTEM_METRICS:
            out_scores[m].append(scores[m][:n].copy())
        n_scored += n
        pending.clear()

    while heap:
        if len(feas_bounds) >= top_k and \
                heap[0][0] > cutoff(-feas_bounds[0]):
            break
        if n_scored + len(pending) >= max_nodes:
            truncated = True            # node budget exhausted before the
            break                       # bound proof closed: lossy, like a
        #                                 trimmed exhaustive grid
        node_bound, node = heapq.heappop(heap)
        pending.append((node_bound, node))
        for s in range(n_slots):
            if node[s] + 1 < len(lists[s]):
                nxt = node[:s] + (node[s] + 1,) + node[s + 1:]
                if nxt not in seen:
                    seen.add(nxt)
                    heapq.heappush(heap, (bound_of(nxt), nxt))
        if len(pending) >= batch:
            flush()
    flush()

    _C_NODES.inc(n_scored)
    _C_PRUNED.inc(max(math.prod(len(c) for c in lists) - n_scored, 0))
    idx = np.concatenate(out_idx) if out_idx else \
        np.empty((0, n_slots), np.int32)
    pos = np.concatenate(out_pos) if out_pos else \
        np.empty((0, n_slots), np.int64)
    rank_sum = np.concatenate(out_rank) if out_rank else \
        np.empty((0,), np.int64)
    scores = {m: (np.concatenate(v) if v else np.empty((0,), np.float32))
              for m, v in out_scores.items()}
    return idx, pos, rank_sum, scores, truncated, n_scored
