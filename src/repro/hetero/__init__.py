"""Heterogeneous composition engine: joint N-level memory-system design.

Where ``repro.api.explore`` picks each cache level independently (the paper's
§5.4 greedy policy), this subsystem scores **whole system compositions** —
the N-level grid of candidate technologies per (level, bucket) slot, for
every level a task declares or the ``levels=`` subset — in batched jnp
evaluations: system area [µm²], total power including refresh [W], bandwidth
margin, and capacity fit are computed per composition, optionally sharded
across devices for large grids, and ranked under an explicit
``ComposePolicy``. Chip-level envelopes arrive as a ``SystemBudget`` applied
to whole compositions; spaces too large to enumerate are searched by the
provably-lossless branch-and-bound in ``repro.hetero.search``. The default
objective reproduces the paper's Table 2 selections *through the joint path*
(see ``tests/test_hetero.py``); budgeted or power-/area-minimizing
objectives let the joint evaluation make tradeoffs the per-level greedy
cannot.

Entry points::

    from repro.api import Compiler
    report = Compiler().compose(task)          # -> CompositionReport
    report = Compiler().compose(task, levels=("L1", "L2"))

    from repro.hetero import compose, ComposePolicy, SystemBudget
    report = compose(table, task, compose_policy=ComposePolicy(
        objective="power", budget=SystemBudget(area_um2=2.5e6)))
"""
from repro.hetero.candidates import (BucketCandidates, Candidate,
                                     bucket_candidates, level_candidates)
from repro.hetero.compose import (ComposePolicy, Composition,
                                  CompositionReport, LevelComposition,
                                  compose)
from repro.hetero.search import balanced_norms, branch_and_bound
from repro.hetero.system import (SYSTEM_METRICS, SystemBudget,
                                 composition_eval_count, score_grid,
                                 score_grid_corners)

__all__ = [
    "Candidate", "BucketCandidates", "bucket_candidates", "level_candidates",
    "ComposePolicy", "Composition", "LevelComposition", "CompositionReport",
    "compose",
    "balanced_norms", "branch_and_bound",
    "SYSTEM_METRICS", "SystemBudget", "score_grid", "score_grid_corners",
    "composition_eval_count",
]
