"""Heterogeneous composition engine: joint (L1, L2) memory-system design.

Where ``repro.api.explore`` picks each cache level independently (the paper's
§5.4 greedy policy), this subsystem scores **whole system compositions** —
the cross-product of candidate technologies per (level, bucket) slot — in one
batched jnp evaluation: system area [µm²], total power including refresh [W],
bandwidth margin, and capacity fit are computed per composition, optionally
sharded across devices for large grids, and ranked under an explicit
``ComposePolicy``. The default objective reproduces the paper's Table 2
selections *through the joint path* (see ``tests/test_hetero.py``); budgeted
or power-/area-minimizing objectives let the joint evaluation make tradeoffs
the per-level greedy cannot.

Entry points::

    from repro.api import Compiler
    report = Compiler().compose(task)          # -> CompositionReport

    from repro.hetero import compose, ComposePolicy
    report = compose(table, task, compose_policy=ComposePolicy(
        objective="power", area_budget_um2=2.5e6))
"""
from repro.hetero.candidates import (BucketCandidates, Candidate,
                                     bucket_candidates, level_candidates)
from repro.hetero.compose import (ComposePolicy, Composition,
                                  CompositionReport, LevelComposition,
                                  compose)
from repro.hetero.system import (SYSTEM_METRICS, composition_eval_count,
                                 score_grid)

__all__ = [
    "Candidate", "BucketCandidates", "bucket_candidates", "level_candidates",
    "ComposePolicy", "Composition", "LevelComposition", "CompositionReport",
    "compose",
    "SYSTEM_METRICS", "score_grid", "composition_eval_count",
]
