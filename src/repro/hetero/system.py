"""Batched system-level scoring of memory compositions (pure jnp).

One *composition* assigns a DesignTable row to every (level, bucket) slot of
a task. This module prices whole compositions: the chosen macro is tiled to
the slot's capacity share, and per-composition system metrics are reduced
over the slots —

``area_um2``        Σ tiles · macro area                          [µm²]
``p_static_w``      Σ tiles · (leakage + refresh) power           [W]
``p_dyn_w``         Σ read energy · required read frequency       [W]
``p_w``             p_static_w + p_dyn_w                          [W]
``bw_margin``       min over slots of f_op / f_required           [ratio]
``capacity_bits``   Σ tiles · macro bits                          [bits]
``overprovision``   capacity_bits / Σ required bits               [ratio]

Everything is a gather + reduction over a ``(J, S)`` index matrix (J
compositions × S slots), evaluated in ONE jit so a multi-thousand-row
composition grid costs a single device dispatch. The same kernel runs
sharded over the grid axis via ``repro.parallel.grid.shard_leading`` when
``sharded=True`` — results are identical, only placement changes.

Slots carrying the infeasible sentinel (``config_idx < 0``) price at +inf
area/power so they sort last and are flagged infeasible by the caller.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.grid import shard_leading

# DesignTable metric columns the scorer gathers from
METRIC_COLS = ("area_um2", "bits", "p_leak_w", "p_refresh_w", "e_read_j",
               "f_op_hz")

# output metric names, in the order score_kernel returns them
SYSTEM_METRICS = ("area_um2", "p_static_w", "p_dyn_w", "p_w", "bw_margin",
                  "capacity_bits", "overprovision")

# how many batched composition evaluations this process has run (a compose()
# cache hit leaves the counter unchanged — tests use it the same way they use
# api.characterize_call_count for the DesignTable cache)
_eval_calls = 0


def composition_eval_count() -> int:
    """Number of batched composition scoring sweeps executed so far."""
    return _eval_calls


def score_kernel(idx: jnp.ndarray, cols: Dict[str, jnp.ndarray],
                 cap_bits: jnp.ndarray, f_req: jnp.ndarray
                 ) -> Dict[str, jnp.ndarray]:
    """Score a composition grid. Pure jnp; safe under jit and shard_map.

    ``idx``       (J, S) int32 row indices into the table (-1 = sentinel).
    ``cols``      metric columns (each ``(n_configs,)``), METRIC_COLS keys.
    ``cap_bits``  (S,) required capacity per slot [bits].
    ``f_req``     (S,) required read frequency per slot [Hz].

    Returns a dict of ``(J,)`` float32 arrays keyed by SYSTEM_METRICS.
    """
    bad = idx < 0
    safe = jnp.maximum(idx, 0)

    def take(name):
        return jnp.take(cols[name], safe, axis=0)        # (J, S)

    bits = jnp.maximum(take("bits"), 1.0)
    tiles = jnp.ceil(cap_bits[None, :] / bits)           # macros per slot
    inf = jnp.float32(jnp.inf)

    area_um2 = jnp.sum(jnp.where(bad, inf, tiles * take("area_um2")), axis=1)
    p_static_w = jnp.sum(
        jnp.where(bad, inf,
                  tiles * (take("p_leak_w") + take("p_refresh_w"))), axis=1)
    p_dyn_w = jnp.sum(jnp.where(bad, inf, take("e_read_j") * f_req[None, :]),
                      axis=1)
    bw_margin = jnp.min(
        jnp.where(bad, 0.0,
                  take("f_op_hz") / jnp.maximum(f_req[None, :], 1.0)), axis=1)
    capacity_bits = jnp.sum(jnp.where(bad, 0.0, tiles * bits), axis=1)
    overprov = capacity_bits / jnp.maximum(jnp.sum(cap_bits), 1.0)
    return {
        "area_um2": area_um2,
        "p_static_w": p_static_w,
        "p_dyn_w": p_dyn_w,
        "p_w": p_static_w + p_dyn_w,
        "bw_margin": bw_margin,
        "capacity_bits": capacity_bits,
        "overprovision": overprov,
    }


_score_jit = jax.jit(score_kernel)


def tiles_for(metrics: Mapping[str, np.ndarray], idx: np.ndarray,
              cap_bits: np.ndarray) -> np.ndarray:
    """Macros needed per slot — numpy mirror of the kernel's tiling rule,
    in float32 like the kernel so the reported tile counts can never
    disagree with the metrics priced from them."""
    bits = np.maximum(np.asarray(metrics["bits"], np.float32)[
        np.maximum(idx, 0)], np.float32(1.0))
    slot_cap_bits = np.asarray(cap_bits, np.float32)
    return np.where(idx < 0, 0,
                    np.ceil(slot_cap_bits[None, :] / bits)).astype(np.int64)


def score_grid(metrics: Mapping[str, np.ndarray], idx: np.ndarray,
               cap_bits: Sequence[float], f_req: Sequence[float],
               *, sharded: bool = False,
               devices: Optional[Sequence] = None) -> Dict[str, np.ndarray]:
    """Score ``(J, S)`` composition grid ``idx`` against table ``metrics``.

    ``sharded=True`` splits the grid's J axis across every visible device
    (``repro.compat`` mesh + shard_map); single-device hosts fall back to the
    plain jit call with identical results. Returns numpy ``(J,)`` arrays
    keyed by SYSTEM_METRICS.
    """
    global _eval_calls
    cols = {k: jnp.asarray(np.asarray(metrics[k]), jnp.float32)
            for k in METRIC_COLS}
    idx_dev = jnp.asarray(np.asarray(idx), jnp.int32)
    slot_cap_bits = jnp.asarray(np.asarray(cap_bits), jnp.float32)
    slot_f_req_hz = jnp.asarray(np.asarray(f_req), jnp.float32)
    from repro.analysis import sanitize
    if sharded:
        # shard_map composes badly with checkify's error plumbing; the
        # sanitizer covers the single-device path, which computes the same
        # values
        out = shard_leading(_score_jit, idx_dev, cols, slot_cap_bits,
                            slot_f_req_hz, devices=devices)
    else:
        out = sanitize.maybe_wrap(_score_jit)(
            idx_dev, cols, slot_cap_bits, slot_f_req_hz)
    _eval_calls += 1
    return {k: np.asarray(v) for k, v in out.items()}
