"""Batched system-level scoring of memory compositions (pure jnp).

One *composition* assigns a DesignTable row to every (level, bucket) slot of
a task. This module prices whole compositions: the chosen macro is tiled to
the slot's capacity share, and per-composition system metrics are reduced
over the slots —

``area_um2``        Σ tiles · macro area                          [µm²]
``p_static_w``      Σ tiles · (leakage + refresh) power           [W]
``p_dyn_w``         Σ read energy · required read frequency       [W]
``p_w``             p_static_w + p_dyn_w                          [W]
``bw_margin``       min over slots of f_op / f_required           [ratio]
``capacity_bits``   Σ tiles · macro bits                          [bits]
``overprovision``   capacity_bits / Σ required bits               [ratio]

Everything is a gather + reduction over a ``(J, S)`` index matrix (J
compositions × S slots), evaluated in ONE jit so a multi-thousand-row
composition grid costs a single device dispatch. The same kernel runs
sharded over the grid axis via ``repro.parallel.grid.shard_leading`` when
``sharded=True`` — results are identical, only placement changes.

Slots carrying the infeasible sentinel (``config_idx < 0``) price at +inf
area/power so they sort last and are flagged infeasible by the caller.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels import backend
from repro.parallel.grid import shard2d, shard_leading

# DesignTable metric columns the scorer gathers from
METRIC_COLS = ("area_um2", "bits", "p_leak_w", "p_refresh_w", "e_read_j",
               "f_op_hz")

# output metric names, in the order score_kernel returns them
SYSTEM_METRICS = ("area_um2", "p_static_w", "p_dyn_w", "p_w", "bw_margin",
                  "capacity_bits", "overprovision")


@dataclass(frozen=True)
class SystemBudget:
    """Chip-level envelopes applied to WHOLE compositions.

    Unlike per-slot caps, these constrain the reduced system metrics the
    scorer returns: ``area_um2`` is the total system area ceiling [µm²],
    ``power_w`` the total (static + dynamic) power ceiling [W], and
    ``bw_margin_min`` the minimum acceptable bandwidth margin (min over
    slots of f_op / f_required, a ratio — 1.0 means every slot must at
    least meet its required read frequency). ``None`` disables a rail.

    Compositions violating any active rail are marked infeasible and sort
    after every feasible one; each active rail pins its per-slot
    extremal row into the candidate grid (argmin area / argmin power /
    argmax f_op) so ``n_feasible == 0`` on an untruncated grid proves the
    budget is genuinely unmeetable rather than a cap artifact.
    """
    area_um2: Optional[float] = None
    power_w: Optional[float] = None
    bw_margin_min: Optional[float] = None

    @property
    def active(self) -> bool:
        return (self.area_um2 is not None or self.power_w is not None
                or self.bw_margin_min is not None)

    def ensure_orders(self) -> Tuple[str, ...]:
        """Candidate-pin keys for the active rails (see
        ``repro.hetero.candidates.bucket_candidates``)."""
        return tuple(k for k, v in (("area", self.area_um2),
                                    ("power", self.power_w),
                                    ("bandwidth", self.bw_margin_min))
                     if v is not None)

    def feasible(self, scores: Mapping[str, np.ndarray]) -> np.ndarray:
        """Boolean mask over scored compositions passing every active rail
        (``scores`` keyed by SYSTEM_METRICS, each ``(J,)``)."""
        mask = np.ones(np.asarray(scores["area_um2"]).shape[0], bool)
        if self.area_um2 is not None:
            mask &= np.asarray(scores["area_um2"]) <= self.area_um2
        if self.power_w is not None:
            mask &= np.asarray(scores["p_w"]) <= self.power_w
        if self.bw_margin_min is not None:
            mask &= np.asarray(scores["bw_margin"]) >= self.bw_margin_min
        return mask


# how many batched composition evaluations this process has run (a compose()
# cache hit leaves the counter unchanged — tests use it the same way they use
# api.characterize_call_count for the DesignTable cache); lives on the
# repro.obs metrics registry, read through the thin alias below
_C_EVALS = obs.counter("hetero.compose_evals")


def composition_eval_count() -> int:
    """Number of batched composition scoring sweeps executed so far
    (backed by the ``hetero.compose_evals`` obs counter)."""
    return _C_EVALS.value


def score_kernel(idx: jnp.ndarray, cols: Dict[str, jnp.ndarray],
                 cap_bits: jnp.ndarray, f_req: jnp.ndarray
                 ) -> Dict[str, jnp.ndarray]:
    """Score a composition grid. Pure jnp; safe under jit and shard_map.

    ``idx``       (J, S) int32 row indices into the table (-1 = sentinel).
    ``cols``      metric columns (each ``(n_configs,)``), METRIC_COLS keys.
    ``cap_bits``  (S,) required capacity per slot [bits].
    ``f_req``     (S,) required read frequency per slot [Hz].

    Returns a dict of ``(J,)`` float32 arrays keyed by SYSTEM_METRICS.
    """
    bad = idx < 0
    safe = jnp.maximum(idx, 0)

    def take(name):
        return jnp.take(cols[name], safe, axis=0)        # (J, S)

    bits = jnp.maximum(take("bits"), 1.0)
    tiles = jnp.ceil(cap_bits[None, :] / bits)           # macros per slot
    inf = jnp.float32(jnp.inf)

    area_um2 = jnp.sum(jnp.where(bad, inf, tiles * take("area_um2")), axis=1)
    p_static_w = jnp.sum(
        jnp.where(bad, inf,
                  tiles * (take("p_leak_w") + take("p_refresh_w"))), axis=1)
    p_dyn_w = jnp.sum(jnp.where(bad, inf, take("e_read_j") * f_req[None, :]),
                      axis=1)
    bw_margin = jnp.min(
        jnp.where(bad, 0.0,
                  take("f_op_hz") / jnp.maximum(f_req[None, :], 1.0)), axis=1)
    capacity_bits = jnp.sum(jnp.where(bad, 0.0, tiles * bits), axis=1)
    overprov = capacity_bits / jnp.maximum(jnp.sum(cap_bits), 1.0)
    return {
        "area_um2": area_um2,
        "p_static_w": p_static_w,
        "p_dyn_w": p_dyn_w,
        "p_w": p_static_w + p_dyn_w,
        "bw_margin": bw_margin,
        "capacity_bits": capacity_bits,
        "overprovision": overprov,
    }


_score_jit = jax.jit(score_kernel)


def _score_interpret(idx, cols, cap_bits, f_req) -> Dict[str, np.ndarray]:
    """Pure-numpy float32 mirror of ``score_kernel`` — the oracle the
    registry-level interpret-vs-xla divergence sweep
    (``tests/test_backend_divergence.py``) drives against the jit path."""
    idx = np.asarray(idx)
    bad = idx < 0
    safe = np.maximum(idx, 0)

    def take(name):
        return np.asarray(cols[name], np.float32)[safe]          # (J, S)

    slot_cap_bits = np.asarray(cap_bits, np.float32)
    slot_f_req_hz = np.asarray(f_req, np.float32)
    bits = np.maximum(take("bits"), np.float32(1.0))
    tiles = np.ceil(slot_cap_bits[None, :] / bits)
    inf = np.float32(np.inf)
    area_um2 = np.sum(np.where(bad, inf, tiles * take("area_um2")),
                      axis=1, dtype=np.float32)
    p_static_w = np.sum(
        np.where(bad, inf, tiles * (take("p_leak_w") + take("p_refresh_w"))),
        axis=1, dtype=np.float32)
    p_dyn_w = np.sum(
        np.where(bad, inf, take("e_read_j") * slot_f_req_hz[None, :]),
        axis=1, dtype=np.float32)
    bw_margin = np.min(
        np.where(bad, np.float32(0.0),
                 take("f_op_hz") / np.maximum(slot_f_req_hz[None, :],
                                              np.float32(1.0))), axis=1)
    capacity_bits = np.sum(np.where(bad, np.float32(0.0), tiles * bits),
                           axis=1, dtype=np.float32)
    overprov = capacity_bits / np.maximum(
        np.sum(slot_cap_bits, dtype=np.float32), np.float32(1.0))
    return {
        "area_um2": area_um2,
        "p_static_w": p_static_w,
        "p_dyn_w": p_dyn_w,
        "p_w": (p_static_w + p_dyn_w).astype(np.float32),
        "bw_margin": bw_margin.astype(np.float32),
        "capacity_bits": capacity_bits,
        "overprovision": overprov.astype(np.float32),
    }


# the composition scorer is a registered dispatch point like every other
# compute hot-spot: "xla" is the jit kernel score_grid runs, "interpret" the
# numpy oracle above, and the divergence sweep proves them against each other
backend.register("compose_score", xla=_score_jit, interpret=_score_interpret)


def _score_corners_kernel(idx: jnp.ndarray, cols: Dict[str, jnp.ndarray],
                          cap_bits: jnp.ndarray, f_req: jnp.ndarray
                          ) -> Dict[str, jnp.ndarray]:
    """``score_kernel`` vmapped over corner-stacked metric columns: ``cols``
    leaves are ``(C, n_configs)`` and every output leaf is ``(C, J)``."""
    return jax.vmap(score_kernel, in_axes=(None, 0, None, None))(
        idx, cols, cap_bits, f_req)


_score_corners_jit = jax.jit(_score_corners_kernel)


def tiles_for(metrics: Mapping[str, np.ndarray], idx: np.ndarray,
              cap_bits: np.ndarray) -> np.ndarray:
    """Macros needed per slot — numpy mirror of the kernel's tiling rule,
    in float32 like the kernel so the reported tile counts can never
    disagree with the metrics priced from them."""
    bits = np.maximum(np.asarray(metrics["bits"], np.float32)[
        np.maximum(idx, 0)], np.float32(1.0))
    slot_cap_bits = np.asarray(cap_bits, np.float32)
    return np.where(idx < 0, 0,
                    np.ceil(slot_cap_bits[None, :] / bits)).astype(np.int64)


def score_grid(metrics: Mapping[str, np.ndarray], idx: np.ndarray,
               cap_bits: Sequence[float], f_req: Sequence[float],
               *, sharded: bool = False,
               devices: Optional[Sequence] = None) -> Dict[str, np.ndarray]:
    """Score ``(J, S)`` composition grid ``idx`` against table ``metrics``.

    ``sharded=True`` splits the grid's J axis across every visible device
    (``repro.compat`` mesh + shard_map); single-device hosts fall back to the
    plain jit call with identical results. Returns numpy ``(J,)`` arrays
    keyed by SYSTEM_METRICS.
    """
    cols = {k: jnp.asarray(np.asarray(metrics[k]), jnp.float32)
            for k in METRIC_COLS}
    idx_dev = jnp.asarray(np.asarray(idx), jnp.int32)
    slot_cap_bits = jnp.asarray(np.asarray(cap_bits), jnp.float32)
    slot_f_req_hz = jnp.asarray(np.asarray(f_req), jnp.float32)
    from repro.analysis import sanitize
    with obs.span("hetero.score", probe=_score_jit,
                  J=int(idx_dev.shape[0]), S=int(idx_dev.shape[1]),
                  sharded=sharded):
        if sharded:
            # shard_map composes badly with checkify's error plumbing; the
            # sanitizer covers the single-device path, which computes the same
            # values
            out = shard_leading(_score_jit, idx_dev, cols, slot_cap_bits,
                                slot_f_req_hz, devices=devices)
        else:
            out = sanitize.maybe_wrap(_score_jit)(
                idx_dev, cols, slot_cap_bits, slot_f_req_hz)
    _C_EVALS.inc()
    return {k: np.asarray(v) for k, v in out.items()}


def score_grid_corners(corner_metrics: Sequence[Mapping[str, np.ndarray]],
                       idx: np.ndarray, cap_bits: Sequence[float],
                       f_req: Sequence[float], *, sharded: bool = False,
                       devices: Optional[Sequence] = None
                       ) -> Dict[str, np.ndarray]:
    """Score one ``(J, S)`` grid under ``C`` operating-corner column sets in
    a single dispatch (``corner_metrics`` is one metric mapping per corner,
    e.g. ``[table.corner_metrics(c) for c in table.corner_labels]``).

    ``sharded=True`` spreads the work over a 2D (compositions × corners)
    device mesh (``repro.parallel.grid.shard2d``); results are bit-identical
    to the single-device path. Returns ``(C, J)`` numpy arrays keyed by
    SYSTEM_METRICS.
    """
    cols = {k: jnp.asarray(np.stack([np.asarray(m[k])
                                     for m in corner_metrics]), jnp.float32)
            for k in METRIC_COLS}
    idx_dev = jnp.asarray(np.asarray(idx), jnp.int32)
    slot_cap_bits = jnp.asarray(np.asarray(cap_bits), jnp.float32)
    slot_f_req_hz = jnp.asarray(np.asarray(f_req), jnp.float32)
    from repro.analysis import sanitize
    with obs.span("hetero.score", probe=_score_corners_jit,
                  J=int(idx_dev.shape[0]), S=int(idx_dev.shape[1]),
                  corners=len(corner_metrics), sharded=sharded):
        if sharded:
            # same caveat as score_grid: shard_map composes badly with
            # checkify, and the single-device path computes identical values
            out = shard2d(_score_corners_jit, idx_dev, cols, slot_cap_bits,
                          slot_f_req_hz, devices=devices)
        else:
            out = sanitize.maybe_wrap(_score_corners_jit)(
                idx_dev, cols, slot_cap_bits, slot_f_req_hz)
    _C_EVALS.inc()
    return {k: np.asarray(v) for k, v in out.items()}
