"""Joint (L1, L2, ...) composition: assemble, score, and rank system designs.

``compose(space, task)`` is the heterogeneous counterpart of
``repro.api.explore``: instead of picking each cache level independently it
forms the N-level grid of per-(level, bucket) candidates (see
``repro.hetero.candidates``) — every level the task declares, or the
``levels=`` subset — prices whole-system compositions in batched jnp
evaluations (``repro.hetero.system``), and ranks them under a
``ComposePolicy``: exhaustively for small grids, or by the provably-lossless
branch-and-bound of ``repro.hetero.search`` when the space outgrows
``search_threshold``. Chip-level envelopes arrive as a ``SystemBudget``
applied to whole compositions. The default ``objective="preference"``
reproduces the
paper's greedy Table-2 selections exactly (the preference-rank sum of
independent slots decomposes, and per-family representatives are chosen with
the same power-then-area order as ``select_bucket_idx``); the other
objectives — and the optional system area/power budgets — are where joint
evaluation earns its keep, trading technologies across levels against a
shared constraint.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core import corners as corners_mod
from repro.core.select import (BucketPick, LevelReq, SelectionPolicy,
                               TaskReq, as_task_req, composition_label)
from repro.hetero import expand as expand_mod
from repro.hetero.candidates import BucketCandidates, level_candidates
from repro.hetero.search import balanced_norms, branch_and_bound
from repro.hetero.system import (SYSTEM_METRICS, SystemBudget, score_grid,
                                 tiles_for)

OBJECTIVES = ("preference", "power", "area", "balanced")
SEARCH_MODES = ("auto", "exhaustive", "branch_and_bound")

# composition-report cache traffic (repro.obs registry; a hit proves the
# repeat compose() re-ran neither the scoring nor the search)
_C_CACHE_HIT = obs.counter("hetero.cache_hits")
_C_CACHE_MISS = obs.counter("hetero.cache_misses")
# swept (operating point x refresh margin) blocks built beyond the base one
_C_EXPANDED = obs.counter("hetero.expanded_points")


@dataclass(frozen=True)
class ComposePolicy:
    """How the composition grid is built and ranked.

    ``objective``  ranking rule:
        - "preference": paper policy — minimize preference-rank sum, then
          static power [W], then area [µm²] (Table-2 parity mode);
        - "power": minimize total power [W], then area;
        - "area": minimize system area [µm²], then power;
        - "balanced": minimize area/min_area + power/min_power.
    ``candidate_mode``  "per_family_best" (one row per technology family per
        bucket, chosen by the paper's power-then-area rule — the parity
        mode) or "all_feasible" (every feasible row). NOTE: under
        "per_family_best" the non-preference objectives optimize over those
        greedy representatives only; use "all_feasible" when the true
        power-/area-optimum over every feasible row is wanted.
    ``max_candidates_per_bucket``  cap per slot in "all_feasible" mode.
    ``max_compositions``  hard cap on the grid size; candidate lists are
        trimmed worst-first until the product fits. ``truncated`` is set on
        the report whenever this or ``max_candidates_per_bucket`` dropped
        feasible rows, i.e. whenever the grid was not exhaustive.
    ``area_budget_um2`` / ``power_budget_w``  legacy two-rail spelling of
        ``budget`` (kept for 2-level callers); mutually exclusive with it.
    ``budget``  optional chip-level ``SystemBudget`` (area [µm²] / power [W] /
        bandwidth-margin [ratio] envelopes on WHOLE compositions).
        Compositions violating any active rail are marked infeasible and
        sort after every feasible one; each active rail pins its per-slot
        extremal rows into the grid past any cap, so the global extremal
        composition is always evaluated and ``n_feasible == 0`` on an
        untruncated grid proves the budget is genuinely unmeetable.
    ``search``  "exhaustive" scores the full cross-product grid;
        "branch_and_bound" enumerates best-first by decomposed per-slot
        objective contributions (``repro.hetero.search``), scoring only
        until the top-k proof closes — identical ranking, far fewer
        evaluations on deep hierarchies; "auto" (default) picks
        branch-and-bound only when the composition space exceeds
        ``search_threshold``.
    ``search_threshold``  "auto" switchover size (full-product count).
    ``search_batch``  branch-and-bound scoring batch (fixed shape: one jit
        trace regardless of how many batches the search needs).
    ``top_k``  how many ranked compositions the report materializes.
    ``vdd_sweep``  per-level (vdd, refresh-margin) co-optimization, axis 1:
        supply points to search *in addition to* the table's base point.
        Entries may be supply voltages [V] (paired with the nominal 300 K),
        ``(vdd [V], temp_k [K])`` tuples, corner names, or full
        ``repro.api.OperatingPoint``s; each adds a virtually re-characterized
        block of every table row at that point (retention re-solved by the
        transient solver, so refresh power follows the physics). Picks record
        the winning point in ``BucketPick.op``.
    ``refresh_margin_sweep``  axis 2: refresh safety margins (fractions of
        solver retention, each in (0, 1]) to search besides the analytic
        default; a block scheduled at margin ``m`` prices refresh at
        ``p_refresh_w / m`` (1/m as many refreshes as refreshing exactly at
        the retention wall). Crossed with ``vdd_sweep``. Winning margins land
        in ``BucketPick.refresh_margin``. Both sweeps are incompatible with
        ``compose(robust="worst_case")``.
    """
    objective: str = "preference"
    candidate_mode: str = "per_family_best"
    max_candidates_per_bucket: int = 64
    max_compositions: int = 200_000
    area_budget_um2: Optional[float] = None
    power_budget_w: Optional[float] = None
    budget: Optional[SystemBudget] = None
    search: str = "auto"
    search_threshold: int = 200_000
    search_batch: int = 512
    top_k: int = 8
    vdd_sweep: Tuple = ()
    refresh_margin_sweep: Tuple[float, ...] = ()

    def __post_init__(self):
        if self.objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {self.objective!r}; "
                             f"choose from {OBJECTIVES}")
        if self.search not in SEARCH_MODES:
            raise ValueError(f"unknown search mode {self.search!r}; "
                             f"choose from {SEARCH_MODES}")
        if self.budget is not None and (self.area_budget_um2 is not None
                                        or self.power_budget_w is not None):
            raise ValueError(
                "pass chip envelopes either as budget=SystemBudget(...) or "
                "via the legacy area_budget_um2/power_budget_w fields, "
                "not both")
        # normalize the sweeps once, here, so every downstream consumer
        # (expansion, cache keys via dataclasses.asdict, report repr) sees
        # canonical OperatingPoints / floats (frozen dataclass -> setattr)
        pts = tuple(corners_mod.as_operating_point(
            (float(p), corners_mod.NOMINAL.temp_k)
            if isinstance(p, (int, float)) and not isinstance(p, bool)
            else p) for p in self.vdd_sweep)
        labels = [p.corner for p in pts]
        if len(set(labels)) != len(labels):
            raise ValueError(f"vdd_sweep labels collide: {labels}")
        object.__setattr__(self, "vdd_sweep", pts)
        margins = []
        for m in self.refresh_margin_sweep:
            m = float(m)
            # same rule as repro.sim.refresh (not imported: hetero sits
            # below sim): a margin must be a usable fraction of retention
            if not math.isfinite(m) or not 0.0 < m <= 1.0:
                raise ValueError(
                    f"refresh_margin_sweep entries must be in (0, 1], "
                    f"got {m!r}")
            margins.append(m)
        if len(set(margins)) != len(margins):
            raise ValueError(f"refresh_margin_sweep repeats: {margins}")
        object.__setattr__(self, "refresh_margin_sweep", tuple(margins))

    def system_budget(self) -> SystemBudget:
        """The effective chip-level budget: ``budget`` if given, else the
        legacy two-rail fields folded into a ``SystemBudget``."""
        if self.budget is not None:
            return self.budget
        return SystemBudget(area_um2=self.area_budget_um2,
                            power_w=self.power_budget_w)


@dataclass(frozen=True)
class LevelComposition:
    """One cache level inside a composition: per-bucket picks + tiling.

    ``picks[i]`` is the (family, table row) serving bucket ``i``;
    ``tiles[i]`` is how many copies of that macro cover the bucket's
    capacity share. ``label`` joins the distinct families in bucket order
    (paper Table-2 nomenclature), or "infeasible" when no bucket found a
    technology.
    """
    level: LevelReq
    label: str
    picks: Tuple[BucketPick, ...]
    tiles: Tuple[int, ...] = field(default_factory=tuple)

    @property
    def feasible(self) -> bool:
        return all(p.family is not None for p in self.picks)


@dataclass(frozen=True)
class Composition:
    """One whole-system design: every level composed, system metrics attached.

    ``metrics`` holds the batched-scorer outputs for this design —
    ``area_um2`` [µm²], ``p_static_w``/``p_dyn_w``/``p_w`` [W],
    ``bw_margin`` (min f_op/f_required ratio), ``capacity_bits`` [bits],
    ``overprovision`` (ratio ≥ 1 when every slot is covered).
    """
    levels: Dict[str, LevelComposition]
    metrics: Dict[str, float]
    pref_rank: int
    feasible: bool

    def labels(self) -> Dict[str, str]:
        """Table-2 style ``{"L1": label, "L2": label}`` for this design."""
        return {name: lc.label for name, lc in self.levels.items()}

    def __repr__(self) -> str:
        cells = "  ".join(f"{n}: {lc.label}" for n, lc in self.levels.items())
        a, p = self.metrics["area_um2"], self.metrics["p_w"]
        stats = (f"area={a:.0f}um2, p={p * 1e3:.3f}mW"
                 if math.isfinite(a) else "infeasible slots")
        return f"Composition({cells}; {stats})"


@dataclass(frozen=True)
class CompositionReport:
    """Result of one ``compose()`` call.

    ``ranked`` is best-first (``best`` is ``ranked[0]``); ``n_compositions``
    is the number of compositions actually scored and ``n_feasible`` how many
    of THOSE passed slot feasibility + budgets — under
    ``search="branch_and_bound"`` that is the enumerated subset (``n_space``
    records the full cross-product size), under "exhaustive" the whole grid
    (``n_compositions == n_space`` unless trimmed). ``truncated`` flags a
    lossy search: ``max_compositions`` trimmed the exhaustive grid / stopped
    the branch-and-bound walk before its bound proof closed, or
    ``max_candidates_per_bucket`` capped a slot.
    """
    table: object                       # repro.api.DesignTable
    task: TaskReq
    policy: SelectionPolicy
    compose_policy: ComposePolicy
    ranked: Tuple[Composition, ...]
    n_compositions: int
    n_feasible: int
    truncated: bool = False
    # which engine ranked the grid ("exhaustive" | "branch_and_bound") and
    # the untrimmed cross-product size it drew from (python int: 64-candidate
    # slots at depth overflow int64)
    search: str = "exhaustive"
    n_space: int = 0
    # set to "simulate" by the repro.sim re-rank: ``ranked`` is then ordered
    # by trace-replayed energy/latency and every composition's ``metrics``
    # carries the ``sim_*`` keys
    refined: Optional[str] = None
    # "worst_case" when candidates/scoring priced the per-row worst corner
    robust: Optional[str] = None

    @property
    def best(self) -> Composition:
        return self.ranked[0]

    def labels(self) -> Dict[str, str]:
        """Table 2 cell for this task: ``{"L1": label, "L2": label}``."""
        return self.best.labels()

    def matches(self, expected: Mapping[str, str]) -> bool:
        """Does the best composition reproduce ``expected`` level labels?"""
        got = self.labels()
        return all(got.get(lvl) == lab for lvl, lab in expected.items())

    def pick_macro(self, level: str, bucket: int = 0):
        """The selected macro (as ``repro.api.Macro``) for one slot.

        A vdd-swept pick re-characterizes its config at the pick's operating
        point (and scales refresh power by its scheduled margin), so the
        returned PPA is the one the composition was actually priced at."""
        pick = self.best.levels[level].picks[bucket]
        if pick.config_idx < 0:
            raise LookupError(f"{self.task.task_id} {level} bucket {bucket} "
                              f"is infeasible under {self.policy}")
        if pick.op is None and pick.refresh_margin is None:
            return self.table.macro(pick.config_idx)
        from repro.api import Macro                 # runtime: avoids cycle
        from repro.core import characterize as chz
        cfg = self.table.config(pick.config_idx)
        ppa = chz.characterize_config(cfg, tp=pick.op)
        if pick.refresh_margin is not None:
            ppa["p_refresh_w"] /= float(pick.refresh_margin)
        return Macro(config=cfg, ppa=ppa)

    def summary(self) -> str:
        b = self.best
        m = b.metrics
        lines = [f"task {self.task.task_id} {self.task.name}: "
                 f"{self.n_compositions} compositions evaluated, "
                 f"{self.n_feasible} feasible"
                 + (" (truncated grid)" if self.truncated else "")]
        for name, lc in b.levels.items():
            per = "  ".join(
                f"[{i}] {p.family or '-'} x{t}"
                for i, (p, t) in enumerate(zip(lc.picks, lc.tiles)))
            lines.append(f"  {name}: {lc.label:40s} {per}")
        if math.isfinite(m["area_um2"]):
            lines.append(
                f"  system: area {m['area_um2'] / 1e6:.3f} mm^2, "
                f"power {m['p_w'] * 1e3:.3f} mW "
                f"(static {m['p_static_w'] * 1e3:.3f} mW), "
                f"bw margin {m['bw_margin']:.2f}x, "
                f"overprovision {m['overprovision']:.2f}x")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# grid assembly
# ---------------------------------------------------------------------------


def _trim_to_budget(slots: Sequence[BucketCandidates],
                    max_compositions: int):
    """Drop worst-ranked candidates (from the largest slot first) until the
    cross-product fits, never dropping a budget-pinned row.
    Returns (candidate lists, truncated flag)."""
    lists = [list(bc.candidates) for bc in slots]
    pinned = [set(bc.pinned) for bc in slots]
    truncated = False
    # math.prod: arbitrary-precision (np.prod would wrap in int64 and skip
    # trimming entirely for ~11+ slots at the 64-candidate cap)
    while math.prod(len(c) for c in lists) > max_compositions:
        dropped = False
        for s in sorted(range(len(lists)), key=lambda s: -len(lists[s])):
            if len(lists[s]) <= 1:
                continue
            # lists are ordered best-first: drop the worst unpinned row
            for j in range(len(lists[s]) - 1, -1, -1):
                if lists[s][j].config_idx not in pinned[s]:
                    lists[s].pop(j)
                    dropped = truncated = True
                    break
            if dropped:
                break
        if not dropped:      # nothing left but pins/singletons: stop (the
            break            # excess is bounded by a few pins per slot)
    return lists, truncated


def _composition_grid(slots: Sequence[BucketCandidates],
                      max_compositions: int):
    """Cross-product of per-slot candidates.

    Returns ``(idx (J,S) int32, pos (J,S) candidate-list positions,
    rank_sum (J,), truncated)``.
    """
    lists, truncated = _trim_to_budget(slots, max_compositions)
    counts = [len(c) for c in lists]
    pos = np.indices(counts).reshape(len(counts), -1)      # (S, J)
    idx = np.empty(pos.shape[::-1], np.int32)              # (J, S)
    ranks = np.zeros(pos.shape[1], np.int64)
    for s, cands in enumerate(lists):
        cfg = np.array([c.config_idx for c in cands], np.int32)
        rk = np.array([c.pref_rank for c in cands], np.int64)
        idx[:, s] = cfg[pos[s]]
        ranks += rk[pos[s]]
    return idx, pos.T, ranks, truncated


def _order(scores: Dict[str, np.ndarray], rank_sum: np.ndarray,
           feasible: np.ndarray, cp: ComposePolicy, pos: np.ndarray,
           norms: Optional[Tuple[float, float]] = None) -> np.ndarray:
    """Best-first permutation of the composition grid under the objective.

    ``pos`` is the (J, S) candidate-list position matrix: its columns are
    the lowest-priority tie-break keys (slot 0 most significant), which is
    exactly the row-major order ``np.indices`` lays the exhaustive grid out
    in — so the exhaustive ranking is unchanged from a plain stable lexsort,
    and the branch-and-bound path (which scores the same compositions in a
    different order) breaks metric ties identically. ``norms`` carries the
    analytic ``(a0 [µm²], p0 [W])`` normalizers for "balanced"
    (``repro.hetero.search.balanced_norms``) — a function of the candidate
    lists alone, so both search paths normalize identically.
    """
    infeas = (~feasible).astype(np.int64)
    big = np.finfo(np.float64).max

    def finite(name):
        return np.nan_to_num(np.asarray(scores[name], np.float64), posinf=big)

    area, p_st, p_w = finite("area_um2"), finite("p_static_w"), finite("p_w")
    ties = tuple(pos[:, s] for s in reversed(range(pos.shape[1])))
    if cp.objective == "preference":
        keys = (area, p_st, rank_sum, infeas)
    elif cp.objective == "power":
        keys = (area, p_w, infeas)
    elif cp.objective == "area":
        keys = (p_w, area, infeas)
    else:                                           # balanced
        if norms is not None:
            a0, p0 = norms
        else:
            fa = area[feasible] if feasible.any() else area
            fp = p_w[feasible] if feasible.any() else p_w
            a0 = max(float(np.min(fa)), 1e-30)
            p0 = max(float(np.min(fp)), 1e-30)
        with np.errstate(over="ignore"):    # sentinel rows: max/a0 -> inf,
            keys = (area / a0 + p_w / p0, infeas)   # which sorts last anyway
    return np.lexsort(ties + keys)         # last key is the primary sort


# ---------------------------------------------------------------------------
# compose
# ---------------------------------------------------------------------------


def _materialize(table, task: TaskReq, idx_row: np.ndarray,
                 tiles_row: np.ndarray, metrics_row: Dict[str, float],
                 rank: int, feasible: bool, points=None) -> Composition:
    """Build one Composition dataclass from a scored grid row (slot order:
    levels in task order, buckets in bucket order).

    ``points`` is the vdd-sweep block schedule (``expand.expansion_points``)
    when the grid was virtually expanded: row indices then decode as
    ``(block, base row)`` and each pick records its block's operating point
    and refresh margin; ``config_idx`` is always a PHYSICAL table row."""
    fam_col = np.asarray(table.families)
    n_base = len(fam_col)
    levels: Dict[str, LevelComposition] = {}
    s = 0
    for name, level in task.levels.items():
        picks, tiles = [], []
        for bucket in level.buckets:
            cfg = int(idx_row[s])
            op = margin = None
            if cfg >= 0 and points is not None and len(points) > 1:
                block, cfg = divmod(cfg, n_base)
                op, margin = points[block]
            fam = str(fam_col[cfg]) if cfg >= 0 else None
            picks.append(BucketPick(bucket=bucket, family=fam,
                                    config_idx=cfg, op=op,
                                    refresh_margin=margin))
            tiles.append(int(tiles_row[s]))
            s += 1
        levels[name] = LevelComposition(
            level=level, label=composition_label(p.family for p in picks),
            picks=tuple(picks), tiles=tuple(tiles))
    return Composition(levels=levels, metrics=metrics_row,
                       pref_rank=rank, feasible=feasible)


def compose(space=None, task=None, policy: Optional[SelectionPolicy] = None,
            compose_policy: Optional[ComposePolicy] = None,
            cache=None, sharded: bool = False,
            refine: Optional[str] = None,
            sim_policy=None, corners=None,
            robust: Optional[str] = None,
            levels: Optional[Sequence[str]] = None) -> CompositionReport:
    """Joint heterogeneous composition for one task.

    ``space``   MacroConfig list, a built ``DesignTable``, or None for the
                paper's §5.4 grid (characterized via the cached vmap path).
    ``task``    anything ``repro.core.select.as_task_req`` understands —
                a ``gainsight.Task``, a ``TaskReq`` from
                ``repro.profiler.traffic.arch_task``, or a plain mapping.
    ``policy``  feasibility/preference policy (paper default).
    ``compose_policy``  grid + ranking policy (see ``ComposePolicy``).
    ``cache``   directory for BOTH the DesignTable npz cache and the
                composition-report npz cache; a repeated ``compose()`` on the
                same (grid, task, policies) re-runs neither the vmap
                characterization nor the batched scoring.
    ``sharded`` split the composition grid across every visible device
                (identical results; throughput only).
    ``refine``  ``"simulate"`` prunes analytically to the policy's ``top_k``
                and re-ranks those leaders by trace-replayed energy/latency
                (``repro.sim``); the simulated report caches beside the
                analytic one. ``sim_policy`` is a ``repro.sim.SimPolicy``
                (phases, bins, refresh scheduling, re-rank objective).
    ``corners`` operating points (``repro.api.OperatingPoint``s / names)
                batched into the characterization; None = nominal only.
    ``robust``  ``"worst_case"`` prices candidate feasibility and the system
                scoring on the per-row worst corner, so the winning
                composition must hold at EVERY corner; None uses the base
                (``corners[0]``) columns.
    ``levels``  optional level-name subset (e.g. ``("L1", "L2")``) composed
                in the given order; None composes every level the task
                declares. Unknown names raise ``KeyError``.
    """
    from repro.api import DesignTable           # runtime: avoids module cycle
    if refine not in (None, "simulate"):
        raise ValueError(f"unknown refine mode {refine!r}; "
                         f"valid: None, 'simulate'")
    if task is None:
        raise TypeError("compose() requires a task "
                        "(e.g. repro.core.gainsight.TASKS[0])")
    task = as_task_req(task)
    if levels is not None:
        missing = [n for n in levels if n not in task.levels]
        if missing:
            raise KeyError(f"task {task.task_id!r} has no level(s) {missing};"
                           f" available: {list(task.levels)}")
        task = TaskReq(task.task_id, task.name,
                       {n: task.levels[n] for n in levels})
    policy = policy or SelectionPolicy()
    cp = compose_policy or ComposePolicy()
    if robust is not None and (cp.vdd_sweep or cp.refresh_margin_sweep):
        raise ValueError(
            "vdd_sweep/refresh_margin_sweep cannot be combined with "
            "robust='worst_case': worst-corner columns fold the corner axis "
            "the sweep is searching over")
    table = DesignTable.build(space, cache=cache, corners=corners)

    def _refine(report: CompositionReport) -> CompositionReport:
        if refine != "simulate":
            return report
        from repro.sim.rerank import simulate_report   # runtime: no cycle
        return simulate_report(report, sim_policy=sim_policy, cache=cache)

    compose_span = obs.span("hetero.compose", task=str(task.task_id),
                            objective=cp.objective)
    with compose_span:
        return _compose_inner(table, task, policy, cp, cache, sharded,
                              robust, _refine, compose_span)


def _compose_inner(table, task, policy, cp, cache, sharded, robust,
                   _refine, sp) -> CompositionReport:
    if cache is not None:
        from repro.hetero import cache as cache_mod
        hit = cache_mod.load_report(cache, table, task, policy, cp,
                                    robust=robust)
        if hit is not None:
            _C_CACHE_HIT.inc()
            sp.set(cache="hit")
            return _refine(hit)
        _C_CACHE_MISS.inc()
        sp.set(cache="miss")

    metrics = table.robust_metrics(robust)
    fam_col = table.families
    points = expand_mod.expansion_points(cp)
    if len(points) > 1:
        # virtual (operating point x refresh margin) expansion: every table
        # row replicated per swept block, re-characterized at that block's
        # supply/temperature (see repro.hetero.expand)
        with obs.span("hetero.expand", n_points=len(points),
                      n_base=len(fam_col)):
            metrics, fam_col = expand_mod.expand_metrics(table, metrics,
                                                         points)
        _C_EXPANDED.inc(len(points) - 1)
    # candidate lists are ordered by the active objective's tiled slot
    # contribution so per-bucket caps and grid trimming discard the
    # objective's *worst* rows, not its best; active budgets pin their
    # per-slot argmin rows into the grid so an all-infeasible result proves
    # the budget is truly unmeetable (not a cap artifact)
    order_by = cp.objective if cp.objective in ("power", "area", "balanced") \
        else "preference"
    budget = cp.system_budget()
    slots: Tuple[BucketCandidates, ...] = tuple(
        bc for level in task.levels.values()
        for bc in level_candidates(metrics, fam_col, level, policy,
                                   mode=cp.candidate_mode,
                                   max_per_bucket=cp.max_candidates_per_bucket,
                                   order_by=order_by,
                                   ensure_orders=budget.ensure_orders()))
    cap_bits = np.array([bc.capacity_bits for bc in slots], np.float64)
    f_req = np.array([bc.bucket.f_hz for bc in slots], np.float64)

    # full cross-product size as a python int: 64-candidate slots at 11+
    # levels overflow int64, and this number keys the auto search switch
    n_space = math.prod(len(bc.candidates) for bc in slots)
    use_bb = (cp.search == "branch_and_bound"
              or (cp.search == "auto" and n_space > cp.search_threshold))
    norms = balanced_norms(slots, metrics) \
        if cp.objective == "balanced" else None
    with obs.span("hetero.search",
                  search=("branch_and_bound" if use_bb else "exhaustive"),
                  n_space=int(n_space)) as search_span:
        if use_bb:
            idx, pos, rank_sum, scores, truncated, _ = branch_and_bound(
                slots, metrics, cap_bits, f_req, cp.objective, budget,
                top_k=cp.top_k, max_nodes=cp.max_compositions,
                batch=cp.search_batch, sharded=sharded)
        else:
            idx, pos, rank_sum, truncated = _composition_grid(
                slots, cp.max_compositions)
            scores = score_grid(metrics, idx, cap_bits, f_req,
                                sharded=sharded)
        search_span.set(n_scored=int(idx.shape[0]))
    truncated = truncated or any(bc.capped for bc in slots)

    feasible = np.all(idx >= 0, axis=1) & budget.feasible(scores)

    order = _order(scores, rank_sum, feasible, cp, pos, norms)
    top = order[:max(cp.top_k, 1)]
    tiles = tiles_for(metrics, idx[top], cap_bits)
    ranked = tuple(
        _materialize(table, task, idx[j], tiles[k],
                     {m: float(scores[m][j]) for m in SYSTEM_METRICS},
                     int(rank_sum[j]), bool(feasible[j]), points=points)
        for k, j in enumerate(top))
    report = CompositionReport(table=table, task=task, policy=policy,
                               compose_policy=cp, ranked=ranked,
                               n_compositions=int(idx.shape[0]),
                               n_feasible=int(feasible.sum()),
                               truncated=truncated, robust=robust,
                               search=("branch_and_bound" if use_bb
                                       else "exhaustive"),
                               n_space=int(n_space))
    if cache is not None:
        from repro.hetero import cache as cache_mod
        cache_mod.save_report(cache, report, idx[top])
    return _refine(report)
