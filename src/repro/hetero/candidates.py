"""Per-(level, bucket) candidate enumeration for the composition engine.

A *candidate* is one DesignTable row that can serve one bucket of one cache
level: it must be feasible for the bucket's (read frequency [Hz], data
lifetime [s]) point under the active ``SelectionPolicy`` (refresh rules
included). The composition grid is the cross-product of these per-slot
candidate lists, so the lists are kept deliberately small:

``per_family_best`` (default)
    one representative row per technology family, chosen exactly like the
    paper's greedy policy (lowest leak+refresh power, then area) — the mode
    under which the joint path provably reproduces ``select_level``.
``all_feasible``
    every feasible row, capped at ``max_per_bucket`` — the mode for
    exhaustive sweeps and benchmarks. The list (and therefore what the cap
    and any grid trimming keep) is ordered by the active objective:
    preference-rank-major by default; for "power"/"area"/"balanced" it is
    ordered by the row's **tiled slot contribution** — the quantity the
    system scorer actually sums (``ceil(capacity_bits/bits) * metric``,
    plus ``e_read_j * f_hz`` dynamic power for "power") — NOT the raw
    per-macro metric, which anti-correlates with the system optimum when a
    big macro tiles fewer times. Because slot contributions add
    independently across slots, the head of each list contains the slot's
    true optimum, so caps/trimming cannot discard what an unbudgeted
    power/area objective is looking for.

Budget pins: for each active budget rail (``ensure_orders``), the extremal
row over **every** feasible row — not just the rows the mode/order kept — is
pinned into the list (and marked in ``BucketCandidates.pinned`` so grid
trimming cannot drop it either): argmin tiled area for "area", argmin tiled
power for "power", argmax operating frequency for "bandwidth". The grid
therefore always evaluates the global extremal composition for every rail of
a ``SystemBudget``, making an all-infeasible budget verdict trustworthy in
every mode.

Slots with no feasible row get a single *sentinel* candidate
(``family=None, config_idx=-1``) so the cross-product still forms; the
system scorer prices sentinel slots at +inf and the report marks the
composition infeasible (mirroring ``select_level``'s "infeasible" label).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Optional, Tuple

import numpy as np

from repro.core.select import (Bucket, LevelReq, SelectionPolicy,
                               feasible_mask)


@dataclass(frozen=True)
class Candidate:
    """One DesignTable row eligible for one (level, bucket) slot.

    ``family``     technology family ("sram" | "si-si" | "os-si" | "os-os"),
                   or None for the infeasible sentinel.
    ``config_idx`` row index into the DesignTable (-1 for the sentinel).
    ``pref_rank``  index into ``SelectionPolicy.preference`` (lower is more
                   preferred; sentinels rank after every real family).
    """
    family: Optional[str]
    config_idx: int
    pref_rank: int


@dataclass(frozen=True)
class BucketCandidates:
    """Candidate list for one bucket slot plus its capacity share.

    ``capacity_bits`` is the bucket's slice of the level capacity
    (``level.capacity_bits * bucket.frac``) [bits]; the system model tiles
    the chosen macro to cover it. ``capped`` records that ``max_per_bucket``
    dropped feasible rows — the grid built from this slot is not exhaustive
    (surfaced as ``CompositionReport.truncated``). ``pinned`` holds the
    config indices of budget-ensured rows that grid trimming must keep.
    """
    level_name: str
    bucket_index: int
    bucket: Bucket
    capacity_bits: float
    candidates: Tuple[Candidate, ...]
    capped: bool = False
    pinned: Tuple[int, ...] = field(default_factory=tuple)

    @property
    def feasible(self) -> bool:
        return self.candidates[0].config_idx >= 0


def bucket_candidates(metrics: Mapping[str, np.ndarray],
                      families: np.ndarray, bucket: Bucket,
                      *, level_name: str, bucket_index: int,
                      capacity_bits: float,
                      policy: SelectionPolicy = SelectionPolicy(),
                      mode: str = "per_family_best",
                      max_per_bucket: int = 64,
                      order_by: str = "preference",
                      ensure_orders: Tuple[str, ...] = ()) -> BucketCandidates:
    """Enumerate candidate rows for one bucket (see module docstring).

    ``metrics``   DesignTable metric columns (each shape ``(n_configs,)``).
    ``families``  technology family per row.
    ``order_by``  list order in "all_feasible" mode: "preference"
                  (rank-major, the default) or "power"/"area"/"balanced" —
                  ordered by the row's tiled slot contribution [W]/[µm²]
                  (see module docstring). Caps/trimming keep the head, so
                  this must match the ranking objective.
    ``ensure_orders``  budget rails ("area"/"power"/"bandwidth") whose
                  per-slot extremal row — over ALL feasible rows, regardless
                  of mode — must be pinned into the list (``compose`` passes
                  ``SystemBudget.ensure_orders()``; "bandwidth" pins the
                  argmax-``f_op_hz`` row since the bw-margin rail is a
                  floor, not a ceiling).
    Returns a ``BucketCandidates`` whose list is never empty (sentinel when
    nothing is feasible).
    """
    if mode not in ("per_family_best", "all_feasible"):
        raise ValueError(f"unknown candidate mode {mode!r}")
    if order_by not in ("preference", "power", "area", "balanced"):
        raise ValueError(f"unknown candidate order {order_by!r}")
    if set(ensure_orders) - {"power", "area", "bandwidth"}:
        raise ValueError(f"unknown ensure_orders {ensure_orders!r}")
    mask = feasible_mask(metrics, bucket.f_hz, bucket.lifetime_s,
                         allow_refresh=policy.allow_refresh,
                         refresh_power_frac=policy.refresh_power_frac)
    families = np.asarray(families)
    power = (np.asarray(metrics["p_leak_w"], np.float64)
             + np.asarray(metrics["p_refresh_w"], np.float64))
    area = np.asarray(metrics["area_um2"], np.float64)

    # feasible rows per family, in preference order
    blocks = []                                   # (rank, fam, row indices)
    for rank, fam in enumerate(policy.preference):
        idx = np.where(mask & (families == fam))[0]
        if idx.size:
            blocks.append((rank, fam, idx))

    out = []
    for rank, fam, idx in blocks:
        # within-family order identical to select_bucket_idx: power, then area
        order = np.lexsort((area[idx], power[idx]))
        take = 1 if mode == "per_family_best" else len(order)
        out.extend(Candidate(fam, int(idx[i]), rank) for i in order[:take])

    sys_area = sys_power = None
    if blocks and (order_by != "preference" or ensure_orders):
        # tiled slot contribution: what score_kernel actually sums per slot
        tiles = np.ceil(capacity_bits
                        / np.maximum(np.asarray(metrics["bits"],
                                                np.float64), 1.0))
        sys_area = tiles * area
        sys_power = (tiles * power
                     + np.asarray(metrics["e_read_j"], np.float64)
                     * bucket.f_hz)

    if out and order_by == "power":
        out.sort(key=lambda c: (sys_power[c.config_idx],
                                sys_area[c.config_idx]))
    elif out and order_by == "area":
        out.sort(key=lambda c: (sys_area[c.config_idx],
                                sys_power[c.config_idx]))
    elif out and order_by == "balanced":          # slot-normalized blend
        rows = [c.config_idx for c in out]
        a0 = max(float(sys_area[rows].min()), 1e-30)
        p0 = max(float(sys_power[rows].min()), 1e-30)
        out.sort(key=lambda c: sys_area[c.config_idx] / a0
                 + sys_power[c.config_idx] / p0)

    capped = len(out) > max_per_bucket
    out = out[:max_per_bucket]

    # budget pins: argmin over EVERY feasible row (not just the kept/ordered
    # ones), deduplicated, and recorded so grid trimming keeps them too
    pinned = []
    if blocks and ensure_orders:
        all_rows = np.concatenate([idx for _, _, idx in blocks])
        rank_fam = {int(i): (rank, fam)
                    for rank, fam, idx in blocks for i in idx}
        f_op = np.asarray(metrics["f_op_hz"], np.float64)
        for ensure in ensure_orders:
            # each rail's extremal contribution: min tiled area / min tiled
            # power / max frequency (bandwidth margin is a floor)
            contrib = {"area": sys_area, "power": sys_power,
                       "bandwidth": -f_op}[ensure]
            r = int(all_rows[np.argmin(contrib[all_rows])])
            rank, fam = rank_fam[r]
            cand = Candidate(fam, r, rank)
            if cand not in out:
                out.append(cand)
            if r not in pinned:
                pinned.append(r)

    if not out:
        out = [Candidate(None, -1, len(policy.preference))]
    return BucketCandidates(level_name=level_name, bucket_index=bucket_index,
                            bucket=bucket, capacity_bits=capacity_bits,
                            candidates=tuple(out), capped=capped,
                            pinned=tuple(pinned))


def level_candidates(metrics: Mapping[str, np.ndarray], families: np.ndarray,
                     level: LevelReq,
                     policy: SelectionPolicy = SelectionPolicy(),
                     mode: str = "per_family_best",
                     max_per_bucket: int = 64,
                     order_by: str = "preference",
                     ensure_orders: Tuple[str, ...] = ()
                     ) -> Tuple[BucketCandidates, ...]:
    """Candidate lists for every bucket of one cache level, in bucket order."""
    return tuple(
        bucket_candidates(metrics, families, b, level_name=level.name,
                          bucket_index=i,
                          capacity_bits=level.capacity_bits * b.frac,
                          policy=policy, mode=mode,
                          max_per_bucket=max_per_bucket, order_by=order_by,
                          ensure_orders=ensure_orders)
        for i, b in enumerate(level.buckets))
