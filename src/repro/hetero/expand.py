"""Operating-point expansion: the (vdd, refresh-margin) search axis.

``ComposePolicy.vdd_sweep`` / ``refresh_margin_sweep`` turn the per-level
technology choice into a per-level *operating point* choice too: every
DesignTable row is virtually replicated once per swept
``(operating point, refresh margin)`` pair, re-characterized at that supply
and temperature through the very same per-corner jitted vmap the corner
machinery uses (``core.characterize.characterize_corners`` — retention and
therefore refresh power are re-derived by the ``core.retention`` transient
solver at the swept point, not scaled). The composition engine then searches
the enlarged table with zero changes: candidates, exhaustive scoring, and
branch-and-bound all index metric columns by candidate row, and per-slot
contributions still decompose, so the B&B bound proof stays lossless.

Virtual indexing: block ``b`` of point ``points[b]`` holds rows
``[b * n_base, (b + 1) * n_base)``; ``base = idx % n_base`` recovers the
physical table row (axes, families, and ``bits`` are operating-point
invariant). Block 0 is always the un-swept base point and its columns are
the input metrics *passed through untouched*, so an empty sweep — or the
base block winning — is bit-identical to the pre-sweep compiler.

Refresh-margin blocks price the *schedule*, not the physics: refreshing at
``margin × retention_s`` issues ``1/margin`` as many refreshes as the
analytic steady-state (which refreshes exactly at the retention wall), so
``p_refresh_w`` scales by ``1/margin``; retention itself is untouched.
"""
from __future__ import annotations

from typing import Dict, Mapping, Tuple

import numpy as np


def expansion_points(compose_policy) -> Tuple[Tuple[object, object], ...]:
    """The virtual-block schedule for a ComposePolicy: ``(op, margin)`` per
    block, block 0 always ``(None, None)`` (the table's own base point).

    The sweep axes cross: every swept vdd point is also tried at every swept
    refresh margin (and at the analytic default, ``margin=None``)."""
    vdds = (None,) + tuple(compose_policy.vdd_sweep)
    margins = (None,) + tuple(compose_policy.refresh_margin_sweep)
    return tuple((v, m) for v in vdds for m in margins)


def expand_metrics(table, metrics: Mapping[str, np.ndarray],
                   points: Tuple[Tuple[object, object], ...]
                   ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Build the virtually-expanded ``(metrics, families)`` for ``points``.

    ``metrics`` is the (n_base,)-column dict the compose pass would
    otherwise rank on; the return columns have ``len(points) * n_base`` rows
    in block order. Characterized columns come from one vmapped dispatch per
    swept operating point; columns the characterizer does not produce
    (axis-derived or user-added ones) are operating-point invariant and tile
    through unchanged, as do the table's family labels.
    """
    import jax.numpy as jnp

    from repro.core import characterize as chz

    families = np.asarray(table.families)
    n_base = len(families)
    per_op: Dict[object, Dict[str, np.ndarray]] = {}
    blocks: list = []
    for op, margin in points:
        if op is None:
            block = dict(metrics)            # base point: columns untouched
        else:
            if op not in per_op:
                vecs = jnp.stack([c.to_vector()
                                  for c in table.to_configs()])
                out = chz.characterize_corners(vecs, (op,))
                per_op[op] = {k: np.asarray(v)[:, 0] for k, v in out.items()}
            char = per_op[op]
            block = {k: char.get(k, metrics[k]) for k in metrics}
        if margin is not None:
            block = dict(block)
            block["p_refresh_w"] = (np.asarray(block["p_refresh_w"])
                                    / float(margin))
        blocks.append(block)
    expanded = {k: np.concatenate([np.asarray(b[k]) for b in blocks])
                for k in metrics}
    return expanded, np.concatenate([families] * len(points))


def to_base(idx: np.ndarray, n_base: int) -> np.ndarray:
    """Map virtual row indices back to physical table rows, preserving the
    ``-1`` infeasible sentinel."""
    idx = np.asarray(idx)
    return np.where(idx >= 0, idx % max(n_base, 1), idx)
