"""Version-portable jax substrate (supported range: jax 0.4.30 – 0.7.x).

Every jax API this repo touches that drifted across the 0.4.x → 0.7.x
releases goes through here, and ONLY here — no module outside this file may
reference ``jax.sharding.AxisType``, ``pltpu.CompilerParams`` /
``pltpu.TPUCompilerParams``, or construct a ``Mesh`` directly.  The drift
this file absorbs:

  =====================  ==========================  =========================
  API                    old spelling (0.4.x)        new spelling (0.6+)
  =====================  ==========================  =========================
  Pallas TPU params      pltpu.TPUCompilerParams     pltpu.CompilerParams
  mesh axis types        (kwarg does not exist)      jax.make_mesh(...,
                                                       axis_types=(AxisType
                                                       .Auto, ...))
  shard_map              jax.experimental.shard_map  jax.shard_map
                           (check_rep=...)             (check_vma=...)
  =====================  ==========================  =========================

Resolution happens at CALL time, not import time, so tests can monkeypatch
either spelling onto the live jax modules and both code paths stay covered
on whichever jax is pinned (see tests/test_compat.py).
"""
from __future__ import annotations

import inspect
from typing import Optional, Sequence

import jax
import numpy as np


def jax_version() -> tuple:
    """(major, minor, patch) ints, tolerant of dev/rc suffixes."""
    parts = []
    for p in jax.__version__.split(".")[:3]:
        digits = "".join(ch for ch in p if ch.isdigit())
        parts.append(int(digits) if digits else 0)
    return tuple(parts)


def _accepts(fn, name: str) -> bool:
    try:
        return name in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


# ---------------------------------------------------------------------------
# Pallas TPU compiler params
# ---------------------------------------------------------------------------


def tpu_compiler_params(**kwargs):
    """Build Pallas-TPU compiler params under either spelling.

    jax >= 0.6.2 renamed ``TPUCompilerParams`` -> ``CompilerParams``;
    releases before the dataclass existed take a plain ``{"mosaic": {...}}``
    dict.  Typical use::

        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        return {"mosaic": dict(kwargs)}
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------


def axis_types(kind: Optional[str], n: int):
    """``n``-tuple of ``jax.sharding.AxisType`` members, or None where the
    enum does not exist (jax < 0.6 treats every axis as implicitly auto)."""
    if kind is None:
        return None
    enum = getattr(jax.sharding, "AxisType", None)
    if enum is None:
        return None
    member = getattr(enum, kind.capitalize(), None)
    return None if member is None else (member,) * n


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              kind: Optional[str] = "auto", devices=None):
    """``jax.make_mesh`` across the ``axis_types`` drift.

    ``kind`` is the symbolic axis type ("auto" / "explicit" / "manual")
    applied to every axis; it degrades to nothing where the enum or the
    kwarg is missing.  Falls back to hand-arranged ``jax.sharding.Mesh``
    construction on releases that predate ``jax.make_mesh`` itself.
    """
    types = axis_types(kind, len(axis_names))
    fn = getattr(jax, "make_mesh", None)
    if fn is not None:
        kw = {}
        if devices is not None:
            kw["devices"] = devices
        if types is not None and _accepts(fn, "axis_types"):
            kw["axis_types"] = types
        return fn(tuple(axis_shapes), tuple(axis_names), **kw)
    n = int(np.prod(axis_shapes))
    devs = list(devices) if devices is not None else jax.devices()[:n]
    return jax.sharding.Mesh(
        np.asarray(devs).reshape(tuple(axis_shapes)), tuple(axis_names))


def current_mesh():
    """The physical mesh activated by ``with mesh:``, or None.

    This is the one private-API touchpoint (``thread_resources`` has no
    public accessor on 0.4.x); isolating it here keeps the model code free
    of ``jax._src`` imports."""
    try:
        from jax._src import mesh as mesh_lib
        env_mesh = mesh_lib.thread_resources.env.physical_mesh
    except Exception:
        return None
    if env_mesh is None or getattr(env_mesh, "empty", True):
        return None
    return env_mesh


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: Optional[bool] = None):
    """``jax.shard_map`` (>= 0.6) / ``jax.experimental.shard_map`` (0.4.x).

    ``check_rep`` maps onto whichever replication-check kwarg the installed
    release spells (``check_vma`` after the rename); None leaves the
    default."""
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # 0.4.x
    kw = {}
    if check_rep is not None:
        if _accepts(fn, "check_vma"):
            kw["check_vma"] = check_rep
        elif _accepts(fn, "check_rep"):
            kw["check_rep"] = check_rep
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


# ---------------------------------------------------------------------------
# sharding constructors (checkpoint restore & dry-run placement)
# ---------------------------------------------------------------------------


def named_sharding(mesh, *spec):
    """NamedSharding from PartitionSpec parts (or a ready PartitionSpec)."""
    from jax.sharding import NamedSharding, PartitionSpec
    if len(spec) == 1 and isinstance(spec[0], PartitionSpec):
        return NamedSharding(mesh, spec[0])
    return NamedSharding(mesh, PartitionSpec(*spec))


def replicated_like(mesh, tree):
    """Pytree of fully-replicated NamedShardings matching ``tree``'s leaves
    (the reshard-on-restore default when no explicit shardings are given)."""
    sh = named_sharding(mesh)
    return jax.tree.map(lambda _: sh, tree)
