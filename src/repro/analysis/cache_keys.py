"""CK: cache-key completeness.

The repo caches characterization tables, composition reports, and simulated
re-ranks under content fingerprints. PR 5's bug class was *key drift*: a new
policy field (``corners``, ``robust``) that silently did not flow into the
key, so stale cached reports were served for new inputs. This checker pins
the key-construction sites and cross-checks them against the dataclasses
they must fingerprint:

CK01  every field of SelectionPolicy / ComposePolicy / SimPolicy /
      OperatingPoint (and TaskReq, plus MacroConfig vs VEC_FIELDS) must be
      *covered* by its key function — via ``dataclasses.asdict``/``astuple``/
      ``fields`` on the parameter, a direct ``param.field`` access, a
      same-module helper the parameter is passed to, or a method call on the
      parameter (recursed into).
CK02  every parameter of a key function must be read in its body.
CK03  a key function must reference its required ingredients (e.g.
      ``grid_hash`` must call ``corners_fingerprint`` and ``_hash_seed``).
CK04  ``_physics_fingerprint`` must hash (at least) the ``repro.core``
      import closure of ``core/characterize.py``.
CK05  a spec target (file / function / class) no longer exists — the
      checker spec itself rotted and must be updated with the code.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from repro.analysis.astutil import (
    Module, Project, arg_names, classes_of, dataclass_fields, dotted,
    functions_of, import_aliases, methods_of, names_read,
)
from repro.analysis.findings import Finding

# (key-fn file, key-fn qualname, param name, dataclass file, dataclass name)
DATACLASS_SPECS: Tuple[Tuple[str, str, str, str, str], ...] = (
    ("src/repro/hetero/cache.py", "report_key", "policy",
     "src/repro/core/select.py", "SelectionPolicy"),
    ("src/repro/hetero/cache.py", "report_key", "compose_policy",
     "src/repro/hetero/compose.py", "ComposePolicy"),
    ("src/repro/hetero/cache.py", "report_key", "task",
     "src/repro/core/select.py", "TaskReq"),
    ("src/repro/hetero/cache.py", "sim_report_key", "sim_policy",
     "src/repro/sim/engine.py", "SimPolicy"),
    ("src/repro/core/corners.py", "OperatingPoint.fingerprint", "self",
     "src/repro/core/corners.py", "OperatingPoint"),
)

# (key-fn file, key-fn qualname, required ingredient names)
INGREDIENT_SPECS: Tuple[Tuple[str, str, Tuple[str, ...]], ...] = (
    ("src/repro/api.py", "grid_hash",
     ("_hash_seed", "corners_fingerprint", "AXIS_NAMES")),
    ("src/repro/api.py", "DesignTable.grid_hash",
     ("_hash_seed", "corners_fingerprint", "AXIS_NAMES")),
    ("src/repro/core/corners.py", "corners_fingerprint", ("fingerprint",)),
    ("src/repro/hetero/cache.py", "report_key", ("_task_fingerprint",)),
)

# every key function: all parameters must be read (CK02)
KEY_FUNCTIONS: Tuple[Tuple[str, str], ...] = (
    ("src/repro/hetero/cache.py", "report_key"),
    ("src/repro/hetero/cache.py", "sim_report_key"),
    ("src/repro/api.py", "grid_hash"),
    ("src/repro/api.py", "DesignTable.grid_hash"),
    ("src/repro/core/corners.py", "OperatingPoint.fingerprint"),
    ("src/repro/core/corners.py", "corners_fingerprint"),
)

# vmap axis spec vs config dataclass (the characterize grid must stack every
# config axis, or a new MacroConfig field silently never varies)
VEC_FIELDS_SPEC = ("src/repro/core/macro.py", "VEC_FIELDS", "MacroConfig")

PHYSICS_FP_SPEC = ("src/repro/api.py", "_physics_fingerprint",
                   "src/repro/core/characterize.py")

_EXPAND_CALLS = {"asdict", "astuple", "fields"}


def _find_fn(mod: Module, qualname: str) -> Optional[ast.AST]:
    if "." in qualname:
        cls_name, meth = qualname.split(".", 1)
        cls = classes_of(mod.tree).get(cls_name)
        if cls is None:
            return None
        return methods_of(cls).get(meth)
    return functions_of(mod.tree).get(qualname)


def _coverage(mod: Module, fn: ast.AST, param: str,
              dc_mod: Module, dc_cls: Optional[ast.ClassDef],
              depth: int = 0) -> Set[str]:
    """Field names of ``param`` provably flowing into the key built by
    ``fn``. The sentinel '*' means full coverage (asdict and friends)."""
    covered: Set[str] = set()
    if depth > 4:
        return covered
    funcs = functions_of(mod.tree)
    dc_methods = methods_of(dc_cls) if dc_cls is not None else {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and node.value.id == param:
            covered.add(node.attr)
        if not isinstance(node, ast.Call):
            continue
        callee = dotted(node.func)
        first_arg_is_param = bool(
            node.args and isinstance(node.args[0], ast.Name)
            and node.args[0].id == param)
        if callee and callee.split(".")[-1] in _EXPAND_CALLS \
                and first_arg_is_param:
            covered.add("*")
            return covered
        if callee == "getattr" and first_arg_is_param and len(node.args) > 1:
            if isinstance(node.args[1], ast.Constant) and \
                    isinstance(node.args[1].value, str):
                covered.add(node.args[1].value)
        # helper(.., param, ..) defined in the same module: recurse with the
        # helper's matching parameter name
        if isinstance(node.func, ast.Name) and node.func.id in funcs:
            helper = funcs[node.func.id]
            hargs = arg_names(helper)
            for i, a in enumerate(node.args):
                if isinstance(a, ast.Name) and a.id == param and i < len(hargs):
                    covered |= _coverage(mod, helper, hargs[i], dc_mod,
                                         dc_cls, depth + 1)
        # param.method(...): recurse into the dataclass method as `self`
        if isinstance(node.func, ast.Attribute) and \
                isinstance(node.func.value, ast.Name) and \
                node.func.value.id == param and \
                node.func.attr in dc_methods:
            covered |= _coverage(dc_mod, dc_methods[node.func.attr], "self",
                                 dc_mod, dc_cls, depth + 1)
    return covered


def _references(fn: ast.AST, name: str) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and node.id == name:
            return True
        if isinstance(node, ast.Attribute) and node.attr == name:
            return True
    return False


def _spec_missing(findings: List[Finding], rel: str, what: str) -> None:
    findings.append(Finding("CK05", rel, 0, f"spec target missing: {what}",
                            snippet=what))


def _check_dataclass_specs(project: Project, findings: List[Finding]) -> None:
    for fn_rel, qual, param, dc_rel, dc_name in DATACLASS_SPECS:
        mod = project.module(fn_rel)
        dc_mod = project.module(dc_rel)
        if mod is None:
            _spec_missing(findings, fn_rel, f"file {fn_rel}")
            continue
        if dc_mod is None:
            _spec_missing(findings, dc_rel, f"file {dc_rel}")
            continue
        fn = _find_fn(mod, qual)
        if fn is None:
            _spec_missing(findings, fn_rel, f"function {qual}")
            continue
        dc_cls = classes_of(dc_mod.tree).get(dc_name)
        if dc_cls is None:
            _spec_missing(findings, dc_rel, f"class {dc_name}")
            continue
        fields = dataclass_fields(dc_cls)
        covered = _coverage(mod, fn, param, dc_mod, dc_cls)
        if "*" in covered:
            continue
        for f in fields:
            if f not in covered:
                findings.append(Finding(
                    "CK01", fn_rel, fn.lineno,
                    f"{dc_name}.{f} does not flow into {qual} — a value "
                    f"change would silently hit a stale cache entry",
                    snippet=f"{qual}<-{dc_name}.{f}"))


def _check_ingredients(project: Project, findings: List[Finding]) -> None:
    for fn_rel, qual, ingredients in INGREDIENT_SPECS:
        mod = project.module(fn_rel)
        if mod is None:
            _spec_missing(findings, fn_rel, f"file {fn_rel}")
            continue
        fn = _find_fn(mod, qual)
        if fn is None:
            _spec_missing(findings, fn_rel, f"function {qual}")
            continue
        for ing in ingredients:
            if not _references(fn, ing):
                findings.append(Finding(
                    "CK03", fn_rel, fn.lineno,
                    f"{qual} no longer references required key ingredient "
                    f"{ing!r}", snippet=f"{qual}<-{ing}"))


def _check_params_read(project: Project, findings: List[Finding]) -> None:
    for fn_rel, qual in KEY_FUNCTIONS:
        mod = project.module(fn_rel)
        if mod is None:
            continue    # CK05 already raised by the other passes
        fn = _find_fn(mod, qual)
        if fn is None:
            continue
        read = names_read(ast.Module(body=fn.body, type_ignores=[]))
        for p in arg_names(fn):
            if p.startswith("_"):
                continue
            if p not in read:
                findings.append(Finding(
                    "CK02", fn_rel, fn.lineno,
                    f"parameter {p!r} of key function {qual} is never read "
                    f"— it cannot affect the cache key",
                    snippet=f"{qual}({p})"))


def _check_vec_fields(project: Project, findings: List[Finding]) -> None:
    rel, var, dc_name = VEC_FIELDS_SPEC
    mod = project.module(rel)
    if mod is None:
        _spec_missing(findings, rel, f"file {rel}")
        return
    dc_cls = classes_of(mod.tree).get(dc_name)
    if dc_cls is None:
        _spec_missing(findings, rel, f"class {dc_name}")
        return
    vec_node = None
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == var:
                    vec_node = node
    if vec_node is None:
        _spec_missing(findings, rel, f"assignment {var}")
        return
    listed = {n.value for n in ast.walk(vec_node.value)
              if isinstance(n, ast.Constant) and isinstance(n.value, str)}
    for f in dataclass_fields(dc_cls):
        if f not in listed:
            findings.append(Finding(
                "CK01", rel, vec_node.lineno,
                f"{dc_name}.{f} missing from {var} — the axis would never "
                f"vary in the vmap grid and never enter the grid hash",
                snippet=f"{var}<-{dc_name}.{f}"))


def _module_basename(dotted_name: str) -> Optional[str]:
    parts = dotted_name.split(".")
    if parts[:2] == ["repro", "core"] and len(parts) >= 3:
        return parts[2]
    return None


def _core_import_closure(project: Project, start_rel: str) -> Set[str]:
    """Basenames of repro.core modules transitively imported from start."""
    seen: Set[str] = set()
    queue = [start_rel]
    while queue:
        rel = queue.pop()
        mod = project.module(rel)
        if mod is None:
            continue
        base = rel.rsplit("/", 1)[-1][:-3]
        if base in seen:
            continue
        seen.add(base)
        for node in ast.walk(mod.tree):
            targets = []
            if isinstance(node, ast.Import):
                targets = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module:
                if node.module == "repro.core":
                    targets = [f"repro.core.{a.name}" for a in node.names]
                else:
                    targets = [node.module]
            for t in targets:
                b = _module_basename(t)
                if b and b not in seen:
                    queue.append(f"src/repro/core/{b}.py")
    return seen


def _check_physics_fingerprint(project: Project,
                               findings: List[Finding]) -> None:
    api_rel, fp_name, chz_rel = PHYSICS_FP_SPEC
    mod = project.module(api_rel)
    if mod is None:
        _spec_missing(findings, api_rel, f"file {api_rel}")
        return
    fn = functions_of(mod.tree).get(fp_name)
    if fn is None:
        _spec_missing(findings, api_rel, f"function {fp_name}")
        return
    aliases = import_aliases(mod.tree)
    # also pick up imports local to the fingerprint function itself
    aliases.update(import_aliases(ast.Module(body=fn.body, type_ignores=[])))
    hashed: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.For):
            for n in ast.walk(node.iter):
                if isinstance(n, ast.Name) and n.id in aliases:
                    b = _module_basename(aliases[n.id])
                    if b:
                        hashed.add(b)
    closure = _core_import_closure(project, chz_rel)
    for b in sorted(closure - hashed):
        findings.append(Finding(
            "CK04", api_rel, fn.lineno,
            f"repro.core.{b} is in the import closure of characterize but "
            f"is not hashed by {fp_name} — edits there would not invalidate "
            f"cached tables", snippet=f"{fp_name}<-{b}"))


def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    _check_dataclass_specs(project, findings)
    _check_ingredients(project, findings)
    _check_params_read(project, findings)
    _check_vec_fields(project, findings)
    _check_physics_fingerprint(project, findings)
    return findings
