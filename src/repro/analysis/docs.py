"""DC: documentation checks (links, anchors, rule catalog).

Stdlib-only and free of intra-package imports on purpose:
``scripts/check_docs.py`` loads this file standalone via importlib so the
docs gate also runs in environments where the ``repro`` package is not
installed (the pre-commit hook, bare checkouts).

DC01  a markdown link targets a file that does not exist
DC02  a markdown link targets a ``#anchor`` with no matching heading slug
DC03  an analyzer rule ID is not documented in ``docs/ANALYSIS.md``
DC04  an ``repro.obs`` catalog entry (span/metric name) is not documented
      in ``docs/OBSERVABILITY.md``

Findings are returned as plain dicts (``rule``/``path``/``line``/
``message``/``snippet``) so this module does not depend on
``repro.analysis.findings``; the runner adapts them.
"""
from __future__ import annotations

import re
from pathlib import Path
from typing import Dict, List, Sequence

_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_FENCE = re.compile(r"^(```|~~~).*?^\1\s*$", re.M | re.S)
_HEADING = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$", re.M)
_EXTERNAL = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")  # http:, mailto:, ...

RULE_CATALOG_MD = "docs/ANALYSIS.md"
OBS_CATALOG_MD = "docs/OBSERVABILITY.md"
OBS_CATALOG_PY = "src/repro/obs/catalog.py"


def _finding(rule: str, path: str, line: int, message: str,
             snippet: str = "") -> Dict[str, object]:
    return {"rule": rule, "path": path, "line": line, "message": message,
            "snippet": snippet}


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for one heading line (underscores are
    preserved — GitHub keeps them in anchors, and this repo's API docs use
    snake_case headings)."""
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)
    text = text.replace("`", "").replace("*", "").strip().lower()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def anchors_of(path: Path) -> frozenset:
    """All heading anchors of a markdown file, with -N duplicate suffixes."""
    body = _FENCE.sub("", path.read_text(encoding="utf-8"))
    seen: dict = {}
    out = set()
    for m in _HEADING.finditer(body):
        slug = slugify(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return frozenset(out)


def _line_of(body: str, pos: int) -> int:
    return body.count("\n", 0, pos) + 1


def check_links(root, files: Sequence[Path] = None) -> List[Dict[str, object]]:
    """DC01/DC02 over docs/*.md + README.md (or an explicit file list)."""
    root = Path(root)
    if files is None:
        files = sorted((root / "docs").glob("*.md"))
        if (root / "README.md").exists():
            files.append(root / "README.md")
    findings: List[Dict[str, object]] = []
    anchor_cache: Dict[Path, frozenset] = {}
    for path in files:
        path = Path(path)
        if not path.is_absolute():
            path = root / path
        if not path.exists():
            findings.append(_finding("DC01", _rel(root, path), 0,
                                     "no such file", snippet=str(path.name)))
            continue
        raw = path.read_text(encoding="utf-8")
        body = _FENCE.sub(lambda m: "\n" * m.group(0).count("\n"), raw)
        for m in _LINK.finditer(body):
            target = m.group(1)
            if _EXTERNAL.match(target):
                continue
            line = _line_of(body, m.start())
            file_part, _, anchor = target.partition("#")
            dest = path if not file_part else (
                path.parent / file_part).resolve()
            if not dest.exists():
                findings.append(_finding(
                    "DC01", _rel(root, path), line,
                    f"broken link {target!r} (no such file {file_part})",
                    snippet=target))
                continue
            if anchor and dest.suffix.lower() in (".md", ".markdown"):
                if dest not in anchor_cache:
                    anchor_cache[dest] = anchors_of(dest)
                if anchor not in anchor_cache[dest]:
                    findings.append(_finding(
                        "DC02", _rel(root, path), line,
                        f"broken anchor {target!r} (no heading slug "
                        f"'#{anchor}' in {_rel(root, dest)})",
                        snippet=target))
    return findings


def check_rule_docs(root, rule_ids: Sequence[str]) -> List[Dict[str, object]]:
    """DC03: every analyzer rule ID must appear in docs/ANALYSIS.md."""
    root = Path(root)
    catalog = root / RULE_CATALOG_MD
    if not catalog.exists():
        return [_finding("DC03", RULE_CATALOG_MD, 0,
                         "rule catalog docs/ANALYSIS.md does not exist",
                         snippet=RULE_CATALOG_MD)]
    body = catalog.read_text(encoding="utf-8")
    out = []
    for rid in rule_ids:
        if rid not in body:
            out.append(_finding(
                "DC03", RULE_CATALOG_MD, 0,
                f"rule {rid} is not documented in docs/ANALYSIS.md",
                snippet=rid))
    return out


def check_obs_docs(root) -> List[Dict[str, object]]:
    """DC04: every span/metric name in the ``repro.obs`` catalog must appear
    backticked in docs/OBSERVABILITY.md.

    The catalog module is loaded standalone via importlib (it is stdlib-only
    pure data by contract), so this check — like the rest of this file —
    works without the ``repro`` package importable.
    """
    import importlib.util

    root = Path(root)
    cat_py = root / OBS_CATALOG_PY
    if not cat_py.exists():
        return [_finding("DC04", OBS_CATALOG_PY, 0,
                         "obs catalog module does not exist",
                         snippet=OBS_CATALOG_PY)]
    spec = importlib.util.spec_from_file_location("_obs_catalog", cat_py)
    catalog = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(catalog)

    doc = root / OBS_CATALOG_MD
    if not doc.exists():
        return [_finding("DC04", OBS_CATALOG_MD, 0,
                         "obs catalog docs/OBSERVABILITY.md does not exist",
                         snippet=OBS_CATALOG_MD)]
    body = doc.read_text(encoding="utf-8")
    out = []
    for kind, names in (("span", catalog.SPANS), ("metric", catalog.METRICS)):
        for name in names:
            if f"`{name}`" not in body:
                out.append(_finding(
                    "DC04", OBS_CATALOG_MD, 0,
                    f"obs {kind} {name!r} is not documented in "
                    f"docs/OBSERVABILITY.md", snippet=name))
    return out


def _rel(root: Path, path: Path) -> str:
    try:
        return path.resolve().relative_to(Path(root).resolve()).as_posix()
    except ValueError:
        return str(path)
