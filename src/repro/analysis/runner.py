"""Orchestrates the checkers, applies noqa + baseline, computes exit codes."""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.analysis import backend_cov, cache_keys, docs as docs_mod
from repro.analysis import jit_purity, units
from repro.analysis.astutil import Project
from repro.analysis.findings import Baseline, Finding, is_suppressed
from repro.analysis.rules import EXIT_BITS, RULES, family_of

DEFAULT_BASELINE = "analysis_baseline.json"

CHECKERS = {
    "CK": cache_keys.check,
    "JP": jit_purity.check,
    "US": units.check,
    "BK": backend_cov.check,
}

# The semantic tier (imports jax, traces IR, executes jit sites) is opt-in
# via --semantic / explicit --rules and loaded lazily so that plain AST runs
# — and pre-commit — never pay the jax import.
SEMANTIC_FAMILIES = ("PB", "DT", "RC")


@dataclasses.dataclass
class Report:
    findings: List[Finding]            # active: fail the build
    suppressed: List[Finding]          # silenced by inline  # noqa
    baselined: List[Finding]           # matched a committed baseline entry
    stale_baseline: List[dict]         # baseline entries matching nothing
    families_run: tuple = ()           # which rule families actually ran

    @property
    def exit_code(self) -> int:
        code = 0
        for f in self.findings:
            code |= EXIT_BITS.get(family_of(f.rule), 0)
        return code

    def to_dict(self) -> dict:
        by_family: Dict[str, int] = {}
        for f in self.findings:
            by_family[family_of(f.rule)] = \
                by_family.get(family_of(f.rule), 0) + 1
        return {
            "exit_code": self.exit_code,
            "families_run": list(self.families_run),
            "counts": {"active": len(self.findings),
                       "suppressed": len(self.suppressed),
                       "baselined": len(self.baselined),
                       "stale_baseline": len(self.stale_baseline),
                       "by_family": by_family},
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "baselined": [f.to_dict() for f in self.baselined],
            "stale_baseline": list(self.stale_baseline),
        }

    def format_text(self) -> str:
        lines = []
        for f in sorted(self.findings, key=lambda f: (f.path, f.line, f.rule)):
            title = RULES.get(f.rule, ("?",))[0]
            lines.append(f"{f.format()} [{title}]")
        n = len(self.findings)
        lines.append(f"repro.analysis: {n} active finding(s), "
                     f"{len(self.baselined)} baselined, "
                     f"{len(self.suppressed)} noqa-suppressed")
        if self.stale_baseline:
            lines.append(f"note: {len(self.stale_baseline)} stale baseline "
                         f"entr(y/ies) no longer match anything — prune "
                         f"{DEFAULT_BASELINE}")
        return "\n".join(lines)


def run_analysis(root, checks: Optional[Sequence[str]] = None,
                 baseline_path=None, with_docs: bool = False,
                 with_semantic: bool = False,
                 project: Optional[Project] = None) -> Report:
    """Run the analyzer over the repo at ``root``.

    ``checks`` restricts to rule families (("CK", "US"), ...); ``with_docs``
    adds the DC family; ``with_semantic`` adds the IR-level PB/DT/RC tier
    (imports jax — CI-only); ``project`` injects a pre-built (possibly
    overlaid) Project — the hook the analyzer's own tests use to mutate
    sources.
    """
    root = Path(root)
    if project is None:
        project = Project(root)
    selected = tuple(checks) if checks else tuple(CHECKERS)
    if with_semantic:
        selected += tuple(f for f in SEMANTIC_FAMILIES if f not in selected)
    families_run: List[str] = []
    raw: List[Finding] = []
    for fam in selected:
        if fam in CHECKERS:
            raw.extend(CHECKERS[fam](project))
            families_run.append(fam)
    semantic_selected = tuple(f for f in selected if f in SEMANTIC_FAMILIES)
    if semantic_selected:
        from repro.analysis import semantic   # lazy: imports jax
        for fam in semantic_selected:
            raw.extend(semantic.CHECKERS[fam](project))
            families_run.append(fam)
    if with_docs or (checks and "DC" in checks):
        for d in docs_mod.check_links(root):
            raw.append(Finding(**d))
        for d in docs_mod.check_rule_docs(root, sorted(RULES)):
            raw.append(Finding(**d))
        for d in docs_mod.check_obs_docs(root):
            raw.append(Finding(**d))
        families_run.append("DC")

    # inline noqa
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    for f in raw:
        mod = project.module(f.path) if f.path.endswith(".py") else None
        line = mod.line(f.line) if (mod and f.line) else ""
        (suppressed if is_suppressed(f, line) else kept).append(f)

    # committed baseline
    if baseline_path is None:
        baseline_path = root / DEFAULT_BASELINE
    baseline = Baseline.load(baseline_path)
    active, baselined = baseline.split(kept)
    return Report(findings=active, suppressed=suppressed,
                  baselined=baselined,
                  stale_baseline=baseline.stale_entries(kept),
                  families_run=tuple(families_run))
