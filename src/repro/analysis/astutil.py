"""Shared AST plumbing for the checkers.

``Project`` is the single entry point: it loads modules lazily from a root
directory and supports an *overlay* — a mapping of repo-relative path to
replacement source text — so tests can inject synthetic mutations
(e.g. "add a field to OperatingPoint", "delete the corners line from
grid_hash") without copying the tree to a tmpdir.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterator, List, Optional


@dataclasses.dataclass
class Module:
    rel: str                 # repo-relative posix path
    path: Path               # absolute path (may not exist under overlay)
    source: str
    tree: ast.Module
    lines: List[str]         # source split into lines (0-based index)

    def line(self, lineno: int) -> str:
        """1-based source line, '' if out of range."""
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def snippet(self, lineno: int) -> str:
        return self.line(lineno).strip()


class Project:
    def __init__(self, root, overlay: Optional[Dict[str, str]] = None):
        self.root = Path(root)
        self.overlay = dict(overlay or {})
        self._cache: Dict[str, Optional[Module]] = {}

    def module(self, rel: str) -> Optional[Module]:
        rel = rel.replace("\\", "/")
        if rel in self._cache:
            return self._cache[rel]
        path = self.root / rel
        if rel in self.overlay:
            source = self.overlay[rel]
        elif path.is_file():
            source = path.read_text(encoding="utf-8")
        else:
            self._cache[rel] = None
            return None
        try:
            tree = ast.parse(source, filename=rel)
        except SyntaxError:
            self._cache[rel] = None
            return None
        mod = Module(rel=rel, path=path, source=source, tree=tree,
                     lines=source.splitlines())
        self._cache[rel] = mod
        return mod

    def iter_modules(self, rel_dir: str) -> Iterator[Module]:
        """All .py modules under a repo-relative directory (recursive)."""
        rel_dir = rel_dir.rstrip("/")
        seen = set()
        base = self.root / rel_dir
        if base.is_dir():
            for p in sorted(base.rglob("*.py")):
                rel = p.relative_to(self.root).as_posix()
                seen.add(rel)
                mod = self.module(rel)
                if mod is not None:
                    yield mod
        # overlay-only modules (paths that don't exist on disk)
        for rel in sorted(self.overlay):
            if rel.startswith(rel_dir + "/") and rel not in seen:
                mod = self.module(rel)
                if mod is not None:
                    yield mod


# ---------------------------------------------------------------------------
# node helpers
# ---------------------------------------------------------------------------

def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, None for anything else."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_aliases(tree: ast.Module) -> Dict[str, str]:
    """Local name -> dotted module/object it refers to.

    Covers ``import a.b as c`` and ``from a.b import c [as d]``.
    """
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out[alias.asname or alias.name.split(".")[0]] = alias.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                out[alias.asname or alias.name] = (
                    f"{node.module}.{alias.name}")
    return out


def functions_of(tree: ast.Module) -> Dict[str, ast.AST]:
    """Top-level function name -> def node (incl. async)."""
    out = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = node
    return out


def classes_of(tree: ast.Module) -> Dict[str, ast.ClassDef]:
    return {node.name: node for node in tree.body
            if isinstance(node, ast.ClassDef)}


def methods_of(cls: ast.ClassDef) -> Dict[str, ast.AST]:
    return {node.name: node for node in cls.body
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))}


def is_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        d = dotted(dec if not isinstance(dec, ast.Call) else dec.func)
        if d and d.split(".")[-1] == "dataclass":
            return True
    return False


def dataclass_fields(cls: ast.ClassDef) -> List[str]:
    """Annotated field names of a dataclass (or NamedTuple) body, in order."""
    fields = []
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
            name = node.target.id
            if not name.startswith("_") and name != "ClassVar":
                # skip typing.ClassVar annotations
                ann = dotted(node.annotation)
                sub = (dotted(node.annotation.value)
                       if isinstance(node.annotation, ast.Subscript) else None)
                if (ann and ann.split(".")[-1] == "ClassVar") or (
                        sub and sub.split(".")[-1] == "ClassVar"):
                    continue
                fields.append(name)
    return fields


def names_read(node: ast.AST) -> set:
    """All Name ids loaded anywhere inside node."""
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


def arg_names(fn: ast.AST) -> List[str]:
    a = fn.args
    names = [x.arg for x in (a.posonlyargs + a.args + a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def string_value(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None
