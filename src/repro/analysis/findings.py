"""Finding records, ``# noqa`` suppression, and the committed baseline.

A *finding* is one (rule, file, line, message) tuple. Two suppression layers
sit between a raw finding and a CI failure:

1. Inline ``# noqa`` comments on the flagged line — ``# noqa`` silences every
   rule on the line, ``# noqa: US01,JP02`` silences only the listed rules.
2. The committed baseline file (``analysis_baseline.json``): a list of
   deliberate exceptions, each with a one-line justification. Baseline
   entries match on (rule, path, snippet) — *not* line numbers — so
   unrelated edits above a baselined site don't invalidate the entry, while
   editing the flagged line itself does (the snippet no longer matches and
   the finding resurfaces for re-review).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Sequence

_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<rules>[A-Z]{2}\d{2}(?:\s*,\s*[A-Z]{2}\d{2})*))?",
                      re.IGNORECASE)


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str            # e.g. "US01"
    path: str            # repo-relative, posix separators
    line: int            # 1-based; 0 for file/project-level findings
    message: str
    snippet: str = ""    # stripped source line, used for baseline matching

    def key(self):
        return (self.rule, self.path, self.snippet)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule} {self.message}"


def noqa_rules(source_line: str) -> Optional[frozenset]:
    """Rules suppressed by a ``# noqa`` comment on this line.

    Returns None if there is no noqa comment, an empty frozenset for a bare
    ``# noqa`` (suppress everything), or the set of named rule IDs.
    """
    m = _NOQA_RE.search(source_line)
    if m is None:
        return None
    rules = m.group("rules")
    if not rules:
        return frozenset()
    return frozenset(r.strip().upper() for r in rules.split(","))


def is_suppressed(finding: Finding, source_line: str) -> bool:
    rules = noqa_rules(source_line)
    if rules is None:
        return False
    return not rules or finding.rule in rules


# ---------------------------------------------------------------------------
# baseline file
# ---------------------------------------------------------------------------

class Baseline:
    """The committed list of deliberate, justified exceptions."""

    def __init__(self, entries: Sequence[dict] = ()):  # noqa documented below
        self.entries: List[dict] = [dict(e) for e in entries]
        self._keys = {(e["rule"], e["path"], e.get("snippet", ""))
                      for e in self.entries}

    @classmethod
    def load(cls, path) -> "Baseline":
        try:
            with open(path, "r", encoding="utf-8") as fh:
                data = json.load(fh)
        except FileNotFoundError:
            return cls()
        if isinstance(data, dict):
            data = data.get("entries", [])
        return cls(data)

    def matches(self, finding: Finding) -> bool:
        return finding.key() in self._keys

    def split(self, findings: Sequence[Finding]):
        """Partition findings into (active, baselined)."""
        active, baselined = [], []
        for f in findings:
            (baselined if self.matches(f) else active).append(f)
        return active, baselined

    def stale_entries(self, findings: Sequence[Finding]) -> List[dict]:
        """Baseline entries that matched no finding (candidates to delete)."""
        seen = {f.key() for f in findings}
        return [e for e in self.entries
                if (e["rule"], e["path"], e.get("snippet", "")) not in seen]

    @staticmethod
    def write(path, findings: Sequence[Finding],
              justifications: Optional[Dict[tuple, str]] = None) -> None:
        justifications = justifications or {}
        entries = [{
            "rule": f.rule,
            "path": f.path,
            "snippet": f.snippet,
            "justification": justifications.get(
                f.key(), "TODO: justify or fix"),
        } for f in sorted(findings, key=lambda f: (f.path, f.rule, f.snippet))]
        with open(path, "w", encoding="utf-8") as fh:
            json.dump({"entries": entries}, fh, indent=2, sort_keys=False)
            fh.write("\n")
