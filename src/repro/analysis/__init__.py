"""repro.analysis — repo-specific static analysis gating CI.

Four AST checkers encode the invariants this codebase keeps re-breaking by
hand (see docs/ANALYSIS.md for the rule catalog):

  CK  cache-key completeness  — every policy field flows into its cache key
  JP  jit purity / host sync  — functions reachable under jit stay pure
  US  unit-suffix convention  — the physics layer names carry their units
  BK  backend-registry coverage — every kernel op has oracle + fallback + test
  DC  docs — links, anchors, and the rule catalog itself

A second, *semantic* tier (``--semantic``) verifies the traced IR itself —
PB proves Pallas BlockSpec index maps over the full launch grid, DT audits
jaxpr dtypes against the float32 policy, RC meters jit trace-cache growth
against committed budgets. It lives in ``repro.analysis.semantic``, imports
jax, and is loaded lazily: the default AST run (and pre-commit) stays
jax-free — it parses sources, never imports them. ``repro.analysis.sanitize``
is the matching runtime tier: opt-in checkify (nan + index) wrapping of the
numeric entry points via ``REPRO_SANITIZE=1`` / ``Compiler(sanitize=True)``.

Run ``python -m repro.analysis`` (see ``__main__.py`` for the CLI).
"""
from repro.analysis.astutil import Project
from repro.analysis.findings import Baseline, Finding
from repro.analysis.rules import EXIT_BITS, FAMILIES, RULES
from repro.analysis.runner import Report, run_analysis

__all__ = ["Project", "Baseline", "Finding", "EXIT_BITS", "FAMILIES",
           "RULES", "Report", "run_analysis"]
