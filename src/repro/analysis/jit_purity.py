"""JP: jit purity / host sync.

Finds functions *reachable under tracing* — seeded from ``jax.jit`` /
``jax.vmap`` / ``lax.scan`` / ``pallas_call`` call sites and from
``kernels.backend.register(...)`` (registered impls are the jit-safety
contract), then closed over same-package call edges — and lints them:

JP01  Python side effects: print/input/breakpoint, ``open``, ``global``.
JP02  host syncs: ``.item()`` / ``.tolist()`` / ``.block_until_ready()``
      anywhere; ``float()/int()/bool()/len()`` or ``np.asarray``/``np.array``
      applied to a traced expression (a jnp/jax/lax call, or a local
      assigned from one).
JP03  Python control flow (``if``/``while``/ternary) on a traced expression
      — a TracerBoolConversionError at trace time.
JP04  a jit static argument whose default is an unhashable literal
      (list/dict/set).

Branches guarded by *type checks* (isinstance/hasattr/callable/``is``
comparisons) are skipped — argument types are static under tracing, so such
branches resolve at trace time and anything inside them never sees a tracer.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import (
    Module, Project, arg_names, dotted, functions_of, import_aliases,
)
from repro.analysis.findings import Finding

SCAN_DIRS = ("src/repro/core", "src/repro/hetero", "src/repro/sim",
             "src/repro/kernels")

_JIT_WRAPPERS = {"jit", "vmap", "pmap", "grad", "value_and_grad", "scan",
                 "pallas_call", "register", "checkpoint", "remat"}
_TRACED_ROOTS = ("jnp", "jax", "lax", "pl")
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_SYNC_CASTS = {"float", "int", "bool", "len"}
_SIDE_EFFECT_CALLS = {"print", "input", "breakpoint", "open"}

FnKey = Tuple[str, str]           # (module rel path, function name)


def _is_type_guard(test: ast.AST) -> bool:
    """Tests that are static under tracing: isinstance/hasattr/callable
    calls, ``x is None`` style identity comparisons, and boolean
    combinations thereof."""
    if isinstance(test, ast.BoolOp):
        return all(_is_type_guard(v) for v in test.values)
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _is_type_guard(test.operand)
    for node in ast.walk(test):
        if isinstance(node, ast.Call):
            callee = dotted(node.func)
            if callee and callee.split(".")[-1] in (
                    "isinstance", "hasattr", "callable", "issubclass"):
                return True
        if isinstance(node, ast.Compare) and any(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return True
    return False


def _resolve(mod: Module, aliases: Dict[str, str], name: str,
             project: Project) -> Optional[FnKey]:
    """Resolve a bare name referenced in ``mod`` to a (file, function)."""
    if name in functions_of(mod.tree):
        return (mod.rel, name)
    target = aliases.get(name)
    if target and target.startswith("repro."):
        parts = target.split(".")
        # repro.a.b.fn -> module repro/a/b.py function fn
        if len(parts) >= 2:
            rel = "src/" + "/".join(parts[:-1]) + ".py"
            other = project.module(rel)
            if other is not None and parts[-1] in functions_of(other.tree):
                return (rel, parts[-1])
    return None


def _resolve_attr(mod: Module, aliases: Dict[str, str], chain: str,
                  project: Project) -> Optional[FnKey]:
    """Resolve ``alias.fn`` where alias is an imported repro module."""
    parts = chain.split(".")
    if len(parts) != 2:
        return None
    target = aliases.get(parts[0])
    if target and target.startswith("repro."):
        rel = "src/" + target.replace(".", "/") + ".py"
        other = project.module(rel)
        if other is not None and parts[1] in functions_of(other.tree):
            return (rel, parts[1])
    return None


def _collect_seeds(project: Project) -> Tuple[Set[FnKey], Dict[FnKey, dict]]:
    """Functions named inside jit/vmap/scan/register call expressions, plus
    per-seed static-arg info for JP04."""
    seeds: Set[FnKey] = set()
    static_info: Dict[FnKey, dict] = {}
    for d in SCAN_DIRS:
        for mod in project.iter_modules(d):
            aliases = import_aliases(mod.tree)
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                callee = dotted(node.func)
                if not callee or callee.split(".")[-1] not in _JIT_WRAPPERS:
                    continue
                refs: List[FnKey] = []
                for sub in node.args + [kw.value for kw in node.keywords]:
                    for n in ast.walk(sub):
                        key = None
                        if isinstance(n, ast.Name):
                            key = _resolve(mod, aliases, n.id, project)
                        elif isinstance(n, ast.Attribute):
                            chain = dotted(n)
                            if chain:
                                key = _resolve_attr(mod, aliases, chain,
                                                    project)
                        if key:
                            refs.append(key)
                seeds.update(refs)
                statics = _static_args_of(node)
                if statics and refs:
                    static_info[refs[0]] = statics
    return seeds, static_info


def _static_args_of(call: ast.Call) -> dict:
    out = {}
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            nums = [n.value for n in ast.walk(kw.value)
                    if isinstance(n, ast.Constant)
                    and isinstance(n.value, int)]
            out["nums"] = nums
        elif kw.arg == "static_argnames":
            names = [n.value for n in ast.walk(kw.value)
                     if isinstance(n, ast.Constant)
                     and isinstance(n.value, str)]
            out["names"] = names
    return out


def _find_function(project: Project, key: FnKey) -> Optional[ast.AST]:
    mod = project.module(key[0])
    if mod is None:
        return None
    return functions_of(mod.tree).get(key[1])


class _FnLinter(ast.NodeVisitor):
    """Single-function pass: traced-local inference, flag collection, and
    outgoing call edges — all skipping type-guarded branches."""

    def __init__(self, mod: Module, fn: ast.AST, aliases: Dict[str, str],
                 project: Project):
        self.mod = mod
        self.fn = fn
        self.aliases = aliases
        self.project = project
        self.traced: Set[str] = set()
        self.findings: List[Finding] = []
        self.edges: Set[FnKey] = set()

    # -- traced-expression test --------------------------------------------
    def _is_traced(self, expr: ast.AST) -> bool:
        for node in ast.walk(expr):
            if isinstance(node, ast.Name) and node.id in self.traced:
                return True
            if isinstance(node, ast.Call):
                callee = dotted(node.func)
                if callee and callee.split(".")[0] in _TRACED_ROOTS:
                    return True
        return False

    def _flag(self, rule: str, node: ast.AST, msg: str) -> None:
        self.findings.append(Finding(
            rule, self.mod.rel, node.lineno,
            f"{msg} (in jit-reachable function {self.fn.name!r})",
            snippet=self.mod.snippet(node.lineno)))

    # -- statements --------------------------------------------------------
    def visit_If(self, node: ast.If) -> None:
        if _is_type_guard(node.test):
            return          # static under tracing: skip whole branch
        if self._is_traced(node.test):
            self._flag("JP03", node, "Python `if` on a traced value")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        if _is_type_guard(node.test):
            return
        if self._is_traced(node.test):
            self._flag("JP03", node, "Python `while` on a traced value")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        if not _is_type_guard(node.test) and self._is_traced(node.test):
            self._flag("JP03", node, "ternary on a traced value")
        self.generic_visit(node)

    def visit_Global(self, node: ast.Global) -> None:
        self._flag("JP01", node, "`global` statement (hidden Python state)")

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        if self._is_traced(node.value):
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        self.traced.add(n.id)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.generic_visit(node)
        if self._is_traced(node.value) and isinstance(node.target, ast.Name):
            self.traced.add(node.target.id)

    def visit_FunctionDef(self, node) -> None:
        # nested defs share the linting context (closures run under trace)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call) -> None:
        self.generic_visit(node)
        callee = dotted(node.func)
        last = callee.split(".")[-1] if callee else None
        # JP01 side effects
        if callee in _SIDE_EFFECT_CALLS:
            self._flag("JP01", node, f"call to {callee}()")
        # JP02 explicit syncs: .item() / .tolist() / .block_until_ready()
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in _SYNC_METHODS:
            self._flag("JP02", node,
                       f".{node.func.attr}() forces a host sync")
        # JP02 casts of traced expressions
        if callee in _SYNC_CASTS and node.args and \
                self._is_traced(node.args[0]):
            self._flag("JP02", node,
                       f"{callee}() on a traced value forces a host sync")
        if callee in ("np.asarray", "np.array", "numpy.asarray",
                      "numpy.array") and node.args and \
                self._is_traced(node.args[0]):
            self._flag("JP02", node,
                       f"{callee}() on a traced value forces a host sync")
        # call edges for reachability
        key = None
        if isinstance(node.func, ast.Name):
            key = _resolve(self.mod, self.aliases, node.func.id, self.project)
        elif callee:
            key = _resolve_attr(self.mod, self.aliases, callee, self.project)
        if key:
            self.edges.add(key)


def _check_static_defaults(project: Project, key: FnKey, statics: dict,
                           findings: List[Finding]) -> None:
    fn = _find_function(project, key)
    if fn is None:
        return
    mod = project.module(key[0])
    params = fn.args.args + fn.args.posonlyargs
    defaults = fn.args.defaults
    # align defaults to trailing params
    offset = len(params) - len(defaults)
    static_names = set(statics.get("names", ()))
    for i in statics.get("nums", ()):
        if 0 <= i < len(params):
            static_names.add(params[i].arg)
    for i, d in enumerate(defaults):
        p = params[offset + i].arg
        if p in static_names and isinstance(d, (ast.List, ast.Dict, ast.Set)):
            findings.append(Finding(
                "JP04", key[0], d.lineno,
                f"static argument {p!r} of {key[1]!r} has an unhashable "
                f"{type(d).__name__.lower()} default — jit will raise at "
                f"call time", snippet=mod.snippet(d.lineno)))


def check(project: Project) -> List[Finding]:
    seeds, static_info = _collect_seeds(project)
    findings: List[Finding] = []
    for key, statics in sorted(static_info.items()):
        _check_static_defaults(project, key, statics, findings)

    visited: Set[FnKey] = set()
    queue = sorted(seeds)
    while queue:
        key = queue.pop()
        if key in visited:
            continue
        visited.add(key)
        # only lint the accelerator-adjacent layers named by the issue
        if not any(key[0].startswith(d + "/") for d in SCAN_DIRS):
            continue
        mod = project.module(key[0])
        if mod is None:
            continue
        fn = functions_of(mod.tree).get(key[1])
        if fn is None:
            continue
        linter = _FnLinter(mod, fn, import_aliases(mod.tree), project)
        # seed traced-ness conservatively: nothing is traced until a
        # jnp/jax/lax call produces it (params stay untraced so static
        # shape/flag arithmetic doesn't flag)
        linter.visit(fn)
        findings.extend(linter.findings)
        for edge in linter.edges:
            if edge not in visited:
                queue.append(edge)
    return findings
