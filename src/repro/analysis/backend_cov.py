"""BK: kernel backend-registry coverage.

Every op registered with ``repro.kernels.backend.register(name, **impls)``
is a dispatch point with three possible paths (tpu / interpret / xla). The
repo's correctness story for kernels is "the pallas path is proved against
the interpret oracle, the xla path is the CPU fallback" — so an op missing
either non-tpu impl has no oracle or no fallback:

BK01  registered op has no ``interpret=`` implementation
BK02  registered op has no ``xla=`` implementation
BK03  registered op name appears in no file under ``tests/`` — nothing
      exercises the dispatch path at all
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.astutil import (
    Project, dotted, import_aliases, string_value,
)
from repro.analysis.findings import Finding

SCAN_DIR = "src/repro"
TESTS_DIR = "tests"
REGISTRY_MODULE = "repro.kernels.backend"


def _is_backend_register(mod, aliases, node: ast.Call) -> bool:
    """Only registrations into the kernel backend registry count — the repo
    has other ``register`` functions (e.g. the model-config registry in
    ``repro.configs``) with different contracts."""
    if mod.rel == "src/" + REGISTRY_MODULE.replace(".", "/") + ".py":
        return isinstance(node.func, ast.Name) and node.func.id == "register"
    if isinstance(node.func, ast.Name):
        return aliases.get(node.func.id) == REGISTRY_MODULE + ".register"
    if isinstance(node.func, ast.Attribute) and node.func.attr == "register":
        base = dotted(node.func.value)
        return base is not None and aliases.get(base) == REGISTRY_MODULE
    return False


def _registrations(project: Project):
    """(module, call node, op name, impl keywords) for every registration
    into the kernel backend registry."""
    for mod in project.iter_modules(SCAN_DIR):
        aliases = import_aliases(mod.tree)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if not _is_backend_register(mod, aliases, node):
                continue
            if not node.args:
                continue
            name = string_value(node.args[0])
            if name is None:
                continue
            impls = {kw.arg for kw in node.keywords if kw.arg}
            yield mod, node, name, impls


def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    test_sources = [m.source for m in project.iter_modules(TESTS_DIR)]
    for mod, node, name, impls in _registrations(project):
        snippet = mod.snippet(node.lineno)
        if "interpret" not in impls:
            findings.append(Finding(
                "BK01", mod.rel, node.lineno,
                f"op {name!r} registered without an 'interpret' impl — no "
                f"oracle to prove the tpu path against", snippet=snippet))
        if "xla" not in impls:
            findings.append(Finding(
                "BK02", mod.rel, node.lineno,
                f"op {name!r} registered without an 'xla' impl — no CPU "
                f"fallback path", snippet=snippet))
        if not any(name in src for src in test_sources):
            findings.append(Finding(
                "BK03", mod.rel, node.lineno,
                f"op {name!r} is not referenced by any file under tests/ — "
                f"no test exercises its dispatch", snippet=snippet))
    return findings
