"""US: unit-suffix lint over the physics layer.

The characterization pipeline's convention (stated in ``api.py``'s module
docstring) is that every physical binding carries its unit in the name:
``_um2`` area, ``_w`` power, ``_s`` time, ``_hz`` frequency, ``_v`` voltage,
``_j`` energy, ``_a`` current, ``_f`` capacitance, ``_ohm`` resistance,
``_k`` temperature, ``_bits``/``_bits_s`` capacity/bandwidth. This checker
does lightweight dimensional algebra over SI base dimensions to enforce it:

US01  a physics binding with no unit suffix, triggered by (a) a quantity
      prefix (``t_`` time, ``e_`` energy, ``p_`` power, ``f_`` frequency,
      ``i_``/``l_`` current, ``c_`` capacitance, ``r_`` resistance, ``v_``
      voltage) or a quantity word (``area``/``delay``/``energy``/``leak``),
      or (b) a right-hand side whose unit is inferable and non-dimensionless.
US02  +/-, comparison, or min/max mixing two *known different* units
      (adding ``_w`` to ``_j``). Bare numeric literals are wildcards here
      (epsilon guards like ``maximum(x, 1e-12)`` don't flag).
US03  a binding whose suffix conflicts with the unit inferred from its
      right-hand side, or with its own prefix (``v_a`` claims amperes but
      the ``v_`` prefix promises volts).

Only the four physics modules are checked (see ``TARGETS``). ALL-UPPERCASE
names (module constants like ``C_GATE_PER_UM``, whose trailing token is a
per-unit denominator, not the value's unit) and names shorter than two
tokens are never suffix-typed.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.astutil import Module, Project, arg_names, dotted
from repro.analysis.findings import Finding

TARGETS = (
    "src/repro/core/characterize.py",
    "src/repro/core/periphery.py",
    "src/repro/core/retention.py",
    "src/repro/hetero/system.py",
)

# dimension vector over (kg, m, s, A, K, bit)
Dim = Tuple[int, int, int, int, int, int]
DIMLESS: Dim = (0, 0, 0, 0, 0, 0)

SUFFIX_DIMS: Dict[str, Dim] = {
    "um2":    (0, 2, 0, 0, 0, 0),
    "um":     (0, 1, 0, 0, 0, 0),
    "s":      (0, 0, 1, 0, 0, 0),
    "hz":     (0, 0, -1, 0, 0, 0),
    "w":      (1, 2, -3, 0, 0, 0),
    "j":      (1, 2, -2, 0, 0, 0),
    "v":      (1, 2, -3, -1, 0, 0),
    "a":      (0, 0, 0, 1, 0, 0),
    "f":      (-1, -2, 4, 2, 0, 0),
    "ohm":    (1, 2, -3, -2, 0, 0),
    "k":      (0, 0, 0, 0, 1, 0),
    "bits":   (0, 0, 0, 0, 0, 1),
    "bits_s": (0, 0, -1, 0, 0, 1),   # matched as a 2-token trailing suffix
}

# quantity prefixes: first name token -> expected dimension
PREFIX_DIMS: Dict[str, Dim] = {
    "t": SUFFIX_DIMS["s"],
    "e": SUFFIX_DIMS["j"],
    "p": SUFFIX_DIMS["w"],
    "f": SUFFIX_DIMS["hz"],
    "i": SUFFIX_DIMS["a"],
    "l": SUFFIX_DIMS["a"],          # leakage currents (l_dec, l_sa, ...)
    "c": SUFFIX_DIMS["f"],
    "r": SUFFIX_DIMS["ohm"],
    "v": SUFFIX_DIMS["v"],
}
# quantity words: an unsuffixed name whose FIRST token is one of these is a
# physics binding by convention even without a single-letter prefix
WORD_DIMS: Dict[str, Dim] = {
    "area":   SUFFIX_DIMS["um2"],
    "delay":  SUFFIX_DIMS["s"],
    "energy": SUFFIX_DIMS["j"],
    "leak":   SUFFIX_DIMS["a"],
}
# names exempt from suffix typing (suffix collides with a non-unit meaning)
NAME_EXEMPT = {"top_k", "self", "cls"}

WILDCARD = "wild"     # numeric literal: compatible with anything in +/-


def suffix_dim(name: str) -> Optional[Dim]:
    """Unit claimed by a name's trailing suffix, or None."""
    if name in NAME_EXEMPT or name.isupper() or name.startswith("_"):
        return None
    tokens = name.split("_")
    if len(tokens) < 2:
        return None
    if len(tokens) >= 3 and "_".join(tokens[-2:]) == "bits_s":
        return SUFFIX_DIMS["bits_s"]
    return SUFFIX_DIMS.get(tokens[-1])


def prefix_dim(name: str) -> Optional[Dim]:
    """Unit promised by a name's quantity prefix/word, or None."""
    if name in NAME_EXEMPT or name.isupper() or name.startswith("_"):
        return None
    tokens = name.split("_")
    if tokens[0] in WORD_DIMS:
        return WORD_DIMS[tokens[0]]
    if len(tokens) >= 2 and tokens[0] in PREFIX_DIMS:
        return PREFIX_DIMS[tokens[0]]
    return None


def _dim_name(d: Dim) -> str:
    for suf, dd in SUFFIX_DIMS.items():
        if dd == d:
            return f"_{suf}"
    if d == DIMLESS:
        return "dimensionless"
    return str(d)


def _combine(a, b, op: str):
    """Dimensional algebra. Values are Dim, WILDCARD, or None (unknown)."""
    if op in ("mul", "div"):
        # literals are dimensionless scale factors here
        aa = DIMLESS if a == WILDCARD else a
        bb = DIMLESS if b == WILDCARD else b
        if aa is None or bb is None:
            return None
        sign = 1 if op == "mul" else -1
        return tuple(x + sign * y for x, y in zip(aa, bb))
    # additive ops: wildcard matches anything
    if a == WILDCARD:
        return b
    if b == WILDCARD:
        return a
    if a is None or b is None:
        return None
    return a if a == b else "mismatch"


_PASSTHROUGH = {"maximum", "minimum", "where", "clip", "abs", "sum", "max",
                "min", "mean", "round", "floor", "ceil", "asarray", "array",
                "diff", "full_like", "zeros_like", "ones_like", "stop_gradient",
                "squeeze", "reshape", "broadcast_to", "select"}


class _UnitEnv:
    def __init__(self, mod: Module, fn: ast.AST):
        self.mod = mod
        self.fn = fn
        self.env: Dict[str, object] = {}
        self.findings: List[Finding] = []
        for p in arg_names(fn):
            d = suffix_dim(p)
            if d is not None:
                self.env[p] = d

    def _flag(self, rule: str, node: ast.AST, msg: str) -> None:
        self.findings.append(Finding(
            rule, self.mod.rel, node.lineno,
            f"{msg} (in {self.fn.name!r})",
            snippet=self.mod.snippet(node.lineno)))

    # -- inference ---------------------------------------------------------
    def infer(self, node: ast.AST):
        if isinstance(node, ast.Constant):
            if isinstance(node.value, bool):
                return DIMLESS
            if isinstance(node.value, (int, float)):
                return WILDCARD
            return None
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return self.env[node.id]
            return suffix_dim(node.id)
        if isinstance(node, ast.Attribute):
            return suffix_dim(node.attr)
        if isinstance(node, ast.UnaryOp):
            return self.infer(node.operand)
        if isinstance(node, ast.BinOp):
            a, b = self.infer(node.left), self.infer(node.right)
            if isinstance(node.op, ast.Mult):
                return _combine(a, b, "mul")
            if isinstance(node.op, (ast.Div, ast.FloorDiv)):
                return _combine(a, b, "div")
            if isinstance(node.op, (ast.Add, ast.Sub)):
                r = _combine(a, b, "add")
                if r == "mismatch":
                    self._flag("US02", node,
                               f"+/- mixes {_dim_name(a)} with "
                               f"{_dim_name(b)}")
                    return None
                return r
            if isinstance(node.op, ast.Pow) and \
                    isinstance(node.right, ast.Constant) and \
                    isinstance(node.right.value, int):
                if a in (None, WILDCARD):
                    return a
                return tuple(x * node.right.value for x in a)
            return None
        if isinstance(node, ast.Compare):
            vals = [self.infer(node.left)] + [self.infer(c)
                                             for c in node.comparators]
            known = [v for v in vals if v not in (None, WILDCARD)]
            if len(set(known)) > 1:
                self._flag("US02", node,
                           "comparison mixes "
                           + " with ".join(_dim_name(v)
                                           for v in sorted(set(known))))
            return DIMLESS
        if isinstance(node, ast.Subscript):
            # metrics["retention_s"] and friends: the key names the unit
            if isinstance(node.slice, ast.Constant) and \
                    isinstance(node.slice.value, str):
                return suffix_dim(node.slice.value)
            return self.infer(node.value)
        if isinstance(node, ast.IfExp):
            r = _combine(self.infer(node.body), self.infer(node.orelse),
                         "add")
            return None if r == "mismatch" else r
        if isinstance(node, ast.Call):
            callee = dotted(node.func)
            last = callee.split(".")[-1] if callee else ""
            if last in ("maximum", "minimum") and len(node.args) == 2:
                a, b = self.infer(node.args[0]), self.infer(node.args[1])
                r = _combine(a, b, "add")
                if r == "mismatch":
                    self._flag("US02", node,
                               f"{last}() mixes {_dim_name(a)} with "
                               f"{_dim_name(b)}")
                    return None
                return r
            if last == "where" and len(node.args) == 3:
                r = _combine(self.infer(node.args[1]),
                             self.infer(node.args[2]), "add")
                return None if r == "mismatch" else r
            if last == "sqrt" and node.args:
                a = self.infer(node.args[0])
                if isinstance(a, tuple) and all(x % 2 == 0 for x in a):
                    return tuple(x // 2 for x in a)
                return None
            if last in _PASSTHROUGH and node.args:
                return self.infer(node.args[0])
            return None
        return None

    # -- statement walk ----------------------------------------------------
    def _check_target(self, name: str, rhs_dim, node: ast.AST) -> None:
        if name in NAME_EXEMPT or name.isupper() or name.startswith("_"):
            return
        sdim = suffix_dim(name)
        pdim = prefix_dim(name)
        if sdim is not None:
            self.env[name] = sdim
            if pdim is not None and pdim != sdim:
                self._flag("US03", node,
                           f"{name!r}: suffix claims {_dim_name(sdim)} but "
                           f"its prefix promises {_dim_name(pdim)}")
            elif isinstance(rhs_dim, tuple) and rhs_dim != sdim:
                self._flag("US03", node,
                           f"{name!r} claims {_dim_name(sdim)} but its "
                           f"right-hand side is {_dim_name(rhs_dim)}")
            return
        # no suffix on the target
        if pdim is not None:
            self._flag("US01", node,
                       f"{name!r} is a physics binding "
                       f"(expects {_dim_name(pdim)}) but has no unit suffix")
            self.env[name] = pdim
            return
        if isinstance(rhs_dim, tuple) and rhs_dim != DIMLESS:
            self._flag("US01", node,
                       f"{name!r} holds a {_dim_name(rhs_dim)} quantity but "
                       f"has no unit suffix")
            self.env[name] = rhs_dim

    def run(self) -> None:
        for node in ast.walk(self.fn):
            if isinstance(node, ast.Assign):
                if len(node.targets) == 1 and \
                        isinstance(node.targets[0], ast.Name):
                    rhs = self.infer(node.value)
                    self._check_target(node.targets[0].id, rhs, node)
                else:
                    # tuple unpacking: no per-element RHS inference, but
                    # prefix-triggered US01 still applies to each name
                    self.infer(node.value)       # surface US02 inside RHS
                    for t in node.targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                self._check_target(n.id, None, node)
            elif isinstance(node, ast.AugAssign) and \
                    isinstance(node.target, ast.Name):
                if isinstance(node.op, (ast.Add, ast.Sub)):
                    a = self.infer(node.target)
                    b = self.infer(node.value)
                    if _combine(a, b, "add") == "mismatch":
                        self._flag("US02", node,
                                   f"augmented +/- mixes {_dim_name(a)} "
                                   f"with {_dim_name(b)}")
            elif isinstance(node, ast.Expr):
                self.infer(node.value)           # surface US02 only
            elif isinstance(node, ast.Return) and node.value is not None:
                self.infer(node.value)
            elif isinstance(node, (ast.If, ast.While)):
                self.infer(node.test)


def check(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for rel in TARGETS:
        mod = project.module(rel)
        if mod is None:
            continue
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                env = _UnitEnv(mod, node)
                env.run()
                findings.extend(env.findings)
    # nested defs are walked once standalone and once inside their parent;
    # keep the first occurrence of each identical finding
    return list(dict.fromkeys(findings))
