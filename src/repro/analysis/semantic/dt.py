"""DT — dtype / weak-type drift audit over traced jaxprs (semantic tier).

Traces the public jit entry points of core/hetero/sim with
``jax.make_jaxpr`` under representative inputs and checks every abstract
value (recursing into pjit/scan/cond sub-jaxprs) against the repo's dtype
policy:

  DT01  a dtype outside the policy appears anywhere in the trace
        (float64/float16/complex promotion — silent precision drift)
  DT02  a top-level output is a weak-typed float: a Python scalar leaked
        through to the boundary, so downstream promotion depends on call
        context instead of the declared dtype
  DT03  an integer accumulation (reduce_sum / cumsum / dot) runs in a
        sub-32-bit dtype
  DT04  spec rot: an entry point or its input builder no longer resolves

The physics pipeline is float32 end to end (Table-2 bit-exactness depends
on it); int32/bool/uint32 cover indices, masks and counters.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

from repro.analysis.findings import Finding

ALLOWED_DTYPES = frozenset(
    {"float32", "bfloat16", "int32", "int64", "uint32", "uint64", "bool"})

_ACCUM_PRIMS = frozenset({"reduce_sum", "cumsum", "dot_general", "add_any"})
_NARROW_INTS = frozenset({"int8", "int16", "uint8", "uint16"})


# ---------------------------------------------------------------------------
# entry-point spec: how to build trace-shaped inputs for each jit site
# ---------------------------------------------------------------------------


def _build_characterize_batch():
    import jax.numpy as jnp
    from repro.core.macro import MacroConfig
    cfgs = [MacroConfig(mem_type="gc_sisi", word_size=16, num_words=16),
            MacroConfig(mem_type="sram6t", word_size=32, num_words=64)]
    return (jnp.stack([c.to_vector() for c in cfgs]),), {}


def _build_characterize_corners_batch():
    import jax.numpy as jnp
    from repro.core import corners
    from repro.core.macro import MacroConfig
    cfgs = [MacroConfig(mem_type="gc_sisi", word_size=16, num_words=16),
            MacroConfig(mem_type="gc_ossi", word_size=32, num_words=32)]
    vecs = jnp.stack([c.to_vector() for c in cfgs])
    tps = corners.stack_tech([corners.as_operating_point(n)
                              for n in ("nominal", "hot")])
    return (vecs, tps), {}


def _build_retention_time_batch():
    import jax.numpy as jnp
    from repro.core import bitcells
    stacked = bitcells.stack_bitcells()
    ls = jnp.zeros(len(bitcells.MEM_TYPE_ORDER), jnp.int32)
    return (stacked, ls), {}


def _build_score_jit():
    import jax.numpy as jnp
    from repro.hetero.system import METRIC_COLS
    cols = {k: jnp.linspace(1.0, 2.0, 8, dtype=jnp.float32)
            for k in METRIC_COLS}
    idx = jnp.zeros((4, 2), jnp.int32)
    cap = jnp.full((2,), 1e6, jnp.float32)
    f_req = jnp.full((2,), 1e8, jnp.float32)
    return (idx, cols, cap, f_req), {}


def _build_score_corners_jit():
    import jax.numpy as jnp
    from repro.hetero.system import METRIC_COLS
    cols = {k: jnp.linspace(1.0, 2.0, 16, dtype=jnp.float32).reshape(2, 8)
            for k in METRIC_COLS}
    idx = jnp.zeros((4, 2), jnp.int32)
    cap = jnp.full((2,), 1e6, jnp.float32)
    f_req = jnp.full((2,), 1e8, jnp.float32)
    return (idx, cols, cap, f_req), {}


def _sim_inputs(J: int):
    import jax.numpy as jnp
    from repro.sim.engine import SIM_COLS
    S, T = 2, 8
    base = {"bits": 4096.0, "word_bits": 32.0, "e_read_j": 1e-12,
            "e_write_j": 2e-12, "f_op_hz": 1e9, "p_leak_w": 1e-6,
            "retention_s": 1e-3}
    shape = (J, S) if J else (S,)
    params = {c: jnp.full(shape, base[c], jnp.float32) for c in SIM_COLS}
    params["tiles"] = jnp.ones(shape, jnp.float32)
    params["interval_s"] = jnp.full(shape, 5e-4, jnp.float32)
    slot = {"cap_bits": jnp.full((S,), 1e6, jnp.float32),
            "lifetime_s": jnp.full((S,), 1e-2, jnp.float32)}
    xs = (jnp.full((T,), 1e-5, jnp.float32),
          jnp.ones((T, S), jnp.float32),
          jnp.full((T, S), 64.0, jnp.float32),
          jnp.full((T, S), 0.5, jnp.float32))
    # [refresh_on, rewrite_overhead, adaptive_on, temp_drift_k, t_total_s]
    consts = jnp.asarray([1.0, 2.0, 0.0, 0.0, 8e-5], jnp.float32)
    return (params, slot, xs, consts), {}


@dataclasses.dataclass(frozen=True)
class DtEntry:
    name: str
    rel: str           # repo-relative module path (finding anchor)
    attr: str          # module attribute holding the jitted callable
    build: Callable[[], Tuple[tuple, dict]]


ENTRIES: Tuple[DtEntry, ...] = (
    DtEntry("characterize_batch", "src/repro/core/characterize.py",
            "characterize_batch", _build_characterize_batch),
    DtEntry("characterize_corners_batch", "src/repro/core/characterize.py",
            "characterize_corners_batch", _build_characterize_corners_batch),
    DtEntry("retention_time_batch", "src/repro/core/retention.py",
            "retention_time_batch", _build_retention_time_batch),
    DtEntry("score_kernel", "src/repro/hetero/system.py",
            "_score_jit", _build_score_jit),
    DtEntry("score_kernel_corners", "src/repro/hetero/system.py",
            "_score_corners_jit", _build_score_corners_jit),
    DtEntry("sim_grid_xla", "src/repro/sim/engine.py",
            "_sim_grid_xla", lambda: _sim_inputs(3)),
    DtEntry("sim_phase_one", "src/repro/sim/engine.py",
            "_sim_one_jit", lambda: _sim_inputs(0)),
)


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _sub_jaxprs(params: dict, closed_cls):
    for v in params.values():
        if isinstance(v, closed_cls):
            yield v
        elif isinstance(v, (list, tuple)):
            for item in v:
                if isinstance(item, closed_cls):
                    yield item


def _walk_eqns(closed_jaxpr):
    # the ClosedJaxpr class is version-drifty to import; make_jaxpr just
    # handed us an instance, so match sub-jaxprs against its own type
    closed_cls = type(closed_jaxpr)
    stack = [closed_jaxpr.jaxpr]
    while stack:
        jx = stack.pop()
        for eqn in jx.eqns:
            yield eqn
            for sub in _sub_jaxprs(eqn.params, closed_cls):
                stack.append(sub.jaxpr)


def audit_callable(name: str, fn, args, kwargs=None) -> List[dict]:
    """Trace ``fn`` and return raw DT issues ({rule, message}); shared by
    the live checker and the analyzer's own test fixtures."""
    import jax
    issues: List[dict] = []
    closed = jax.make_jaxpr(fn)(*args, **(kwargs or {}))

    bad_dtypes: Dict[str, str] = {}
    narrow: Dict[str, str] = {}
    for eqn in _walk_eqns(closed):
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt is None:
                continue
            if str(dt) not in ALLOWED_DTYPES:
                bad_dtypes.setdefault(str(dt), eqn.primitive.name)
            if eqn.primitive.name in _ACCUM_PRIMS and \
                    str(dt) in _NARROW_INTS:
                narrow.setdefault(str(dt), eqn.primitive.name)
    for dt, prim in sorted(bad_dtypes.items()):
        issues.append({"rule": "DT01", "message":
                       f"{name}: primitive {prim!r} manufactures dtype "
                       f"{dt} (policy: {sorted(ALLOWED_DTYPES)})"})
    for dt, prim in sorted(narrow.items()):
        issues.append({"rule": "DT03", "message":
                       f"{name}: integer accumulation {prim!r} runs in "
                       f"{dt} — overflow-prone; accumulate in int32+"})

    weak = [i for i, aval in enumerate(closed.out_avals)
            if getattr(aval, "weak_type", False)
            and "float" in str(getattr(aval, "dtype", ""))]
    if weak:
        issues.append({"rule": "DT02", "message":
                       f"{name}: output leaf/leaves {weak} are weak-typed "
                       f"floats — a Python scalar reached the jit boundary"})
    return issues


# ---------------------------------------------------------------------------
# checker entry
# ---------------------------------------------------------------------------


def _module_of(rel: str) -> str:
    # src/repro/core/characterize.py -> repro.core.characterize
    return rel[len("src/"):-len(".py")].replace("/", ".")


def _anchor_line(project, rel: str, attr: str) -> int:
    import ast
    mod = project.module(rel)
    if mod is None:
        return 0
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == attr
                for t in node.targets):
            return node.lineno
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                node.name == attr:
            return node.lineno
    return 0


def check(project) -> List[Finding]:
    import importlib
    findings: List[Finding] = []
    for entry in ENTRIES:
        line = _anchor_line(project, entry.rel, entry.attr)
        mod = project.module(entry.rel)
        snippet = mod.snippet(line) if (mod and line) else ""

        def emit(rule, msg):
            findings.append(Finding(rule=rule, path=entry.rel, line=line,
                                    message=msg, snippet=snippet))

        try:
            fn = getattr(importlib.import_module(_module_of(entry.rel)),
                         entry.attr)
        except (ImportError, AttributeError) as e:
            emit("DT04", f"{entry.name}: entry point no longer resolves "
                         f"({type(e).__name__}: {e})")
            continue
        try:
            args, kwargs = entry.build()
        except Exception as e:
            emit("DT04", f"{entry.name}: drive-input builder failed "
                         f"({type(e).__name__}: {e})")
            continue
        try:
            issues = audit_callable(entry.name, fn, args, kwargs)
        except Exception as e:
            emit("DT04", f"{entry.name}: tracing failed "
                         f"({type(e).__name__}: {e})")
            continue
        for issue in issues:
            emit(issue["rule"], issue["message"])
    return findings
