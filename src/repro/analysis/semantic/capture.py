"""Pallas-call interception + overlay-aware kernel loading for the PB tier.

``intercept_pallas`` monkeypatches ``jax.experimental.pallas.pallas_call``
with a recorder: instead of lowering a kernel it captures the launch
geometry — grid, BlockSpecs, dimension_semantics, operand/out shapes, and
the call-site file:line — and returns zeros of ``out_shape`` so the wrapper
function completes without executing anything. The PB checker then proves
properties of the captured index maps symbolically.

``load_function`` executes a kernel module's *source* (through the
analyzer's ``Project``, so test overlays apply) into a throwaway namespace:
the PB checker verifies exactly the text under analysis, not whatever is
already imported in ``sys.modules``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple


@dataclasses.dataclass
class PallasCapture:
    """One intercepted ``pallas_call``: everything PB needs to verify it."""
    kernel_name: str
    grid: Tuple[int, ...]
    in_specs: List[Any]                    # pl.BlockSpec objects
    out_specs: Any                         # pl.BlockSpec (single output)
    out_shapes: List[Tuple[int, ...]]      # flattened out_shape shapes
    operand_shapes: List[Tuple[int, ...]]
    dimension_semantics: Optional[Tuple[str, ...]]
    path: str                              # repo-relative call-site module
    line: int                              # 1-based pallas_call line


def _kernel_name(kernel) -> str:
    inner = getattr(kernel, "func", kernel)      # unwrap functools.partial
    return getattr(inner, "__name__", repr(inner))


def dimension_semantics_of(compiler_params) -> Optional[Tuple[str, ...]]:
    """Extract dimension_semantics across the compat spellings: the
    CompilerParams/TPUCompilerParams dataclass, or the {"mosaic": {...}}
    dict fallback (see ``repro.compat.tpu_compiler_params``)."""
    if compiler_params is None:
        return None
    if isinstance(compiler_params, dict):
        inner = compiler_params.get("mosaic", compiler_params)
        ds = inner.get("dimension_semantics") if isinstance(inner, dict) \
            else None
    else:
        ds = getattr(compiler_params, "dimension_semantics", None)
    return tuple(ds) if ds is not None else None


def _call_site(root: Path) -> Tuple[str, int]:
    """(repo-relative path, line) of the innermost caller inside ``root``
    that is not part of the analyzer itself."""
    root = Path(root).resolve()
    f = sys._getframe(2)    # skip _call_site and the fake pallas_call
    while f is not None:
        fn = f.f_code.co_filename
        try:
            rel = Path(fn).resolve().relative_to(root).as_posix()
        except ValueError:
            rel = None
        if rel and "repro/analysis/" not in rel:
            return rel, f.f_lineno
        f = f.f_back
    return "", 0


@contextlib.contextmanager
def intercept_pallas(root):
    """Swap ``pl.pallas_call`` for a recorder; yields the capture list."""
    from jax.experimental import pallas as pl

    captures: List[PallasCapture] = []
    real = pl.pallas_call

    def fake_pallas_call(kernel, *args, **kwargs):
        site = _call_site(Path(root))
        out_shape = kwargs.get("out_shape", args[0] if args else None)

        def runner(*operands):
            import jax
            import jax.numpy as jnp
            flat, _ = jax.tree_util.tree_flatten(out_shape)
            captures.append(PallasCapture(
                kernel_name=_kernel_name(kernel),
                grid=tuple(int(g) for g in kwargs.get("grid", ())),
                in_specs=list(kwargs.get("in_specs", ())),
                out_specs=kwargs.get("out_specs"),
                out_shapes=[tuple(s.shape) for s in flat],
                operand_shapes=[tuple(o.shape) for o in operands],
                dimension_semantics=dimension_semantics_of(
                    kwargs.get("compiler_params")),
                path=site[0], line=site[1]))
            return jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), out_shape)

        return runner

    pl.pallas_call = fake_pallas_call
    try:
        yield captures
    finally:
        pl.pallas_call = real


def load_function(project, rel: str, name: str):
    """Load ``name`` from the (possibly overlaid) source of ``rel`` by
    executing it in a fresh namespace. Returns None when the module or the
    function is missing — the caller reports spec rot."""
    mod = project.module(rel)
    if mod is None:
        return None
    path = str(Path(project.root) / rel)
    ns: Dict[str, Any] = {"__name__": f"_pb_overlay_{Path(rel).stem}",
                          "__file__": path}
    try:
        exec(compile(mod.source, path, "exec"), ns)
    except Exception:
        return None
    return ns.get(name)
