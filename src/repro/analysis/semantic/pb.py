"""PB — Pallas block-spec verifier (semantic tier, imports jax).

For every op registered in ``repro.kernels.backend`` with a ``tpu`` impl,
run its wrapper under ``capture.intercept_pallas`` on representative shapes
derived from ``repro.configs`` and prove, by enumerating every
``BlockSpec.index_map`` over the full launch grid:

  PB01  every block window lies inside the (padded) operand
  PB02  output blocks tile the output exactly (no gaps)
  PB03  no two grid points differing in a "parallel" axis write the same
        output block (revisits are only legal along "arbitrary" axes —
        that is how flash attention accumulates over its kv axis)
  PB04  grid ordering is consistent: dimension_semantics / index_map arity
        match the grid, and a grid axis used identity-style maps onto a
        block dim with exactly that many blocks (locks ssm_scan's
        intentional ``(b, d, c) -> (b, c, d)`` permutation)
  PB05  spec rot: a tpu-registered op with no shape profile here, or a
        profiled op whose wrapper/profile no longer resolves

The grid enumeration is exact, not sampled: profiles are sized so the full
product stays small (hundreds of points), which is what makes the proof a
proof.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding
from repro.analysis.semantic import capture

OPS_REL = "src/repro/kernels/ops.py"

# hard cap on exact grid enumeration; profiles are sized far below it, and
# hitting the cap is itself reported (a silent sample would not be a proof)
MAX_GRID_POINTS = 200_000


@dataclasses.dataclass(frozen=True)
class Profile:
    label: str                             # e.g. "qwen3_8b:1x4x1024x128"
    build: Callable[[], tuple]             # () -> (args, kwargs)


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    rel: str                               # kernel module, repo-relative
    func: str                              # wrapper function name
    profiles: Callable[[], List[Profile]]


def _attention_profiles() -> List[Profile]:
    import jax.numpy as jnp
    from repro.configs.base import get_config

    profs = []
    for arch in ("qwen3-8b", "granite-34b", "hymba-1.5b"):
        cfg = get_config(arch)
        H = min(cfg.num_heads, 4)
        S = max(2 * cfg.attn_chunk, 256)
        D = cfg.head_dim

        def build(H=H, S=S, D=D):
            q = jnp.zeros((1, H, S, D), jnp.float32)
            return (q, q, q), {}

        profs.append(Profile(f"{arch}:1x{H}x{S}x{D}", build))
    return profs


def _ssm_profiles() -> List[Profile]:
    import jax.numpy as jnp
    from repro.configs.base import get_config, reduce_config

    cfg = get_config("hymba-1.5b")
    red = reduce_config(cfg)
    cases = [
        # (label, B, S, di, n, kwargs): hymba's di = d_model * ssm_expand is
        # 3200 — NOT a multiple of the 512 default, so representative runs
        # must pass an explicit divisor block_d just as the model code does
        (f"hymba:full:di{cfg.d_model * cfg.ssm_expand}",
         1, 256, cfg.d_model * cfg.ssm_expand, cfg.ssm_state,
         {"block_d": 320, "chunk": 128}),
        (f"hymba:reduced:di{red.d_model * red.ssm_expand}",
         2, 128, red.d_model * red.ssm_expand, red.ssm_state, {}),
        (f"hymba:decode:di{cfg.d_model * cfg.ssm_expand}",
         1, 128, cfg.d_model * cfg.ssm_expand, cfg.ssm_state,
         {"block_d": 400, "chunk": 64}),
    ]

    profs = []
    for label, B, S, di, n, kw in cases:
        def build(B=B, S=S, di=di, n=n, kw=kw):
            x = jnp.zeros((B, S, di), jnp.float32)
            bc = jnp.zeros((B, S, n), jnp.float32)
            A = jnp.zeros((di, n), jnp.float32)
            D = jnp.zeros((di,), jnp.float32)
            return (x, x, A, bc, bc, D), dict(kw)

        profs.append(Profile(label, build))
    return profs


def _retention_profiles() -> List[Profile]:
    import jax.numpy as jnp
    from repro.core import bitcells
    from repro.core import retention as ret

    n_cells = len(bitcells.BITCELLS)
    cases = [
        (f"bitcell-menu:B{n_cells}", n_cells),     # pad to one 128 block
        ("corner-sweep:B256", 256),                # exact two-block tiling
        ("ragged:B130", 130),                      # padding + multi-block
    ]

    profs = []
    for label, B in cases:
        def build(B=B):
            params = jnp.ones((B, 10), jnp.float32)
            ts = jnp.asarray(ret.time_grid(), jnp.float32)
            return (params, ts), {}

        profs.append(Profile(label, build))
    return profs


KERNEL_SPECS: Dict[str, KernelSpec] = {
    "attention": KernelSpec("src/repro/kernels/flash_attention.py",
                            "flash_attention", _attention_profiles),
    "ssm_scan": KernelSpec("src/repro/kernels/ssm_scan.py",
                           "ssm_scan_pallas", _ssm_profiles),
    "retention": KernelSpec("src/repro/kernels/retention_kernel.py",
                            "retention_pallas", _retention_profiles),
}


# ---------------------------------------------------------------------------
# index-map algebra
# ---------------------------------------------------------------------------


def _normalize(idx) -> Tuple[int, ...]:
    if not isinstance(idx, tuple):
        idx = (idx,)
    return tuple(int(i) for i in idx)


def _num_blocks(shape: Sequence[int], block: Sequence[int]) -> Tuple[int, ...]:
    """Blocks per dim under Pallas padding: ceil(extent / block); a None
    block entry is a squeezed size-1 dim."""
    return tuple(math.ceil(s / (b or 1)) for s, b in zip(shape, block))


def identity_map(index_map, grid: Sequence[int]) -> Dict[int, int]:
    """grid axis -> block position it maps onto 1:1 (probed with unit
    vectors: delta of exactly +1 in exactly one output position)."""
    base = _normalize(index_map(*([0] * len(grid))))
    out: Dict[int, int] = {}
    for a in range(len(grid)):
        if grid[a] <= 1:
            continue
        probe = [0] * len(grid)
        probe[a] = 1
        deltas = [o - b for o, b in
                  zip(_normalize(index_map(*probe)), base)]
        nz = [p for p, d in enumerate(deltas) if d != 0]
        if len(nz) == 1 and deltas[nz[0]] == 1:
            out[a] = nz[0]
    return out


def verify_capture(cap: capture.PallasCapture) -> List[dict]:
    """Prove PB01-PB04 for one captured pallas_call. Returns raw issues
    (dicts with rule/message) anchored by the caller."""
    issues: List[dict] = []
    grid = cap.grid
    n_points = math.prod(grid) if grid else 0
    if not grid or n_points > MAX_GRID_POINTS:
        issues.append({"rule": "PB04", "message":
                       f"kernel {cap.kernel_name}: grid {grid} is empty or "
                       f"too large to enumerate exactly "
                       f"(> {MAX_GRID_POINTS} points)"})
        return issues

    sem = cap.dimension_semantics
    if sem is not None and len(sem) != len(grid):
        issues.append({"rule": "PB04", "message":
                       f"kernel {cap.kernel_name}: dimension_semantics "
                       f"arity {len(sem)} != grid arity {len(grid)}"})
        sem = None
    # with no semantics declared, Pallas runs the grid sequentially —
    # treat every axis as "arbitrary" (no concurrency, no races)
    parallel_axes = tuple(a for a, s in enumerate(sem or ())
                          if s == "parallel")

    specs = [(f"in_spec[{i}] of {cap.kernel_name}", s, shape, False)
             for i, (s, shape) in
             enumerate(zip(cap.in_specs, cap.operand_shapes))]
    if cap.out_specs is not None and cap.out_shapes:
        specs.append((f"out_spec of {cap.kernel_name}", cap.out_specs,
                      cap.out_shapes[0], True))

    for label, spec, shape, is_out in specs:
        block = tuple(spec.block_shape)
        fmap = spec.index_map
        try:
            probe = _normalize(fmap(*([0] * len(grid))))
        except TypeError:
            issues.append({"rule": "PB04", "message":
                           f"{label}: index_map arity != grid arity "
                           f"{len(grid)}"})
            continue
        if len(probe) != len(block):
            issues.append({"rule": "PB04", "message":
                           f"{label}: index_map returns {len(probe)} "
                           f"indices for a {len(block)}-d block"})
            continue
        nblocks = _num_blocks(shape, block)

        written: Dict[Tuple[int, ...], set] = {}
        oob = None
        for point in itertools.product(*(range(g) for g in grid)):
            idx = _normalize(fmap(*point))
            if oob is None and any(not 0 <= i < n
                                   for i, n in zip(idx, nblocks)):
                oob = (point, idx)
            if is_out:
                par = tuple(point[a] for a in parallel_axes)
                written.setdefault(idx, set()).add(par)
        if oob is not None:
            issues.append({"rule": "PB01", "message":
                           f"{label}: grid point {oob[0]} addresses block "
                           f"{oob[1]} outside the padded operand "
                           f"{shape} / blocks {nblocks}"})

        if is_out:
            expected = set(itertools.product(*(range(n) for n in nblocks)))
            gaps = expected - set(written)
            if gaps:
                issues.append({"rule": "PB02", "message":
                               f"{label}: {len(gaps)} of "
                               f"{len(expected)} output blocks are never "
                               f"written (e.g. {sorted(gaps)[0]})"})
            raced = [b for b, pars in written.items() if len(pars) > 1]
            if raced:
                issues.append({"rule": "PB03", "message":
                               f"{label}: output block {sorted(raced)[0]} "
                               f"is written from {len(written[sorted(raced)[0]])} "
                               f"distinct parallel-axis coordinates "
                               f"(write race across "
                               f"{len(raced)} block(s))"})
            # ordering consistency: an identity-mapped axis must supply
            # exactly one grid step per output block along its target dim
            for axis, pos in identity_map(fmap, grid).items():
                if nblocks[pos] != grid[axis]:
                    issues.append({"rule": "PB04", "message":
                                   f"{label}: grid axis {axis} (extent "
                                   f"{grid[axis]}) maps 1:1 onto block dim "
                                   f"{pos} which has {nblocks[pos]} "
                                   f"block(s) — inconsistent axis "
                                   f"ordering"})
    return issues


# ---------------------------------------------------------------------------
# checker entry
# ---------------------------------------------------------------------------


def _register_line(project, op: str) -> Tuple[str, int]:
    """Anchor for registry-level findings: the register("<op>", ...) call."""
    import ast
    mod = project.module(OPS_REL)
    if mod is not None:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and node.args and \
                    isinstance(node.args[0], ast.Constant) and \
                    node.args[0].value == op:
                return OPS_REL, node.lineno
    return OPS_REL, 0


def _finding(project, rel: str, line: int, rule: str, msg: str) -> Finding:
    mod = project.module(rel)
    snippet = mod.snippet(line) if (mod and line) else ""
    return Finding(rule=rule, path=rel, line=line, message=msg,
                   snippet=snippet)


def verify_all(project) -> Tuple[List[Finding], Dict[str, int]]:
    """Run every profile of every spec'd kernel; returns (findings,
    {op: profiles proved clean})."""
    import repro.kernels.ops  # noqa: F401  (populates the registry)
    from repro.kernels import backend

    findings: List[Finding] = []
    stats: Dict[str, int] = {}

    tpu_ops = [name for name in backend.registered()
               if "tpu" in backend.impl_map(name)]
    for op in tpu_ops:
        if op not in KERNEL_SPECS:
            rel, line = _register_line(project, op)
            findings.append(_finding(
                project, rel, line, "PB05",
                f"op {op!r} has a tpu impl but no PB shape profile — add "
                f"one to repro.analysis.semantic.pb.KERNEL_SPECS"))

    for op, spec in KERNEL_SPECS.items():
        if op not in tpu_ops:
            rel, line = _register_line(project, op)
            findings.append(_finding(
                project, rel, line, "PB05",
                f"PB spec names op {op!r} which is not registered with a "
                f"tpu impl — the spec rotted"))
            continue
        fn = capture.load_function(project, spec.rel, spec.func)
        if fn is None:
            findings.append(_finding(
                project, spec.rel, 0, "PB05",
                f"op {op!r}: function {spec.func!r} not loadable from "
                f"{spec.rel} — the spec rotted"))
            continue
        clean = 0
        for prof in spec.profiles():
            args, kwargs = prof.build()
            with capture.intercept_pallas(project.root) as caps:
                try:
                    fn(*args, **kwargs)
                except Exception as e:
                    findings.append(_finding(
                        project, spec.rel, 0, "PB05",
                        f"op {op!r} profile {prof.label}: wrapper raised "
                        f"{type(e).__name__}: {e}"))
                    continue
            if not caps:
                findings.append(_finding(
                    project, spec.rel, 0, "PB05",
                    f"op {op!r} profile {prof.label}: no pallas_call "
                    f"reached — wrapper no longer lowers through Pallas"))
                continue
            n_before = len(findings)
            for cap in caps:
                for issue in verify_capture(cap):
                    rel = cap.path or spec.rel
                    findings.append(_finding(
                        project, rel, cap.line, issue["rule"],
                        f"[{op}:{prof.label}] {issue['message']}"))
            if len(findings) == n_before:
                clean += 1
        stats[op] = clean
    return findings, stats


def check(project) -> List[Finding]:
    return verify_all(project)[0]
