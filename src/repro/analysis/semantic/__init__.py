"""Semantic (IR-level) analyzer tier: PB / DT / RC.

Unlike the AST tier this package imports jax, traces jaxprs, and executes
jit sites — it is CI-only (``python -m repro.analysis --semantic``), never
part of pre-commit. ``repro.analysis`` itself must stay importable without
jax, so nothing here is imported at package-import time: the runner pulls
in ``repro.analysis.semantic`` lazily only when the semantic families are
requested.
"""
from __future__ import annotations

from repro.analysis.semantic import dt, pb, rc

CHECKERS = {
    "PB": pb.check,
    "DT": dt.check,
    "RC": rc.check,
}

__all__ = ["CHECKERS", "pb", "dt", "rc"]
