"""RC — recompilation-cavity audit (semantic tier, executes the jit sites).

Every module-level ``jax.jit`` site in core/hetero/sim carries a committed
trace-cache budget here. The checker drives each site through its public
API with the distinct (shape, static-arg) profiles the benchmarks actually
use, measuring ``_cache_size()`` *deltas* (the suite shares one process, so
absolute counts would be polluted by whatever compiled earlier):

  RC01  driving the profiles grew the cache beyond the budget — a
        static-argnum leak or shape churn silently multiplying compiles
  RC02  re-driving the *same* profiles added entries — the cache key is
        unstable (weak-type flip-flop, unhashable static, fresh closures)
  RC03  a module-level jit site exists with no budget entry (AST sweep,
        overlay-aware) — its compile count is unwatched
  RC04  spec rot: a budgeted site no longer resolves, the cache-size API
        is gone, or a driver crashed

``_characterize_jit`` (an lru-cached per-corner factory *inside* a
function) is intentionally out of scope: RC03 only sweeps module-level
sites, which is exactly the set with process-lifetime caches.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, List, Tuple

from repro.analysis import astutil
from repro.analysis.findings import Finding

SCAN_DIRS = ("src/repro/core", "src/repro/hetero", "src/repro/sim")


# ---------------------------------------------------------------------------
# drivers: exercise the public APIs with the benchmark shape profiles
# ---------------------------------------------------------------------------


def _drive_characterize() -> None:
    import jax.numpy as jnp
    from repro.core import characterize as chz
    from repro.core.macro import MacroConfig
    cfgs = [MacroConfig(mem_type="gc_sisi", word_size=16, num_words=16),
            MacroConfig(mem_type="sram6t", word_size=16, num_words=16),
            MacroConfig(mem_type="gc_ossi", word_size=32, num_words=32)]
    v2 = jnp.stack([c.to_vector() for c in cfgs[:2]])
    v3 = jnp.stack([c.to_vector() for c in cfgs])
    chz.characterize_batch(v2)
    chz.characterize_batch(v3)
    chz.characterize_corners(v2, ("nominal", "hot"))
    chz.characterize_corners(v3, ("nominal", "hot"))


def _drive_retention() -> None:
    import jax.numpy as jnp
    from repro.core import bitcells, retention
    full = bitcells.stack_bitcells()
    retention.retention_time_batch(
        full, jnp.zeros(len(bitcells.MEM_TYPE_ORDER), jnp.int32))
    sub = bitcells.stack_bitcells(("gc_sisi", "gc_ossi", "gc_osos"))
    retention.retention_time_batch(sub, jnp.ones(3, jnp.int32))


def _drive_score() -> None:
    import numpy as np
    from repro.hetero import system
    vals = {"area_um2": 100.0, "bits": 1024.0, "p_leak_w": 1e-6,
            "p_refresh_w": 1e-7, "e_read_j": 1e-12, "f_op_hz": 1e9}
    metrics = {k: np.full(8, v, np.float32)
               for k, v in vals.items()}
    for J in (4, 6):
        system.score_grid(metrics, np.zeros((J, 2), np.int64),
                          [1e6, 1e6], [1e8, 1e8])
    for C, J in ((2, 4), (3, 6)):
        system.score_grid_corners([metrics] * C,
                                  np.zeros((J, 2), np.int64),
                                  [1e6, 1e6], [1e8, 1e8])


def _sim_trace(T: int):
    import numpy as np
    from repro.sim.trace import Trace
    S = 2
    return Trace(phase="prefill",
                 t_bin_s=np.full(T, 1e-5),
                 reads=np.ones((S, T)),
                 write_bits=np.full((S, T), 64.0),
                 occupancy=np.full((S, T), 0.5),
                 cap_bits=np.full(S, 1e6),
                 f_req_hz=np.full(S, 1e8),
                 lifetime_s=np.full(S, 1e-2))


def _drive_sim() -> None:
    import numpy as np
    from repro.sim import engine
    vals = {"bits": 4096.0, "word_bits": 32.0, "e_read_j": 1e-12,
            "e_write_j": 2e-12, "f_op_hz": 1e9, "p_leak_w": 1e-6,
            "retention_s": 1e-3}
    cols = {k: np.full(4, v, np.float32) for k, v in vals.items()}
    idx = np.zeros((3, 2), np.int64)
    for T in (8, 16):
        engine.simulate_traces(cols, idx, [_sim_trace(T)], backend="xla")
    engine.simulate_traces(cols, idx, [_sim_trace(8)], backend="interpret")


DRIVERS: Tuple[Callable[[], None], ...] = (
    _drive_characterize, _drive_retention, _drive_score, _drive_sim)


# ---------------------------------------------------------------------------
# budget spec: every module-level jit site in the scanned packages
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RcSite:
    name: str
    rel: str
    attr: str
    budget: int        # max new trace-cache entries the drivers may add


SITES: Tuple[RcSite, ...] = (
    # two batch sizes
    RcSite("characterize_batch", "src/repro/core/characterize.py",
           "characterize_batch", 2),
    # two batch sizes x one stacked-corner shape
    RcSite("characterize_corners_batch", "src/repro/core/characterize.py",
           "characterize_corners_batch", 2),
    # full bitcell menu + a 3-cell subset
    RcSite("retention_time_batch", "src/repro/core/retention.py",
           "retention_time_batch", 2),
    # two composition-grid heights
    RcSite("score_kernel", "src/repro/hetero/system.py", "_score_jit", 2),
    # two (corner-count x grid-height) profiles on the corner-vmapped path
    RcSite("score_kernel_corners", "src/repro/hetero/system.py",
           "_score_corners_jit", 2),
    # two trace bin counts on the vmapped grid path
    RcSite("sim_grid_xla", "src/repro/sim/engine.py", "_sim_grid_xla", 2),
    # the interpret oracle replays J compositions of identical shape: one
    # trace regardless of J
    RcSite("sim_phase_one", "src/repro/sim/engine.py", "_sim_one_jit", 1),
)


def _resolve(site: RcSite):
    import importlib
    module = site.rel[len("src/"):-len(".py")].replace("/", ".")
    return getattr(importlib.import_module(module), site.attr)


def _cache_size(fn) -> int:
    size = getattr(fn, "_cache_size", None)
    if size is None:
        raise AttributeError(
            f"{fn!r} has no _cache_size() — not a jitted callable, or the "
            f"jax cache-introspection API drifted")
    return int(size())


def _anchor(project, rel: str, attr: str) -> Tuple[int, str]:
    mod = project.module(rel)
    if mod is None:
        return 0, ""
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == attr
                for t in node.targets):
            return node.lineno, mod.snippet(node.lineno)
    return 0, ""


def audit_sites(sites=None, drivers=None):
    """Measure (first-pass delta, repeat delta) per site. Returns
    ({site.name: (delta1, delta2)}, [(site, error_str)]) — shared by the
    live checker and the analyzer's own tests. Defaults resolve to the
    module-level SITES/DRIVERS at call time (tests monkeypatch them)."""
    sites = SITES if sites is None else sites
    drivers = DRIVERS if drivers is None else drivers
    resolved, broken = {}, []
    for site in sites:
        try:
            fn = _resolve(site)
            _cache_size(fn)
        except Exception as e:
            broken.append((site, f"{type(e).__name__}: {e}"))
            continue
        resolved[site.name] = (site, fn)

    deltas: Dict[str, Tuple[int, int]] = {}
    before = {n: _cache_size(fn) for n, (_, fn) in resolved.items()}
    errors = []
    for drive in drivers:
        try:
            drive()
        except Exception as e:
            errors.append(f"driver {drive.__name__} failed: "
                          f"{type(e).__name__}: {e}")
    mid = {n: _cache_size(fn) for n, (_, fn) in resolved.items()}
    for drive in drivers:
        try:
            drive()
        except Exception:
            pass    # first pass already reported it
    after = {n: _cache_size(fn) for n, (_, fn) in resolved.items()}
    for n in resolved:
        deltas[n] = (mid[n] - before[n], after[n] - mid[n])
    return deltas, broken, errors


def _jit_sites_in_tree(project) -> List[Tuple[str, str, int]]:
    """(rel, name, line) of every module-level binding whose value calls
    jax.jit, plus defs decorated with it."""
    out = []
    for scan in SCAN_DIRS:
        for mod in project.iter_modules(scan):
            aliases = astutil.import_aliases(mod.tree)

            def is_jit(call: ast.AST) -> bool:
                if not isinstance(call, ast.Call):
                    return False
                d = astutil.dotted(call.func)
                if d is None:
                    return False
                head, _, rest = d.partition(".")
                full = aliases.get(head, head) + ("." + rest if rest else "")
                return full == "jax.jit" or full.endswith(".jax.jit")

            for node in mod.tree.body:
                if isinstance(node, ast.Assign) and any(
                        is_jit(c) for c in ast.walk(node.value)):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            out.append((mod.rel, t.id, node.lineno))
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)) and any(
                        is_jit(d) or (astutil.dotted(d) or "").endswith(
                            "jax.jit")
                        for d in node.decorator_list):
                    out.append((mod.rel, node.name, node.lineno))
    return out


def check(project) -> List[Finding]:
    findings: List[Finding] = []

    def emit(rule, rel, line, snippet, msg):
        findings.append(Finding(rule=rule, path=rel, line=line, message=msg,
                                snippet=snippet))

    # RC03: every module-level jit site must be budgeted
    covered = {(s.rel, s.attr) for s in SITES}
    for rel, name, line in _jit_sites_in_tree(project):
        if (rel, name) not in covered:
            mod = project.module(rel)
            emit("RC03", rel, line, mod.snippet(line) if mod else "",
                 f"module-level jit site {name!r} has no RC budget entry — "
                 f"add it to repro.analysis.semantic.rc.SITES")

    deltas, broken, errors = audit_sites()
    for site, why in broken:
        line, snippet = _anchor(project, site.rel, site.attr)
        emit("RC04", site.rel, line, snippet,
             f"{site.name}: budget-spec entry no longer resolves ({why})")
    for why in errors:
        emit("RC04", "src/repro/analysis/semantic/rc.py", 0, "", why)
    for site in SITES:
        if site.name not in deltas:
            continue
        d1, d2 = deltas[site.name]
        line, snippet = _anchor(project, site.rel, site.attr)
        if d1 > site.budget:
            emit("RC01", site.rel, line, snippet,
                 f"{site.name}: driving its shape profiles added {d1} trace "
                 f"cache entr(y/ies), budget {site.budget} — a static-arg "
                 f"or shape leak is multiplying compiles")
        if d2 > 0:
            emit("RC02", site.rel, line, snippet,
                 f"{site.name}: re-driving identical profiles added {d2} "
                 f"more entr(y/ies) — unstable cache key")
    return findings
