"""``python -m repro.analysis`` — the CI gate.

    python -m repro.analysis                    # human output, exit bitmask
    python -m repro.analysis --format=json      # machine-readable report
    python -m repro.analysis --docs             # + link/anchor/rule-doc checks
    python -m repro.analysis --rules CK,US      # restrict to families
    python -m repro.analysis --write-baseline   # snapshot current findings
    python -m repro.analysis --list-rules       # rule catalog

Exit code is the OR of the family bits (CK=1 JP=2 US=4 BK=8 DC=16) of every
*active* finding — 0 means clean against the committed baseline.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.findings import Baseline
from repro.analysis.rules import EXIT_BITS, FAMILIES, RULES, family_of
from repro.analysis.runner import DEFAULT_BASELINE, run_analysis


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis (cache keys, jit purity, "
                    "unit suffixes, backend coverage, docs)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detect from this package)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this file")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: <root>/{DEFAULT_BASELINE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write every current finding into the baseline "
                         "file (with TODO justifications) and exit 0")
    ap.add_argument("--docs", action="store_true",
                    help="also run the DC docs checks (links, anchors, "
                         "rule catalog)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule families to run "
                         f"(default: all of {','.join(FAMILIES)})")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, (title, summary) in sorted(RULES.items()):
            bit = EXIT_BITS[family_of(rid)]
            print(f"{rid}  [{title}] (exit bit {bit})\n      {summary}")
        return 0

    root = Path(args.root) if args.root else _detect_root()
    checks = None
    if args.rules:
        checks = tuple(r.strip().upper() for r in args.rules.split(","))
        bad = [c for c in checks if c not in FAMILIES]
        if bad:
            ap.error(f"unknown rule famil(y/ies) {bad}; valid: {FAMILIES}")
    baseline = Path(args.baseline) if args.baseline else None

    report = run_analysis(root, checks=checks, baseline_path=baseline,
                          with_docs=args.docs)

    if args.write_baseline:
        path = baseline or (root / DEFAULT_BASELINE)
        Baseline.write(path, report.findings + report.baselined)
        print(f"wrote {len(report.findings) + len(report.baselined)} "
              f"entr(y/ies) to {path}")
        return 0

    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            json.dump(report.to_dict(), fh, indent=2)
            fh.write("\n")
    if args.format == "json":
        print(json.dumps(report.to_dict(), indent=2))
    else:
        print(report.format_text())
    return report.exit_code


def _detect_root() -> Path:
    """src/repro/analysis/__main__.py -> repo root three levels up from
    the package directory (works for editable installs and src layouts)."""
    pkg = Path(__file__).resolve().parent
    for cand in (pkg.parents[2], Path.cwd()):
        if (cand / "src" / "repro").is_dir() or (cand / "repro").is_dir():
            return cand
    return Path.cwd()


if __name__ == "__main__":
    sys.exit(main())
