"""``python -m repro.analysis`` — the CI gate.

    python -m repro.analysis                    # human output, exit bitmask
    python -m repro.analysis --format=json      # machine-readable report
    python -m repro.analysis --docs             # + link/anchor/rule-doc checks
    python -m repro.analysis --semantic         # + IR tier (PB/DT/RC; needs jax)
    python -m repro.analysis --rules CK,US      # restrict to families
    python -m repro.analysis --write-baseline   # snapshot current findings
    python -m repro.analysis --prune-baseline   # drop stale baseline entries
    python -m repro.analysis --list-rules       # rule catalog

Exit code is the OR of the family bits (CK=1 JP=2 US=4 BK=8 DC=16 PB=32
DT=64 RC=128) of every *active* finding — 0 means clean against the
committed baseline. The default run is AST-only and jax-free (pre-commit
safe); ``--semantic`` adds the traced-IR tier and belongs in CI.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.findings import Baseline
from repro.analysis.rules import EXIT_BITS, FAMILIES, RULES, family_of
from repro.analysis.runner import DEFAULT_BASELINE, run_analysis


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis (cache keys, jit purity, "
                    "unit suffixes, backend coverage, docs)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detect from this package)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this file")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: <root>/{DEFAULT_BASELINE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write every current finding into the baseline "
                         "file (with TODO justifications) and exit 0")
    ap.add_argument("--docs", action="store_true",
                    help="also run the DC docs checks (links, anchors, "
                         "rule catalog)")
    ap.add_argument("--semantic", action="store_true",
                    help="also run the IR-level PB/DT/RC tier (imports jax "
                         "and executes the jit sites — CI-only, slow)")
    ap.add_argument("--prune-baseline", action="store_true",
                    help="rewrite the baseline file dropping entries that "
                         "no longer match any finding of a family that ran "
                         "in this invocation, then exit 0")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule families to run "
                         f"(default: all of {','.join(FAMILIES)})")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, (title, summary) in sorted(RULES.items()):
            bit = EXIT_BITS[family_of(rid)]
            print(f"{rid}  [{title}] (exit bit {bit})\n      {summary}")
        return 0

    root = Path(args.root) if args.root else _detect_root()
    checks = None
    if args.rules:
        checks = tuple(r.strip().upper() for r in args.rules.split(","))
        bad = [c for c in checks if c not in FAMILIES]
        if bad:
            ap.error(f"unknown rule famil(y/ies) {bad}; valid: {FAMILIES}")
    baseline = Path(args.baseline) if args.baseline else None

    report = run_analysis(root, checks=checks, baseline_path=baseline,
                          with_docs=args.docs, with_semantic=args.semantic)

    if args.write_baseline:
        path = baseline or (root / DEFAULT_BASELINE)
        Baseline.write(path, report.findings + report.baselined)
        print(f"wrote {len(report.findings) + len(report.baselined)} "
              f"entr(y/ies) to {path}")
        return 0

    if args.prune_baseline:
        path = baseline or (root / DEFAULT_BASELINE)
        kept, dropped = prune_baseline(path, report)
        print(f"pruned {dropped} stale entr(y/ies) from {path} "
              f"({kept} kept; families run: "
              f"{','.join(report.families_run) or 'none'})")
        return 0

    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            json.dump(report.to_dict(), fh, indent=2)
            fh.write("\n")
    if args.format == "json":
        print(json.dumps(report.to_dict(), indent=2))
    else:
        print(report.format_text())
    return report.exit_code


def prune_baseline(path, report) -> tuple:
    """Rewrite the baseline at ``path`` dropping stale entries.

    Only entries whose rule family actually *ran* in this invocation are
    prunable — a CK-only run must not delete PB entries it never
    re-checked. Returns (kept, dropped) counts.
    """
    baseline = Baseline.load(path)
    stale_keys = {(e["rule"], e["path"], e.get("snippet", ""))
                  for e in report.stale_baseline
                  if family_of(e["rule"]) in report.families_run}
    keep = [e for e in baseline.entries
            if (e["rule"], e["path"], e.get("snippet", "")) not in stale_keys]
    dropped = len(baseline.entries) - len(keep)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"entries": keep}, fh, indent=2)
        fh.write("\n")
    return len(keep), dropped


def _detect_root() -> Path:
    """src/repro/analysis/__main__.py -> repo root three levels up from
    the package directory (works for editable installs and src layouts)."""
    pkg = Path(__file__).resolve().parent
    for cand in (pkg.parents[2], Path.cwd()):
        if (cand / "src" / "repro").is_dir() or (cand / "repro").is_dir():
            return cand
    return Path.cwd()


if __name__ == "__main__":
    sys.exit(main())
