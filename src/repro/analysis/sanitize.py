"""Opt-in runtime sanitizer: checkify wrapping of the numeric entry points.

The static tiers prove structural properties; this shim catches the
*value-level* failures they cannot — NaN/Inf appearing mid-pipeline and
out-of-bounds gathers — by running the characterization / composition /
simulation entry points under ``jax.experimental.checkify`` with
``nan_checks | index_checks``. First error wins and raises
``JaxRuntimeError`` with the offending primitive's traceback.

Two switches, innermost wins:

  ``REPRO_SANITIZE=1``            process-wide (the opt-in CI job)
  ``Compiler(sanitize=True)``     per-instance, via ``enabled_scope``

Off (the default) the wrapped entry points call the original jitted
functions untouched — zero overhead, bit-identical results. On, checkify
re-traces with error plumbing threaded through, so outputs stay numerically
identical but compile caches are separate; never enable it under the RC
recompilation audit.
"""
from __future__ import annotations

import contextlib
import functools
import os
from typing import List, Optional

_FORCED: List[bool] = []     # enabled_scope() overrides, innermost last


def enabled(explicit: Optional[bool] = None) -> bool:
    """Is the sanitizer on? ``explicit`` beats scopes beats the env var."""
    if explicit is not None:
        return bool(explicit)
    if _FORCED:
        return _FORCED[-1]
    return os.environ.get("REPRO_SANITIZE") == "1"


@contextlib.contextmanager
def enabled_scope(on: bool = True):
    """Force the sanitizer on/off inside the block (nests; innermost wins)."""
    _FORCED.append(bool(on))
    try:
        yield
    finally:
        _FORCED.pop()


def wrap(fn):
    """Checkify ``fn`` (nan + index errors) and raise on the first hit.

    The wrapper keeps ``fn``'s signature and return value; the checkify
    error is consumed by ``throw()`` so callers never see the (err, out)
    pair.
    """
    from jax.experimental import checkify
    checked = checkify.checkify(
        fn, errors=checkify.nan_checks | checkify.index_checks)

    @functools.wraps(fn)
    def sanitized(*args, **kwargs):
        err, out = checked(*args, **kwargs)
        err.throw()
        return out

    sanitized.__sanitized__ = True
    return sanitized


def maybe_wrap(fn, explicit: Optional[bool] = None):
    """``wrap(fn)`` when the sanitizer is enabled, else ``fn`` unchanged."""
    return wrap(fn) if enabled(explicit) else fn
