"""Rule registry of the repo-specific static analyzer.

Pure data, stdlib-only, no intra-package imports: ``scripts/check_docs.py``
loads this file standalone (importlib, no ``repro`` package import) so the
rule-ID documentation check runs even in environments without the package
installed.

Rule IDs group into families by two-letter prefix; the family prefix is also
the unit of the CLI exit-code bitmask (see ``EXIT_BITS``):

  CK  cache-key completeness    (policy fields / ingredients -> cache keys)
  JP  jit purity / host sync    (functions reachable under jit/vmap/scan)
  US  unit-suffix convention    (physics-layer naming + unit algebra)
  BK  backend-registry coverage (kernels.backend ops: impls + tests)
  DC  docs                      (intra-repo links, anchors, rule catalog)

Semantic tier (``--semantic``, imports jax — CI-only, never pre-commit):

  PB  Pallas block verifier     (BlockSpec index maps proved over the grid)
  DT  dtype / weak-type drift   (jaxprs of the jit entry points vs policy)
  RC  recompilation cavity      (trace-cache growth vs committed budgets)
"""
from __future__ import annotations

# id -> (title, one-line description)
RULES = {
    "CK01": ("policy-field-not-keyed",
             "a field of a policy dataclass does not flow into the cache-key "
             "construction that fingerprints it"),
    "CK02": ("key-param-unused",
             "a parameter of a cache-key function is never read in its body "
             "(an input that cannot affect the key)"),
    "CK03": ("key-ingredient-missing",
             "a cache-key function no longer references a required "
             "ingredient (e.g. grid_hash without corners_fingerprint)"),
    "CK04": ("physics-fingerprint-drift",
             "a module in the import closure of the characterization "
             "pipeline is not hashed by _physics_fingerprint"),
    "CK05": ("key-spec-target-missing",
             "a file/function/class named by the cache-key checker spec "
             "does not exist (the analyzer spec rotted)"),
    "JP01": ("jit-side-effect",
             "Python side effect (print/open/input/global/os.environ write) "
             "in a function reachable under jit/vmap/scan"),
    "JP02": ("jit-host-sync",
             ".item()/.tolist()/float()/int()/bool()/np.asarray on a traced "
             "value in a jit-reachable function (forces a device sync)"),
    "JP03": ("jit-data-dependent-branch",
             "Python if/while branching on a traced value in a jit-reachable "
             "function (TracerBoolConversionError at trace time)"),
    "JP04": ("jit-unhashable-static-arg",
             "a parameter declared static via static_argnums/static_argnames "
             "has an unhashable (list/dict/set) default"),
    "US01": ("unit-suffix-missing",
             "a physics binding (t_/e_/p_/f_/i_/l_/c_/r_/v_ prefix, or a "
             "quantity with an inferable unit) lacks a unit suffix"),
    "US02": ("unit-mix",
             "arithmetic (+/-, comparison, min/max) mixes incompatible unit "
             "suffixes, e.g. adding _w to _j"),
    "US03": ("unit-suffix-conflict",
             "a binding's unit suffix conflicts with the unit inferred from "
             "its right-hand side (or with its prefix convention)"),
    "BK01": ("backend-missing-interpret",
             "an op registered in kernels.backend has no 'interpret' "
             "implementation (no oracle to prove the tpu path against)"),
    "BK02": ("backend-missing-xla",
             "an op registered in kernels.backend has no 'xla' "
             "implementation (no CPU fallback path)"),
    "BK03": ("backend-op-untested",
             "an op registered in kernels.backend is not referenced by any "
             "test (no bit-exactness proof exercises it)"),
    "DC01": ("doc-broken-link",
             "a markdown link targets a file that does not exist"),
    "DC02": ("doc-broken-anchor",
             "a markdown link targets a #anchor with no matching heading"),
    "DC03": ("rule-undocumented",
             "an analyzer rule ID is not documented in docs/ANALYSIS.md"),
    "DC04": ("obs-name-undocumented",
             "a repro.obs catalog entry (span/metric name) is not documented "
             "in docs/OBSERVABILITY.md"),
    "PB01": ("pallas-block-out-of-bounds",
             "a BlockSpec index_map addresses a block outside the (padded) "
             "operand for some point of the launch grid"),
    "PB02": ("pallas-output-gap",
             "the output BlockSpec does not tile the output exactly — some "
             "output block is never written by any grid point"),
    "PB03": ("pallas-output-race",
             "two grid points differing in a 'parallel' grid axis write the "
             "same output block (a write race; revisits are only legal "
             "along 'arbitrary' axes)"),
    "PB04": ("pallas-grid-order-mismatch",
             "grid-axis ordering is inconsistent: dimension_semantics / "
             "index_map arity differs from the grid, or a grid axis maps "
             "identity-style onto a block dim whose block count differs "
             "from the axis extent"),
    "PB05": ("pallas-op-unprofiled",
             "an op registered with a tpu impl has no PB shape profile (or "
             "a profiled op/function no longer exists — the spec rotted)"),
    "DT01": ("dtype-policy-violation",
             "a traced jit entry point manufactures a dtype outside the "
             "declared policy (float64/float16/complex promotion)"),
    "DT02": ("weak-type-output",
             "a jit entry point returns a weak-typed float — a Python "
             "scalar leaked through and the output dtype is "
             "promotion-fragile"),
    "DT03": ("int-accumulation-overflow",
             "an integer accumulation (reduce_sum/cumsum/dot) runs in a "
             "sub-32-bit dtype — overflow-prone at benchmark sizes"),
    "DT04": ("dt-spec-rot",
             "a DT entry-point spec no longer resolves (module/attr gone or "
             "drive inputs fail to build) — the checker silently lost "
             "coverage"),
    "RC01": ("recompile-budget-exceeded",
             "driving a jit site with its benchmark (shape, static-arg) "
             "profiles grew the trace cache beyond the committed budget"),
    "RC02": ("cache-thrash-on-repeat",
             "re-driving a jit site with identical profiles added new trace "
             "cache entries — the cache key is unstable (static-arg leak)"),
    "RC03": ("jit-site-unbudgeted",
             "a module-level jax.jit site in core/hetero/sim is not covered "
             "by the RC budget spec — its compile count is unwatched"),
    "RC04": ("rc-spec-rot",
             "an RC budget-spec entry no longer resolves (module/attr gone, "
             "no cache-size API, or the driver failed)"),
}

FAMILIES = ("CK", "JP", "US", "BK", "DC", "PB", "DT", "RC")

# exit-code bitmask per family: the CLI exits with the OR of the bits of
# every family that produced at least one active (unsuppressed, unbaselined)
# finding. 0 = clean.
EXIT_BITS = {"CK": 1, "JP": 2, "US": 4, "BK": 8, "DC": 16,
             "PB": 32, "DT": 64, "RC": 128}


def family_of(rule_id: str) -> str:
    return rule_id[:2]


def is_known(rule_id: str) -> bool:
    return rule_id in RULES
