"""Rule registry of the repo-specific static analyzer.

Pure data, stdlib-only, no intra-package imports: ``scripts/check_docs.py``
loads this file standalone (importlib, no ``repro`` package import) so the
rule-ID documentation check runs even in environments without the package
installed.

Rule IDs group into families by two-letter prefix; the family prefix is also
the unit of the CLI exit-code bitmask (see ``EXIT_BITS``):

  CK  cache-key completeness    (policy fields / ingredients -> cache keys)
  JP  jit purity / host sync    (functions reachable under jit/vmap/scan)
  US  unit-suffix convention    (physics-layer naming + unit algebra)
  BK  backend-registry coverage (kernels.backend ops: impls + tests)
  DC  docs                      (intra-repo links, anchors, rule catalog)
"""
from __future__ import annotations

# id -> (title, one-line description)
RULES = {
    "CK01": ("policy-field-not-keyed",
             "a field of a policy dataclass does not flow into the cache-key "
             "construction that fingerprints it"),
    "CK02": ("key-param-unused",
             "a parameter of a cache-key function is never read in its body "
             "(an input that cannot affect the key)"),
    "CK03": ("key-ingredient-missing",
             "a cache-key function no longer references a required "
             "ingredient (e.g. grid_hash without corners_fingerprint)"),
    "CK04": ("physics-fingerprint-drift",
             "a module in the import closure of the characterization "
             "pipeline is not hashed by _physics_fingerprint"),
    "CK05": ("key-spec-target-missing",
             "a file/function/class named by the cache-key checker spec "
             "does not exist (the analyzer spec rotted)"),
    "JP01": ("jit-side-effect",
             "Python side effect (print/open/input/global/os.environ write) "
             "in a function reachable under jit/vmap/scan"),
    "JP02": ("jit-host-sync",
             ".item()/.tolist()/float()/int()/bool()/np.asarray on a traced "
             "value in a jit-reachable function (forces a device sync)"),
    "JP03": ("jit-data-dependent-branch",
             "Python if/while branching on a traced value in a jit-reachable "
             "function (TracerBoolConversionError at trace time)"),
    "JP04": ("jit-unhashable-static-arg",
             "a parameter declared static via static_argnums/static_argnames "
             "has an unhashable (list/dict/set) default"),
    "US01": ("unit-suffix-missing",
             "a physics binding (t_/e_/p_/f_/i_/l_/c_/r_/v_ prefix, or a "
             "quantity with an inferable unit) lacks a unit suffix"),
    "US02": ("unit-mix",
             "arithmetic (+/-, comparison, min/max) mixes incompatible unit "
             "suffixes, e.g. adding _w to _j"),
    "US03": ("unit-suffix-conflict",
             "a binding's unit suffix conflicts with the unit inferred from "
             "its right-hand side (or with its prefix convention)"),
    "BK01": ("backend-missing-interpret",
             "an op registered in kernels.backend has no 'interpret' "
             "implementation (no oracle to prove the tpu path against)"),
    "BK02": ("backend-missing-xla",
             "an op registered in kernels.backend has no 'xla' "
             "implementation (no CPU fallback path)"),
    "BK03": ("backend-op-untested",
             "an op registered in kernels.backend is not referenced by any "
             "test (no bit-exactness proof exercises it)"),
    "DC01": ("doc-broken-link",
             "a markdown link targets a file that does not exist"),
    "DC02": ("doc-broken-anchor",
             "a markdown link targets a #anchor with no matching heading"),
    "DC03": ("rule-undocumented",
             "an analyzer rule ID is not documented in docs/ANALYSIS.md"),
}

FAMILIES = ("CK", "JP", "US", "BK", "DC")

# exit-code bitmask per family: the CLI exits with the OR of the bits of
# every family that produced at least one active (unsuppressed, unbaselined)
# finding. 0 = clean.
EXIT_BITS = {"CK": 1, "JP": 2, "US": 4, "BK": 8, "DC": 16}


def family_of(rule_id: str) -> str:
    return rule_id[:2]


def is_known(rule_id: str) -> bool:
    return rule_id in RULES
