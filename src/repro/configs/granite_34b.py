"""granite-34b [dense] — 88L d_model=6144 48H (GQA kv=1 / MQA) d_ff=24576 vocab=49152.

llama-arch code model. d_ff = 4x d_model => non-gated (gelu) MLP.
[arXiv:2405.04324; hf]
"""
from repro.configs.base import ArchConfig, register


@register("granite-34b")
def config() -> ArchConfig:
    return ArchConfig(
        name="granite-34b",
        family="dense",
        num_layers=88,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,        # MQA
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        qk_norm=False,
        rope_theta=10_000.0,
        mlp_type="gelu",
        source="arXiv:2405.04324; hf",
    )
