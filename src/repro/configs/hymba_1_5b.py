"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16. Parallel attention + mamba heads in every block, 128 meta tokens,
SWA everywhere except 3 global-attention layers. [arXiv:2411.13676; hf]
"""
from repro.configs.base import ArchConfig, register


@register("hymba-1.5b")
def config() -> ArchConfig:
    return ArchConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        rope_theta=10_000.0,
        window=1024,
        full_attn_every=(0, 15, 31),
        ssm_state=16,
        ssm_expand=2,
        conv_width=4,
        meta_tokens=128,
        mlp_type="swiglu",
        supports_long_context=True,   # SWA + SSM: cache is window-bounded
        source="arXiv:2411.13676; hf",
    )
