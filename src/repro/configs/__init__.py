"""Config registry: importing this package registers all assigned architectures."""
from repro.configs.base import (  # noqa: F401
    ArchConfig,
    ShapeConfig,
    SHAPES,
    applicable_shapes,
    get_config,
    list_archs,
    reduce_config,
)

# side-effect registration of the 10 assigned architectures
from repro.configs import (  # noqa: F401
    qwen3_32b,
    qwen3_8b,
    granite_34b,
    internlm2_1_8b,
    deepseek_v3_671b,
    moonshot_v1_16b_a3b,
    hymba_1_5b,
    xlstm_125m,
    phi3_vision_4_2b,
    musicgen_medium,
)

ALL_ARCHS = list_archs()
