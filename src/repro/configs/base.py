"""Architecture + shape configuration registry.

Every assigned architecture is a frozen ``ArchConfig``; the four input-shape
cells are ``ShapeConfig``s. ``reduce_config`` produces the structurally
faithful but tiny config used by CPU smoke tests; the FULL configs are only
ever lowered via ShapeDtypeStructs in the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, Tuple

# ---------------------------------------------------------------------------
# Shapes (assigned cells)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    window: int = 1024          # SWA window (hybrid family)
    attn_chunk: int = 512       # query/kv chunk for blocked attention
    full_attn_every: Tuple[int, ...] = ()   # layer indices with full (non-SWA) attn

    # MoE
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_gate: str = "sigmoid"  # sigmoid (deepseek-style) | softmax

    # MLA (deepseek)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False           # multi-token-prediction module (1 extra depth)

    # SSM / hybrid / xlstm
    ssm_state: int = 0
    ssm_expand: int = 2
    conv_width: int = 4
    meta_tokens: int = 0
    slstm_layers: Tuple[int, ...] = ()

    # multimodal stubs
    vision: bool = False
    num_patches: int = 0
    vision_dim: int = 0
    audio_codebooks: int = 0
    cross_attn: bool = False
    cond_len: int = 0
    cond_dim: int = 0

    mlp_type: str = "swiglu"    # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # which shape cells are applicable (long_500k only for sub-quadratic archs)
    supports_long_context: bool = False

    # citation tier from the assignment table
    source: str = ""

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# registry -------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ArchConfig:
    # import side-effect registration
    from repro import configs as _c  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)


def applicable_shapes(cfg: ArchConfig):
    """Shape cells that are live for this architecture (skips per DESIGN.md §4)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue
        out.append(s)
    return out


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------


def reduce_config(cfg: ArchConfig) -> ArchConfig:
    """Structurally faithful, tiny version of ``cfg`` for CPU smoke tests."""
    kw = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=256,
        dtype="float32",
        attn_chunk=16,
        window=16,
        meta_tokens=4 if cfg.meta_tokens else 0,
    )
    if cfg.num_kv_heads == 1:
        kw["num_kv_heads"] = 1
    if cfg.moe:
        # capacity_factor 16 => provably dropless at smoke scale (C >= N),
        # so decode-vs-prefill consistency is exact; full configs keep 1.25
        kw.update(num_experts=8, top_k=2, moe_d_ff=32, capacity_factor=16.0,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  first_dense_layers=1 if cfg.first_dense_layers else 0)
    if cfg.mla:
        kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                  v_head_dim=16)
    if cfg.full_attn_every:
        kw["full_attn_every"] = (0, kw["num_layers"] - 1)
    if cfg.slstm_layers:
        kw["slstm_layers"] = (1,)
    if cfg.ssm_state:
        kw.update(ssm_state=8, ssm_expand=2, conv_width=4)
    if cfg.vision:
        kw.update(num_patches=8, vision_dim=32)
    if cfg.cross_attn:
        kw.update(cond_len=8, cond_dim=32)
    return cfg.replace(**kw)
