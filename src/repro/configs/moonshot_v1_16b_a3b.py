"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (GQA kv=16) d_ff=1408(expert)
vocab=163840, MoE 64e top-6 (kimi/moonlight style: 1 shared + 64 routed).

[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.configs.base import ArchConfig, register


@register("moonshot-v1-16b-a3b")
def config() -> ArchConfig:
    return ArchConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=11264,            # dense first layer (8x expert width)
        vocab_size=163840,
        rope_theta=50_000.0,
        moe=True,
        num_experts=64,
        top_k=6,
        moe_d_ff=1408,
        n_shared_experts=1,
        first_dense_layers=1,
        router_gate="sigmoid",
        mlp_type="swiglu",
        source="hf:moonshotai/Moonlight-16B-A3B; hf",
    )
