"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff=2048(expert) vocab=129280.

MLA attention (q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128),
MoE: 1 shared + 256 routed, top-8, sigmoid gating; first 3 layers dense
(d_ff 18432); MTP module. [arXiv:2412.19437; hf]
"""
from repro.configs.base import ArchConfig, register


@register("deepseek-v3-671b")
def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-671b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,      # MLA: per-head k/v expanded from the latent
        head_dim=128,          # v head dim; qk uses nope+rope = 192
        d_ff=18432,            # dense layers (first 3)
        vocab_size=129280,
        rope_theta=10_000.0,
        moe=True,
        num_experts=256,
        top_k=8,
        moe_d_ff=2048,
        n_shared_experts=1,
        first_dense_layers=3,
        router_gate="sigmoid",
        mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        mtp=True,
        mlp_type="swiglu",
        source="arXiv:2412.19437; hf",
    )
