"""musicgen-medium [audio] — 48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.

Decoder-only over EnCodec tokens: 4 codebooks (delay pattern), summed codebook
embeddings, 4 output heads; cross-attention to a text-conditioning STUB
(``input_specs()`` provides precomputed T5-style embeddings). [arXiv:2306.05284; hf]
"""
from repro.configs.base import ArchConfig, register


@register("musicgen-medium")
def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        rope_theta=10_000.0,
        audio_codebooks=4,
        cross_attn=True,
        cond_len=64,
        cond_dim=768,
        mlp_type="gelu",       # MusicGen uses non-gated transformer FFN
        source="arXiv:2306.05284; hf",
    )
