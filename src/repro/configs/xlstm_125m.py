"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks (7:1-ish ratio -> sLSTM at blocks {2, 8}); mLSTM blocks
carry their own 2x up-projection, sLSTM blocks are followed by a gated FFN,
so d_ff=0 in the table. [arXiv:2405.04517; unverified]
"""
from repro.configs.base import ArchConfig, register


@register("xlstm-125m")
def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        head_dim=192,          # d_model / heads
        d_ff=0,
        vocab_size=50304,
        slstm_layers=(2, 8),
        mlp_type="swiglu",
        supports_long_context=True,   # pure recurrent state, O(1) cache
        source="arXiv:2405.04517; unverified",
    )
