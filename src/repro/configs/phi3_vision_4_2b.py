"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064.

phi3-mini backbone + CLIP frontend STUB: ``input_specs()`` provides
precomputed patch embeddings (B, num_patches, vision_dim); a 2-layer MLP
projector maps them into the backbone. [hf:microsoft/Phi-3-vision-128k-instruct; hf]
"""
from repro.configs.base import ArchConfig, register


@register("phi-3-vision-4.2b")
def config() -> ArchConfig:
    return ArchConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab_size=32064,
        rope_theta=10_000.0,
        vision=True,
        num_patches=576,       # CLIP ViT-L/14 @ 336px
        vision_dim=1024,
        mlp_type="swiglu",
        source="hf:microsoft/Phi-3-vision-128k-instruct; hf",
    )
