"""Trace-driven heterogeneous memory simulator (`repro.sim`).

The analytic composition engine (``repro.hetero``) prices refresh and dynamic
power as *steady-state averages* — it never replays a workload against a
composed memory system over time, so phase-dependent effects are invisible to
it: prefill fills a KV slot while decode only reads it back, refresh pulses
collide with demand accesses at the bank ports, and data whose lifetime
outruns a gain cell's retention must be rewritten. This subsystem is the
time-resolved layer between the profiler and the compose engine:

``trace``
    converts a ``TaskReq`` (and, via ``repro.profiler.traffic.arch_traces``,
    compiled dry-run records) into time-binned traffic traces per phase —
    prefill / decode / train-step — with per-slot reads [accesses], written
    bits, and live-capacity occupancy per bin.
``refresh``
    derives per-macro refresh intervals from the ``core.retention`` solver's
    ``retention_s`` metric (interval = margin × retention) and the refresh
    op rates the scheduler issues against them.
``engine``
    a batched ``jax.lax.scan`` over time bins that models per-bank
    refresh/access port collisions, dynamic access energy, retention-expiry
    rewrites, and occupancy — vmapped over the full (J compositions × S
    slots) grid so thousands of candidate systems simulate in one call,
    dispatched through the ``repro.kernels.backend`` registry (op
    ``sim_replay``: "xla" vmapped scan, "interpret" per-composition loop).
``rerank``
    simulate-then-rerank DSE: prune analytically to top-K with
    ``repro.hetero.compose``, replay the traces against the survivors, and
    re-rank by simulated energy/latency (``compose(refine="simulate")`` /
    ``Compiler.simulate``), with npz trace-report caching beside the hetero
    cache.
"""
from repro.sim.engine import (SIM_METRICS, SimPolicy, sim_eval_count,
                              simulate_traces)
from repro.sim.refresh import (DEFAULT_REFRESH_MARGIN, refresh_interval_s,
                               refresh_intervals)
from repro.sim.rerank import simulate_report
from repro.sim.trace import PHASES, Trace, phase_trace, task_traces

__all__ = [
    "PHASES", "Trace", "phase_trace", "task_traces",
    "DEFAULT_REFRESH_MARGIN", "refresh_interval_s", "refresh_intervals",
    "SIM_METRICS", "SimPolicy", "simulate_traces", "sim_eval_count",
    "simulate_report",
]
