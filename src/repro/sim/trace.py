"""Time-binned traffic traces per workload phase.

A ``Trace`` is the simulator's input normal form: for one phase (prefill /
decode / train-step) and one task, it bins the phase's duration into ``T``
equal time bins and gives every (level, bucket) *slot* — same slot order as
``repro.hetero.compose``: levels in task order, buckets in bucket order —

``reads``       demand read accesses per slot per bin [accesses]
``write_bits``  bits written per slot per bin [bits] (turnover + fills)
``occupancy``   fraction of the slot's capacity holding live data [0..1]

The totals are anchored to the same numbers the analytic scorer prices: the
read volume of every slot integrates to ``f_hz × duration`` in every phase
(``Σ_t reads[s, t] == bucket.f_hz * duration_s``), so a flat trace replayed
through the simulator recovers the steady-state dynamic energy
``e_read_j * f_hz`` — the phases only *shape* the traffic in time.

Phase envelopes (over normalized time ``x ∈ [0, 1)``; "long-lived" means the
bucket's lifetime reaches the phase duration — KV cache and weights; all
other buckets are "short-lived" — activations, partials):

``prefill``     long-lived occupancy ramps 0→1 (the KV/weight slot fills);
                its reads ramp with the fill (``2x``, mean 1); short-lived
                slots run flat.
``decode``      steady state: everything flat at full occupancy.
``train_step``  short-lived occupancy triangles 0→1→0 (forward produces
                residuals, backward consumes them); its reads weight 0.8 in
                the forward half and 1.2 in the backward half (mean 1);
                long-lived slots run flat.

Write volume is a line-granular turnover model: live data turns over once
per bucket lifetime (``occupancy × cap_bits × t_bin / lifetime_s`` bits per
bin), plus fill writes for any occupancy *increase* between bins
(``Δocc⁺ × cap_bits``). Hour-lived weights therefore write ≈ nothing during
a phase, microsecond-lived activations rewrite constantly — exactly the
asymmetry the analytic average can't see. The engine converts bits to port
accesses with each macro's own word width.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Sequence, Tuple

import numpy as np

from repro.core.select import TaskReq, as_task_req

PHASES: Tuple[str, ...] = ("prefill", "decode", "train_step")

# default replay window [s]: long enough that ms-lived buckets turn over,
# short enough that hour-lived weights stay still
DEFAULT_DURATION_S = 1e-3
DEFAULT_N_BINS = 32


@dataclass(frozen=True)
class Trace:
    """One phase's time-binned traffic for every slot of a task.

    Arrays are float64 numpy; shapes ``(S, T)`` for per-slot-per-bin fields,
    ``(T,)`` for ``t_bin_s`` (bin durations [s]) and ``(S,)`` for the slot
    requirement vectors (``cap_bits`` [bits], ``f_req_hz`` [Hz],
    ``lifetime_s`` [s]).
    """
    phase: str
    t_bin_s: np.ndarray
    reads: np.ndarray
    write_bits: np.ndarray
    occupancy: np.ndarray
    cap_bits: np.ndarray
    f_req_hz: np.ndarray
    lifetime_s: np.ndarray

    @property
    def n_slots(self) -> int:
        return int(self.reads.shape[0])

    @property
    def n_bins(self) -> int:
        return int(self.reads.shape[1])

    @property
    def duration_s(self) -> float:
        return float(self.t_bin_s.sum())

    def fingerprint(self) -> str:
        """16-hex content hash — part of the sim-report cache key."""
        h = hashlib.sha256(self.phase.encode())
        for a in (self.t_bin_s, self.reads, self.write_bits, self.occupancy,
                  self.cap_bits, self.f_req_hz, self.lifetime_s):
            h.update(np.ascontiguousarray(a, np.float64).tobytes())
        return h.hexdigest()[:16]


def task_slots(task: TaskReq):
    """``(cap_bits, f_hz, lifetime_s)`` arrays in compose slot order
    (levels in task order, buckets in bucket order)."""
    cap, f, life = [], [], []
    for level in task.levels.values():
        for b in level.buckets:
            cap.append(level.capacity_bits * b.frac)
            f.append(b.f_hz)
            life.append(b.lifetime_s)
    return (np.asarray(cap, np.float64), np.asarray(f, np.float64),
            np.asarray(life, np.float64))


def _envelopes(phase: str, x: np.ndarray, long_lived: np.ndarray):
    """(occupancy (S, T), read envelope (S, T)) for bin centers ``x``."""
    S, T = long_lived.shape[0], x.shape[0]
    occ = np.ones((S, T))
    env = np.ones((S, T))
    ll = long_lived[:, None]
    if phase == "prefill":
        occ = np.where(ll, np.broadcast_to(x, (S, T)) + 0.5 / T, occ)
        env = np.where(ll, 2.0 * np.broadcast_to(x, (S, T)) + 1.0 / T, env)
    elif phase == "train_step":
        tri = np.where(x < 0.5, 2.0 * x, 2.0 * (1.0 - x)) + 0.5 / T
        occ = np.where(~ll, np.broadcast_to(tri, (S, T)), occ)
        fwd_bwd = np.where(x < 0.5, 0.8, 1.2)
        env = np.where(~ll, np.broadcast_to(fwd_bwd, (S, T)), env)
    elif phase != "decode":
        raise ValueError(f"unknown phase {phase!r}; choose from {PHASES}")
    return np.clip(occ, 0.0, 1.0), env


def phase_trace(task, phase: str, duration_s: float = DEFAULT_DURATION_S,
                n_bins: int = DEFAULT_N_BINS) -> Trace:
    """Bin one phase of ``task`` into a ``Trace`` (see module docstring).

    ``task`` is anything ``repro.core.select.as_task_req`` understands;
    ``duration_s`` is the replayed wall-clock window [s], split into
    ``n_bins`` equal bins.
    """
    if n_bins < 1:
        raise ValueError(f"n_bins must be >= 1, got {n_bins}")
    task = as_task_req(task)
    cap, f_req, life = task_slots(task)
    T = int(n_bins)
    t_bin = np.full(T, duration_s / T, np.float64)
    x = (np.arange(T) + 0.5) / T                     # bin centers in [0, 1)
    long_lived = life >= duration_s
    occ, env = _envelopes(phase, x, long_lived)
    # normalize the read envelope so Σ reads == f_hz * duration exactly
    env = env / np.maximum(env.mean(axis=1, keepdims=True), 1e-30)
    reads = f_req[:, None] * t_bin[None, :] * env
    turnover = occ * cap[:, None] * t_bin[None, :] / life[:, None]
    # fills: only in-phase occupancy INCREASES write (decode inherits its
    # warm KV slot from prefill — no phantom first-bin fill)
    d_occ = np.diff(occ, axis=1, prepend=occ[:, :1])
    fills = np.maximum(d_occ, 0.0) * cap[:, None]
    return Trace(phase=phase, t_bin_s=t_bin, reads=reads,
                 write_bits=turnover + fills, occupancy=occ,
                 cap_bits=cap, f_req_hz=f_req, lifetime_s=life)


def task_traces(task, phases: Sequence[str] = ("prefill", "decode"),
                duration_s: float = DEFAULT_DURATION_S,
                n_bins: int = DEFAULT_N_BINS) -> Tuple[Trace, ...]:
    """One ``Trace`` per phase, all over the same slot order and window."""
    return tuple(phase_trace(task, p, duration_s=duration_s, n_bins=n_bins)
                 for p in phases)
