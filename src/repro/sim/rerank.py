"""Simulate-then-rerank DSE: replay traces against the analytic top-K.

``repro.hetero.compose`` prunes the composition grid analytically (steady-
state pricing) and materializes its ``top_k`` leaders. This module replays
the task's phase traces (``repro.sim.trace``) against exactly those leaders
with the batched engine (``repro.sim.engine``) and re-ranks them by
*simulated* energy/latency — the re-rank can only permute the analytic
top-K, never introduce or drop a composition, so the analytic pruning
guarantees still hold.

Ranking is a **refinement**, not a replacement, of the compose objective:
the simulated keys substitute for the analytic steady-state tiebreaks but
the objective's primary structure stays —

- ``objective="preference"`` (paper parity): infeasibility, then preference-
  rank sum — which has a *unique* minimizer in ``per_family_best`` mode —
  then the simulated key. The Table-2 winner therefore cannot be overturned
  at default settings; simulation refines the ordering of the runners-up.
- ``objective="power"``: the simulated energy replaces the analytic ``p_w``
  as the power key (this is where replay genuinely re-decides).
- ``objective="area"``: analytic area stays primary; simulation breaks ties.
- ``objective="balanced"``: the blend's power term becomes the simulated
  key.

Reports are cached as ``sim_<key>.npz`` beside the hetero report cache
(``repro.hetero.cache``); a cache hit re-runs neither the trace replay
(proved by ``repro.sim.engine.sim_eval_count``) nor, upstream, the vmap
characterization or analytic scoring.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro import obs
from repro.hetero import cache as hcache
from repro.hetero.compose import CompositionReport
from repro.sim.engine import SIM_METRICS, SimPolicy, simulate_traces
from repro.sim.trace import Trace, task_traces

# sim-report cache traffic (repro.obs registry; a hit proves the repeat
# simulate() re-ran no trace replay — see sim.engine.sim_eval_count)
_C_CACHE_HIT = obs.counter("sim.cache_hits")
_C_CACHE_MISS = obs.counter("sim.cache_misses")


def composition_idx(report: CompositionReport) -> np.ndarray:
    """(K, S) int32 table-row matrix of the report's ranked compositions,
    in compose slot order (levels in task order, buckets in order)."""
    rows = []
    for c in report.ranked:
        row = [p.config_idx for name in report.task.levels
               for p in c.levels[name].picks]
        rows.append(row)
    return np.asarray(rows, np.int32)


def sim_cols(table) -> Dict[str, np.ndarray]:
    """Engine input columns for a DesignTable: metrics + the word width
    axis (``word_bits``) the bits→accesses conversion needs."""
    return {**table.metrics,
            "word_bits": np.asarray(table["word_size"], np.float64)}


def _finite(a: np.ndarray) -> np.ndarray:
    return np.nan_to_num(np.asarray(a, np.float64),
                         posinf=np.finfo(np.float64).max)


def _rerank_order(report: CompositionReport, sim: Dict[str, np.ndarray],
                  policy: SimPolicy) -> np.ndarray:
    """Best-first permutation of the ranked list under the simulated keys
    (see module docstring for the per-objective structure)."""
    infeas = np.array([not c.feasible for c in report.ranked], np.int64)
    rank_sum = np.array([c.pref_rank for c in report.ranked], np.int64)
    area = _finite([c.metrics["area_um2"] for c in report.ranked])
    e = _finite(sim["e_total_j"])
    t = _finite(sim["t_sim_s"])
    prim = {"energy": e, "latency": t, "edp": e * t}[policy.objective]
    sec = t if policy.objective != "latency" else e
    cobj = report.compose_policy.objective
    if cobj == "preference":
        keys = (area, sec, prim, rank_sum, infeas)
    elif cobj == "power":
        keys = (area, sec, prim, infeas)
    elif cobj == "area":
        keys = (sec, prim, area, infeas)
    else:                                            # balanced
        feas = infeas == 0
        a0 = max(float(area[feas].min() if feas.any() else area.min()), 1e-30)
        p0 = max(float(prim[feas].min() if feas.any() else prim.min()), 1e-30)
        keys = (area / a0 + prim / p0, infeas)
    return np.lexsort(keys)


def _apply(report: CompositionReport, sim: Dict[str, np.ndarray],
           order: np.ndarray) -> CompositionReport:
    ranked = tuple(
        dataclasses.replace(
            report.ranked[int(j)],
            metrics={**report.ranked[int(j)].metrics,
                     **{f"sim_{m}": float(sim[m][int(j)])
                        for m in SIM_METRICS}})
        for j in order)
    return dataclasses.replace(report, ranked=ranked, refined="simulate")


def simulate_report(report: CompositionReport,
                    sim_policy: Optional[SimPolicy] = None,
                    traces: Optional[Sequence[Trace]] = None,
                    cache=None,
                    backend: Optional[str] = None) -> CompositionReport:
    """Re-rank ``report.ranked`` by trace replay (see module docstring).

    ``traces`` overrides the task-derived phase traces (e.g. dry-run-derived
    traces from ``repro.profiler.traffic.arch_traces``); slot order must
    match the report's task. ``cache`` enables the ``sim_<key>.npz`` report
    cache beside the hetero cache. Returns a new ``CompositionReport`` with
    the same composition set, reordered, each composition's ``metrics``
    extended with the ``sim_*`` keys, and ``refined="simulate"``.
    """
    policy = sim_policy or SimPolicy()
    if traces is None:
        traces = task_traces(report.task, phases=policy.phases,
                             duration_s=policy.duration_s,
                             n_bins=policy.n_bins)
    idx = composition_idx(report)

    with obs.span("sim.rerank", task=str(report.task.task_id),
                  n_ranked=len(report.ranked),
                  objective=policy.objective) as sp:
        key = None
        if cache is not None:
            base = hcache.report_key(report.table.grid_hash, report.task,
                                     report.policy, report.compose_policy,
                                     robust=report.robust)
            key = hcache.sim_report_key(base, policy,
                                        [t.fingerprint() for t in traces])
            hit = hcache.load_sim_report(cache, key,
                                         n_ranked=len(report.ranked))
            if hit is not None:
                _C_CACHE_HIT.inc()
                sp.set(cache="hit")
                return _apply(report, hit["metrics"], hit["order"])
            _C_CACHE_MISS.inc()
            sp.set(cache="miss")

        sim = simulate_traces(sim_cols(report.table), idx, traces,
                              policy=policy, backend=backend)
        order = _rerank_order(report, sim, policy)
        if cache is not None:
            hcache.save_sim_report(cache, key, order,
                                   {m: sim[m] for m in SIM_METRICS},
                                   sim["phases"])
        return _apply(report, sim, order)
