"""Batched trace replay: a ``jax.lax.scan`` over time bins, vmapped over the
full (J compositions × S slots) grid.

For every composition (one DesignTable row per slot) and every time bin of a
``repro.sim.trace.Trace``, the engine models what the analytic scorer
averages away:

- **port collisions**: demand reads/writes, scheduled refresh ops
  (``repro.sim.refresh``), and expiry rewrites all contend for the slot's
  aggregate port capacity ``tiles × f_op_hz × t_bin``; a bin whose total op
  count exceeds it stretches (service time ``t_bin × max(1, utilization)``),
  and the overlap of refresh with demand traffic is reported as
  ``collisions``.
- **dynamic access energy**: ``reads × e_read_j + write_ops × e_write_j``,
  with write bits converted to port accesses by each macro's own word width.
- **refresh energy**: every live word rewritten once per scheduled interval,
  ``(e_read_j + e_write_j)`` per op — only for slots whose data must outlive
  the cell's retention.
- **retention-expiry rewrites**: with refresh *disabled*, the same slots
  lose data at rate ``1/retention_s`` and must rewrite it (at
  ``rewrite_overhead × e_write_j`` per access — the overhead covers the
  upstream re-fetch).
- **occupancy / age**: live data ages with time and is rejuvenated by
  writes; the peak age is reported so callers can see how close a
  composition sails to its retention wall.

Everything per-bin is float32 elementwise arithmetic + per-slot reductions,
so the whole grid runs as ONE ``jit(vmap(scan))`` dispatch. The grid kernel
is registered with ``repro.kernels.backend`` as op ``"sim_replay"``:

  "xla"        the vmapped scan (default everywhere; there is no TPU-only
               path, so TPU hosts fall back here too)
  "interpret"  a per-composition Python loop over the same jitted
               single-composition scan — the bit-exactness oracle the tests
               compare against
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels import backend as _backend
from repro.sim import refresh as refresh_mod
from repro.sim.trace import Trace

# metric columns the engine gathers from a DesignTable, plus the axis-derived
# "word_bits" column (``table["word_size"]``) the caller must add
SIM_COLS = ("bits", "word_bits", "e_read_j", "e_write_j", "f_op_hz",
            "p_leak_w", "retention_s")

# per-composition outputs, in the order the report/caching layers persist
SIM_METRICS = ("e_dyn_j", "e_refresh_j", "e_rewrite_j", "e_leak_j",
               "e_total_j", "t_sim_s", "t_wall_s", "stall_frac",
               "collisions", "util_peak", "age_peak_s", "p_avg_w")

# how many batched trace replays this process has run (a cached
# simulate/rerank leaves it unchanged — same proof pattern as
# api.characterize_call_count / hetero.composition_eval_count); lives on
# the repro.obs metrics registry, read through the thin alias below
_C_REPLAYS = obs.counter("sim.replay_calls")

# temperature-drift Arrhenius baseline: the solver's nominal die temperature
# and activation ratio Ea/kB [K] (Ea = 0.5 eV, matching core.corners)
_T_NOMINAL_K = 300.0
_EA_OVER_KB_K = 0.5 / 8.617333262e-5


def sim_eval_count() -> int:
    """Number of batched trace-replay sweeps executed so far
    (backed by the ``sim.replay_calls`` obs counter)."""
    return _C_REPLAYS.value


@dataclass(frozen=True)
class SimPolicy:
    """How traces are built, replayed, and used to re-rank.

    ``phases``           which phase traces to replay (``repro.sim.trace``
                         envelopes); energies/times sum across phases.
    ``duration_s``       replayed window per phase [s].
    ``n_bins``           time bins per phase.
    ``refresh``          True: schedule refresh at ``refresh_margin ×
                         retention_s``; False: let data expire and pay
                         retention-expiry rewrites instead.
    ``refresh_margin``   interval safety factor on the solver's retention.
    ``rewrite_overhead`` energy multiplier per expiry-rewrite access (the
                         upstream re-fetch the write implies).
    ``objective``        simulated re-rank key: "energy" (total J),
                         "latency" (simulated time incl. stalls), or "edp"
                         (energy × delay). The analytic top-K prune itself
                         is ``ComposePolicy.top_k`` — the re-rank replays
                         exactly the compositions the analytic report
                         materialized.
    ``corner``           operating-corner label (e.g. "hot") whose
                         ``retention_s@<corner>`` column drives refresh
                         intervals, expiry rewrites, and the retention wall
                         — requires a corner-batched DesignTable; None uses
                         the base ``retention_s``.
    ``adaptive_refresh`` True: a per-bank refresh controller that adapts the
                         effective interval to the observed traffic phase —
                         demand writes rejuvenate the words they touch, so
                         each bin's scheduled refresh ops are scaled by
                         ``1 - turnover`` (the fraction of live data the
                         bin's writes already rewrote). Write-heavy phases
                         therefore stretch the refresh duty; read-mostly
                         phases pay the full schedule.
    ``temp_drift_k``     linear die-temperature drift [K] across each phase's
                         replay window (300 K at t=0 → 300+drift at the end).
                         Retention follows the solver's Arrhenius law
                         (Ea=0.5 eV, as ``core.corners``) bin by bin inside
                         the scan, shrinking refresh intervals and
                         accelerating expiry rewrites as the die heats.
                         0.0 (default) replays at constant temperature,
                         bit-identical to the pre-drift engine.
    """
    phases: Tuple[str, ...] = ("prefill", "decode")
    duration_s: float = 1e-3
    n_bins: int = 32
    refresh: bool = True
    refresh_margin: float = refresh_mod.DEFAULT_REFRESH_MARGIN
    rewrite_overhead: float = 2.0
    objective: str = "energy"
    corner: Optional[str] = None
    adaptive_refresh: bool = False
    temp_drift_k: float = 0.0

    def __post_init__(self):
        if self.objective not in ("energy", "latency", "edp"):
            raise ValueError(f"unknown sim objective {self.objective!r}; "
                             f"choose from ('energy', 'latency', 'edp')")
        unknown = set(self.phases) - {"prefill", "decode", "train_step"}
        if unknown:
            raise ValueError(f"unknown phases {sorted(unknown)}")
        refresh_mod._check_margin(self.refresh_margin)
        drift = float(self.temp_drift_k)
        if not np.isfinite(drift) or _T_NOMINAL_K + drift <= 0.0:
            raise ValueError(
                f"temp_drift_k must be finite and keep the die above 0 K "
                f"(baseline {_T_NOMINAL_K:g} K), got {self.temp_drift_k!r}")


# ---------------------------------------------------------------------------
# the scan kernel
# ---------------------------------------------------------------------------


def _sim_phase_one(params, slot, xs, consts):
    """Replay one phase against ONE composition. Pure jnp; float32.

    ``params``  dict of (S,) per-slot macro columns (gathered table rows).
    ``slot``    dict of (S,) slot requirement vectors (cap_bits, lifetime_s).
    ``xs``      (t_bin (T,), reads (T, S), write_bits (T, S), occ (T, S)).
    ``consts``  (5,) f32: [refresh_on, rewrite_overhead, adaptive_on,
                temp_drift_k, t_total_s].
    Returns a dict of scalar outputs keyed by SIM_METRICS.

    Temperature drift and the adaptive controller live INSIDE the scan: each
    bin scales retention by the Arrhenius factor of the current die
    temperature (linear 300 K → 300+drift ramp over ``t_total_s``) before
    deriving refresh need, interval, and expiry rate; the adaptive controller
    then skips the fraction of scheduled refreshes the bin's own writes
    already performed. Both collapse to exact multiplications by 1.0 when
    disabled, keeping the base replay bit-identical.
    """
    p, s = params, slot
    eps = jnp.float32(1e-30)
    refresh_on, overhead = consts[0], consts[1]
    adaptive_on, drift_k, t_total = consts[2], consts[3], consts[4]
    num_words = p["bits"] / p["word_bits"]
    interval = p["interval_s"]
    cap_rate = p["tiles"] * p["f_op_hz"]             # port ops/s per slot

    def step(carry, x):
        age, e_dyn, e_ref, e_rew, t_sim, coll, upk, apk, t_acc = carry
        t_bin, reads, wbits, occ = x
        # die temperature at this bin; retention Arrhenius scale vs 300 K
        # (drift 0 -> exponent exactly 0 -> rs exactly 1.0)
        t_now = _T_NOMINAL_K + drift_k * (t_acc / jnp.maximum(t_total, eps))
        rs = jnp.exp(_EA_OVER_KB_K * (1.0 / t_now - 1.0 / _T_NOMINAL_K))
        ret = p["retention_s"] * rs
        need = refresh_mod.needs_refresh(
            ret, s["lifetime_s"]).astype(jnp.float32)
        wops = wbits / p["word_bits"]
        turn = jnp.clip(wbits / jnp.maximum(occ * s["cap_bits"], eps),
                        0.0, 1.0)
        # adaptive controller: writes are refreshes of the words they touch,
        # so skip that fraction of the schedule (adaptive_on gates to 1.0)
        refr = ((1.0 - adaptive_on * turn) * refresh_on * need
                * refresh_mod.refresh_ops(
                    p["tiles"] * num_words, interval * rs, occ, t_bin))
        rewr = ((1.0 - refresh_on) * need * occ * s["cap_bits"] * t_bin
                / jnp.maximum(ret, eps) / p["word_bits"])
        cap_ops = jnp.maximum(cap_rate * t_bin, eps)
        util = (reads + wops + refr + rewr) / cap_ops
        age = (age + t_bin) * (1.0 - turn)
        carry = (
            age,
            e_dyn + jnp.sum(reads * p["e_read_j"] + wops * p["e_write_j"]),
            e_ref + jnp.sum(refr * (p["e_read_j"] + p["e_write_j"])),
            e_rew + jnp.sum(rewr * p["e_write_j"]) * overhead,
            t_sim + t_bin * jnp.maximum(jnp.max(util), 1.0),
            coll + jnp.sum(refr * jnp.minimum((reads + wops) / cap_ops, 1.0)),
            jnp.maximum(upk, jnp.max(util)),
            jnp.maximum(apk, jnp.max(age)),
            t_acc + t_bin,
        )
        return carry, None

    S = p["bits"].shape[0]
    zero = jnp.float32(0.0)
    carry0 = (jnp.zeros((S,), jnp.float32),) + (zero,) * 8
    (age, e_dyn, e_ref, e_rew, t_sim, coll, upk, apk, _), _ = jax.lax.scan(
        step, carry0, xs)
    t_wall = jnp.sum(xs[0])
    e_leak = jnp.sum(p["p_leak_w"] * p["tiles"]) * t_sim
    e_total = e_dyn + e_ref + e_rew + e_leak
    return {
        "e_dyn_j": e_dyn, "e_refresh_j": e_ref, "e_rewrite_j": e_rew,
        "e_leak_j": e_leak, "e_total_j": e_total,
        "t_sim_s": t_sim, "t_wall_s": t_wall,
        "stall_frac": (t_sim - t_wall) / jnp.maximum(t_wall, eps),
        "collisions": coll, "util_peak": upk, "age_peak_s": apk,
        "p_avg_w": e_total / jnp.maximum(t_sim, eps),
    }


_sim_grid_xla = jax.jit(jax.vmap(_sim_phase_one, in_axes=(0, None, None,
                                                          None)))
_sim_one_jit = jax.jit(_sim_phase_one)


def _sim_grid_interpret(params, slot, xs, consts):
    """Per-composition Python loop over the same jitted scan — the oracle the
    vmapped path must match bit-for-bit."""
    J = next(iter(params.values())).shape[0]
    rows = [_sim_one_jit({k: v[j] for k, v in params.items()},
                         slot, xs, consts) for j in range(J)]
    return {m: jnp.stack([r[m] for r in rows]) for m in SIM_METRICS}


_backend.register("sim_replay", xla=_sim_grid_xla,
                  interpret=_sim_grid_interpret)


# ---------------------------------------------------------------------------
# public batched entry
# ---------------------------------------------------------------------------


def _gather_params(cols: Mapping[str, np.ndarray], idx: np.ndarray,
                   cap_bits: np.ndarray,
                   policy: SimPolicy) -> Dict[str, jnp.ndarray]:
    if policy.corner is not None:
        # schedule refresh / expiry off the named corner's retention column
        cols = {**cols,
                "retention_s": refresh_mod.retention_column(
                    cols, policy.corner)}
    safe = jnp.maximum(jnp.asarray(np.asarray(idx), jnp.int32), 0)
    missing = [c for c in SIM_COLS if c not in cols]
    if missing:
        raise KeyError(f"sim cols missing {missing}; callers gather "
                       f"DesignTable metrics + word_bits=table['word_size']")
    p = {c: jnp.take(jnp.asarray(np.asarray(cols[c]), jnp.float32), safe,
                     axis=0) for c in SIM_COLS}
    bits = jnp.maximum(p["bits"], 1.0)
    cap = jnp.asarray(np.asarray(cap_bits), jnp.float32)
    p["tiles"] = jnp.ceil(cap[None, :] / bits)       # scorer's tiling rule
    p["interval_s"] = jnp.asarray(
        refresh_mod.refresh_interval_s(p["retention_s"],
                                       policy.refresh_margin), jnp.float32)
    return p


def simulate_traces(cols: Mapping[str, np.ndarray], idx: np.ndarray,
                    traces: Sequence[Trace],
                    policy: Optional[SimPolicy] = None,
                    backend: Optional[str] = None) -> Dict[str, object]:
    """Replay ``traces`` against every composition of ``idx``.

    ``cols``    DesignTable metric columns + ``word_bits`` (each
                ``(n_configs,)``) — see ``SIM_COLS``.
    ``idx``     (J, S) int32 row indices (-1 = infeasible sentinel; such
                compositions price at +inf energy/time like the analytic
                scorer).
    ``traces``  one ``Trace`` per phase, identical slot order as ``idx``
                columns.
    ``backend`` kernel backend override ("xla" | "interpret"); default via
                ``repro.kernels.backend.resolve_backend``.

    Returns ``{metric: (J,) float64}`` over ``SIM_METRICS`` — energies,
    times, and collisions summed across phases, peaks maxed — plus
    ``"phases"``: the same per-phase dicts keyed by phase name.
    """
    if not traces:
        raise ValueError("simulate_traces() needs at least one Trace")
    policy = policy or SimPolicy()
    idx = np.asarray(idx)
    S = idx.shape[1]
    if any(t.n_slots != S for t in traces):
        raise ValueError(f"trace slot counts {[t.n_slots for t in traces]} "
                         f"!= grid slot count {S}")
    t0 = traces[0]
    params = _gather_params(cols, idx, t0.cap_bits, policy)
    slot = {"cap_bits": jnp.asarray(t0.cap_bits, jnp.float32),
            "lifetime_s": jnp.asarray(t0.lifetime_s, jnp.float32)}
    from repro.analysis import sanitize
    impl = sanitize.maybe_wrap(_backend.get_impl("sim_replay", backend))

    per_phase: Dict[str, Dict[str, np.ndarray]] = {}
    bad = np.any(idx < 0, axis=1)
    with obs.span("sim.replay", J=int(idx.shape[0]), S=int(S),
                  phases=len(traces)):
        for tr in traces:
            # the drift ramp spans each phase's own replay window
            consts = jnp.asarray(
                [1.0 if policy.refresh else 0.0, policy.rewrite_overhead,
                 1.0 if policy.adaptive_refresh else 0.0,
                 policy.temp_drift_k, float(np.sum(tr.t_bin_s))],
                jnp.float32)
            xs = (jnp.asarray(tr.t_bin_s, jnp.float32),
                  jnp.asarray(tr.reads.T, jnp.float32),
                  jnp.asarray(tr.write_bits.T, jnp.float32),
                  jnp.asarray(tr.occupancy.T, jnp.float32))
            with obs.span("sim.replay_phase", probe=_sim_grid_xla,
                          phase=tr.phase):
                out = impl(params, slot, xs, consts)
            per_phase[tr.phase] = _mask_sentinels(
                {m: np.asarray(out[m], np.float64) for m in SIM_METRICS}, bad)
    _C_REPLAYS.inc()

    combined = _mask_sentinels(_combine_phases(per_phase), bad)
    combined["phases"] = per_phase
    return combined


def _mask_sentinels(metrics: Dict[str, np.ndarray],
                    bad: np.ndarray) -> Dict[str, np.ndarray]:
    """Price compositions with any sentinel slot (clamped to table row 0 by
    the gather) at +inf energy/time, zero diagnostics — the analytic
    scorer's contract, applied to combined AND per-phase outputs."""
    if not bad.any():
        return metrics
    for m in ("e_dyn_j", "e_refresh_j", "e_rewrite_j", "e_leak_j",
              "e_total_j", "t_sim_s", "p_avg_w"):
        metrics[m] = np.where(bad, np.inf, metrics[m])
    for m in ("collisions", "util_peak", "age_peak_s", "stall_frac"):
        metrics[m] = np.where(bad, 0.0, metrics[m])
    return metrics


def _combine_phases(per_phase: Mapping[str, Mapping[str, np.ndarray]]
                    ) -> Dict[str, np.ndarray]:
    """Sum energies/times/collisions across phases, max the peaks, and
    re-derive the ratio metrics from the combined totals."""
    phases = list(per_phase.values())
    out: Dict[str, np.ndarray] = {}
    for m in ("e_dyn_j", "e_refresh_j", "e_rewrite_j", "e_leak_j",
              "e_total_j", "t_sim_s", "t_wall_s", "collisions"):
        out[m] = np.sum([ph[m] for ph in phases], axis=0)
    for m in ("util_peak", "age_peak_s"):
        out[m] = np.max([ph[m] for ph in phases], axis=0)
    # sentinel rows hold inf sums: inf-inf / inf/inf transiently produce
    # nans here that _mask_sentinels overwrites — keep numpy quiet about it
    with np.errstate(invalid="ignore"):
        out["stall_frac"] = ((out["t_sim_s"] - out["t_wall_s"])
                             / np.maximum(out["t_wall_s"], 1e-30))
        out["p_avg_w"] = out["e_total_j"] / np.maximum(out["t_sim_s"], 1e-30)
    return out
