"""Refresh scheduling rules derived from the retention solver.

The analytic model prices refresh as a steady-state average power
(``characterize``'s ``p_refresh_w = (e_read + e_write) * num_words /
retention_s``). The simulator instead *schedules* refresh: every stored word
is rewritten once per refresh interval, where the interval comes straight
from the ``core.retention`` transient solver's ``retention_s`` metric scaled
by a safety margin —

    interval_s = DEFAULT_REFRESH_MARGIN × retention_s

(refresh before the stored '1' droops to the read-margin threshold, not at
it). The issued op rate is occupancy-aware — only live words refresh — and
the ops compete with demand accesses at the bank ports, which is where the
collision behavior the steady-state average cannot see comes from.

All functions are plain arithmetic on arrays and work on numpy and jnp
inputs alike (the engine calls them under jit).
"""
from __future__ import annotations

import math
from typing import Mapping

import numpy as np

# refresh at 80% of the solver's retention time (guard band before the
# read-margin crossing); SRAM rows carry retention_s = 1e12 s, so their
# interval is effectively infinite and the scheduler never fires for them
DEFAULT_REFRESH_MARGIN = 0.8


def _check_margin(margin: float) -> float:
    """Validate a refresh safety margin at the python entry points.

    A margin ≤ 0 would schedule negative/zero intervals (``refresh_ops``
    divides by the interval) and a margin > 1 refreshes *after* the solver's
    read-margin crossing — both silently nonsensical, so reject them loudly
    here rather than inside the jit'd arithmetic."""
    m = float(margin)
    if not math.isfinite(m) or not 0.0 < m <= 1.0:
        raise ValueError(
            f"refresh margin must be in (0, 1] (a fraction of the solver's "
            f"retention time; refreshing at or before the read-margin "
            f"crossing), got {margin!r}")
    return m


def refresh_interval_s(retention_s, margin: float = DEFAULT_REFRESH_MARGIN):
    """Scheduled refresh interval [s] for a macro with ``retention_s`` [s].

    ``margin`` must be in (0, 1]. Elementwise; works on scalars, numpy, and
    jnp arrays."""
    return _check_margin(margin) * retention_s


def retention_column(metrics: Mapping[str, np.ndarray],
                     corner: str = None) -> np.ndarray:
    """The retention column [s] refresh scheduling should derive from:
    the base ``retention_s`` when ``corner`` is None, else the per-corner
    ``retention_s@<corner>`` column of a corner-batched DesignTable — a
    refresh schedule sized for the *hot* corner keeps data alive at
    temperature, where the nominal solver retention would under-refresh."""
    if corner is None:
        return np.asarray(metrics["retention_s"], np.float64)
    key = f"retention_s@{corner}"
    if key not in metrics:
        raise KeyError(
            f"retention column {key!r} not in metrics; build the "
            f"DesignTable with corners=[...] including the {corner!r} "
            f"operating point")
    return np.asarray(metrics[key], np.float64)


def refresh_intervals(metrics: Mapping[str, np.ndarray],
                      margin: float = DEFAULT_REFRESH_MARGIN,
                      corner: str = None) -> np.ndarray:
    """Per-row refresh intervals [s] for a DesignTable metric dict — the
    solver parity anchor: ``refresh_intervals(table.metrics) ==
    margin * table.metrics["retention_s"]`` by construction. ``corner``
    schedules from that corner's retention column instead (e.g. "hot")."""
    return refresh_interval_s(retention_column(metrics, corner), margin)


def refresh_ops(num_words, interval_s, occupancy, t_bin_s):
    """Refresh operations issued in one bin: every live word once per
    interval — ``occupancy × num_words × t_bin / interval`` [ops].

    Elementwise (jnp-safe); the engine multiplies by the slot's tile count
    and masks slots whose macro retention already covers the data lifetime
    (no refresh needed when data expires before the cell droops)."""
    return occupancy * num_words * t_bin_s / interval_s


def needs_refresh(retention_s, lifetime_s):
    """True where stored data must outlive the cell's retention — the slots
    the scheduler (or, with refresh disabled, the expiry-rewrite path)
    fires for. Elementwise (jnp-safe)."""
    return retention_s < lifetime_s
