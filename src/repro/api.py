"""The OpenGCRAM-JAX compiler façade: one API from MacroConfig to DSE report.

Units everywhere in this module: frequencies [Hz], energies [J], areas
[µm²], powers [W], times/lifetimes [s], capacities [bits].

Four pillars (everything else in ``repro.core``/``repro.hetero`` is the
physics and composition machinery under them):

``Compiler``
    ``Compiler().compile(cfg) -> Macro``. A ``Macro`` bundles the PPA
    characterization (``.ppa``), retention, and artifact emission
    (``.verilog()`` / ``.lib()`` / ``.lef()`` / ``.layout()`` /
    ``.write_all(dir)``).

``DesignTable``
    Columnar struct-of-arrays over a config grid: config axes + every
    characterization metric as named columns, chainable
    ``filter`` / ``feasible`` / ``pareto`` / ``best`` queries,
    ``to_configs()`` round-trip, and ``save``/``load`` npz caching keyed on
    a config-grid hash so repeated DSE runs skip the vmap
    re-characterization.

``explore(space, tasks, policy=...) -> DSEReport``
    grid -> characterize -> per-task feasibility -> independent per-level
    selection, in one call: Table-2 labels, per-bucket picks, and Fig-11
    shmoo maps, under an explicit ``SelectionPolicy``.

``compose(space, task, ...) -> CompositionReport``
    the joint counterpart (``repro.hetero``): whole (L1 tech, L2 tech)
    system designs scored in one batched jnp evaluation — system area
    [µm²], total power incl. refresh [W], bandwidth margin, capacity fit —
    and ranked under a ``ComposePolicy``.

    >>> from repro.api import Compiler, explore
    >>> macro = Compiler().compile(mem_type="gc_sisi", word_size=32,
    ...                            num_words=64, level_shift=True)
    >>> macro.ppa["f_read_hz"]          # doctest: +SKIP
    >>> report = explore()              # paper Table 2   # doctest: +SKIP
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import hashlib
import json
import warnings
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro import obs
from repro.core import artifacts as artifacts_mod
from repro.core import bitcells, characterize as chz, layout as layout_mod
from repro.core import corners as corners_mod
from repro.core import macro as macro_mod
from repro.core import netlist as netlist_mod
from repro.core.corners import (  # noqa: F401  (re-exported façade names)
    CORNERS, HOT, NOMINAL, OperatingPoint, TechParams,
)
from repro.core.macro import MacroConfig
from repro.core.select import (  # noqa: F401  (re-exported façade names)
    DISPLAY, PREFERENCE, TECH_FAMILIES, Bucket, BucketPick, LevelReq,
    LevelSelection, SelectionPolicy, TaskReq, as_task_req, family_of,
    feasible_mask, pareto_mask, select_level,
)
from repro.hetero.compose import (  # noqa: F401  (re-exported façade names)
    ComposePolicy, CompositionReport, compose,
)
from repro.sim.engine import SimPolicy  # noqa: F401  (re-exported façade name)

__all__ = [
    "Bucket", "LevelReq", "TaskReq", "SelectionPolicy",
    "MacroConfig", "Macro", "Compiler",
    "DesignTable", "design_space",
    "explore", "DSEReport",
    "compose", "ComposePolicy", "CompositionReport",
    "simulate", "SimPolicy",
    "OperatingPoint", "TechParams", "NOMINAL", "HOT", "CORNERS",
    "gradient_size_macro", "characterize_call_count",
]

# cache schema version: bump on npz-layout changes that a physics-source
# fingerprint can't catch (the fingerprint below already invalidates caches
# whenever any characterization-model module is edited).
# 2: per-corner metric columns + corners/physics stamped into the meta
_SCHEMA_VERSION = 2


@functools.lru_cache(maxsize=1)
def _physics_fingerprint() -> str:
    """Hash of the characterization-model sources: any edit to the physics
    (device curves, periphery, retention, geometry, operating-corner
    derivation, characterize itself) changes the fingerprint and therefore
    every DesignTable cache key."""
    from repro.core import devices, periphery, retention, tech
    h = hashlib.sha256()
    for mod in (bitcells, chz, corners_mod, devices, macro_mod, periphery,
                retention, tech):
        h.update(Path(mod.__file__).read_bytes())
    return h.hexdigest()[:16]


def _hash_seed() -> "hashlib._Hash":
    return hashlib.sha256(
        f"schema={_SCHEMA_VERSION};physics={_physics_fingerprint()}".encode())

# how many times the vmap characterization actually ran (cache-hit proof);
# lives on the repro.obs metrics registry, read through the thin alias below
# so existing cache-proof tests and callers are unchanged
_C_CHARACTERIZE = obs.counter("api.characterize_calls")
_C_TABLE_HIT = obs.counter("api.table_cache_hits")
_C_TABLE_MISS = obs.counter("api.table_cache_misses")


def characterize_call_count() -> int:
    """Number of vmap characterization sweeps this process has executed.

    A ``DesignTable`` cache hit leaves this counter unchanged — tests use it
    to prove that repeated ``explore()`` calls skip the re-characterization.
    (Backed by the ``api.characterize_calls`` obs counter.)
    """
    return _C_CHARACTERIZE.value


DEFAULT_MEM_TYPES = ("sram6t", "gc_sisi", "gc_ossi")


def design_space(mem_types: Sequence[str] = DEFAULT_MEM_TYPES,
                 word_sizes: Sequence[int] = (16, 32, 64, 128),
                 num_words: Sequence[int] = (16, 32, 64, 128, 256, 512),
                 ls_options: Sequence[bool] = (False, True),
                 banks: Sequence[int] = (1,)) -> List[MacroConfig]:
    """Enumerate the paper's §5.4 config grid (SRAM has no level shifter).

    ``mem_types``  bitcell menu (keys of ``repro.core.bitcells.BITCELLS``);
    ``word_sizes`` word widths [bits]; ``num_words`` depths [words];
    ``ls_options`` write-wordline level-shifter on/off (gain cells only).
    Returns the full cross-product as ``MacroConfig`` objects.
    """
    out = []
    for mt in mem_types:
        for wz in word_sizes:
            for nw in num_words:
                for b in banks:
                    for ls in (ls_options if mt != "sram6t" else (False,)):
                        out.append(MacroConfig(
                            mem_type=mt, word_size=wz, num_words=nw,
                            banks=b, level_shift=ls))
    return out


# ---------------------------------------------------------------------------
# DesignTable
# ---------------------------------------------------------------------------

SpaceLike = Union[None, "DesignTable", Sequence[MacroConfig]]

# metrics where the *worst* corner is the smallest value; every other metric
# (areas [µm²], energies [J], powers [W], delays [s]) worsens upward
_HIGHER_IS_BETTER = frozenset({
    "f_read_hz", "f_write_hz", "f_op_hz",
    "bandwidth_bits_s", "bandwidth_total_bits_s", "retention_s",
})
# geometry columns are corner-invariant: worst-case passes them through
_GEOMETRY_METRICS = frozenset({"rows", "cols", "mux", "bits"})


class DesignTable:
    """Columnar (struct-of-arrays) view of a characterized design space.

    Columns are the config axes (``mem_type``, ``word_size``, ``num_words``,
    ``banks``, ``level_shift``, ``sa_current_mode``, ``mux``) plus every
    metric the characterization returns (``f_op_hz``, ``area_um2``,
    ``retention_s``, ...). Query methods return new (filtered) tables, so
    they chain::

        table.feasible(1e9, 1e-3).pareto("area_um2", "p_leak_w").best("area_um2")

    With ``corners=[...]`` (``repro.api.OperatingPoint``s or names like
    "hot") the characterization vmaps over the (designs × corners) grid in
    one dispatch: the base metric columns come from ``corners[0]`` and every
    corner additionally lands as ``<metric>@<label>`` columns (e.g.
    ``retention_s@hot``); ``worst_case_metrics()`` reduces them to the
    per-row worst corner for corner-robust DSE.
    """

    AXIS_NAMES: Tuple[str, ...] = macro_mod.VEC_FIELDS

    def __init__(self, axes: Mapping[str, np.ndarray],
                 metrics: Mapping[str, np.ndarray],
                 corners: Sequence[OperatingPoint] = (corners_mod.NOMINAL,)):
        self._axes = {k: np.asarray(v) for k, v in axes.items()}
        self._metrics = {k: np.asarray(v) for k, v in metrics.items()}
        self._corners = corners_mod.as_corners(corners)
        n = {len(v) for v in self._axes.values()}
        n |= {len(v) for v in self._metrics.values()}
        if len(n) > 1:
            raise ValueError(f"ragged columns: lengths {sorted(n)}")

    # ------------------------------------------------------------- build/io
    @classmethod
    def from_configs(cls, configs: Sequence[MacroConfig],
                     corners=None) -> "DesignTable":
        """Characterize a config list (one vmap sweep) into a table.

        ``corners``: operating points to batch over (None = nominal only;
        the nominal-only path is byte-identical to the pre-corner one)."""
        import jax.numpy as jnp

        from repro.analysis import sanitize
        ops = corners_mod.as_corners(corners)
        vecs = jnp.stack([c.to_vector() for c in configs])
        with obs.span("api.characterize", probe=chz.characterize_batch,
                      n_configs=len(configs), n_corners=len(ops)):
            if ops == (corners_mod.NOMINAL,):
                out = sanitize.maybe_wrap(chz.characterize_batch)(vecs)
                metrics = {k: np.asarray(v) for k, v in out.items()}
            else:
                # characterize_corners sanitizes each per-corner dispatch
                # itself (one jitted vmap per corner)
                out = chz.characterize_corners(vecs, ops)
                metrics = {}
                for k, v in out.items():
                    grid = np.asarray(v)                    # (N, C)
                    metrics[k] = grid[:, 0]
                    for c, op in enumerate(ops):
                        metrics[f"{k}@{op.corner}"] = grid[:, c]
        _C_CHARACTERIZE.inc()
        axes = {
            "mem_type": np.array([c.mem_type for c in configs]),
            "word_size": np.array([c.word_size for c in configs], np.int64),
            "num_words": np.array([c.num_words for c in configs], np.int64),
            "banks": np.array([c.banks for c in configs], np.int64),
            "level_shift": np.array([c.level_shift for c in configs], bool),
            "sa_current_mode": np.array([c.sa_current_mode for c in configs],
                                        bool),
            "mux": np.array([c.mux for c in configs], np.int64),
        }
        return cls(axes, metrics, corners=ops)

    @classmethod
    def build(cls, space: SpaceLike = None,
              cache: Union[None, str, Path] = None,
              corners=None) -> "DesignTable":
        """Characterize ``space`` (default: the paper grid), consulting an
        npz cache directory keyed on the (config grid, corners) hash when
        given. ``corners``: operating points to batch over (None = nominal;
        a pre-built ``space`` table must already carry them)."""
        if isinstance(space, DesignTable):
            if corners is not None \
                    and corners_mod.as_corners(corners) != space.corners:
                raise ValueError(
                    f"corners={corners!r} conflicts with the pre-built "
                    f"table's corners {[op.corner for op in space.corners]}; "
                    f"rebuild the table with DesignTable.build(configs, "
                    f"corners=...)")
            return space
        configs = list(space) if space is not None else design_space()
        if cache is None:
            return cls.from_configs(configs, corners=corners)
        cache_path = Path(cache) / \
            f"table_{grid_hash(configs, corners=corners)}.npz"
        with obs.span("api.table_build", n_configs=len(configs)) as sp:
            if cache_path.exists():
                try:
                    table = cls.load(cache_path)
                    _C_TABLE_HIT.inc()
                    sp.set(cache="hit")
                    return table
                except Exception as e:     # corrupt / stale cache: rebuild it
                    warnings.warn(f"ignoring unreadable DesignTable cache "
                                  f"{cache_path}: {e}", RuntimeWarning,
                                  stacklevel=2)
            _C_TABLE_MISS.inc()
            sp.set(cache="miss")
            table = cls.from_configs(configs, corners=corners)
            table.save(cache_path)
            return table

    def save(self, path: Union[str, Path]) -> Path:
        """Persist axes + metrics to ``path`` (npz, stamped with the grid
        hash, the operating corners, and the physics-source fingerprint)."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        payload = {f"axis_{k}": v for k, v in self._axes.items()}
        payload.update({f"metric_{k}": v for k, v in self._metrics.items()})
        meta = {"schema": _SCHEMA_VERSION, "grid_hash": self.grid_hash,
                "physics": _physics_fingerprint(),
                "corners": [[float(op.vdd), float(op.temp_k), op.corner]
                            for op in self._corners]}
        np.savez(path, __meta__=json.dumps(meta), **payload)
        return path

    @classmethod
    def load(cls, path: Union[str, Path]) -> "DesignTable":
        """Load a saved table, rejecting stale caches loudly: a snapshot
        whose stored physics fingerprint no longer matches the current
        characterization sources raises instead of silently reusing numbers
        the live models would no longer produce."""
        with np.load(Path(path), allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
            if meta.get("schema") != _SCHEMA_VERSION:
                raise ValueError(
                    f"{path}: cache schema {meta.get('schema')} != "
                    f"{_SCHEMA_VERSION}; delete the cache and re-run")
            stored = meta.get("physics")
            if stored != _physics_fingerprint():
                raise ValueError(
                    f"{path}: stale physics fingerprint {stored} != current "
                    f"{_physics_fingerprint()} (the characterization models "
                    f"changed since this cache was written); delete the "
                    f"cache or re-run DesignTable.build")
            ops = tuple(OperatingPoint(vdd=c[0], temp_k=c[1], corner=str(c[2]))
                        for c in meta.get("corners",
                                          [[corners_mod.NOMINAL.vdd,
                                            corners_mod.NOMINAL.temp_k,
                                            "nominal"]]))
            axes = {k[5:]: z[k] for k in z.files if k.startswith("axis_")}
            metrics = {k[7:]: z[k] for k in z.files
                       if k.startswith("metric_")}
        return cls(axes, metrics, corners=ops)

    # ------------------------------------------------------------ accessors
    def __len__(self) -> int:
        return len(next(iter(self._axes.values())))

    def __getitem__(self, name: str) -> np.ndarray:
        if name in self._axes:
            return self._axes[name]
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._axes or name in self._metrics

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(self._axes)

    @property
    def metric_names(self) -> Tuple[str, ...]:
        return tuple(self._metrics)

    @property
    def columns(self) -> Dict[str, np.ndarray]:
        return {**self._axes, **self._metrics}

    @property
    def metrics(self) -> Dict[str, np.ndarray]:
        """Metric columns only (the legacy ``evaluate_space`` dict)."""
        return dict(self._metrics)

    @property
    def families(self) -> np.ndarray:
        """Technology family per row ("sram" | "si-si" | "os-si" | "os-os")."""
        return np.array([family_of(mt) for mt in self._axes["mem_type"]])

    @property
    def corners(self) -> Tuple[OperatingPoint, ...]:
        """The operating points this table was characterized at, in column
        order (``corners[0]`` backs the base metric columns)."""
        return self._corners

    @property
    def corner_labels(self) -> Tuple[str, ...]:
        return tuple(op.corner for op in self._corners)

    def corner_metrics(self, corner: str) -> Dict[str, np.ndarray]:
        """Base-named metric dict evaluated at one corner label (the
        ``<metric>@<corner>`` columns, re-keyed without the suffix)."""
        if corner not in self.corner_labels:
            raise KeyError(f"corner {corner!r} not in table corners "
                           f"{self.corner_labels}; build the table with "
                           f"corners=[...] including it")
        if len(self._corners) == 1:
            return dict(self._metrics)
        suffix = f"@{corner}"
        return {k[:-len(suffix)]: v for k, v in self._metrics.items()
                if k.endswith(suffix)}

    def worst_case_metrics(self) -> Dict[str, np.ndarray]:
        """Per-row worst-corner reduction of every base metric: min over
        corners for rate-like metrics (``f_*``, ``bandwidth_*``,
        ``retention_s``), max for cost-like ones (areas, energies, powers,
        delays); geometry columns pass through. Feasibility/ranking on this
        dict is the ``robust="worst_case"`` DSE mode — a design must satisfy
        the requirement at EVERY characterized corner."""
        if len(self._corners) == 1:
            return dict(self._metrics)
        base = [k for k in self._metrics if "@" not in k]
        out: Dict[str, np.ndarray] = {}
        for k in base:
            stack_keys = [f"{k}@{op.corner}" for op in self._corners]
            # geometry and derived with_column() columns have no per-corner
            # variants: pass them through as-is
            if k in _GEOMETRY_METRICS or \
                    not all(sk in self._metrics for sk in stack_keys):
                out[k] = self._metrics[k]
                continue
            stack = np.stack([self._metrics[sk] for sk in stack_keys], axis=1)
            out[k] = (stack.min(axis=1) if k in _HIGHER_IS_BETTER
                      else stack.max(axis=1))
        return out

    def robust_metrics(self, robust: Optional[str]) -> Dict[str, np.ndarray]:
        """The metric dict a DSE pass should rank on: ``None`` -> the base
        (``corners[0]``) columns, ``"worst_case"`` -> the per-row worst
        corner."""
        if robust is None:
            return self.metrics
        if robust == "worst_case":
            return self.worst_case_metrics()
        raise ValueError(f"unknown robust mode {robust!r}; "
                         f"valid: None, 'worst_case'")

    @property
    def grid_hash(self) -> str:
        """Cache key: config grid (axes) + operating corners +
        physics-source fingerprint."""
        h = _hash_seed()
        h.update(corners_mod.corners_fingerprint(self._corners).encode())
        for name in self.AXIS_NAMES:
            col = self._axes[name]
            h.update(name.encode())
            h.update(np.asarray(col, dtype="U16" if col.dtype.kind in "US"
                                else np.float64).tobytes())
        return h.hexdigest()[:16]

    def config(self, i: int) -> MacroConfig:
        a = self._axes
        return MacroConfig(
            mem_type=str(a["mem_type"][i]),
            word_size=int(a["word_size"][i]),
            num_words=int(a["num_words"][i]),
            banks=int(a["banks"][i]),
            level_shift=bool(a["level_shift"][i]),
            sa_current_mode=bool(a["sa_current_mode"][i]),
            mux=int(a["mux"][i]))

    def to_configs(self) -> List[MacroConfig]:
        """Round-trip the axis columns back into MacroConfig objects."""
        return [self.config(i) for i in range(len(self))]

    def row(self, i: int) -> Dict[str, object]:
        return {k: v[i].item() if hasattr(v[i], "item") else v[i]
                for k, v in self.columns.items()}

    def macro(self, i: int) -> "Macro":
        """Row ``i`` as a full Macro (PPA from the table, no re-solve)."""
        ppa = {k: float(v[i]) for k, v in self._metrics.items()}
        return Macro(config=self.config(i), ppa=ppa)

    def with_column(self, name: str, values: np.ndarray) -> "DesignTable":
        """New table with a derived metric column appended."""
        values = np.asarray(values)
        if len(values) != len(self):
            raise ValueError(f"column {name}: length {len(values)} != "
                             f"{len(self)}")
        return DesignTable(self._axes, {**self._metrics, name: values},
                           corners=self._corners)

    # -------------------------------------------------------------- queries
    def filter(self, mask) -> "DesignTable":
        """Rows where ``mask`` holds. ``mask`` is a boolean array or a
        callable ``table -> boolean array``."""
        if callable(mask):
            mask = mask(self)
        mask = np.asarray(mask, bool)
        return DesignTable({k: v[mask] for k, v in self._axes.items()},
                           {k: v[mask] for k, v in self._metrics.items()},
                           corners=self._corners)

    def feasible(self, f_hz: float, lifetime_s: float,
                 allow_refresh: bool = False) -> "DesignTable":
        """Configs that sustain read frequency ``f_hz`` [Hz] and retain data
        for ``lifetime_s`` [s] (``allow_refresh`` admits refreshed gain
        cells, paper §5.3). Returns the filtered table."""
        return self.filter(self.shmoo(f_hz, lifetime_s,
                                      allow_refresh=allow_refresh))

    def shmoo(self, f_hz: float, lifetime_s: float,
              allow_refresh: bool = False) -> np.ndarray:
        """Fig 11: boolean feasibility per row (green/red) for one
        (``f_hz`` [Hz], ``lifetime_s`` [s]) point — a mask, not filtered."""
        return feasible_mask(self._metrics, f_hz, lifetime_s,
                             allow_refresh=allow_refresh)

    def pareto(self, *objectives: str) -> "DesignTable":
        """Non-dominated rows for the named (lower-is-better) metric columns;
        prefix a name with ``-`` to maximize it instead."""
        if not objectives:
            raise ValueError("pareto() needs at least one objective column")
        cols = []
        for name in objectives:
            sign = 1.0
            if name.startswith("-"):
                sign, name = -1.0, name[1:]
            cols.append(sign * np.asarray(self[name], np.float64))
        return self.filter(pareto_mask(np.stack(cols, axis=1)))

    def best(self, by: str, ascending: bool = True) -> "Macro":
        """The single best row by one column, as a Macro."""
        if not len(self):
            raise ValueError("best() on an empty table")
        col = np.asarray(self[by], np.float64)
        i = int(np.argmin(col) if ascending else np.argmax(col))
        return self.macro(i)

    def __repr__(self) -> str:
        extra = "" if len(self._corners) == 1 and \
            self._corners == (corners_mod.NOMINAL,) else \
            f", corners={list(self.corner_labels)}"
        return (f"DesignTable({len(self)} configs x "
                f"{len(self._metrics)} metrics, grid={self.grid_hash}"
                f"{extra})")


def grid_hash(configs: Sequence[MacroConfig], corners=None) -> str:
    """Cache key of a (config grid, corners) pair without characterizing it
    (includes the physics-source fingerprint, so model edits invalidate old
    caches)."""
    h = _hash_seed()
    h.update(corners_mod.corners_fingerprint(
        corners_mod.as_corners(corners)).encode())
    for name in DesignTable.AXIS_NAMES:
        if name == "mem_type":
            col = np.array([c.mem_type for c in configs], dtype="U16")
        else:
            col = np.array([float(getattr(c, name)) for c in configs],
                           np.float64)
        h.update(name.encode())
        h.update(col.tobytes())
    return h.hexdigest()[:16]


# ---------------------------------------------------------------------------
# Compiler / Macro
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Macro:
    """One compiled memory macro: config + PPA + artifact emission.

    ``ppa`` is the full characterization as plain floats: ``f_*_hz`` [Hz],
    ``area_*_um2`` [µm²], ``e_*_j`` [J], ``p_*_w`` [W], ``t_*_s`` /
    ``retention_s`` [s], ``bandwidth_*_bits_s`` [bit/s]. Produced by
    ``Compiler.compile`` (fresh characterization) or
    ``DesignTable.macro``/``best`` (PPA lifted from the table)."""
    config: MacroConfig
    ppa: Dict[str, float]

    @property
    def name(self) -> str:
        c = self.config
        return f"{c.mem_type}_{c.word_size}x{c.num_words}"

    @property
    def retention_s(self) -> float:
        return self.ppa["retention_s"]

    @property
    def family(self) -> str:
        return family_of(self.config.mem_type)

    def verilog(self) -> str:
        return artifacts_mod.emit_verilog(self.config, res=self.ppa)

    def lib(self) -> str:
        return artifacts_mod.emit_lib(self.config, res=self.ppa)

    def lef(self) -> str:
        return artifacts_mod.emit_lef(self.config)

    def netlist(self):
        """(Netlist, spice_text) for the macro."""
        return netlist_mod.build_netlist(self.config)

    def layout(self):
        """Abstract floorplan (layout.Floorplan)."""
        return layout_mod.build_floorplan(self.config)

    def write_all(self, outdir) -> Dict[str, object]:
        """Full flow: netlist + floorplan + DRC/LVS + .sp/.v/.lib/.lef/.json
        into ``outdir``; returns the report dict."""
        return artifacts_mod.generate_all(self.config, outdir, res=self.ppa)

    def __repr__(self) -> str:
        return (f"Macro({self.name}, f_op={self.ppa['f_op_hz'] / 1e6:.0f}MHz, "
                f"area={self.ppa['area_um2']:.0f}um2, "
                f"retention={self.ppa['retention_s']:.2e}s)")


@dataclass(frozen=True)
class Compiler:
    """Entry point of the memory compiler.

    ``tech`` names the device/bitcell library (one 22nm-class stack ships
    with the repo); ``mem_types`` is the default bitcell menu for
    ``design_space``/``table``/``explore``; ``sanitize=True`` runs every
    characterization/composition/simulation this instance launches under
    the checkify runtime sanitizer (nan + index checks, see
    ``repro.analysis.sanitize``) — numerically identical outputs, raises on
    the first NaN/Inf or out-of-bounds gather instead of propagating it.
    ``telemetry=True`` records ``repro.obs`` spans for every call this
    instance launches (same events ``REPRO_TRACE`` enables process-wide);
    off (default) the obs layer is a no-op and outputs are bit-identical.
    """
    tech: str = "gf22"
    mem_types: Tuple[str, ...] = DEFAULT_MEM_TYPES
    sanitize: bool = False
    telemetry: bool = False

    def __post_init__(self):
        unknown = [m for m in self.mem_types if m not in bitcells.BITCELLS]
        if unknown:
            raise KeyError(f"unknown mem_types {unknown}; available: "
                           f"{sorted(bitcells.BITCELLS)}")

    def _sanitize_scope(self):
        """Force-enable the sanitizer for calls made by this instance;
        a plain Compiler() leaves the ambient REPRO_SANITIZE setting in
        charge instead of force-disabling it."""
        if not self.sanitize:
            return contextlib.nullcontext()
        from repro.analysis import sanitize as sanitize_mod
        return sanitize_mod.enabled_scope(True)

    def _obs_scope(self):
        """Force-enable span recording for calls made by this instance;
        a plain Compiler() leaves the ambient REPRO_TRACE setting in
        charge instead of force-disabling it."""
        if not self.telemetry:
            return contextlib.nullcontext()
        return obs.enabled_scope(True)

    # ------------------------------------------------------------- compile
    def compile(self, config: Optional[MacroConfig] = None,
                **overrides) -> Macro:
        """Characterize one macro. Pass a MacroConfig, or its fields::

            Compiler().compile(mem_type="gc_ossi", word_size=64, num_words=128)
        """
        op = overrides.pop("op", None)
        if config is None:
            config = MacroConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        if config.mem_type not in bitcells.BITCELLS:
            raise KeyError(f"unknown mem_type {config.mem_type!r}")
        with self._sanitize_scope(), self._obs_scope(), \
                obs.span("api.compile", mem_type=config.mem_type,
                         word_size=config.word_size,
                         num_words=config.num_words):
            return Macro(config=config, ppa=chz.characterize_config(config,
                                                                    tp=op))

    # ----------------------------------------------------------- exploration
    def design_space(self, **kw) -> List[MacroConfig]:
        kw.setdefault("mem_types", self.mem_types)
        return design_space(**kw)

    def table(self, space: SpaceLike = None,
              cache: Union[None, str, Path] = None,
              corners=None) -> DesignTable:
        if space is None:
            space = self.design_space()
        with self._sanitize_scope(), self._obs_scope():
            return DesignTable.build(space, cache=cache, corners=corners)

    def explore(self, tasks=None, space: SpaceLike = None,
                policy: Optional[SelectionPolicy] = None,
                cache: Union[None, str, Path] = None,
                corners=None, robust: Optional[str] = None) -> "DSEReport":
        """Independent per-level DSE; see module-level ``explore``.

        ``corners`` operating points to characterize at (None = nominal);
        ``robust="worst_case"`` selects on per-row worst-corner metrics so a
        winner must meet the requirement at every corner.
        """
        if space is None:
            space = self.design_space()
        with self._sanitize_scope(), self._obs_scope():
            return explore(space=space, tasks=tasks, policy=policy,
                           cache=cache, corners=corners, robust=robust)

    def compose(self, task, space: SpaceLike = None,
                policy: Optional[SelectionPolicy] = None,
                compose_policy=None, cache: Union[None, str, Path] = None,
                sharded: bool = False, refine: Optional[str] = None,
                sim_policy=None, corners=None,
                robust: Optional[str] = None, levels=None):
        """Joint heterogeneous composition for one task -> CompositionReport.

        Where ``explore`` picks each cache level independently, ``compose``
        scores joint N-level system designs — one technology pick per
        (level, bucket) slot across every level the task declares — pricing
        system area [µm²], total power incl. refresh [W], bandwidth margin,
        and capacity fit in batched jnp evaluations, ranked under an
        explicit ``repro.hetero.ComposePolicy``. The default policy
        reproduces the paper's Table-2 selections through the joint path;
        chip-level envelopes go in ``ComposePolicy.budget`` (a
        ``repro.hetero.SystemBudget``), and spaces past
        ``ComposePolicy.search_threshold`` are searched by lossless
        branch-and-bound instead of exhaustive enumeration.

        ``task``    anything ``as_task_req`` understands (a
                    ``gainsight.Task``, a profiler ``TaskReq``, a mapping).
        ``cache``   directory shared with the DesignTable npz cache; repeat
                    calls skip both the vmap characterization and the
                    composition scoring.
        ``sharded`` spread the composition grid across all visible devices.
        ``refine``  ``"simulate"`` re-ranks the analytic top-K by trace
                    replay (see ``Compiler.simulate``).
        ``corners`` operating points to characterize at (None = nominal).
        ``robust``  ``"worst_case"`` prices candidates/feasibility on the
                    per-row worst corner (see ``DesignTable.worst_case_metrics``).
        ``levels``  optional level-name subset, e.g. ``levels=("L1", "L2")``
                    composes just those two levels of a deeper task.
        """
        if space is None:
            space = self.design_space()
        with self._sanitize_scope(), self._obs_scope():
            return compose(space=space, task=task, policy=policy,
                           compose_policy=compose_policy, cache=cache,
                           sharded=sharded, refine=refine,
                           sim_policy=sim_policy, corners=corners,
                           robust=robust, levels=levels)

    def simulate(self, task, space: SpaceLike = None,
                 policy: Optional[SelectionPolicy] = None,
                 compose_policy=None, sim_policy=None,
                 cache: Union[None, str, Path] = None,
                 sharded: bool = False, corners=None,
                 robust: Optional[str] = None):
        """Simulate-then-rerank DSE for one task -> CompositionReport.

        Prunes the composition grid analytically (``compose``) to the
        ``ComposePolicy.top_k`` leaders, replays the task's time-binned
        phase traces against them — per-bank refresh/access collisions,
        dynamic access energy, retention-expiry rewrites, occupancy
        (``repro.sim``) — and re-ranks by simulated energy/latency. The
        returned report has ``refined == "simulate"`` and each
        composition's ``metrics`` carries the ``sim_*`` keys
        (``sim_e_total_j`` [J], ``sim_t_sim_s`` [s], ``sim_stall_frac``,
        ``sim_collisions``, ...).

        ``sim_policy`` is a ``repro.api.SimPolicy`` (phases, bins, window,
        refresh scheduling, re-rank objective); ``cache`` additionally
        stores the simulated report as ``sim_<key>.npz`` beside the hetero
        cache, so a repeat call re-runs neither the characterization, the
        analytic scoring, nor the trace replay.
        """
        return self.compose(task, space=space, policy=policy,
                            compose_policy=compose_policy, cache=cache,
                            sharded=sharded, refine="simulate",
                            sim_policy=sim_policy, corners=corners,
                            robust=robust)

    def gradient_size(self, config: MacroConfig, **kw) -> Dict[str, float]:
        """Beyond-paper continuous device sizing (see gradient_size_macro)."""
        return gradient_size_macro(config, **kw)


# ---------------------------------------------------------------------------
# explore -> DSEReport
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DSEReport:
    """Typed result of one heterogeneous-memory exploration.

    ``selections[task_id][level_name]`` is a ``LevelSelection`` (Table-2
    label + per-bucket picks into ``table``)."""
    table: DesignTable
    tasks: Tuple[TaskReq, ...]
    policy: SelectionPolicy
    selections: Dict[object, Dict[str, LevelSelection]]
    # "worst_case" when the selections ranked per-row worst-corner metrics
    robust: Optional[str] = None

    def labels(self) -> Dict[object, Dict[str, str]]:
        """Table 2: ``{task_id: {"L1": label, "L2": label}}``."""
        return {tid: {lvl: sel.label for lvl, sel in levels.items()}
                for tid, levels in self.selections.items()}

    def matches(self, expected: Mapping[object, Mapping[str, str]]) -> int:
        """How many tasks reproduce ``expected`` exactly (all levels)."""
        got = self.labels()
        return sum(
            tid in got and all(got[tid].get(lvl) == lab
                               for lvl, lab in levels.items())
            for tid, levels in expected.items())

    def pick_macro(self, task_id, level: str, bucket: int = 0) -> Macro:
        """The selected macro for one (task, level, bucket) cell."""
        pick = self.selections[task_id][level].picks[bucket]
        if pick.config_idx < 0:
            raise LookupError(f"task {task_id} {level} bucket {bucket} is "
                              f"infeasible under {self.policy}")
        return self.table.macro(pick.config_idx)

    def shmoo(self, task_id, level: str, bucket: int = 0) -> np.ndarray:
        """Fig 11 map for one (task, level) cell: feasibility of every config
        in the table against that bucket's requirement."""
        task = next(t for t in self.tasks if t.task_id == task_id)
        b = task.levels[level].buckets[bucket]
        return self.table.shmoo(b.f_hz, b.lifetime_s,
                                allow_refresh=self.policy.allow_refresh)

    def summary(self) -> str:
        lines = [f"{len(self.table)} configs, {len(self.tasks)} tasks, "
                 f"preference={'>'.join(self.policy.preference)}"
                 f"{' +refresh' if self.policy.allow_refresh else ''}"]
        for t in self.tasks:
            cells = "  ".join(f"{lvl}: {sel.label}"
                              for lvl, sel in self.selections[t.task_id].items())
            lines.append(f"  task {t.task_id} {t.name:24s} {cells}")
        return "\n".join(lines)


def explore(space: SpaceLike = None, tasks=None,
            policy: Optional[SelectionPolicy] = None,
            cache: Union[None, str, Path] = None,
            corners=None, robust: Optional[str] = None) -> DSEReport:
    """One call from design space to heterogeneous-memory report.

    ``space``   MacroConfig list, an existing DesignTable, or None for the
                paper's §5.4 grid.
    ``tasks``   task-like objects (``gainsight.TASKS`` by default; anything
                ``select.as_task_req`` understands).
    ``policy``  SelectionPolicy (paper default: OS-Si > Si-Si > SRAM, no
                refresh).
    ``cache``   directory for the grid-hash-keyed DesignTable cache; a second
                explore() on the same (grid, corners) skips the vmap
                characterization.
    ``corners`` operating points (``OperatingPoint``s / names) batched into
                the characterization; None = nominal only.
    ``robust``  ``"worst_case"`` ranks/filters on the per-row worst corner
                (a pick must be feasible at EVERY corner); None ranks on the
                base (``corners[0]``) columns — with the default corners
                this is exactly the paper's nominal Table-2 path.
    """
    if tasks is None:
        from repro.core import gainsight
        tasks = gainsight.TASKS
    task_reqs = tuple(as_task_req(t) for t in tasks)
    policy = policy or SelectionPolicy()
    with obs.span("api.explore", n_tasks=len(task_reqs),
                  robust=robust or "nominal"):
        table = DesignTable.build(space, cache=cache, corners=corners)
        metrics = table.robust_metrics(robust)
        families = table.families
        selections: Dict[object, Dict[str, LevelSelection]] = {}
        for t in task_reqs:
            selections[t.task_id] = {
                lvl: select_level(metrics, families, req, policy)
                for lvl, req in t.levels.items()}
    return DSEReport(table=table, tasks=task_reqs, policy=policy,
                     selections=selections, robust=robust)


def simulate(space: SpaceLike = None, task=None,
             policy: Optional[SelectionPolicy] = None,
             compose_policy=None, sim_policy=None,
             cache: Union[None, str, Path] = None,
             sharded: bool = False, corners=None,
             robust: Optional[str] = None) -> CompositionReport:
    """Simulate-then-rerank DSE: ``compose(refine="simulate")``.

    Analytic top-K prune, then trace replay (``repro.sim``) re-ranks the
    leaders by simulated energy/latency — see ``Compiler.simulate`` for the
    full contract. Module-level twin of the method, mirroring
    ``explore``/``compose``.
    """
    return compose(space=space, task=task, policy=policy,
                   compose_policy=compose_policy, cache=cache,
                   sharded=sharded, refine="simulate", sim_policy=sim_policy,
                   corners=corners, robust=robust)


# ---------------------------------------------------------------------------
# gradient sizing (beyond paper)
# ---------------------------------------------------------------------------


def gradient_size_macro(cfg: MacroConfig, steps: int = 200,
                        lr: float = 0.03,
                        area_weight: float = 0.2) -> Dict[str, float]:
    """Beyond-paper: continuous sizing via jax.grad on the differentiable
    delay model. Optimizes (log) read-device and write-device widths of the
    bitcell to minimize  t_read * (1 + w*area_overhead).

    OpenGCRAM explores discrete configs only; a differentiable compiler can
    descend the continuous sizing space directly.

    Returns a dict: ``w_read_um``/``w_write_um`` [µm],
    ``t_cell_before_s``/``t_cell_after_s`` [s],
    ``area_before_um2``/``area_after_um2`` [µm²], and ``speedup`` (ratio).
    """
    import jax
    import jax.numpy as jnp

    from repro.core import periphery, tech

    base_cell = bitcells.BITCELLS[cfg.mem_type]
    vec = cfg.to_vector()

    def objective(logw):
        w_read, w_write = jnp.exp(logw)
        # rebuild the geometry with resized devices
        cell = base_cell._replace(
            w_read=w_read, w_write=w_write,
            c_sn=base_cell.c_sn + (w_read - base_cell.w_read) * 1e-15,
            cell_w=base_cell.cell_w * (1 + 0.6 * (w_read - base_cell.w_read
                                                  + w_write - base_cell.w_write)))
        g = macro_mod.geometry(vec)
        g = {**g, "cell": cell}
        area, _ = macro_mod.macro_area(g)
        i_rd = chz._read_current(cell, g["ls"])
        c_bl, r_bl = periphery.bitline_rc(g["rows"], cell.cell_h, cell.w_read)
        t_bl = c_bl * tech.V_SENSE / jnp.maximum(i_rd, 1e-9)
        i_w = chz._write_current(cell, g["ls"])
        t_sn = cell.c_sn * bitcells.sn_high_level(cell, g["ls"]) / jnp.maximum(i_w, 1e-9)
        t = t_bl + t_sn + 0.7 * r_bl * c_bl
        area0, _ = macro_mod.macro_area(macro_mod.geometry(vec))
        # log-space objective: well-scaled gradients regardless of absolute ps
        return jnp.log(t) + area_weight * (area / area0 - 1.0), (t, area)

    logw = jnp.log(jnp.asarray([float(base_cell.w_read),
                                float(base_cell.w_write)]))
    grad_fn = jax.jit(jax.grad(lambda lw: objective(lw)[0]))
    val_fn = jax.jit(lambda lw: objective(lw)[1])
    for _ in range(steps):
        logw = jnp.clip(logw - lr * grad_fn(logw),
                        jnp.log(0.06), jnp.log(0.60))
    t0, a0 = val_fn(jnp.log(jnp.asarray([float(base_cell.w_read),
                                         float(base_cell.w_write)])))
    t1, a1 = val_fn(logw)
    return {
        "w_read_um": float(jnp.exp(logw)[0]),
        "w_write_um": float(jnp.exp(logw)[1]),
        "t_cell_before_s": float(t0), "t_cell_after_s": float(t1),
        "area_before_um2": float(a0), "area_after_um2": float(a1),
        "speedup": float(t0 / t1),
    }
