"""Delay / frequency / bandwidth / power characterization of a macro config.

The full pipeline mirrors OpenGCRAM's HSPICE runs with analytic circuit
models: decoder logical-effort chain -> WL RC -> cell read current
discharging/charging the RBL -> column mux -> sense amp -> output DFF, with
the control delay-chain quantization that produces the paper's 1:1-aspect
frequency cliff. Everything is jnp -> the whole design space characterizes
under one vmap (and is differentiable for the gradient sizing optimizer).

Every stage takes the operating corner (``repro.core.corners.TechParams``)
as an optional trailing argument: the nominal default reproduces the
pre-corner pipeline bit-for-bit, and ``characterize_corners`` vmaps the
whole thing over a stacked (designs x corners) grid in one dispatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitcells, corners, devices, macro, periphery, \
    retention, tech


def _read_current(cell, ls, tp=None):
    """Worst-case sense current: stored-'0' on-current minus the residual
    false current of a worst-case droopy '1' (smaller margin without LS)."""
    tp = corners.resolve(tp)
    rdev = devices.take_device(bitcells.DEVICE_STACK,
                               cell.read_dev.astype(jnp.int32))
    i0 = devices.mosfet_id(rdev, tp.vdd, 0.5 * tp.vdd, cell.w_read, tp)
    v1 = bitcells.sn_high_level(cell, ls, tp)
    i1 = devices.mosfet_id(rdev, tp.vdd - v1, 0.5 * tp.vdd, cell.w_read, tp)
    return jnp.maximum(i0 - i1, 0.05 * i0)


def _write_current(cell, ls, tp=None):
    """Write-device current charging the SN to its target level (end-of-write
    overdrive: WWL - 0.9*target)."""
    tp = corners.resolve(tp)
    wdev = devices.take_device(bitcells.DEVICE_STACK,
                               cell.write_dev.astype(jnp.int32))
    vwwl = jnp.where(ls > 0, tp.vdd_boost, tp.vdd)
    v_sn_v = bitcells.sn_high_level(cell, ls, tp)
    vgs = vwwl - 0.9 * v_sn_v
    return devices.mosfet_id(wdev, vgs, jnp.maximum(tp.vdd - 0.9 * v_sn_v, 0.1),
                             cell.w_write, tp)


def _sram_cell_current(cell, tp=None):
    tp = corners.resolve(tp)
    adev = devices.take_device(bitcells.DEVICE_STACK,
                               cell.write_dev.astype(jnp.int32))
    return 0.8 * devices.i_on(adev, cell.w_write, tp=tp)


def characterize(vec, tp=None):
    """Full PPA + retention characterization of one config vector at one
    operating corner (``tp``: TechParams / OperatingPoint / corner name;
    None = nominal).

    Returns a flat dict of jnp scalars (vmap-able)."""
    tp = corners.resolve(tp)
    g = macro.geometry(vec)
    cell, rows, cols = g["cell"], g["rows"], g["cols"]
    ls, m, wz = g["ls"], g["mux"], g["wz"]
    is_gc = g["is_gc"]

    area_um2, breakdown = macro.macro_area(g)

    # ---------------- read path -------------------------------------------
    _, t_dec_s, e_dec_j, l_dec_a = periphery.decoder(rows, tp)
    c_wl_f, r_wl_ohm = periphery.wordline_rc(cols, cell.cell_w, cell.w_read)
    _, t_wl_s, e_wl_j, l_wl_a = periphery.wl_driver(c_wl_f, r_wl_ohm, tp=tp)
    c_bl_f, r_bl_ohm = periphery.bitline_rc(rows, cell.cell_h, cell.w_read)

    i_rd_gc_a = _read_current(cell, ls, tp)
    t_bl_gc_s = c_bl_f * tp.v_sense / jnp.maximum(i_rd_gc_a, 1e-9)
    i_rd_sram_a = _sram_cell_current(cell, tp)
    t_bl_sram_s = c_bl_f * tp.v_sense_sram / jnp.maximum(i_rd_sram_a, 1e-9)
    t_bl_s = jnp.where(is_gc > 0, t_bl_gc_s, t_bl_sram_s)

    _, t_mux_s, e_mux_j, l_mux_a = periphery.column_mux(m, tp)
    _, t_sa_s, e_sa_j, l_sa_a = periphery.sense_amp(tp=tp)
    _, t_sa2_s, e_sa2_j, l_sa2_a = periphery.sense_amp(current_mode=True,
                                                       tp=tp)
    t_sa_s = jnp.where(g["sa_cm"] > 0, t_sa2_s, t_sa_s)
    e_sa_j = jnp.where(g["sa_cm"] > 0, e_sa2_j, e_sa_j)

    t_read_s = (tech.T_DFF_CQ + t_dec_s + t_wl_s
                + 0.7 * r_bl_ohm * c_bl_f + t_bl_s
                + t_mux_s + t_sa_s + tech.T_SETUP)
    t_read_cyc_s, _, e_dc_j, l_dc_a = periphery.delay_chain(t_read_s, tp)

    # ---------------- write path ------------------------------------------
    c_wwl_f, r_wwl_ohm = periphery.wordline_rc(cols, cell.cell_w,
                                               cell.w_write)
    _, t_wwl_s, e_wwl_j, l_wwl_a = periphery.wl_driver(c_wwl_f, r_wwl_ohm,
                                                       boost=True, tp=tp)
    _, t_ls_s, e_ls_j, l_ls_a = periphery.level_shifter(tp)
    t_wwl_s = t_wwl_s + ls * t_ls_s * is_gc
    c_wbl_f, _ = periphery.bitline_rc(rows, cell.cell_h, cell.w_write)
    _, t_wd_s, e_wd_j, l_wd_a = periphery.write_driver(c_wbl_f, tp)
    i_w_a = _write_current(cell, ls, tp)
    t_sn_s = cell.c_sn * bitcells.sn_high_level(cell, ls, tp) \
        / jnp.maximum(i_w_a, 1e-9)
    t_sn_s = jnp.where(is_gc > 0, t_sn_s, 30e-12)   # SRAM: driver overpowers
    t_write_s = (tech.T_DFF_CQ + t_dec_s + t_wwl_s + t_wd_s + t_sn_s
                 + tech.T_SETUP)
    t_write_cyc_s, _, _, _ = periphery.delay_chain(t_write_s, tp)

    # ---------------- frequency / bandwidth --------------------------------
    f_read_hz = 1.0 / t_read_cyc_s
    f_write_hz = 1.0 / t_write_cyc_s
    # dual-port GC: concurrent R/W; SRAM: shared port (~30% write traffic)
    f_sram_hz = 1.0 / jnp.maximum(t_read_cyc_s, t_write_cyc_s)
    f_op_hz = jnp.where(is_gc > 0, jnp.minimum(f_read_hz, f_write_hz),
                        f_sram_hz)
    # effective READ bandwidth: SRAM's shared port loses ~30% to writes
    # (Fig 8b: "SRAM bandwidth is higher but reduced by the shared port");
    # dual-port GC reads are never blocked, and total BW adds the write port.
    bw_bits = jnp.where(is_gc > 0, wz * f_read_hz, wz * f_sram_hz * 0.7)
    bw_total_bits = jnp.where(
        is_gc > 0, wz * (f_read_hz + f_write_hz * g["dual"]),
        wz * f_sram_hz * 0.7)

    # ---------------- energy / power ---------------------------------------
    e_bl_rd_j = c_bl_f * tp.vdd * tp.v_sense * cols / jnp.maximum(m, 1.0)
    e_read_j = (e_dec_j + e_wl_j + c_wl_f * tp.vdd ** 2 + e_bl_rd_j
                + wz * e_sa_j + e_mux_j + 2 * wz * tech.E_DFF)
    # one write asserts a single WWL, so exactly one row's level shifter
    # switches per access (a previous revision multiplied by `rows` and then
    # zeroed the whole term out; the boost-rail recharge is the separate
    # c_wwl_f term below)
    e_write_j = (e_dec_j + e_wwl_j + e_wd_j * wz + ls * e_ls_j * is_gc
                 + c_wbl_f * tp.vdd ** 2 * wz * 0.5 + wz * tech.E_DFF
                 + ls * is_gc * (c_wwl_f * (tp.vdd_boost ** 2 - tp.vdd ** 2)))
    p_dyn_w = (e_read_j + e_write_j * 0.5) * f_op_hz * tech.ACTIVITY

    # leakage: SRAM array has static VDD->GND paths; GC array has none.
    adev = devices.take_device(bitcells.DEVICE_STACK,
                               cell.write_dev.astype(jnp.int32))
    i_cell_leak_a = cell.leak_paths * devices.i_off(adev, 0.15, tp=tp)
    ncells = g["wz"] * g["nw"]
    p_leak_array_w = ncells * i_cell_leak_a * tp.vdd
    i_periph_leak_a = (l_dec_a * (1 + g["dual"]) + l_wl_a + l_wwl_a
                       + wz * (l_sa_a + l_wd_a) + l_mux_a * cols + l_dc_a
                       + ls * l_ls_a * rows * is_gc
                       + periphery.control(tp)[3]) * g["banks"]
    p_leak_w = p_leak_array_w + i_periph_leak_a * tp.vdd

    # ---------------- retention / refresh -----------------------------------
    t_ret_s = jnp.where(is_gc > 0, retention.retention_time(cell, ls, tp),
                        1e12)
    p_refresh_w = jnp.where(
        is_gc > 0,
        (e_read_j + e_write_j) * g["nw"] / jnp.maximum(t_ret_s, 1e-9), 0.0)

    return {
        "area_um2": area_um2,
        "area_array_um2": breakdown["array"],
        "f_read_hz": jnp.where(is_gc > 0, f_read_hz, f_sram_hz),
        "f_write_hz": jnp.where(is_gc > 0, f_write_hz, f_sram_hz),
        "f_op_hz": f_op_hz,
        "bandwidth_bits_s": bw_bits,
        "bandwidth_total_bits_s": bw_total_bits,
        "t_read_s": t_read_s, "t_write_s": t_write_s,
        "e_read_j": e_read_j, "e_write_j": e_write_j,
        "p_dyn_w": p_dyn_w, "p_leak_w": p_leak_w, "p_refresh_w": p_refresh_w,
        "retention_s": t_ret_s,
        "rows": rows, "cols": cols, "mux": m,
        "bits": ncells,
    }


characterize_batch = jax.jit(jax.vmap(characterize))

# (designs, corners) grid in one dispatch: inner vmap over the stacked
# TechParams corner axis, outer over config vectors. Metric shapes (N, C).
characterize_corners_batch = jax.jit(
    jax.vmap(jax.vmap(characterize, in_axes=(None, 0)), in_axes=(0, None)))


# one jitted vmap closure per corner: tp stays a python-float NamedTuple
# closed over the trace, so XLA folds the very same constants the scalar
# `_characterize_jit` path folds — per-corner columns are bit-identical to
# the same corner characterized alone (a stacked traced-tp operand is not:
# the algebraic simplifier reassociates constants differently there)
@functools.lru_cache(maxsize=32)
def _characterize_vmap_jit(tp):
    return jax.jit(jax.vmap(functools.partial(characterize, tp=tp)))


def characterize_corners(vecs, ops):
    """Characterize config vectors ``vecs`` (N, 7) at every operating point
    of ``ops`` (OperatingPoints / corner names), one vmapped dispatch per
    corner so each corner column is bit-exact with the scalar
    ``characterize_config`` path at that corner.

    Returns a dict of (N, C) jnp arrays, corner order = ``ops`` order."""
    import jax.numpy as jnp

    from repro.analysis import sanitize
    per_corner = []
    for o in ops:
        tp = corners.resolve(corners.as_operating_point(o))
        fn = characterize_batch if tp == corners.NOMINAL_TECH \
            else _characterize_vmap_jit(tp)
        per_corner.append(sanitize.maybe_wrap(fn)(vecs))
    return {k: jnp.stack([out[k] for out in per_corner], axis=1)
            for k in per_corner[0]}


# one jitted closure per corner: tp stays a python-float NamedTuple closed
# over the trace, so its values fold to the same constants the pre-corner
# pipeline folded (bit-for-bit at nominal) instead of becoming traced args
@functools.lru_cache(maxsize=32)
def _characterize_jit(tp):
    return jax.jit(functools.partial(characterize, tp=tp))


def characterize_config(cfg: macro.MacroConfig, tp=None):
    """Single-config convenience wrapper returning python floats.

    ``tp``: operating corner (TechParams / OperatingPoint / name; None =
    nominal)."""
    from repro.analysis import sanitize
    fn = sanitize.maybe_wrap(_characterize_jit(corners.resolve(tp)))
    out = fn(cfg.to_vector())
    return {k: float(v) for k, v in out.items()}
