"""Delay / frequency / bandwidth / power characterization of a macro config.

The full pipeline mirrors OpenGCRAM's HSPICE runs with analytic circuit
models: decoder logical-effort chain -> WL RC -> cell read current
discharging/charging the RBL -> column mux -> sense amp -> output DFF, with
the control delay-chain quantization that produces the paper's 1:1-aspect
frequency cliff. Everything is jnp -> the whole design space characterizes
under one vmap (and is differentiable for the gradient sizing optimizer).

Every stage takes the operating corner (``repro.core.corners.TechParams``)
as an optional trailing argument: the nominal default reproduces the
pre-corner pipeline bit-for-bit, and ``characterize_corners`` vmaps the
whole thing over a stacked (designs x corners) grid in one dispatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitcells, corners, devices, macro, periphery, \
    retention, tech


def _read_current(cell, ls, tp=None):
    """Worst-case sense current: stored-'0' on-current minus the residual
    false current of a worst-case droopy '1' (smaller margin without LS)."""
    tp = corners.resolve(tp)
    rdev = devices.take_device(bitcells.DEVICE_STACK,
                               cell.read_dev.astype(jnp.int32))
    i0 = devices.mosfet_id(rdev, tp.vdd, 0.5 * tp.vdd, cell.w_read, tp)
    v1 = bitcells.sn_high_level(cell, ls, tp)
    i1 = devices.mosfet_id(rdev, tp.vdd - v1, 0.5 * tp.vdd, cell.w_read, tp)
    return jnp.maximum(i0 - i1, 0.05 * i0)


def _write_current(cell, ls, tp=None):
    """Write-device current charging the SN to its target level (end-of-write
    overdrive: WWL - 0.9*target)."""
    tp = corners.resolve(tp)
    wdev = devices.take_device(bitcells.DEVICE_STACK,
                               cell.write_dev.astype(jnp.int32))
    vwwl = jnp.where(ls > 0, tp.vdd_boost, tp.vdd)
    v_t = bitcells.sn_high_level(cell, ls, tp)
    vgs = vwwl - 0.9 * v_t
    return devices.mosfet_id(wdev, vgs, jnp.maximum(tp.vdd - 0.9 * v_t, 0.1),
                             cell.w_write, tp)


def _sram_cell_current(cell, tp=None):
    tp = corners.resolve(tp)
    adev = devices.take_device(bitcells.DEVICE_STACK,
                               cell.write_dev.astype(jnp.int32))
    return 0.8 * devices.i_on(adev, cell.w_write, tp=tp)


def characterize(vec, tp=None):
    """Full PPA + retention characterization of one config vector at one
    operating corner (``tp``: TechParams / OperatingPoint / corner name;
    None = nominal).

    Returns a flat dict of jnp scalars (vmap-able)."""
    tp = corners.resolve(tp)
    g = macro.geometry(vec)
    cell, rows, cols = g["cell"], g["rows"], g["cols"]
    ls, m, wz = g["ls"], g["mux"], g["wz"]
    is_gc = g["is_gc"]

    area, breakdown = macro.macro_area(g)

    # ---------------- read path -------------------------------------------
    dec_a, t_dec, e_dec, l_dec = periphery.decoder(rows, tp)
    c_wl, r_wl = periphery.wordline_rc(cols, cell.cell_w, cell.w_read)
    _, t_wl, e_wl, l_wl = periphery.wl_driver(c_wl, r_wl, tp=tp)
    c_bl, r_bl = periphery.bitline_rc(rows, cell.cell_h, cell.w_read)

    i_rd_gc = _read_current(cell, ls, tp)
    t_bl_gc = c_bl * tp.v_sense / jnp.maximum(i_rd_gc, 1e-9)
    i_rd_sram = _sram_cell_current(cell, tp)
    t_bl_sram = c_bl * tp.v_sense_sram / jnp.maximum(i_rd_sram, 1e-9)
    t_bl = jnp.where(is_gc > 0, t_bl_gc, t_bl_sram)

    _, t_mux, e_mux, l_mux = periphery.column_mux(m, tp)
    sa_a, t_sa, e_sa, l_sa = periphery.sense_amp(tp=tp)
    sa_a2, t_sa2, e_sa2, l_sa2 = periphery.sense_amp(current_mode=True, tp=tp)
    t_sa = jnp.where(g["sa_cm"] > 0, t_sa2, t_sa)
    e_sa = jnp.where(g["sa_cm"] > 0, e_sa2, e_sa)

    t_read = (tech.T_DFF_CQ + t_dec + t_wl + 0.7 * r_bl * c_bl + t_bl
              + t_mux + t_sa + tech.T_SETUP)
    t_read_cyc, dc_a, e_dc, l_dc = periphery.delay_chain(t_read, tp)

    # ---------------- write path ------------------------------------------
    c_wwl, r_wwl = periphery.wordline_rc(cols, cell.cell_w, cell.w_write)
    _, t_wwl, e_wwl, l_wwl = periphery.wl_driver(c_wwl, r_wwl, boost=True,
                                                 tp=tp)
    ls_a, t_ls, e_ls, l_ls = periphery.level_shifter(tp)
    t_wwl = t_wwl + ls * t_ls * is_gc
    c_wbl, _ = periphery.bitline_rc(rows, cell.cell_h, cell.w_write)
    wd_a, t_wd, e_wd, l_wd = periphery.write_driver(c_wbl, tp)
    i_w = _write_current(cell, ls, tp)
    t_sn = cell.c_sn * bitcells.sn_high_level(cell, ls, tp) \
        / jnp.maximum(i_w, 1e-9)
    t_sn = jnp.where(is_gc > 0, t_sn, 30e-12)       # SRAM: driver overpowers
    t_write = tech.T_DFF_CQ + t_dec + t_wwl + t_wd + t_sn + tech.T_SETUP
    t_write_cyc, _, _, _ = periphery.delay_chain(t_write, tp)

    # ---------------- frequency / bandwidth --------------------------------
    f_read = 1.0 / t_read_cyc
    f_write = 1.0 / t_write_cyc
    # dual-port GC: concurrent R/W; SRAM: shared port (~30% write traffic)
    f_sram = 1.0 / jnp.maximum(t_read_cyc, t_write_cyc)
    f_op = jnp.where(is_gc > 0, jnp.minimum(f_read, f_write), f_sram)
    # effective READ bandwidth: SRAM's shared port loses ~30% to writes
    # (Fig 8b: "SRAM bandwidth is higher but reduced by the shared port");
    # dual-port GC reads are never blocked, and total BW adds the write port.
    bw_bits = jnp.where(is_gc > 0, wz * f_read, wz * f_sram * 0.7)
    bw_total_bits = jnp.where(
        is_gc > 0, wz * (f_read + f_write * g["dual"]), wz * f_sram * 0.7)

    # ---------------- energy / power ---------------------------------------
    e_bl_rd = c_bl * tp.vdd * tp.v_sense * cols / jnp.maximum(m, 1.0)
    e_read = (e_dec + e_wl + c_wl * tp.vdd ** 2 + e_bl_rd + wz * e_sa
              + e_mux + 2 * wz * tech.E_DFF)
    # one write asserts a single WWL, so exactly one row's level shifter
    # switches per access (a previous revision multiplied by `rows` and then
    # zeroed the whole term out; the boost-rail recharge is the separate
    # c_wwl term below)
    e_write = (e_dec + e_wwl + e_wd * wz + ls * e_ls * is_gc
               + c_wbl * tp.vdd ** 2 * wz * 0.5 + wz * tech.E_DFF
               + ls * is_gc * (c_wwl * (tp.vdd_boost ** 2 - tp.vdd ** 2)))
    p_dyn = (e_read + e_write * 0.5) * f_op * tech.ACTIVITY

    # leakage: SRAM array has static VDD->GND paths; GC array has none.
    adev = devices.take_device(bitcells.DEVICE_STACK,
                               cell.write_dev.astype(jnp.int32))
    i_cell_leak = cell.leak_paths * devices.i_off(adev, 0.15, tp=tp)
    ncells = g["wz"] * g["nw"]
    p_leak_array = ncells * i_cell_leak * tp.vdd
    periph_leak = (l_dec * (1 + g["dual"]) + l_wl + l_wwl + wz * (l_sa + l_wd)
                   + l_mux * cols + l_dc + ls * l_ls * rows * is_gc
                   + periphery.control(tp)[3]) * g["banks"]
    p_leak = p_leak_array + periph_leak * tp.vdd

    # ---------------- retention / refresh -----------------------------------
    t_ret = jnp.where(is_gc > 0, retention.retention_time(cell, ls, tp), 1e12)
    p_refresh = jnp.where(
        is_gc > 0,
        (e_read + e_write) * g["nw"] / jnp.maximum(t_ret, 1e-9), 0.0)

    return {
        "area_um2": area,
        "area_array_um2": breakdown["array"],
        "f_read_hz": jnp.where(is_gc > 0, f_read, f_sram),
        "f_write_hz": jnp.where(is_gc > 0, f_write, f_sram),
        "f_op_hz": f_op,
        "bandwidth_bits_s": bw_bits,
        "bandwidth_total_bits_s": bw_total_bits,
        "t_read_s": t_read, "t_write_s": t_write,
        "e_read_j": e_read, "e_write_j": e_write,
        "p_dyn_w": p_dyn, "p_leak_w": p_leak, "p_refresh_w": p_refresh,
        "retention_s": t_ret,
        "rows": rows, "cols": cols, "mux": m,
        "bits": ncells,
    }


characterize_batch = jax.jit(jax.vmap(characterize))

# (designs, corners) grid in one dispatch: inner vmap over the stacked
# TechParams corner axis, outer over config vectors. Metric shapes (N, C).
characterize_corners_batch = jax.jit(
    jax.vmap(jax.vmap(characterize, in_axes=(None, 0)), in_axes=(0, None)))


def characterize_corners(vecs, ops):
    """Characterize config vectors ``vecs`` (N, 7) at every operating point
    of ``ops`` (OperatingPoints / corner names) in one vmapped dispatch.

    Returns a dict of (N, C) jnp arrays, corner order = ``ops`` order."""
    tps = corners.stack_tech([corners.as_operating_point(o) for o in ops])
    return characterize_corners_batch(vecs, tps)


# one jitted closure per corner: tp stays a python-float NamedTuple closed
# over the trace, so its values fold to the same constants the pre-corner
# pipeline folded (bit-for-bit at nominal) instead of becoming traced args
@functools.lru_cache(maxsize=32)
def _characterize_jit(tp):
    return jax.jit(functools.partial(characterize, tp=tp))


def characterize_config(cfg: macro.MacroConfig, tp=None):
    """Single-config convenience wrapper returning python floats.

    ``tp``: operating corner (TechParams / OperatingPoint / name; None =
    nominal)."""
    out = _characterize_jit(corners.resolve(tp))(cfg.to_vector())
    return {k: float(v) for k, v in out.items()}
