# The paper's primary contribution: the OpenGCRAM memory compiler in JAX —
# device models, bitcells, macro composition, SPICE-style characterization
# (delay/power/retention), netlist+layout with DRC/LVS checks, artifact
# emission, and the heterogeneous-memory design-space exploration engine.
#
# The public entry point is the `repro.api` façade (Compiler / DesignTable /
# explore); the names below are the physics layer plus legacy re-exports.
from repro.core.macro import MacroConfig  # noqa: F401
from repro.core.characterize import characterize_batch, characterize_config  # noqa: F401
from repro.core.retention import retention_time, decay_curve, retention_estimate  # noqa: F401
from repro.core.artifacts import generate_all  # noqa: F401
from repro.core import characterize, dse, gainsight, retention  # noqa: F401,F811

# keep the submodules (not same-named functions) bound on the package
import sys as _sys
characterize = _sys.modules["repro.core.characterize"]
retention = _sys.modules["repro.core.retention"]
