"""Macro-level composition: geometry, floorplan areas, and the MacroConfig
that the whole compiler flows from.

A GCRAM macro (paper Fig 4): GCRAM bank + Data_DFF + read/write controllers;
inside the bank, Write_Port_Address/Data drive WWL/WBL and
Read_Port_Address/Data drive RWL and sense RBL. SRAM macros share the
structure with a single shared port and differential BLs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp

from repro.core import bitcells, periphery, tech


@dataclass(frozen=True)
class MacroConfig:
    mem_type: str = "gc_sisi"     # key into bitcells.BITCELLS
    word_size: int = 32           # WZ bits
    num_words: int = 32           # NW
    banks: int = 1
    level_shift: bool = False     # WWL level shifter (+boost ring)
    sa_current_mode: bool = False
    mux: int = 0                  # 0 = auto (square-ish aspect)

    @property
    def bits(self):
        return self.word_size * self.num_words

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)

    def to_vector(self):
        """Numeric encoding for the vmap'd characterization path."""
        return jnp.asarray([
            bitcells.MEM_TYPE[self.mem_type], self.word_size, self.num_words,
            self.banks, int(self.level_shift), int(self.sa_current_mode),
            self.mux,
        ], jnp.float32)


VEC_FIELDS = ("mem_type", "word_size", "num_words", "banks", "level_shift",
              "sa_current_mode", "mux")


def auto_mux(word_size, num_words):
    """Pick a power-of-2 column-mux ratio that squares the array."""
    target = jnp.sqrt(num_words / jnp.maximum(word_size, 1.0))
    m = 2.0 ** jnp.round(jnp.log2(jnp.maximum(target, 1.0)))
    return jnp.clip(m, 1.0, 8.0)


def geometry(vec):
    """vec -> dict of geometric quantities (all jnp scalars)."""
    mem_idx = vec[0].astype(jnp.int32)
    wz, nw, banks = vec[1], vec[2], vec[3]
    ls, sa_cm, mux = vec[4], vec[5], vec[6]
    cell = bitcells.take_bitcell(bitcells.stack_bitcells(), mem_idx)
    nw_bank = nw / banks
    m = jnp.where(mux > 0, mux, auto_mux(wz, nw_bank))
    m = jnp.minimum(m, nw_bank)                      # cannot exceed words/bank
    rows = jnp.maximum(nw_bank / m, 1.0)
    cols = wz * m
    return {
        "cell": cell, "mem_idx": mem_idx, "wz": wz, "nw": nw, "banks": banks,
        "ls": ls, "sa_cm": sa_cm, "mux": m, "rows": rows, "cols": cols,
        "is_gc": (cell.kind > 0).astype(jnp.float32),
        "dual": cell.dual_port,
    }


def macro_area(g):
    """Total macro area [um^2] incl. periphery, control, power rings.

    Returns (total, breakdown dict)."""
    cell, rows, cols = g["cell"], g["rows"], g["cols"]
    wz, m, ls, dual = g["wz"], g["mux"], g["ls"], g["dual"]
    arr_w = cols * cell.cell_w
    arr_h = rows * cell.cell_h * 1.04               # WL strap overhead
    a_array = arr_w * arr_h

    dec_area, _, _, _ = periphery.decoder(rows)
    c_wl, r_wl = periphery.wordline_rc(cols, cell.cell_w, cell.w_write)
    drv_area, _, _, _ = periphery.wl_driver(c_wl, r_wl)
    a_row_port = dec_area + rows * drv_area
    # GCRAM: separate read + write row ports; write port may add LS per row
    a_row = a_row_port * (1.0 + dual) + ls * rows * tech.LS_AREA * g["is_gc"]

    sa_area, _, _, _ = periphery.sense_amp()
    sa_area_cm, _, _, _ = periphery.sense_amp(current_mode=True)
    a_sa = wz * jnp.where(g["sa_cm"] > 0, sa_area_cm, sa_area)
    c_bl, _ = periphery.bitline_rc(rows, cell.cell_h, cell.w_read)
    wd_area, _, _, _ = periphery.write_driver(c_bl)
    mux_a, _, _, _ = periphery.column_mux(m)
    a_col = (a_sa + wz * wd_area + cols * mux_a
             + cols * jnp.where(g["is_gc"] > 0, tech.PREDIS_AREA,
                                tech.PRECH_AREA))
    # data + address DFFs (dual-port GC: separate addr regs per port)
    n_addr = jnp.ceil(jnp.log2(jnp.maximum(g["nw"], 2.0)))
    a_dff = (2 * wz + n_addr * (1.0 + dual)) * tech.DFF_AREA

    a_ctrl, _, _, _ = periphery.control()
    a_ctrl = a_ctrl * (1.0 + 0.5 * dual)            # separate R/W controllers

    core_area = (a_array + a_row + a_col + a_dff + a_ctrl) * g["banks"]
    core_area = core_area + (g["banks"] > 1) * 40.0 * g["banks"]  # bank decode

    # power rings: 2 supplies + 1 boost ring when level-shifted
    side = jnp.sqrt(core_area)
    n_rings = 2.0 + ls * g["is_gc"]
    a_ring = 4.0 * side * tech.RING_PITCH_UM * n_rings
    total = core_area + a_ring
    return total, {
        "array": a_array * g["banks"], "row_periph": a_row * g["banks"],
        "col_periph": a_col * g["banks"], "dff": a_dff * g["banks"],
        "control": a_ctrl * g["banks"], "ring": a_ring,
    }
