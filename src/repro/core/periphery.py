"""Peripheral circuit models: decoders, wordline drivers, sense amps, write
drivers, predischarge/precharge, level shifters, DFFs, delay chain, control.

Each helper returns jnp-friendly scalars (area um^2 / delay s / energy J /
leakage A) parameterized by the macro geometry, so the whole periphery rolls
up under vmap across the design space. Drivers are auto-sized: delay is held
near a target and the AREA grows with load (logical-effort style sizing).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import corners, devices, tech

INV_LEAK = 60e-12    # A per um of gate width, periphery std-cell average
INV_CIN = 1.5e-15    # F per um of input gate width


def wordline_rc(cols, cell_w, w_access):
    """C and R of one wordline spanning `cols` cells."""
    c = cols * (w_access * tech.C_GATE_PER_UM + cell_w * tech.C_WIRE_PER_UM)
    r = cols * cell_w * tech.R_WIRE_PER_UM
    return c, r


def bitline_rc(rows, cell_h, w_drain):
    c = rows * (w_drain * tech.C_JUNC_PER_UM + cell_h * tech.C_WIRE_PER_UM)
    r = rows * cell_h * tech.R_WIRE_PER_UM
    return c, r


def decoder(rows, tp=None):
    """Row decoder: predecode + final NAND per row. Returns (area, delay,
    energy/access, leakage). ``tp`` = operating corner (switching energies
    scale with vdd^2)."""
    tp = corners.resolve(tp)
    n_addr = jnp.ceil(jnp.log2(jnp.maximum(rows, 2.0)))
    stages = 2.0 + jnp.ceil(n_addr / 3.0)          # predecode depth
    area_um2 = rows * tech.GATE_AREA + n_addr * 4.0 * tech.GATE_AREA
    delay_s = stages * tech.T_GATE
    energy_j = (n_addr * 4.0 + 2.0) * 1.2e-15 * tp.vdd ** 2
    leak_a = (rows + n_addr * 4.0) * 0.5 * INV_LEAK
    return area_um2, delay_s, energy_j, leak_a


def wl_driver(c_load, r_wire, boost=False, tp=None):
    """Auto-sized WL driver: fixed ~T_WL_DRV drive delay + wire RC tail; area
    scales with the load it must drive. `boost` = driven from VDD_BOOST rail
    (level-shifted WWL)."""
    tp = corners.resolve(tp)
    vdd = tp.vdd_boost if boost else tp.vdd
    w_drv = jnp.maximum(c_load / (8.0 * INV_CIN), 1.0)      # fanout-of-8 sizing
    area_um2 = 0.8 + 0.35 * w_drv
    delay_s = tech.T_WL_DRV + 0.4 * r_wire * c_load
    energy_j = (c_load + w_drv * INV_CIN) * vdd ** 2
    leak_a = w_drv * INV_LEAK
    return area_um2, delay_s, energy_j, leak_a


def level_shifter(tp=None):
    """WWL level shifter (per row): area + small insertion delay. The boost
    rail also costs an extra power ring at the macro level (macro.py)."""
    tp = corners.resolve(tp)
    return tech.LS_AREA, 18e-12, 2.5e-15 * tp.vdd_boost ** 2 / tp.vdd ** 2, 2 * INV_LEAK


def sense_amp(current_mode=False, tp=None):
    tp = corners.resolve(tp)
    e_sa_j = tech.E_SA * (tp.vdd ** 2 / tech.VDD ** 2)  # CV^2-class sense op
    if current_mode:
        return (tech.SA_AREA_CURRENT, tech.T_SA_CURRENT, e_sa_j * 1.6,
                4 * INV_LEAK)
    return tech.SA_AREA, tech.T_SA, e_sa_j, 3 * INV_LEAK


def write_driver(c_bl, tp=None):
    tp = corners.resolve(tp)
    w_drv = jnp.maximum(c_bl / (10.0 * INV_CIN), 1.0)
    area_um2 = tech.WRITE_DRV_AREA + 0.3 * w_drv
    delay_s = 20e-12 + c_bl * tp.vdd / devices.i_on(devices.SI_NMOS, w_drv,
                                                    tp=tp)
    energy_j = c_bl * tp.vdd ** 2 * 0.5            # avg data activity
    leak_a = w_drv * INV_LEAK
    return area_um2, delay_s, energy_j, leak_a


def column_mux(mux_ratio, tp=None):
    """Pass-gate column mux: delay per stage, area per column."""
    tp = corners.resolve(tp)
    is_mux = (mux_ratio > 1).astype(jnp.float32) if hasattr(mux_ratio, "astype") \
        else float(mux_ratio > 1)
    stages = jnp.ceil(jnp.log2(jnp.maximum(mux_ratio, 1.0)))
    area_per_col_um2 = 0.9 * is_mux
    delay_s = stages * tech.T_MUX
    energy_j = stages * 0.8e-15 * tp.vdd ** 2
    return area_per_col_um2, delay_s, energy_j, 0.2 * INV_LEAK * is_mux


def predischarge(rows, tp=None):
    """NMOS predischarge of the RBL (GCRAM read port, active-high EN —
    OpenGCRAM adds the extra inverter in the read controller, §4.2)."""
    tp = corners.resolve(tp)
    return tech.PREDIS_AREA, 25e-12, 0.5e-15 * tp.vdd ** 2, 0.3 * INV_LEAK


def precharge(rows, tp=None):
    """PMOS precharge pair (SRAM differential BLs)."""
    tp = corners.resolve(tp)
    return tech.PRECH_AREA, 25e-12, 1.0e-15 * tp.vdd ** 2, 0.5 * INV_LEAK


def dff():
    return tech.DFF_AREA, tech.T_DFF_CQ, tech.E_DFF, 1.2 * INV_LEAK


def delay_chain(t_crit, tp=None):
    """Timing-closure delay chain: quantizes the cycle to DELAY_STAGE ticks
    (+1 margin stage). This is what produces the paper's sharp frequency drop
    for tall 1:1 arrays (Fig 8a)."""
    tp = corners.resolve(tp)
    n_stages = jnp.ceil(t_crit / tech.DELAY_STAGE) + 1.0
    t_cycle_s = n_stages * tech.DELAY_STAGE
    area_um2 = n_stages * tech.DELAY_STAGE_AREA
    energy_j = n_stages * 1.0e-15 * tp.vdd ** 2
    leak_a = n_stages * 0.8 * INV_LEAK
    return t_cycle_s, area_um2, energy_j, leak_a


def control(tp=None):
    tp = corners.resolve(tp)
    return tech.CTRL_AREA, 0.0, 6e-15 * tp.vdd ** 2, 25 * INV_LEAK
