"""DEPRECATED free-function DSE interface (paper §5.4).

The design-space exploration pipeline now lives behind the compiler façade:

    from repro.api import Compiler, DesignTable, explore

    report = explore()                      # grid -> Table 2 in one call
    table = DesignTable.build(cache=...)    # cached characterization
    macro = Compiler().compile(cfg)         # one macro, PPA + artifacts

Every name below is a thin shim kept so existing call sites (and the seed
tests) keep working; each emits a DeprecationWarning pointing at its
replacement. New code should import from :mod:`repro.api`.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Sequence

import numpy as np

from repro.core import macro
# re-exported data model (canonical home: repro.core.select / repro.api)
from repro.core.select import (  # noqa: F401
    DISPLAY, PREFERENCE, TECH_FAMILIES, Bucket, LevelReq, SelectionPolicy,
    family_of,
)


def _deprecated(old: str, new: str):
    warnings.warn(f"repro.core.dse.{old} is deprecated; use repro.api.{new}",
                  DeprecationWarning, stacklevel=3)


def design_space(mem_types: Sequence[str] = ("sram6t", "gc_sisi", "gc_ossi"),
                 word_sizes=(16, 32, 64, 128),
                 num_words=(16, 32, 64, 128, 256, 512),
                 ls_options=(False, True),
                 banks=(1,)) -> List[macro.MacroConfig]:
    _deprecated("design_space", "design_space")
    from repro import api
    return api.design_space(mem_types=mem_types, word_sizes=word_sizes,
                            num_words=num_words, ls_options=ls_options,
                            banks=banks)


def evaluate_space(configs: Sequence[macro.MacroConfig]) -> Dict[str, np.ndarray]:
    _deprecated("evaluate_space", "DesignTable.from_configs")
    from repro import api
    return api.DesignTable.from_configs(configs).metrics


def feasible_mask(res: Dict[str, np.ndarray], f_hz: float, lifetime_s: float,
                  allow_refresh: bool = False) -> np.ndarray:
    _deprecated("feasible_mask", "DesignTable.feasible / select.feasible_mask")
    from repro.core import select
    return select.feasible_mask(res, f_hz, lifetime_s,
                                allow_refresh=allow_refresh)


def tech_of(config: macro.MacroConfig) -> str:
    _deprecated("tech_of", "family_of")
    return family_of(config.mem_type)


def select_bucket(configs, res, bucket: Bucket, preference=PREFERENCE,
                  allow_refresh=False):
    _deprecated("select_bucket", "explore")
    from repro.core import select
    fams = np.array([family_of(c.mem_type) for c in configs])
    policy = SelectionPolicy(preference=tuple(preference),
                             allow_refresh=allow_refresh)
    return select.select_bucket_idx(res, fams, bucket, policy)


def select_level(configs, res, level: LevelReq, preference=PREFERENCE,
                 allow_refresh=False):
    """Heterogeneous composition, legacy return shape:
    ``(label, [{"bucket", "family", "config_idx"}, ...])``."""
    _deprecated("select_level", "explore")
    from repro.core import select
    fams = np.array([family_of(c.mem_type) for c in configs])
    policy = SelectionPolicy(preference=tuple(preference),
                             allow_refresh=allow_refresh)
    sel = select.select_level(res, fams, level, policy)
    picks = [{"bucket": p.bucket, "family": p.family,
              "config_idx": p.config_idx} for p in sel.picks]
    return sel.label, picks


def shmoo(configs, res, f_req_hz: float, lifetime_s: float) -> np.ndarray:
    """Fig 11: boolean feasibility per config (green/red)."""
    _deprecated("shmoo", "DesignTable.shmoo / DSEReport.shmoo")
    from repro.core import select
    return select.feasible_mask(res, f_req_hz, lifetime_s)


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Non-dominated mask for rows of (lower-is-better) objectives."""
    _deprecated("pareto_front", "DesignTable.pareto")
    from repro.core import select
    return select.pareto_mask(points)


def gradient_size_macro(cfg: macro.MacroConfig, steps: int = 200,
                        lr: float = 0.03, area_weight: float = 0.2):
    _deprecated("gradient_size_macro", "gradient_size_macro")
    from repro import api
    return api.gradient_size_macro(cfg, steps=steps, lr=lr,
                                   area_weight=area_weight)
