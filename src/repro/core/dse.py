"""Design-space exploration (paper §5.4).

Pipeline: enumerate the config grid -> vmap-characterize -> per-task
feasibility (read frequency + data lifetime vs retention) -> technology
selection under the paper's policy ("higher-speed and higher-retention types
cover lower ones; prefer power/density: OS-Si ≻ Si-Si ≻ SRAM when speed
permits") -> heterogeneous composition per lifetime/frequency bucket
(Table 2) and per-config shmoo maps (Fig 11). Plus: Pareto front and a
beyond-paper gradient-based sizing optimizer (the differentiable models make
the whole compiler jax.grad-able).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitcells, characterize as chz, macro

TECH_FAMILIES = {
    "sram": ("sram6t",),
    "si-si": ("gc_sisi", "gc_sisi_hvt"),
    "os-si": ("gc_ossi", "gc_ossi_hvt"),
    "os-os": ("gc_osos", "gc_osos_hvt"),
}
# paper's preference order when multiple technologies satisfy the constraints
PREFERENCE = ("os-si", "si-si", "sram")
DISPLAY = {"os-si": "OS-Si GCRAM", "si-si": "Si-Si GCRAM", "sram": "SRAM",
           "os-os": "OS-OS GCRAM"}


def design_space(mem_types: Sequence[str] = ("sram6t", "gc_sisi", "gc_ossi"),
                 word_sizes=(16, 32, 64, 128),
                 num_words=(16, 32, 64, 128, 256, 512),
                 ls_options=(False, True),
                 banks=(1,)) -> List[macro.MacroConfig]:
    out = []
    for mt in mem_types:
        for wz in word_sizes:
            for nw in num_words:
                for b in banks:
                    for ls in (ls_options if mt != "sram6t" else (False,)):
                        out.append(macro.MacroConfig(
                            mem_type=mt, word_size=wz, num_words=nw,
                            banks=b, level_shift=ls))
    return out


def evaluate_space(configs: Sequence[macro.MacroConfig]) -> Dict[str, np.ndarray]:
    vecs = jnp.stack([c.to_vector() for c in configs])
    out = chz.characterize_batch(vecs)
    return {k: np.asarray(v) for k, v in out.items()}


# ---------------------------------------------------------------------------
# task requirements
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Bucket:
    """One capacity fraction of a cache level: required read frequency [Hz]
    and maximum data lifetime [s] of the lines mapped to it."""
    frac: float
    f_hz: float
    lifetime_s: float


@dataclass(frozen=True)
class LevelReq:
    name: str                 # "L1" | "L2"
    capacity_bits: int
    buckets: Tuple[Bucket, ...]


def feasible_mask(res: Dict[str, np.ndarray], f_hz: float, lifetime_s: float,
                  allow_refresh: bool = False) -> np.ndarray:
    # a cache level must sustain the read stream AND the fills: gate on the
    # operating frequency (min of read/write cycle) — the OS write transistor
    # is what caps OS-Si/OS-OS macros (paper Fig 8a)
    ok_f = res["f_op_hz"] >= f_hz
    ok_ret = res["retention_s"] >= lifetime_s
    if allow_refresh:
        # refresh is viable when it costs <10% of the macro's dynamic power
        ok_ret = ok_ret | (res["p_refresh_w"] < 0.1 * np.maximum(
            res["p_dyn_w"], 1e-12))
    return ok_f & ok_ret


def tech_of(config: macro.MacroConfig) -> str:
    for fam, members in TECH_FAMILIES.items():
        if config.mem_type in members:
            return fam
    raise KeyError(config.mem_type)


def select_bucket(configs, res, bucket: Bucket, preference=PREFERENCE,
                  allow_refresh=False):
    """Paper policy: among feasible configs, prefer OS-Si, then Si-Si, then
    SRAM; within a family pick lowest (leak+refresh) power, then area.

    ``allow_refresh`` extends feasibility to refreshed gain cells (used by the
    TPU-analog profiler for hour-lived weight storage, matching the paper's
    'weight storage in AI inference' use case)."""
    mask = feasible_mask(res, bucket.f_hz, bucket.lifetime_s,
                         allow_refresh=allow_refresh)
    fams = np.array([tech_of(c) for c in configs])
    for fam in preference:
        idx = np.where(mask & (fams == fam))[0]
        if idx.size:
            cost = (res["p_leak_w"][idx] + res["p_refresh_w"][idx],
                    res["area_um2"][idx])
            order = np.lexsort((cost[1], cost[0]))
            return fam, int(idx[order[0]])
    return None, -1


def select_level(configs, res, level: LevelReq, preference=PREFERENCE,
                 allow_refresh=False):
    """Heterogeneous composition: one technology per bucket (Table 2)."""
    picks = []
    for b in level.buckets:
        fam, idx = select_bucket(configs, res, b, preference, allow_refresh)
        picks.append({"bucket": b, "family": fam, "config_idx": idx})
    fams = []
    for p in picks:
        if p["family"] and p["family"] not in fams:
            fams.append(p["family"])
    label = " + ".join(DISPLAY[f] for f in fams) if fams else "infeasible"
    return label, picks


def shmoo(configs, res, f_req_hz: float, lifetime_s: float) -> np.ndarray:
    """Fig 11: boolean feasibility per config (green/red)."""
    return feasible_mask(res, f_req_hz, lifetime_s)


# ---------------------------------------------------------------------------
# Pareto + gradient sizing (beyond paper)
# ---------------------------------------------------------------------------


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Non-dominated mask for rows of (lower-is-better) objectives."""
    n = points.shape[0]
    dominated = np.zeros(n, bool)
    for i in range(n):
        if dominated[i]:
            continue
        dom = np.all(points <= points[i], axis=1) & np.any(
            points < points[i], axis=1)
        if np.any(dom):
            dominated[i] = True
    return ~dominated


def gradient_size_macro(cfg: macro.MacroConfig, steps: int = 200,
                        lr: float = 0.03, area_weight: float = 0.2):
    """Beyond-paper: continuous sizing via jax.grad on the differentiable
    delay model. Optimizes (log) read-device and write-device widths of the
    bitcell to minimize  t_read * (1 + w*area_overhead).

    OpenGCRAM explores discrete configs only; a differentiable compiler can
    descend the continuous sizing space directly."""
    base_cell = bitcells.BITCELLS[cfg.mem_type]
    vec = cfg.to_vector()

    from repro.core import periphery, tech

    def objective(logw):
        w_read, w_write = jnp.exp(logw)
        # rebuild the geometry with resized devices
        cell = base_cell._replace(
            w_read=w_read, w_write=w_write,
            c_sn=base_cell.c_sn + (w_read - base_cell.w_read) * 1e-15,
            cell_w=base_cell.cell_w * (1 + 0.6 * (w_read - base_cell.w_read
                                                  + w_write - base_cell.w_write)))
        g = macro.geometry(vec)
        g = {**g, "cell": cell}
        area, _ = macro.macro_area(g)
        i_rd = chz._read_current(cell, g["ls"])
        c_bl, r_bl = periphery.bitline_rc(g["rows"], cell.cell_h, cell.w_read)
        t_bl = c_bl * tech.V_SENSE / jnp.maximum(i_rd, 1e-9)
        i_w = chz._write_current(cell, g["ls"])
        t_sn = cell.c_sn * bitcells.sn_high_level(cell, g["ls"]) / jnp.maximum(i_w, 1e-9)
        t = t_bl + t_sn + 0.7 * r_bl * c_bl
        area0, _ = macro.macro_area(macro.geometry(vec))
        # log-space objective: well-scaled gradients regardless of absolute ps
        return jnp.log(t) + area_weight * (area / area0 - 1.0), (t, area)

    logw = jnp.log(jnp.asarray([float(base_cell.w_read),
                                float(base_cell.w_write)]))
    grad_fn = jax.jit(jax.grad(lambda lw: objective(lw)[0]))
    val_fn = jax.jit(lambda lw: objective(lw)[1])
    hist = []
    for i in range(steps):
        g_ = grad_fn(logw)
        logw = logw - lr * g_
        logw = jnp.clip(logw, jnp.log(0.06), jnp.log(0.60))
    t0, a0 = val_fn(jnp.log(jnp.asarray([float(base_cell.w_read),
                                         float(base_cell.w_write)])))
    t1, a1 = val_fn(logw)
    return {
        "w_read_um": float(jnp.exp(logw)[0]),
        "w_write_um": float(jnp.exp(logw)[1]),
        "t_cell_before_s": float(t0), "t_cell_after_s": float(t1),
        "area_before_um2": float(a0), "area_after_um2": float(a1),
        "speedup": float(t0 / t1),
    }
