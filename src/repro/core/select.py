"""Selection data model + policy primitives for the memory DSE.

This is the *leaf* layer of the compiler façade (`repro.api`): workload
requirements (`Bucket`, `LevelReq`, `TaskReq`), the paper's technology
selection policy (`SelectionPolicy`, §5.4: "higher-speed and higher-retention
types cover lower ones; prefer power/density: OS-Si ≻ Si-Si ≻ SRAM when speed
permits"), and the pure-numpy feasibility / Pareto / bucket-selection
primitives those policies are built from.

It deliberately imports nothing from the rest of ``repro`` so that
``repro.core.gainsight`` (task tables) and ``repro.core.dse`` (deprecated
shims) can import the data model without creating a cycle through the
``repro.api`` façade.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Mapping, Sequence, Tuple

import numpy as np

# bitcell name -> technology family (paper nomenclature)
TECH_FAMILIES: Dict[str, Tuple[str, ...]] = {
    "sram": ("sram6t",),
    "si-si": ("gc_sisi", "gc_sisi_hvt"),
    "os-si": ("gc_ossi", "gc_ossi_hvt"),
    "os-os": ("gc_osos", "gc_osos_hvt"),
}
# paper's preference order when multiple technologies satisfy the constraints
PREFERENCE: Tuple[str, ...] = ("os-si", "si-si", "sram")
DISPLAY: Dict[str, str] = {"os-si": "OS-Si GCRAM", "si-si": "Si-Si GCRAM",
                           "sram": "SRAM", "os-os": "OS-OS GCRAM"}

_FAMILY_OF = {m: fam for fam, members in TECH_FAMILIES.items()
              for m in members}


def family_of(mem_type: str) -> str:
    """Technology family ("sram" | "si-si" | "os-si" | "os-os") of a bitcell."""
    try:
        return _FAMILY_OF[mem_type]
    except KeyError:
        raise KeyError(f"unknown mem_type {mem_type!r}") from None


# ---------------------------------------------------------------------------
# workload requirements
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Bucket:
    """One capacity fraction of a cache level: required read frequency [Hz]
    and maximum data lifetime [s] of the lines mapped to it."""
    frac: float
    f_hz: float
    lifetime_s: float


@dataclass(frozen=True)
class LevelReq:
    name: str                 # "L1" | "L2"
    capacity_bits: int
    buckets: Tuple[Bucket, ...]


@dataclass(frozen=True)
class TaskReq:
    """Normalized workload: one entry per cache level (GainSight Table 1 rows
    and the TPU-analog profiler both reduce to this)."""
    task_id: object
    name: str
    levels: Mapping[str, LevelReq]


def as_task_req(task) -> TaskReq:
    """Coerce a task-like object into a TaskReq.

    Accepts TaskReq itself, anything with ``.l1``/``.l2`` LevelReqs
    (``repro.core.gainsight.Task``), or a ``(task_id, name, {level: LevelReq})``
    tuple / plain ``{level: LevelReq}`` mapping.
    """
    if isinstance(task, TaskReq):
        return task
    if hasattr(task, "l1") and hasattr(task, "l2"):
        return TaskReq(getattr(task, "task_id", getattr(task, "name", "?")),
                       getattr(task, "name", "?"),
                       {"L1": task.l1, "L2": task.l2})
    if isinstance(task, tuple) and len(task) == 3:
        return TaskReq(task[0], task[1], dict(task[2]))
    if isinstance(task, Mapping):
        levels = {k: v for k, v in task.items() if isinstance(v, LevelReq)}
        if levels:
            name = str(task.get("name", "+".join(levels)))
            return TaskReq(task.get("task_id", name), name, levels)
    raise TypeError(f"cannot interpret {task!r} as a task requirement")


# ---------------------------------------------------------------------------
# selection policy + primitives
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SelectionPolicy:
    """The paper's technology-selection policy, made explicit.

    ``preference``    family order tried until one has a feasible config.
    ``allow_refresh`` extend feasibility to refreshed gain cells whose refresh
                      power stays below ``refresh_power_frac`` of dynamic
                      power (paper §5.3, hour-lived weight storage).
    """
    preference: Tuple[str, ...] = PREFERENCE
    allow_refresh: bool = False
    refresh_power_frac: float = 0.1


def feasible_mask(metrics: Mapping[str, np.ndarray], f_hz: float,
                  lifetime_s: float, allow_refresh: bool = False,
                  refresh_power_frac: float = 0.1) -> np.ndarray:
    """Boolean feasibility per config for one (frequency, lifetime) point.

    A cache level must sustain the read stream AND the fills: gate on the
    operating frequency (min of read/write cycle) — the OS write transistor
    is what caps OS-Si/OS-OS macros (paper Fig 8a)."""
    ok_f = np.asarray(metrics["f_op_hz"]) >= f_hz
    ok_ret = np.asarray(metrics["retention_s"]) >= lifetime_s
    if allow_refresh:
        ok_ret = ok_ret | (np.asarray(metrics["p_refresh_w"])
                           < refresh_power_frac
                           * np.maximum(np.asarray(metrics["p_dyn_w"]), 1e-12))
    return ok_f & ok_ret


def pareto_mask(points: np.ndarray) -> np.ndarray:
    """Non-dominated mask for rows of (lower-is-better) objectives."""
    points = np.asarray(points)
    n = points.shape[0]
    dominated = np.zeros(n, bool)
    for i in range(n):
        if dominated[i]:
            continue
        dom = np.all(points <= points[i], axis=1) & np.any(
            points < points[i], axis=1)
        if np.any(dom):
            dominated[i] = True
    return ~dominated


def select_bucket_idx(metrics: Mapping[str, np.ndarray],
                      families: np.ndarray, bucket: Bucket,
                      policy: SelectionPolicy = SelectionPolicy()):
    """Paper policy: among feasible configs, walk the family preference
    order; within a family pick lowest (leak+refresh) power, then area.

    Returns ``(family, row_index)`` or ``(None, -1)`` when infeasible."""
    mask = feasible_mask(metrics, bucket.f_hz, bucket.lifetime_s,
                         allow_refresh=policy.allow_refresh,
                         refresh_power_frac=policy.refresh_power_frac)
    families = np.asarray(families)
    for fam in policy.preference:
        idx = np.where(mask & (families == fam))[0]
        if idx.size:
            power = (np.asarray(metrics["p_leak_w"])[idx]
                     + np.asarray(metrics["p_refresh_w"])[idx])
            area = np.asarray(metrics["area_um2"])[idx]
            order = np.lexsort((area, power))
            return fam, int(idx[order[0]])
    return None, -1


@dataclass(frozen=True)
class BucketPick:
    bucket: Bucket
    family: object            # str | None
    config_idx: int
    # set by the vdd-sweep compose path (repro.hetero): the operating point
    # (a repro.core.corners.OperatingPoint) and scheduled refresh margin the
    # pick is priced at; None = the table's base point / analytic default
    op: object = None
    refresh_margin: object = None   # float | None


@dataclass(frozen=True)
class LevelSelection:
    """Heterogeneous composition of one cache level (one Table-2 cell)."""
    level: LevelReq
    label: str
    picks: Tuple[BucketPick, ...] = field(default_factory=tuple)

    @property
    def feasible(self) -> bool:
        return all(p.family is not None for p in self.picks)


def composition_label(families) -> str:
    """Paper Table-2 nomenclature for one level: the distinct non-None
    families in bucket order joined with " + ", or "infeasible" when no
    bucket found a technology. Shared by ``select_level`` (greedy path) and
    ``repro.hetero`` (joint composition path) so the labeling rule cannot
    drift between them."""
    fams: list = []
    for fam in families:
        if fam and fam not in fams:
            fams.append(fam)
    return " + ".join(DISPLAY[f] for f in fams) if fams else "infeasible"


def select_level(metrics: Mapping[str, np.ndarray], families: np.ndarray,
                 level: LevelReq,
                 policy: SelectionPolicy = SelectionPolicy()) -> LevelSelection:
    """One technology per bucket; label joins the distinct families in bucket
    order (paper Table 2)."""
    picks = []
    for b in level.buckets:
        fam, idx = select_bucket_idx(metrics, families, b, policy)
        picks.append(BucketPick(bucket=b, family=fam, config_idx=idx))
    label = composition_label(p.family for p in picks)
    return LevelSelection(level=level, label=label, picks=tuple(picks))
