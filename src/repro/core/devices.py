"""Analytic transistor models (EKV-style): smooth, differentiable, vmap-able.

I_D = Ispec * W * [F((vg - vt_eff)/(n*UT)) - F((vg - vt_eff - n*vd)/(n*UT))]
with F(u) = ln^2(1 + e^(u/2)), vt_eff = vt - eta*vds (DIBL), plus an off-state
floor (junction leakage for Si, channel floor <1e-18 A/um for OS materials —
the paper's headline OS property).

The catalog is stored as stacked jnp arrays so a whole design space of
(device x VT-class) choices can be characterized in one vmap.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import corners, tech


class DeviceParams(NamedTuple):
    vt: jnp.ndarray            # V (magnitude)
    n: jnp.ndarray             # subthreshold slope factor (SS = n*UT*ln10)
    ispec: jnp.ndarray         # A/um spec current
    eta_dibl: jnp.ndarray      # DIBL coefficient (V/V)
    i_floor: jnp.ndarray       # A/um off-state floor
    j_gate: jnp.ndarray        # A/um gate leakage at VDD
    polarity: jnp.ndarray      # +1 NMOS, -1 PMOS


def _F(u):
    # ln^2(1+e^(u/2)) with overflow-safe softplus
    sp = jnp.where(u > 40.0, u / 2.0, jnp.log1p(jnp.exp(jnp.minimum(u / 2.0, 40.0))))
    return sp * sp


def mosfet_id(dev: DeviceParams, vgs, vds, w_um, tp=None):
    """Drain current [A] for gate-source / drain-source voltages (NMOS sign
    convention; PMOS callers pass magnitudes).

    ``tp`` is the operating corner (``corners.TechParams`` /
    ``OperatingPoint`` / name; None = nominal): the thermal voltage widens
    the subthreshold slope with T, the channel current carries the mobility
    factor, and the off-state floor the Arrhenius leakage factor."""
    tp = corners.resolve(tp)
    vgs = jnp.asarray(vgs, jnp.float32)
    vds = jnp.asarray(vds, jnp.float32)
    vt_eff = dev.vt - dev.eta_dibl * vds
    nut = dev.n * tp.ut
    i_ch = dev.ispec * (_F((vgs - vt_eff) / nut)
                        - _F((vgs - vt_eff - dev.n * vds) / nut))
    i_ch = jnp.maximum(i_ch, 0.0) * tp.drive_scale
    floor = dev.i_floor * tp.leak_scale
    return (i_ch + floor * jnp.sign(jnp.maximum(vds, 0.0))) * w_um


def i_on(dev: DeviceParams, w_um, vdd=None, tp=None):
    tp = corners.resolve(tp)
    v = tp.vdd if vdd is None else vdd
    return mosfet_id(dev, v, v, w_um, tp)


def i_off(dev: DeviceParams, w_um, vds=None, tp=None):
    tp = corners.resolve(tp)
    v = tp.vdd if vds is None else vds
    return mosfet_id(dev, 0.0, v, w_um, tp)


def _mk(vt, ss_mv, ion_target, eta, i_floor, j_gate, polarity=1):
    """Build params calibrated so I_on(VDD,VDD) == ion_target [A/um]."""
    n = ss_mv * 1e-3 / (tech.UT * jnp.log(10.0))
    probe = DeviceParams(*[jnp.asarray(v, jnp.float32) for v in
                           (vt, n, 1.0, eta, 0.0, 0.0, polarity)])
    scale = mosfet_id(probe, tech.VDD, tech.VDD, 1.0)
    return DeviceParams(
        vt=jnp.float32(vt), n=jnp.float32(n),
        ispec=jnp.float32(ion_target / scale),
        eta_dibl=jnp.float32(eta), i_floor=jnp.float32(i_floor),
        j_gate=jnp.float32(j_gate), polarity=jnp.float32(polarity))


# --- catalog (per-um currents at VDD=1.1 V) ----------------------------------
SI_NMOS = _mk(vt=0.45, ss_mv=88.0, ion_target=600e-6, eta=0.08,
              i_floor=1e-12, j_gate=2e-12)
SI_NMOS_HVT = _mk(vt=0.62, ss_mv=85.0, ion_target=420e-6, eta=0.06,
                  i_floor=1e-12, j_gate=2e-12)
# read-port PMOS uses a thick(er)-oxide flavor (standard for gain cells: the
# SN sees this gate, so its tunneling current bounds retention)
SI_PMOS = _mk(vt=0.45, ss_mv=92.0, ion_target=300e-6, eta=0.08,
              i_floor=1e-12, j_gate=2e-14, polarity=-1)
# TCAD-calibrated-style ITO (paper Fig 9d): SS ~65 mV/dec, low Ion, ultra-low
# off floor. Base VT gives ~ms retention; +VT engineering reaches >10 s.
ITO_OS = _mk(vt=0.47, ss_mv=65.0, ion_target=110e-6, eta=0.02,
             i_floor=1e-19, j_gate=0.0)
ITO_OS_HVT = _mk(vt=0.72, ss_mv=65.0, ion_target=70e-6, eta=0.02,
                 i_floor=1e-19, j_gate=0.0)
# p-type OS read FET (CNT/ITO-p hybrid cells, Liu et al. EDL'23 = paper [15]):
# keeps the PMOS-read active-high-RWL sensing scheme uniform for OS-OS cells.
IGZO_OS = _mk(vt=0.55, ss_mv=70.0, ion_target=30e-6, eta=0.02,
              i_floor=1e-19, j_gate=0.0, polarity=-1)

CATALOG = {
    "si_nmos": SI_NMOS,
    "si_nmos_hvt": SI_NMOS_HVT,
    "si_pmos": SI_PMOS,
    "ito_os": ITO_OS,
    "ito_os_hvt": ITO_OS_HVT,
    "igzo_os": IGZO_OS,
}


def stack_devices(names):
    """Stack catalog entries into one DeviceParams of arrays (for jnp.take)."""
    devs = [CATALOG[n] for n in names]
    return DeviceParams(*[jnp.stack([getattr(d, f) for d in devs])
                          for f in DeviceParams._fields])


def take_device(stacked: DeviceParams, idx):
    return DeviceParams(*[jnp.take(getattr(stacked, f), idx)
                          for f in DeviceParams._fields])
