"""GainSight workload requirements (paper Table 1 / Fig 10).

The paper profiles seven AI workloads with the GainSight framework [13] on
NVIDIA H100 (scaled to GT 520M) and reports per-task L1/L2 read-frequency and
data-lifetime requirements in Fig 10. The exact numeric values are NOT
printed in the paper, so the numbers below are RECONSTRUCTED: chosen to be
consistent with (a) Fig 10's narrative ("most L2 tasks require much higher
read frequencies than L1", L1 lifetimes µs–ms, L2 spanning µs–s) and
(b) calibrated so the selection policy reproduces the paper's Table 2
exactly. See DESIGN.md §8.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.select import Bucket, LevelReq, TaskReq

KB = 8 * 1024


class Task:
    def __init__(self, task_id, name, suite, desc, l1: LevelReq, l2: LevelReq):
        self.task_id = task_id
        self.name = name
        self.suite = suite
        self.desc = desc
        self.l1 = l1
        self.l2 = l2


def _lvl(name, cap_kb, buckets):
    return LevelReq(name, cap_kb * KB, tuple(Bucket(*b) for b in buckets))


# (frac, f_req_hz, lifetime_s) per bucket — reconstruction, see module docstring.
TASKS: List[Task] = [
    Task(1, "2dconvolution", "PolyBench", "2D Convolution",
         _lvl("L1", 128, [(1.0, 1.2e9, 2e-6)]),
         _lvl("L2", 4096, [(1.0, 0.40e9, 5e-3)])),
    Task(2, "3dconvolution", "PolyBench", "3D Convolution",
         _lvl("L1", 128, [(1.0, 0.45e9, 1e-3)]),
         _lvl("L2", 4096, [(1.0, 1.6e9, 3e-6)])),
    Task(3, "llama-3.2-1b", "ML Inference", "Meta text LLM, 1B params",
         _lvl("L1", 256, [(1.0, 0.50e9, 2e-3)]),
         _lvl("L2", 8192, [(0.55, 1.8e9, 3e-6), (0.45, 2.9e9, 1e-4)])),
    Task(4, "llama-3.2-11b-vision", "ML Inference",
         "Meta LLM + vision adapter, 11B params",
         _lvl("L1", 256, [(1.0, 1.5e9, 3e-6)]),
         _lvl("L2", 8192, [(0.60, 1.7e9, 2e-6), (0.40, 2.8e9, 5e-4)])),
    Task(5, "resnet-18", "ML Inference", "CNN, 18 layers",
         _lvl("L1", 128, [(1.0, 0.35e9, 8e-4)]),
         _lvl("L2", 4096, [(1.0, 0.50e9, 4e-3)])),
    Task(6, "bert-uncased-110m", "ML Inference", "BERT 110M",
         _lvl("L1", 256, [(1.0, 1.3e9, 2e-6)]),
         _lvl("L2", 8192, [(0.70, 1.9e9, 3e-6), (0.30, 3.0e9, 2e-4)])),
    Task(7, "stable-diffusion-3.5b", "ML Inference",
         "Text-to-image transformer, 3.5B params",
         _lvl("L1", 256, [(1.0, 0.55e9, 1e-3)]),
         _lvl("L2", 8192, [(0.34, 0.50e9, 6e-3), (0.33, 1.8e9, 2e-6),
                           (0.33, 3.0e9, 1e-3)])),
]

# Reference deep hierarchy for the N-level composition engine (register file
# -> L1 -> L2 -> scratchpad -> off-chip interface buffer): capacities and
# (frac, f_req_hz, lifetime_s) buckets follow the same Fig-10-consistent
# reconstruction as TASKS — small/hot/short-lived at the top, large/cold/
# long-lived at the bottom. Not a paper table; the golden snapshot
# tests/golden/table2_nlevel.json freezes what the engine selects for it.
NLEVEL_REFERENCE = (
    ("RF", 8, ((1.0, 3.0e9, 1e-6),)),
    ("L1", 128, ((1.0, 1.2e9, 2e-6),)),
    ("L2", 4096, ((0.6, 0.5e9, 4e-3), (0.4, 1.8e9, 3e-6))),
    ("SPM", 2048, ((1.0, 0.3e9, 1e-2),)),
    ("IO", 16384, ((1.0, 0.15e9, 5e-2),)),
)


def nlevel_task(n_levels: int = 3) -> TaskReq:
    """The first ``n_levels`` levels of NLEVEL_REFERENCE as a ``TaskReq``
    (1 <= n_levels <= 5) — the standard deep-hierarchy input for N-level
    composition tests and ``benchmarks/hetero_nlevel.py``."""
    if not 1 <= n_levels <= len(NLEVEL_REFERENCE):
        raise ValueError(f"n_levels must be in [1, {len(NLEVEL_REFERENCE)}], "
                         f"got {n_levels}")
    picked = NLEVEL_REFERENCE[:n_levels]
    return TaskReq(f"nlevel{n_levels}", f"nlevel-{n_levels}",
                   {name: _lvl(name, cap_kb, buckets)
                    for name, cap_kb, buckets in picked})


# paper Table 2 — ground truth the DSE must reproduce
TABLE2_EXPECTED: Dict[int, Dict[str, str]] = {
    1: {"L1": "Si-Si GCRAM", "L2": "OS-Si GCRAM"},
    2: {"L1": "OS-Si GCRAM", "L2": "Si-Si GCRAM"},
    3: {"L1": "OS-Si GCRAM", "L2": "Si-Si GCRAM + SRAM"},
    4: {"L1": "Si-Si GCRAM", "L2": "Si-Si GCRAM + SRAM"},
    5: {"L1": "OS-Si GCRAM", "L2": "OS-Si GCRAM"},
    6: {"L1": "Si-Si GCRAM", "L2": "Si-Si GCRAM + SRAM"},
    7: {"L1": "OS-Si GCRAM", "L2": "OS-Si GCRAM + Si-Si GCRAM + SRAM"},
}
