"""Technology constants for the 40 nm-class logic process (public-domain
approximations standing in for the paper's TSMC 40 nm PDK — see DESIGN.md §8).

All calibration targets come from the paper itself:
  * bitcell area ratios: Si-Si GC = 0.69x, OS-Si GC = 0.35x of 6T SRAM (Fig 6)
  * Si-Si retention: microseconds; OS-Si: milliseconds, >10 s with VT
    engineering (Fig 9)
  * GCRAM leakage orders of magnitude below SRAM (Fig 8c)
"""
from __future__ import annotations

VDD = 1.1                  # V, nominal supply
VDD_BOOST = 1.6            # V, boosted WWL supply with level shifter
TEMP_K = 300.0
UT = 0.02585               # thermal voltage kT/q at 300 K [V]

# --- capacitances / wires ---------------------------------------------------
C_GATE_PER_UM = 1.0e-15    # F/um of gate width (Cox*L at ~40 nm)
C_JUNC_PER_UM = 0.8e-15    # F/um drain junction
C_WIRE_PER_UM = 0.20e-15   # F/um of routed wire
R_WIRE_PER_UM = 2.0        # ohm/um (min-width local metal)

# --- bitcell geometry (um). 6T from public 40 nm figures; GC ratios = paper.
SRAM6T_W, SRAM6T_H = 0.55, 0.44          # 0.242 um^2
GC_SISI_W, GC_SISI_H = 0.380, 0.44       # 0.167 um^2 = 0.69x SRAM
GC_OSSI_W, GC_OSSI_H = 0.220, 0.385      # 0.0847 um^2 = 0.35x SRAM (BEOL write FET)
GC_OSOS_W, GC_OSOS_H = 0.190, 0.38       # 0.0722 um^2 ~ 0.30x (both FETs stacked)

# --- peripheral geometry -----------------------------------------------------
TRACK_UM = 0.14            # routing track / gate pitch
STD_CELL_H = 1.4           # um standard-cell row height
DFF_AREA = 4.2             # um^2
SA_AREA = 9.0              # um^2 (latch-type voltage SA + ref)
SA_AREA_CURRENT = 12.0     # um^2 (current-mode SA, faster, larger)
WRITE_DRV_AREA = 3.0       # um^2 at unit size
PREDIS_AREA = 1.1          # um^2 per column (NMOS predischarge)
PRECH_AREA = 1.6           # um^2 per column (PMOS precharge pair, SRAM)
LS_AREA = 5.5              # um^2 per WWL level shifter
GATE_AREA = 0.9            # um^2 per decoder NAND/INV
CTRL_AREA = 120.0          # um^2 fixed control block
DELAY_STAGE_AREA = 2.2     # um^2 per delay-chain stage
RING_PITCH_UM = 1.8        # um power-ring width (one supply)

# --- timing primitives --------------------------------------------------------
T_GATE = 15e-12            # s, loaded logic stage (FO4-ish at 40 nm)
T_DFF_CQ = 45e-12
T_SETUP = 30e-12
T_SA = 40e-12              # voltage sense amp resolve
T_SA_CURRENT = 28e-12
T_MUX = 12e-12             # per column-mux stage
T_WL_DRV = 28e-12          # auto-sized wordline driver (area pays for load)
DELAY_STAGE = 60e-12       # delay-chain quantum (timing-closure granularity)
V_SENSE = 0.10             # V, required single-ended RBL swing
V_SENSE_SRAM = 0.08        # V, differential pair needs less swing

# --- energy primitives ---------------------------------------------------------
E_SA = 8e-15               # J per sense op
E_DFF = 4e-15              # J per flop toggle
GATE_LEAK_PER_UM = 2e-9    # A/um^2-ish gate tunneling for Si thin ox
ACTIVITY = 0.5             # switching activity for dynamic power

# retention criterion: stored '1' may droop by this fraction of VDD before the
# read current margin is considered lost (paper uses SPICE read-margin checks)
RETENTION_DV_FRAC = 0.15
