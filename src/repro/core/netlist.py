"""Hierarchical SPICE netlist generation + connectivity model.

Generates the same artifact OpenGCRAM produces from its bitcell/periphery
views: a hierarchical .sp netlist of the macro (bitcell subckt, row, array,
decoders, drivers, SA, DFFs, controllers). The in-memory connectivity graph
is what layout.py's LVS-style check compares against.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.core import bitcells, macro


@dataclass
class Instance:
    name: str
    cell: str
    ports: Dict[str, str]       # port -> net


@dataclass
class Netlist:
    top: str
    instances: List[Instance] = field(default_factory=list)
    nets: Dict[str, int] = field(default_factory=dict)

    def add(self, name, cell, **ports):
        self.instances.append(Instance(name, cell, dict(ports)))
        for net in ports.values():
            self.nets[net] = self.nets.get(net, 0) + 1


def _gc_bitcell_subckt(mem_type: str) -> str:
    cell = bitcells.BITCELLS[mem_type]
    if int(cell.kind) == bitcells.KIND_SRAM:
        return """.SUBCKT sram6t BL BLB WL VDD GND
M_PD1 Q  QB GND GND nmos W=0.15u L=0.04u
M_PD2 QB Q  GND GND nmos W=0.15u L=0.04u
M_PU1 Q  QB VDD VDD pmos W=0.09u L=0.04u
M_PU2 QB Q  VDD VDD pmos W=0.09u L=0.04u
M_A1  BL  WL Q  GND nmos W=0.12u L=0.04u
M_A2  BLB WL QB GND nmos W=0.12u L=0.04u
.ENDS
"""
    wdev = "nmos" if int(cell.write_dev) < 3 else "osfet_n"
    rdev = "pmos" if int(cell.read_dev) == 2 else "osfet_p"
    return f""".SUBCKT {mem_type} WBL WWL RBL RWL GND
* 2T gain cell: {wdev} write, {rdev} read; data on storage node SN
M_W SN WWL WBL GND {wdev} W={float(cell.w_write):.2f}u L=0.04u
M_R RBL SN RWL GND {rdev} W={float(cell.w_read):.2f}u L=0.04u
C_SN SN GND {float(cell.c_sn) * 1e15:.3f}f
.ENDS
"""


def build_netlist(cfg: macro.MacroConfig) -> Tuple[Netlist, str]:
    """Returns (connectivity graph, SPICE text)."""
    import numpy as np
    g = macro.geometry(cfg.to_vector())
    rows, cols = int(g["rows"]), int(g["cols"])
    is_gc = bool(g["is_gc"] > 0)
    nl = Netlist(top=f"{cfg.mem_type}_{cfg.word_size}x{cfg.num_words}")

    for r in range(rows):
        for c in range(cols):
            if is_gc:
                nl.add(f"Xcell_{r}_{c}", cfg.mem_type,
                       WBL=f"wbl{c}", WWL=f"wwl{r}", RBL=f"rbl{c}",
                       RWL=f"rwl{r}", GND="gnd")
            else:
                nl.add(f"Xcell_{r}_{c}", "sram6t",
                       BL=f"bl{c}", BLB=f"blb{c}", WL=f"wl{r}",
                       VDD="vdd", GND="gnd")
    import math
    abits = max(int(math.ceil(math.log2(max(rows, 2)))), 1)
    ports = ("r", "w") if is_gc else ("",)
    for p in ports:
        # address decoder block drives one select net per row
        dec_ports = {f"A{a}": f"{p}addr{a}" for a in range(abits)}
        dec_ports.update({f"O{r}": f"dec{p}_{r}" for r in range(rows)})
        dec_ports.update(VDD="vdd", GND="gnd")
        nl.add(f"Xrowdec{p}", "row_decoder", **dec_ports)
        for a in range(abits):
            nl.add(f"Xdff_addr{p}_{a}", "dff", D=f"{p}addr_pin{a}",
                   Q=f"{p}addr{a}", CLK="clk", VDD="vdd", GND="gnd")
        for r in range(rows):
            nl.add(f"Xdec{p}_{r}", "wl_driver",
                   IN=f"dec{p}_{r}", OUT=f"{p}wl{r}" if is_gc else f"wl{r}",
                   VDD="vdd_boost" if (p == "w" and cfg.level_shift) else "vdd",
                   GND="gnd")
        if p == "w" and cfg.level_shift:
            for r in range(rows):
                nl.add(f"Xls_{r}", "level_shifter", IN=f"decw_{r}",
                       OUT=f"decw_ls_{r}", VDD="vdd", VDDH="vdd_boost",
                       GND="gnd")
                # re-point the WWL driver input at the level-shifted net
                for inst in nl.instances:
                    if inst.name == f"Xdecw_{r}" and inst.cell == "wl_driver":
                        nl.nets[inst.ports["IN"]] -= 1
                        inst.ports["IN"] = f"decw_ls_{r}"
                        nl.nets[f"decw_ls_{r}"] += 1
    for c in range(cols):
        if is_gc:
            nl.add(f"Xpredis_{c}", "predischarge", BL=f"rbl{c}", EN="pdis_en",
                   GND="gnd")
        else:
            nl.add(f"Xprech_{c}", "precharge", BL=f"bl{c}", BLB=f"blb{c}",
                   ENB="pch_enb", VDD="vdd")
    m = int(g["mux"])
    for b in range(int(cfg.word_size)):
        if m > 1:
            mux_ports = {f"I{j}": (f"rbl{b * m + j}" if is_gc else f"bl{b * m + j}")
                         for j in range(m)}
            mux_ports.update(OUT=f"sa_in{b}", SEL="col_sel", GND="gnd")
            nl.add(f"Xmux_{b}", "column_mux", **mux_ports)
            sa_in = f"sa_in{b}"
        else:
            sa_in = f"rbl{b}" if is_gc else f"bl{b}"
        nl.add(f"Xsa_{b}", "sense_amp", IN=sa_in, OUT=f"dout{b}",
               EN="sa_en", VDD="vdd", GND="gnd")
        nl.add(f"Xwd_{b}", "write_driver", DIN=f"din{b}",
               BL=f"wbl{b}" if is_gc else f"bl{b}", EN="we", VDD="vdd",
               GND="gnd")
        nl.add(f"Xdff_in_{b}", "dff", D=f"din_pin{b}", Q=f"din{b}", CLK="clk",
               VDD="vdd", GND="gnd")
        nl.add(f"Xdff_out_{b}", "dff", D=f"dout{b}", Q=f"dout_pin{b}",
               CLK="clk", VDD="vdd", GND="gnd")
    if is_gc:
        # predischarge is active-HIGH (vs SRAM's active-low precharge): the
        # read controller gains an extra inverter (paper §4.2)
        nl.add("Xctrl_r", "read_controller", CLK="clk", EN="re", SA_EN="sa_en",
               PDISB="pdis_enb", VDD="vdd", GND="gnd")
        nl.add("Xpdis_inv", "inv", IN="pdis_enb", OUT="pdis_en", VDD="vdd",
               GND="gnd")
        nl.add("Xctrl_w", "write_controller", CLK="clk", EN="we", VDD="vdd",
               GND="gnd")
    else:
        nl.add("Xctrl_r", "read_controller", CLK="clk", EN="re", SA_EN="sa_en",
               PCHB="pch_enb", VDD="vdd", GND="gnd")

    # SPICE text
    lines = [f"* OpenGCRAM-JAX generated macro {nl.top}",
             _gc_bitcell_subckt(cfg.mem_type),
             f".SUBCKT {nl.top} clk re we " +
             " ".join(f"din_pin{b}" for b in range(cfg.word_size)) + " " +
             " ".join(f"dout_pin{b}" for b in range(cfg.word_size)) +
             " vdd gnd" + (" vdd_boost" if cfg.level_shift else "")]
    for inst in nl.instances:
        ports_s = " ".join(inst.ports.values())
        lines.append(f"X{inst.name} {ports_s} {inst.cell}")
    lines.append(".ENDS\n")
    return nl, "\n".join(lines)
