"""Operating-corner physics: the (VDD, temperature) point as a first-class,
batchable axis of the whole characterization pipeline.

``repro.core.tech`` pins one operating point as module globals (VDD = 1.1 V,
TEMP_K = 300 K, UT = kT/q at 300 K). GCRAM retention is strongly voltage- and
temperature-dependent — OpenGCRAM (arXiv:2507.10849) sweeps these knobs as
first-class configuration axes — so this module turns the pinned constants
into a derived parameter object:

``OperatingPoint(vdd, temp_k, corner)``
    the user-facing knob: supply [V], junction temperature [K], and a label
    ("nominal", "hot", ...). Hashable, JSON-fingerprintable; used in every
    DesignTable / hetero / sim cache key that depends on the physics.

``TechParams``
    the derived, corner-dependent quantities the circuit models consume —
    a NamedTuple (a jax pytree) of python floats, so it is hashable at rest
    and vmap-able once stacked (``stack_tech``):

    ``vdd``          supply [V]
    ``vdd_boost``    level-shifted WWL rail [V] (tracks vdd)
    ``temp_k``       temperature [K]
    ``ut``           thermal voltage kT/q [V], scaled linearly in T from the
                     calibrated 300 K value so the nominal point reproduces
                     ``tech.UT`` bit-for-bit
    ``leak_scale``   Arrhenius multiplier on off-state floors and gate
                     leakage vs 300 K: exp(Ea/k · (1/300 − 1/T)), Ea = 0.5 eV
                     (junction/trap-assisted leakage activation energy)
    ``drive_scale``  phonon-limited mobility factor (T/300 K)^−1.5 on the
                     channel drive current
    ``v_sense``      required single-ended RBL swing [V] (scales with vdd)
    ``v_sense_sram`` differential-pair swing [V] (scales with vdd)

All five derived factors are exactly 1.0 (or the legacy constant) at the
nominal point, so default-argument calls through ``devices`` / ``bitcells``
/ ``retention`` / ``periphery`` / ``characterize`` reproduce the pre-corner
pipeline bit-for-bit (proved by tests/test_golden.py).
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from typing import NamedTuple, Sequence, Tuple, Union

from repro.core import tech

# Boltzmann constant in eV/K for the Arrhenius leakage factor
_KB_EV = 8.617333262e-5
# activation energy of the off-state leakage floor [eV] (junction /
# trap-assisted tunneling class; gives ~20x leakage at 85 degC vs 25 degC)
EA_LEAK_EV = 0.5
# phonon-limited mobility exponent: mu ~ (T/T0)^-1.5
MOBILITY_EXP = -1.5

T_NOMINAL_K = tech.TEMP_K                 # 300 K calibration temperature


@dataclass(frozen=True)
class OperatingPoint:
    """One (supply, temperature) operating corner.

    ``vdd`` [V], ``temp_k`` [K]; ``corner`` is the display / column label
    (per-corner DesignTable columns are named ``<metric>@<corner>``).
    """
    vdd: float = tech.VDD
    temp_k: float = tech.TEMP_K
    corner: str = "nominal"

    def __post_init__(self):
        if not (self.vdd > 0 and self.temp_k > 0):
            raise ValueError(f"OperatingPoint needs vdd > 0 V and "
                             f"temp_k > 0 K, got {self}")

    def fingerprint(self) -> str:
        """Stable JSON for cache keys (repr-exact floats)."""
        return json.dumps({"vdd": repr(float(self.vdd)),
                           "temp_k": repr(float(self.temp_k)),
                           "corner": self.corner}, sort_keys=True)


NOMINAL = OperatingPoint()
HOT = OperatingPoint(vdd=tech.VDD, temp_k=358.0, corner="hot")       # 85 degC
COLD = OperatingPoint(vdd=tech.VDD, temp_k=233.0, corner="cold")     # -40 degC
LOW_VDD = OperatingPoint(vdd=0.9, temp_k=tech.TEMP_K, corner="low_vdd")
CORNERS = {op.corner: op for op in (NOMINAL, HOT, COLD, LOW_VDD)}


class TechParams(NamedTuple):
    """Corner-derived technology parameters (see module docstring). A jax
    pytree: python-float fields at rest (hashable), arrays when stacked for
    the (designs x corners) vmap."""
    vdd: float = tech.VDD
    vdd_boost: float = tech.VDD_BOOST
    temp_k: float = tech.TEMP_K
    ut: float = tech.UT
    leak_scale: float = 1.0
    drive_scale: float = 1.0
    v_sense: float = tech.V_SENSE
    v_sense_sram: float = tech.V_SENSE_SRAM

    @classmethod
    def from_op(cls, op: OperatingPoint) -> "TechParams":
        """Derive every corner-dependent quantity from one OperatingPoint.

        At the nominal point every scale factor is exactly 1.0 and every
        voltage is the legacy ``tech`` constant, so the derivation is
        bit-for-bit neutral there (x * 1.0 is exact in IEEE float)."""
        t = float(op.temp_k)
        v = float(op.vdd)
        vr = v / tech.VDD                       # supply ratio (1.0 nominal)
        return cls(
            vdd=v,
            vdd_boost=tech.VDD_BOOST * vr,
            temp_k=t,
            ut=tech.UT * (t / T_NOMINAL_K),
            leak_scale=math.exp(EA_LEAK_EV / _KB_EV
                                * (1.0 / T_NOMINAL_K - 1.0 / t)),
            drive_scale=(t / T_NOMINAL_K) ** MOBILITY_EXP,
            v_sense=tech.V_SENSE * vr,
            v_sense_sram=tech.V_SENSE_SRAM * vr,
        )


NOMINAL_TECH = TechParams.from_op(NOMINAL)

OpLike = Union[None, str, OperatingPoint, TechParams]


def as_operating_point(op: Union[str, OperatingPoint, Sequence[float]]
                       ) -> OperatingPoint:
    """Coerce a corner name ("hot"), an (vdd, temp_k[, label]) tuple, or an
    OperatingPoint into an OperatingPoint."""
    if isinstance(op, OperatingPoint):
        return op
    if isinstance(op, str):
        try:
            return CORNERS[op]
        except KeyError:
            raise KeyError(f"unknown corner {op!r}; named corners: "
                           f"{sorted(CORNERS)}") from None
    if isinstance(op, Sequence) and 2 <= len(op) <= 3:
        vdd, temp_k = float(op[0]), float(op[1])
        label = op[2] if len(op) == 3 else f"v{vdd:g}_t{temp_k:g}"
        return OperatingPoint(vdd=vdd, temp_k=temp_k, corner=str(label))
    raise TypeError(f"cannot interpret {op!r} as an OperatingPoint")


def as_corners(corners) -> Tuple[OperatingPoint, ...]:
    """Normalize a ``corners=`` argument: None -> (NOMINAL,), else a tuple of
    OperatingPoints with unique labels."""
    if corners is None:
        return (NOMINAL,)
    ops = tuple(as_operating_point(c) for c in corners)
    if not ops:
        raise ValueError("corners=[] is empty; pass None for nominal-only")
    labels = [op.corner for op in ops]
    if len(set(labels)) != len(labels):
        raise ValueError(f"duplicate corner labels {labels}; per-corner "
                         f"columns are keyed on the label")
    return ops


def resolve(tp: OpLike) -> TechParams:
    """The default-argument hook every core consumer calls: None -> the
    nominal TechParams; an OperatingPoint / corner name is derived on the
    fly; a TechParams (incl. a stacked/traced one) passes through."""
    if tp is None:
        return NOMINAL_TECH
    if isinstance(tp, TechParams):
        return tp
    if isinstance(tp, (str, OperatingPoint)):
        return TechParams.from_op(as_operating_point(tp))
    raise TypeError(f"expected TechParams / OperatingPoint / corner name / "
                    f"None, got {tp!r}")


def stack_tech(ops: Sequence[OperatingPoint]) -> TechParams:
    """Stack the TechParams of several corners into one TechParams of jnp
    arrays with a leading corner axis — the ``in_axes=0`` operand of the
    (designs x corners) vmap in ``characterize.characterize_corners``."""
    import jax.numpy as jnp
    tps = [TechParams.from_op(as_operating_point(op)) for op in ops]
    # stack in the pipeline's working float dtype (jnp.result_type(float):
    # f32 under the default x64-off config) instead of a hard float32 cast,
    # so the stacked values match what the scalar resolve() path traces
    dtype = jnp.result_type(float)
    return TechParams(*[jnp.asarray([getattr(t, f) for t in tps], dtype)
                        for f in TechParams._fields])


def corners_fingerprint(corners: Tuple[OperatingPoint, ...]) -> str:
    """Stable string over an ordered corner tuple for cache keys. The
    nominal-only tuple returns "" so single-corner cache keys are unchanged
    from the pre-corner schema."""
    if corners == (NOMINAL,):
        return ""
    return ";".join(op.fingerprint() for op in corners)
