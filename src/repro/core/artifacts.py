"""Design-flow artifacts: Verilog behavioral model, .lib timing/power view,
.lef abstract — the files OpenGCRAM emits for integration with synthesis and
P&R flows.
"""
from __future__ import annotations

from repro.core import characterize as chz, layout, macro


def emit_verilog(cfg: macro.MacroConfig, res=None) -> str:
    res = res if res is not None else chz.characterize_config(cfg)
    wz, nw = cfg.word_size, cfg.num_words
    abits = max((nw - 1).bit_length(), 1)
    dual = cfg.mem_type != "sram6t"
    name = f"{cfg.mem_type}_{wz}x{nw}"
    retention_ns = min(res["retention_s"] * 1e9, 1e18)
    if dual:
        return f"""// OpenGCRAM-JAX generated behavioral model
// f_read={res['f_read_hz']/1e6:.0f} MHz f_write={res['f_write_hz']/1e6:.0f} MHz retention={res['retention_s']:.3e} s
module {name} #(parameter RETENTION_NS = {retention_ns:.0f}) (
  input  wire              rclk, wclk,
  input  wire              re, we,
  input  wire [{abits-1}:0]  raddr, waddr,
  input  wire [{wz-1}:0] din,
  output reg  [{wz-1}:0] dout
);
  reg [{wz-1}:0] mem [0:{nw-1}];
`ifndef SYNTHESIS
  time written_at [0:{nw-1}];
`endif
  always @(posedge wclk) if (we) begin
    mem[waddr] <= din;
`ifndef SYNTHESIS
    written_at[waddr] <= $time;
`endif
  end
  always @(posedge rclk) if (re) begin
`ifndef SYNTHESIS
    if ($time - written_at[raddr] > RETENTION_NS)
      dout <= {{{wz}{{1'bx}}}};   // data decayed past retention
    else
`endif
      dout <= mem[raddr];
  end
endmodule
"""
    return f"""// OpenGCRAM-JAX generated behavioral model (single-port SRAM)
module {name} (
  input  wire              clk,
  input  wire              re, we,
  input  wire [{abits-1}:0]  addr,
  input  wire [{wz-1}:0] din,
  output reg  [{wz-1}:0] dout
);
  reg [{wz-1}:0] mem [0:{nw-1}];
  always @(posedge clk) begin
    if (we) mem[addr] <= din;
    if (re) dout <= mem[addr];
  end
endmodule
"""


def emit_lib(cfg: macro.MacroConfig, res=None) -> str:
    res = res if res is not None else chz.characterize_config(cfg)
    name = f"{cfg.mem_type}_{cfg.word_size}x{cfg.num_words}"
    t_ns = res["t_read_s"] * 1e9
    # simple 3x3 NLDM table scaled from the nominal op point
    slews = [0.02, 0.1, 0.4]
    loads = [2.0, 8.0, 32.0]
    rows = []
    for s in slews:
        rows.append(", ".join(f"{t_ns * (1 + 0.3 * s / 0.1) * (1 + 0.05 * l / 8):.4f}"
                              for l in loads))
    table = ' , \\\n          '.join(f'"{r}"' for r in rows)
    return f"""/* OpenGCRAM-JAX generated liberty view */
library ({name}_lib) {{
  time_unit : "1ns"; voltage_unit : "1V"; current_unit : "1mA";
  leakage_power_unit : "1uW"; capacitive_load_unit (1, pf);
  cell ({name}) {{
    area : {res['area_um2']:.1f};
    cell_leakage_power : {res['p_leak_w'] * 1e6:.5f};
    memory () {{ type : ram; address_width : {max((cfg.num_words-1).bit_length(),1)}; word_width : {cfg.word_size}; }}
    pin (dout) {{
      direction : output;
      timing () {{
        related_pin : "rclk"; timing_type : rising_edge;
        cell_rise (delay_3x3) {{
          index_1 ("0.02, 0.1, 0.4");
          index_2 ("2.0, 8.0, 32.0");
          values ( \\
          {table} );
        }}
      }}
    }}
    pg_pin (VDD) {{ voltage_name : VDD; pg_type : primary_power; }}
    pg_pin (VSS) {{ voltage_name : VSS; pg_type : primary_ground; }}
  }}
}}
"""


def emit_lef(cfg: macro.MacroConfig) -> str:
    fp = layout.build_floorplan(cfg)
    name = f"{cfg.mem_type}_{cfg.word_size}x{cfg.num_words}"
    w, h = fp.width + 6.0, fp.height + 6.0
    pins = ["clk", "re", "we"] + [f"din_pin{i}" for i in range(cfg.word_size)] \
        + [f"dout_pin{i}" for i in range(cfg.word_size)]
    pin_txt = []
    for i, p in enumerate(pins):
        y = 1.0 + (i % 64) * 0.28
        side = 0.0 if i % 2 == 0 else w - 0.2
        pin_txt.append(f"""  PIN {p}
    DIRECTION {"OUTPUT" if p.startswith("dout") else "INPUT"} ;
    PORT
      LAYER M3 ;
        RECT {side:.3f} {y:.3f} {side + 0.2:.3f} {y + 0.2:.3f} ;
    END
  END {p}""")
    return f"""# OpenGCRAM-JAX generated LEF abstract
VERSION 5.8 ;
MACRO {name}
  CLASS BLOCK ;
  SIZE {w:.3f} BY {h:.3f} ;
  ORIGIN 0 0 ;
  SYMMETRY X Y ;
{chr(10).join(pin_txt)}
  OBS
    LAYER M1 ;
      RECT 0.5 0.5 {w - 0.5:.3f} {h - 0.5:.3f} ;
  END
END {name}
END LIBRARY
"""


def generate_all(cfg: macro.MacroConfig, outdir, res=None):
    """Full compiler flow for one macro: netlist + floorplan + DRC/LVS +
    verilog/.lib/.lef. Returns a report dict; writes files to outdir.
    ``res`` is an optional precomputed characterization (``Macro.ppa``)."""
    from pathlib import Path

    from repro.core import netlist as nl_mod
    outdir = Path(outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    name = f"{cfg.mem_type}_{cfg.word_size}x{cfg.num_words}"
    res = res if res is not None else chz.characterize_config(cfg)
    nl, spice = nl_mod.build_netlist(cfg)
    fp = layout.build_floorplan(cfg)
    drc = layout.drc_check(fp)
    lvs = layout.lvs_check(cfg, fp, nl)
    (outdir / f"{name}.sp").write_text(spice)
    (outdir / f"{name}.v").write_text(emit_verilog(cfg, res=res))
    (outdir / f"{name}.lib").write_text(emit_lib(cfg, res=res))
    (outdir / f"{name}.lef").write_text(emit_lef(cfg))
    report = {
        "name": name,
        "drc_errors": drc,
        "lvs_errors": lvs,
        "drc_clean": not drc,
        "lvs_clean": not lvs,
        "characterization": res,
    }
    import json
    (outdir / f"{name}.report.json").write_text(
        json.dumps(report, indent=2, default=str))
    return report
