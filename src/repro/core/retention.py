"""Transient retention solver: storage-node decay of a stored '1'.

   C_SN * dV/dt = -[ I_sub(write dev, vgs=0, vds=V) + I_gate(read dev, V) ]

integrated with RK4 on a log-spaced grid (1 ns .. 1e7 s, 30 pts/decade) —
the SPICE transient the paper runs per configuration. Retention time is the
crossing of V below V0 - RETENTION_DV_FRAC*VDD (read-margin criterion).

The pure-jnp scan here is the oracle for the Pallas kernel in
``repro.kernels.retention_kernel`` (same grid, same RK4).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import bitcells, corners, devices

T_START, T_END, PTS_PER_DECADE = 1e-9, 1e7, 30
# plain math, not jnp: computing this with jnp.log10 dispatched device work
# (and possibly platform init) at import time for a compile-time constant
N_STEPS = int(PTS_PER_DECADE * (math.log10(T_END) - math.log10(T_START)))  # 480


def time_grid():
    return jnp.logspace(jnp.log10(T_START), jnp.log10(T_END), N_STEPS + 1)


def leak_current(cell: bitcells.BitcellParams, v_sn, tp=None):
    """Total leakage pulling the stored '1' down [A] (WBL held at 0V worst
    case: write-device subthreshold + DIBL, plus read-device gate leak).
    ``tp`` = operating corner: subthreshold leakage grows with the thermal
    voltage and the Arrhenius floor, gate leak with ``leak_scale``."""
    tp = corners.resolve(tp)
    wdev = devices.take_device(bitcells.DEVICE_STACK,
                               cell.write_dev.astype(jnp.int32))
    rdev = devices.take_device(bitcells.DEVICE_STACK,
                               cell.read_dev.astype(jnp.int32))
    i_sub_a = devices.mosfet_id(wdev, 0.0, v_sn, cell.w_write, tp)
    i_gate_a = rdev.j_gate * tp.leak_scale * cell.w_read * (v_sn / tp.vdd)
    return i_sub_a + i_gate_a


def decay_curve(cell: bitcells.BitcellParams, v0, tp=None):
    """V_SN(t) on the log grid via RK4. Returns (ts, vs)."""
    tp = corners.resolve(tp)
    ts = time_grid()

    def f(v):
        return -leak_current(cell, jnp.maximum(v, 0.0), tp) / jnp.maximum(
            cell.c_sn, 1e-18)

    def step(v, dt):
        k1 = f(v)
        k2 = f(v + 0.5 * dt * k1)
        k3 = f(v + 0.5 * dt * k2)
        k4 = f(v + dt * k3)
        v_new_v = v + dt / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4)
        return jnp.clip(v_new_v, 0.0, 2.0), v_new_v

    dts = jnp.diff(ts)
    _, vs = jax.lax.scan(step, jnp.asarray(v0, jnp.float32), dts)
    return ts, jnp.concatenate([jnp.asarray([v0], jnp.float32), vs])


def read_margin_threshold(cell: bitcells.BitcellParams,
                          false_read_ratio: float = 0.1, tp=None):
    """Absolute SN voltage below which a stored '1' starts to conduct the
    (PMOS, gate=SN) read device at > ratio x the stored-'0' current — i.e.
    the point where the '1' reads as '0'.

    This absolute criterion is what makes the WWL level shifter *improve*
    retention (paper Fig 9c): it raises the stored level from VDD-VT to VDD,
    widening the droop window to the same threshold."""
    tp = corners.resolve(tp)
    rdev = devices.take_device(bitcells.DEVICE_STACK,
                               cell.read_dev.astype(jnp.int32))
    grid = jnp.linspace(0.0, tp.vdd, 256)
    # |vgs| of the read device when SN sits at v: VDD - v
    i_read_a = devices.mosfet_id(rdev, tp.vdd - grid, tp.vdd, cell.w_read, tp)
    i_on0_a = devices.mosfet_id(rdev, tp.vdd, tp.vdd, cell.w_read, tp)
    ok = i_read_a <= false_read_ratio * i_on0_a          # high-enough SN region
    # lowest v on the grid that is still a safe '1'
    idx = jnp.argmax(ok)                             # first True
    return grid[idx]


def retention_time(cell: bitcells.BitcellParams, level_shift=0, tp=None):
    """Seconds until the stored '1' droops below the read-margin threshold.
    ``tp`` = operating corner: hotter corners leak harder (shorter
    retention), higher vdd stores a higher level (longer retention)."""
    tp = corners.resolve(tp)
    v0 = bitcells.sn_high_level(cell, level_shift, tp)
    ts, vs = decay_curve(cell, v0, tp)
    v_min_v = read_margin_threshold(cell, tp=tp)
    crossed = vs < v_min_v
    idx = jnp.argmax(crossed)                       # first crossing (0 if none)
    any_cross = jnp.any(crossed)
    # log-linear interpolation between grid points
    i0 = jnp.maximum(idx - 1, 0)
    t0, t1 = ts[i0], ts[idx]
    v_hi_v, v_lo_v = vs[i0], vs[idx]
    frac = jnp.clip((v_hi_v - v_min_v) / jnp.maximum(v_hi_v - v_lo_v, 1e-9),
                    0.0, 1.0)
    t_cross_s = jnp.exp(jnp.log(t0) + frac * (jnp.log(t1) - jnp.log(t0)))
    return jnp.where(any_cross, t_cross_s, ts[-1])


def retention_estimate(cell: bitcells.BitcellParams, level_shift=0, tp=None):
    """Closed-form sanity estimate t ~ C*dV/I_leak(V0) (first-order; the
    transient solve is more accurate because I_sub varies with V)."""
    tp = corners.resolve(tp)
    v0 = bitcells.sn_high_level(cell, level_shift, tp)
    dv = jnp.maximum(v0 - read_margin_threshold(cell, tp=tp), 0.0)
    i0 = leak_current(cell, v0, tp)
    return cell.c_sn * dv / jnp.maximum(i0, 1e-30)


retention_time_batch = jax.jit(jax.vmap(retention_time, in_axes=(0, 0)))
