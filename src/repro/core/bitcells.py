"""Bitcell models: 6T SRAM, 2T Si-Si GCRAM, 2T OS-Si GCRAM, 2T OS-OS GCRAM.

Each bitcell is a NamedTuple of jnp scalars so a stacked table of all cell
types (x VT class x LS option) can be characterized under vmap. GCRAM cells
follow the paper's polarity choice: NMOS write + PMOS read (active-high RWL
boosts the storage node instead of degrading it — §4.2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import corners, devices, tech


class BitcellParams(NamedTuple):
    kind: jnp.ndarray           # 0=sram6t 1=si-si 2=os-si 3=os-os
    cell_w: jnp.ndarray         # um
    cell_h: jnp.ndarray
    w_write: jnp.ndarray        # write/access device width (um)
    w_read: jnp.ndarray         # read device width (um)
    c_sn: jnp.ndarray           # storage-node cap (F); 0 for SRAM
    write_dev: jnp.ndarray      # index into the device stack
    read_dev: jnp.ndarray
    dual_port: jnp.ndarray      # 1 = separate read/write ports
    leak_paths: jnp.ndarray     # static VDD->GND paths per cell (SRAM=2)


KIND_SRAM, KIND_SISI, KIND_OSSI, KIND_OSOS = 0, 1, 2, 3

# device stack order used by all bitcells
DEVICE_ORDER = ("si_nmos", "si_nmos_hvt", "si_pmos", "ito_os", "ito_os_hvt",
                "igzo_os")
DEV = {n: i for i, n in enumerate(DEVICE_ORDER)}
DEVICE_STACK = devices.stack_devices(DEVICE_ORDER)


def _cell(kind, w, h, w_write, w_read, c_sn, wd, rd, dual, leaks):
    return BitcellParams(*[jnp.asarray(v, jnp.float32) for v in
                           (kind, w, h, w_write, w_read, c_sn, wd, rd, dual,
                            leaks)])


def sram6t():
    return _cell(KIND_SRAM, tech.SRAM6T_W, tech.SRAM6T_H,
                 w_write=0.12, w_read=0.15, c_sn=0.0,
                 wd=DEV["si_nmos"], rd=DEV["si_nmos"], dual=0, leaks=2)


def gc_sisi(hvt_write: bool = False):
    wd = DEV["si_nmos_hvt"] if hvt_write else DEV["si_nmos"]
    # SN cap: read-PMOS gate + write-NMOS junction + local wire
    c_sn = (0.15 * tech.C_GATE_PER_UM + 0.12 * tech.C_JUNC_PER_UM + 0.35e-15)
    return _cell(KIND_SISI, tech.GC_SISI_W, tech.GC_SISI_H,
                 w_write=0.12, w_read=0.15, c_sn=c_sn,
                 wd=wd, rd=DEV["si_pmos"], dual=1, leaks=0)


def gc_ossi(hvt_write: bool = False):
    wd = DEV["ito_os_hvt"] if hvt_write else DEV["ito_os"]
    c_sn = (0.15 * tech.C_GATE_PER_UM + 0.10 * tech.C_JUNC_PER_UM + 0.35e-15)
    return _cell(KIND_OSSI, tech.GC_OSSI_W, tech.GC_OSSI_H,
                 w_write=0.10, w_read=0.15, c_sn=c_sn,
                 wd=wd, rd=DEV["si_pmos"], dual=1, leaks=0)


def gc_osos(hvt_write: bool = False):
    wd = DEV["ito_os_hvt"] if hvt_write else DEV["ito_os"]
    c_sn = (0.12 * tech.C_GATE_PER_UM + 0.10 * tech.C_JUNC_PER_UM + 0.30e-15)
    return _cell(KIND_OSOS, tech.GC_OSOS_W, tech.GC_OSOS_H,
                 w_write=0.10, w_read=0.12, c_sn=c_sn,
                 wd=wd, rd=DEV["igzo_os"], dual=1, leaks=0)


BITCELLS = {
    "sram6t": sram6t(),
    "gc_sisi": gc_sisi(),
    "gc_sisi_hvt": gc_sisi(hvt_write=True),
    "gc_ossi": gc_ossi(),
    "gc_ossi_hvt": gc_ossi(hvt_write=True),
    "gc_osos": gc_osos(),
    "gc_osos_hvt": gc_osos(hvt_write=True),   # + LS: >10 s retention (Fig 9)
}

MEM_TYPE_ORDER = tuple(BITCELLS)
MEM_TYPE = {n: i for i, n in enumerate(MEM_TYPE_ORDER)}


def stack_bitcells(names=MEM_TYPE_ORDER):
    cells = [BITCELLS[n] for n in names]
    return BitcellParams(*[jnp.stack([getattr(c, f) for c in cells])
                           for f in BitcellParams._fields])


def take_bitcell(stacked: BitcellParams, idx):
    return BitcellParams(*[jnp.take(getattr(stacked, f), idx)
                           for f in BitcellParams._fields])


def sn_high_level(cell: BitcellParams, level_shift, tp=None):
    """Stored-'1' voltage on SN: degraded by the write device VT unless the
    WWL is boosted by a level shifter. ``tp`` = operating corner (the stored
    level tracks the supply)."""
    tp = corners.resolve(tp)
    wdev = devices.take_device(DEVICE_STACK, cell.write_dev.astype(jnp.int32))
    degraded = tp.vdd - wdev.vt
    is_gc = cell.kind > 0
    full = jnp.asarray(tp.vdd, jnp.float32)
    lvl = jnp.where(level_shift > 0, full, degraded)
    return jnp.where(is_gc, lvl, full)
