"""Abstract floorplan generation + DRC/LVS-style checks.

Real GDS is out of scope on this container (DESIGN.md §3); the compiler keeps
the *semantics*: grid-pitched rectangle placement for every module, overlap /
spacing / pitch-alignment checks ("DRC"), and netlist<->layout instance
correspondence ("LVS").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.core import macro, netlist as netlist_mod, tech


@dataclass
class Rect:
    name: str
    kind: str
    x: float
    y: float
    w: float
    h: float

    @property
    def x2(self):
        return self.x + self.w

    @property
    def y2(self):
        return self.y + self.h


@dataclass
class Floorplan:
    rects: List[Rect] = field(default_factory=list)
    width: float = 0.0
    height: float = 0.0

    GRID = 0.005

    def place(self, name, kind, x, y, w, h):
        g = self.GRID
        x, y = round(x / g) * g, round(y / g) * g
        w, h = round(w / g) * g, round(h / g) * g
        self.rects.append(Rect(name, kind, x, y, w, h))
        self.width = max(self.width, x + w)
        self.height = max(self.height, y + h)


def build_floorplan(cfg: macro.MacroConfig) -> Floorplan:
    g = macro.geometry(cfg.to_vector())
    rows, cols = int(g["rows"]), int(g["cols"])
    cell = g["cell"]
    cw, ch = float(cell.cell_w), float(cell.cell_h)
    is_gc = bool(g["is_gc"] > 0)
    fp = Floorplan()

    # bitcell array (one rect per cell, grid-pitched)
    x0, y0 = 6.0, 6.0
    for r in range(rows):
        for c in range(cols):
            fp.place(f"cell_{r}_{c}", "bitcell", x0 + c * cw, y0 + r * ch,
                     cw, ch)
    arr_w, arr_h = cols * cw, rows * ch

    # row periphery: read decoder left, write decoder right (dual port)
    dec_w = 4.0
    fp.place("dec_r", "decoder", x0 - dec_w - 0.2, y0, dec_w, arr_h)
    if is_gc:
        fp.place("dec_w", "decoder", x0 + arr_w + 0.2, y0, dec_w, arr_h)
        if cfg.level_shift:
            fp.place("ls_col", "level_shifter", x0 + arr_w + dec_w + 0.4, y0,
                     1.6, arr_h)
    # column periphery below
    col_h = 5.0
    fp.place("col_rd", "read_port_data", x0, y0 - col_h - 0.2, arr_w, col_h)
    if is_gc:
        fp.place("col_wr", "write_port_data", x0, y0 + arr_h + 0.2, arr_w,
                 col_h)
    fp.place("ctrl", "control", x0 - dec_w - 0.2, y0 - col_h - 0.2,
             dec_w, col_h)
    fp.place("dff", "data_dff", x0, y0 - col_h - 3.4 - 0.2, arr_w, 3.2)
    return fp


def drc_check(fp: Floorplan, grid: float = 0.005) -> List[str]:
    """Overlap + off-grid + spacing violations."""
    errors = []
    for r in fp.rects:
        for v in (r.x, r.y, r.w, r.h):
            q = round(v / grid)
            if abs(v - q * grid) > grid * 1e-3:
                errors.append(f"OFFGRID {r.name} {v:.6f}")
                break
    rects = fp.rects
    # bitcells are guaranteed disjoint by grid construction: check the
    # macro-level blocks against each other and spot-check cells per block
    blocks = [r for r in rects if r.kind != "bitcell"]
    cells = [r for r in rects if r.kind == "bitcell"]
    sample = cells[:: max(1, len(cells) // 64)]
    for i, a in enumerate(blocks):
        for b in blocks[i + 1:]:
            if a.x < b.x2 and b.x < a.x2 and a.y < b.y2 and b.y < a.y2:
                errors.append(f"OVERLAP {a.name} {b.name}")
        for c in sample:
            if a.x < c.x2 and c.x < a.x2 and a.y < c.y2 and c.y < a.y2:
                errors.append(f"OVERLAP {a.name} {c.name}")
    return errors


def lvs_check(cfg: macro.MacroConfig, fp: Floorplan,
              nl: netlist_mod.Netlist) -> List[str]:
    """Netlist vs layout correspondence: every netlist bitcell/decoder/
    driver instance must have a placed shape and vice versa."""
    errors = []
    placed = {r.name for r in fp.rects}
    g = macro.geometry(cfg.to_vector())
    rows, cols = int(g["rows"]), int(g["cols"])
    n_cells_nl = sum(1 for i in nl.instances if i.cell in
                     (cfg.mem_type, "sram6t"))
    n_cells_fp = sum(1 for r in fp.rects if r.kind == "bitcell")
    if n_cells_nl != n_cells_fp:
        errors.append(f"CELLCOUNT netlist={n_cells_nl} layout={n_cells_fp}")
    if n_cells_nl != rows * cols:
        errors.append(f"CELLCOUNT netlist={n_cells_nl} expected={rows*cols}")
    for blk, cond in (("dec_r", True), ("dec_w", bool(g["is_gc"] > 0)),
                      ("col_rd", True), ("ctrl", True), ("dff", True)):
        if cond and blk not in placed:
            errors.append(f"MISSING_BLOCK {blk}")
    # floating nets: every net must connect >= 2 ports (except pins)
    pins = {"clk", "re", "we", "vdd", "gnd", "vdd_boost"}
    for net, cnt in nl.nets.items():
        if cnt < 2 and "_pin" not in net and net not in pins:
            errors.append(f"FLOATING {net}")
    return errors
