"""GainSight-analog profiler for the 10 assigned architectures.

The paper profiles GPU workloads (Table 1) for L1/L2 read-frequency and
data-lifetime needs, then lets OpenGCRAM pick memory technologies. Here we do
the same for a TPU-v5e-like accelerator running the assigned architectures:
per-tensor-class traffic and lifetimes are derived from the *compiled
dry-run* records (artifacts/dryrun/*.json) + the architecture configs, and
fed to the same DSE.

Tensor classes ("buckets" in DSE terms):
  weights      — read-mostly, long-lived (inference) / step-lived (training)
  activations  — produced+consumed within ~one layer time: microsecond-lived
  kv_cache     — write-once read-many across a decode session: second-lived
  accumulators — latency-critical running state (flash-attention m/l, MXU
                 accumulators): must run at core speed
"""
from __future__ import annotations

import json
import warnings
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.configs import SHAPES, get_config
from repro.core.select import Bucket, LevelReq, TaskReq

# dry-run shape kind -> simulator phase envelope (repro.sim.trace.PHASES)
_KIND_TO_PHASE = {"train": "train_step", "prefill": "prefill",
                  "decode": "decode"}

# TPU-v5e-like hardware constants (same as the roofline)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CORE_CLOCK_HZ = 0.94e9        # v5e-class core clock
L1_ANALOG_BITS = 8 * (1 << 20)      # ~1 MiB tile/operand buffers
L2_ANALOG_BITS = 8 * (64 << 20)     # ~64 MiB on-chip staging (CMEM-class)


def load_dryrun_record(arch: str, shape: str, mesh: str = "pod16x16",
                       outdir: str = "artifacts/dryrun") -> Optional[dict]:
    p = Path(outdir) / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        return None
    rec = json.loads(p.read_text())
    return rec if rec.get("status") == "ok" else None


def step_time_estimate(rec: dict) -> float:
    """Roofline-style lower bound on the step time from the dry-run record."""
    t_c = rec["cost"]["flops_per_device"] / PEAK_FLOPS
    t_m = rec["cost"]["bytes_per_device"] / HBM_BW
    t_l = rec["collective_bytes_per_device"] / LINK_BW
    return max(t_c, t_m, t_l, 1e-9)


def arch_requirements(arch: str, shape_name: str,
                      rec: Optional[dict] = None) -> Dict[str, LevelReq]:
    """Per-tensor-class memory requirements for one (arch x shape) cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = rec or load_dryrun_record(arch, shape_name)
    if rec is None:
        raise FileNotFoundError(f"no dry-run record for {arch} {shape_name}")
    t_step = step_time_estimate(rec)
    layers = max(cfg.num_layers, 1)

    # lifetimes ------------------------------------------------------------
    act_lifetime = max(t_step / layers, 1e-7)
    if shape.kind == "train":
        # residuals live from forward until their backward layer
        act_lifetime = max(t_step, 1e-6)
        weight_lifetime = t_step          # overwritten by the optimizer
    else:
        weight_lifetime = 3600.0          # serving session scale
    kv_lifetime = shape.seq_len * t_step if shape.kind == "decode" else t_step

    # read frequencies -------------------------------------------------------
    # operand buffers feed the MXU every cycle; staging buffers sustain the
    # HBM-side stream for this cell
    f_l1 = CORE_CLOCK_HZ
    words_per_step = rec["cost"]["bytes_per_device"] / 64.0   # 512-bit lines
    f_l2 = min(words_per_step / t_step, 3.0e9)

    l1 = LevelReq("L1", L1_ANALOG_BITS, (
        Bucket(0.7, f_l1, act_lifetime),          # operands/accumulators
        Bucket(0.3, f_l1, act_lifetime),          # spilled partials
    ))
    moe_frac = (cfg.top_k / cfg.num_experts) if cfg.moe else 1.0
    l2_buckets = [
        Bucket(0.45, f_l2, act_lifetime),                     # activations
        Bucket(0.35, f_l2 * moe_frac * 0.5, weight_lifetime),  # weight stream
    ]
    if shape.kind == "decode":
        l2_buckets.append(Bucket(0.20, f_l2 * 0.5, kv_lifetime))
    else:
        l2_buckets.append(Bucket(0.20, f_l2, act_lifetime))
    l2 = LevelReq("L2", L2_ANALOG_BITS, tuple(l2_buckets))
    return {"L1": l1, "L2": l2, "t_step": t_step}


def arch_task(arch: str, shape_name: str,
              rec: Optional[dict] = None) -> TaskReq:
    """One (arch x shape) cell as a TaskReq for ``repro.api.explore`` or
    ``repro.api.Compiler.compose`` (both consume the same normal form)."""
    reqs = arch_requirements(arch, shape_name, rec)
    return TaskReq(task_id=f"{arch}/{shape_name}",
                   name=f"{arch} {shape_name}",
                   levels={"L1": reqs["L1"], "L2": reqs["L2"]})


def available_arch_tasks(
    shapes: Sequence[str] = ("train_4k", "decode_32k"),
    archs: Optional[Sequence[str]] = None,
    mesh: str = "pod16x16",
    outdir: str = "artifacts/dryrun",
    return_missing: bool = False,
) -> Union[List[TaskReq], Tuple[List[TaskReq], List[Tuple[str, str]]]]:
    """Every (arch x shape) cell with a clean dry-run record, as TaskReqs.

    This is the profiler-side requirements source for the composition engine
    (the GainSight paper tasks in ``repro.core.gainsight`` are the other).
    ``mesh`` selects which dry-run mesh's records to read (``"pod2x16x16"``
    for ``--multi-pod`` runs). Fresh checkouts without ``artifacts/dryrun``
    get an empty list so callers degrade gracefully instead of raising — but
    never *silently*: when every requested cell is missing a
    ``RuntimeWarning`` names the record directory and the generator command,
    and ``return_missing=True`` returns ``(tasks, missing)`` where
    ``missing`` lists the (arch, shape) cells that had no clean record.
    """
    from repro.configs import ALL_ARCHS
    tasks: List[TaskReq] = []
    missing: List[Tuple[str, str]] = []
    for arch in (archs if archs is not None else ALL_ARCHS):
        for shape in shapes:
            rec = load_dryrun_record(arch, shape, mesh=mesh, outdir=outdir)
            if rec is not None:
                tasks.append(arch_task(arch, shape, rec))
            else:
                missing.append((arch, shape))
    if missing and not tasks:
        warnings.warn(
            f"no clean dry-run records under {outdir!r} for mesh {mesh!r} "
            f"({len(missing)} (arch, shape) cells missing; generate them "
            f"with `python -m repro.launch.dryrun --all`)",
            RuntimeWarning, stacklevel=2)
    if return_missing:
        return tasks, missing
    return tasks


def arch_traces(arch: str, shape_name: str, rec: Optional[dict] = None,
                n_bins: int = 32, n_steps: int = 4, mesh: str = "pod16x16",
                outdir: str = "artifacts/dryrun"):
    """Dry-run-derived time-binned traces for one (arch x shape) cell.

    The trace export of the profiler: the cell's requirements
    (``arch_task``) are binned by ``repro.sim.trace`` with the phase
    envelope matching the dry-run shape's kind (train -> train_step,
    prefill/decode as themselves) over a window of ``n_steps`` compiled
    step times — so the simulator replays the same roofline-derived step
    the analytic requirements were priced from. ``mesh``/``outdir`` select
    the record set like ``available_arch_tasks`` (``"pod2x16x16"`` for
    ``--multi-pod`` runs). Returns a 1-tuple of ``repro.sim.trace.Trace``.
    """
    from repro.sim.trace import task_traces
    rec = rec or load_dryrun_record(arch, shape_name, mesh=mesh,
                                    outdir=outdir)
    if rec is None:
        raise FileNotFoundError(f"no dry-run record for {arch} {shape_name} "
                                f"({mesh}) under {outdir}")
    task = arch_task(arch, shape_name, rec)
    phase = _KIND_TO_PHASE[SHAPES[shape_name].kind]
    duration = max(step_time_estimate(rec), 1e-6) * n_steps
    return task_traces(task, phases=(phase,), duration_s=duration,
                       n_bins=n_bins)
