"""Shared model primitives: init, norms, rope, losses.

Everything is functional: params are nested dicts of jnp arrays, modules are
pure functions ``f(params, x, ...)``. Matmul-bearing weights keep d_model as
the FIRST dim of 2-D kernels so the sharding rules in ``repro.parallel`` can
pattern-match on names + ranks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal init with 1/sqrt(fan_in) scaling (fan_in = shape[-2])."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def _var_dot(x):
    """mean(x^2) with fp32 accumulation via a dot (bf16 x bf16 -> f32).

    Using a dot instead of square(convert(x)) matters: the elementwise fp32
    convert of the layer input is loop-invariant w.r.t. the layer-scan and
    XLA hoists it, materializing an fp32 copy of the whole saved residual
    stack. A dot's output is (...,) — nothing to hoist."""
    return jnp.einsum("...d,...d->...", x, x,
                      preferred_element_type=jnp.float32)[..., None] / x.shape[-1]


@jax.custom_vjp
def rmsnorm(x, scale, eps: float = 1e-6):
    inv = jax.lax.rsqrt(_var_dot(x) + eps).astype(x.dtype)
    return (x * inv) * (1.0 + scale).astype(x.dtype)


def _rmsnorm_fwd(x, scale, eps=1e-6):
    inv32 = jax.lax.rsqrt(_var_dot(x) + eps)               # (..., 1) fp32
    inv = inv32.astype(x.dtype)
    y = (x * inv) * (1.0 + scale).astype(x.dtype)
    return y, (x, inv32, scale)


def _rmsnorm_bwd(res, dy):
    # Hand-written so no fp32 convert is applied *directly* to the saved
    # residual x: autodiff's 2·convert(x)·dvar pattern gets hoisted out of the
    # layer-scan backward by XLA, materializing an fp32 copy of the whole
    # (L,B,S,d) residual stack. Here x only appears in bf16 products.
    x, inv32, scale = res
    d = x.shape[-1]
    g = (1.0 + scale).astype(x.dtype)
    dyg = dy * g
    inv = inv32.astype(x.dtype)
    dot = jnp.sum((dyg * x).astype(jnp.float32), axis=-1, keepdims=True)
    coef = (dot * inv32 * inv32 * inv32 / d).astype(x.dtype)   # (..., 1)
    dx = dyg * inv - x * coef
    ds = jnp.sum((dy * x * inv).astype(jnp.float32),
                 axis=tuple(range(x.ndim - 1)))
    return dx, ds, None


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm_init(d):
    return jnp.zeros((d,), jnp.float32)   # stored as (scale - 1)


# ---------------------------------------------------------------------------
# rope
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) rotated pairwise; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, d/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (...,S,1,d/2)
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels, mask=None):
    """Vocab-parallel-safe CE: one-hot contraction instead of gather so GSPMD
    keeps the vocab dim sharded (partial-sum + small all-reduce)."""
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lz = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    vocab = logits.shape[-1]
    onehot = jax.nn.one_hot(labels, vocab, dtype=lf.dtype)
    ll = jnp.sum(lf * onehot, axis=-1)
    nll = lz - ll
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_cross_entropy(h, head, labels, chunk: int = 1024):
    """CE over vocab-parallel logits, chunked over the sequence so only a
    (B, chunk, V/tp) logits slab is ever live (the full (B,S,V) fp32 logits +
    backward transposes otherwise dominate train memory).

    h (B,S,d), head (d,V), labels (B,S). The chunk body is rematerialized in
    backward (jax.checkpoint)."""
    from repro.parallel.sharding import hint
    B, S, d = h.shape
    n_valid = B * S
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        S = S + pad
    nc = S // chunk
    hs = jnp.moveaxis(h.reshape(B, nc, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    def body(tot, xs):
        hc, lc = xs
        logits = hint(jnp.einsum("bsd,dv->bsv", hc, head), "D", None, "M")
        lf = logits.astype(jnp.float32)
        m = jnp.max(lf, axis=-1, keepdims=True)
        lz = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
        onehot = jax.nn.one_hot(lc, logits.shape[-1], dtype=lf.dtype)
        ll = jnp.sum(lf * onehot, axis=-1)
        valid = (lc >= 0).astype(jnp.float32)
        return tot + jnp.sum((lz - ll) * valid), None

    tot, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                          (hs, ls))
    return tot / n_valid


def zloss(logits):
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lz = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    return jnp.mean(lz * lz)
