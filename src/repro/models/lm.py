"""Top-level language-model assembly for all 10 assigned architectures.

``LM(cfg)`` exposes:
    init(key)                          -> params pytree
    loss(params, batch)                -> (scalar, metrics)  [train]
    prefill(params, batch)             -> (cache, last_logits)
    decode(params, cache, batch, pos)  -> (logits, cache)
    init_cache(B, max_seq)             -> cache pytree (zeros)
    input_specs(shape)                 -> dict of ShapeDtypeStructs

Layers are stacked per homogeneous *segment* and evaluated with
``jax.lax.scan`` (+ jax.checkpoint in train mode) so the HLO stays small for
61–88-layer configs and activation memory is bounded by the remat policy.
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (chunked_cross_entropy, cross_entropy,
                                 dense_init, dtype_of, embed_init, rmsnorm,
                                 rmsnorm_init, split_keys)
from repro.models.mlp import init_mlp, mlp_block
from repro.parallel.sharding import hint

REMAT_POLICIES = {
    "none": None,
    "dots": "dots_with_no_batch_dims_saveable",
    "full": "nothing_saveable",
}


def _ckpt(fn, policy: str):
    if policy == "none":
        return fn
    pol = getattr(jax.checkpoint_policies, REMAT_POLICIES[policy])
    return jax.checkpoint(fn, policy=pol)


# ===========================================================================
# per-layer init / apply
# ===========================================================================


def _init_layer(key, cfg, dtype, *, kind: str):
    """kind: dense | moe | hymba | mlstm | slstm"""
    d = cfg.d_model
    ks = split_keys(key, 6)
    if kind == "mlstm":
        return {"ln": rmsnorm_init(d), "core": xlstm_mod.init_mlstm(ks[0], cfg, dtype)}
    if kind == "slstm":
        return {"ln": rmsnorm_init(d), "core": xlstm_mod.init_slstm(ks[0], cfg, dtype)}
    p: Dict[str, Any] = {"ln1": rmsnorm_init(d), "ln2": rmsnorm_init(d)}
    if cfg.mla:
        p["attn"] = mla_mod.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = attn.init_attn(ks[0], cfg, dtype)
    if kind == "moe":
        p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_type, dtype)
    if kind == "hymba":
        p["ssm"] = ssm_mod.init_ssm(ks[2], cfg, dtype)
        p["mix_a"] = 0.5 * jnp.ones((d,), jnp.float32)
        p["mix_s"] = 0.5 * jnp.ones((d,), jnp.float32)
        p["norm_a"] = rmsnorm_init(d)
        p["norm_s"] = rmsnorm_init(d)
    if cfg.cross_attn:
        p["ln_x"] = rmsnorm_init(d)
        p["cross"] = attn.init_cross_attn(ks[3], cfg, dtype)
    return p


def _mixer(p, x, cfg, positions, *, kind, window, sink, cache=None, pos=None,
           ssm_state=None):
    """Attention(+SSM) sub-block. Returns (out, new_cache, new_ssm_state)."""
    if kind in ("mlstm", "slstm"):
        raise AssertionError
    if cache is None:  # train / prefill
        if cfg.mla:
            a, kv = mla_mod.mla_block(p["attn"], x, cfg, positions)
        else:
            a, kv = attn.attn_block(p["attn"], x, cfg, positions, window=window,
                                    sink=sink)
        if kind == "hymba":
            s, ssm_state = ssm_mod.ssm_block(p["ssm"], x, cfg)
            a = (rmsnorm(a, p["norm_a"]) * p["mix_a"].astype(a.dtype)
                 + rmsnorm(s, p["norm_s"]) * p["mix_s"].astype(a.dtype))
        return a, kv, ssm_state
    # decode
    if cfg.mla:
        a, cache = mla_mod.mla_decode_block(p["attn"], x, cfg, cache[0], cache[1], pos)
    else:
        a, cache = attn.decode_attn_block(p["attn"], x, cfg, cache[0], cache[1],
                                          pos, window=window)
    if kind == "hymba":
        s, ssm_state = ssm_mod.ssm_decode_block(p["ssm"], x, cfg, ssm_state[0],
                                                ssm_state[1])
        a = (rmsnorm(a, p["norm_a"]) * p["mix_a"].astype(a.dtype)
             + rmsnorm(s, p["norm_s"]) * p["mix_s"].astype(a.dtype))
    return a, cache, ssm_state


def _layer_apply(p, x, cfg, positions, *, kind, window, sink, cond=None):
    """Train/prefill layer. Returns (x, cache_entry, aux)."""
    if kind == "mlstm":
        h, state = xlstm_mod.mlstm_block(p["core"], rmsnorm(x, p["ln"]), cfg)
        return x + h, state, None
    if kind == "slstm":
        h, state = xlstm_mod.slstm_block(p["core"], rmsnorm(x, p["ln"]), cfg)
        return x + h, state, None
    a, kv, ssm_state = _mixer(p, rmsnorm(x, p["ln1"]), cfg, positions, kind=kind,
                              window=window, sink=sink)
    x = x + a
    if cond is not None:
        x = x + attn.cross_attn_block(p["cross"], rmsnorm(x, p["ln_x"]), cond)
    aux = None
    h = rmsnorm(x, p["ln2"])
    if kind == "moe":
        m, aux = moe_mod.moe_block(p["moe"], h, cfg)
    else:
        m = mlp_block(p["mlp"], h)
    cache = (kv, ssm_state) if kind == "hymba" else kv
    out = x + m
    if os.environ.get("REPRO_SEQ_SHARDED") == "1":
        # Megatron-SP analog: keep the residual stream sequence-sharded over
        # "model" between blocks; TP partial-sums lower to reduce-scatter and
        # the per-layer activation all-gathers disappear (§Perf iteration)
        out = hint(out, "D", "M", None)
    return out, cache, aux


def _layer_decode(p, x, cfg, cache, pos, *, kind, window, cond=None):
    """Decode layer. Returns (x, new_cache)."""
    if kind == "mlstm":
        h, state = xlstm_mod.mlstm_decode(p["core"], rmsnorm(x, p["ln"]), cfg, cache)
        return x + h, state
    if kind == "slstm":
        h, state = xlstm_mod.slstm_decode(p["core"], rmsnorm(x, p["ln"]), cfg, cache)
        return x + h, state
    kv = cache[0] if kind == "hymba" else cache
    ssm_state = cache[1] if kind == "hymba" else None
    a, kv, ssm_state = _mixer(p, rmsnorm(x, p["ln1"]), cfg, None, kind=kind,
                              window=window, sink=0, cache=kv, pos=pos,
                              ssm_state=ssm_state)
    x = x + a
    if cond is not None:
        x = x + attn.cross_attn_block(p["cross"], rmsnorm(x, p["ln_x"]), cond)
    h = rmsnorm(x, p["ln2"])
    if kind == "moe":
        m, _ = moe_mod.moe_block(p["moe"], h, cfg)
    else:
        m = mlp_block(p["mlp"], h)
    cache = (kv, ssm_state) if kind == "hymba" else kv
    return x + m, cache


# ===========================================================================
# segment plan
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class Segment:
    name: str
    kind: str          # dense | moe | hymba | mlstm | slstm
    layers: tuple      # absolute layer indices
    window: Any        # None = full attention


def build_plan(cfg):
    L = cfg.num_layers
    segs = []
    if cfg.family in ("dense", "vlm", "audio"):
        segs.append(Segment("blocks", "dense", tuple(range(L)), None))
    elif cfg.family == "moe":
        nd = cfg.first_dense_layers
        if nd:
            segs.append(Segment("dense", "dense", tuple(range(nd)), None))
        segs.append(Segment("moe", "moe", tuple(range(nd, L)), None))
    elif cfg.family == "hybrid":
        full = set(cfg.full_attn_every)
        i = 0
        si = 0
        while i < L:
            if i in full:
                segs.append(Segment(f"full{i}", "hymba", (i,), None))
                i += 1
            else:
                j = i
                while j < L and j not in full:
                    j += 1
                segs.append(Segment(f"swa{si}", "hymba", tuple(range(i, j)),
                                    cfg.window))
                si += 1
                i = j
    elif cfg.family == "ssm":
        sl = set(cfg.slstm_layers)
        i = 0
        si = 0
        while i < L:
            if i in sl:
                segs.append(Segment(f"slstm{i}", "slstm", (i,), None))
                i += 1
            else:
                j = i
                while j < L and j not in sl:
                    j += 1
                segs.append(Segment(f"mlstm{si}", "mlstm", tuple(range(i, j)), None))
                si += 1
                i = j
    else:
        raise ValueError(cfg.family)
    return segs


# ===========================================================================
# LM
# ===========================================================================


class LM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.plan = build_plan(cfg)
        self.dtype = dtype_of(cfg)

    # ------------------------------------------------------------------ init
    def init(self, key):
        cfg, dtype = self.cfg, self.dtype
        d = cfg.d_model
        keys = split_keys(key, len(self.plan) + 6)
        params: Dict[str, Any] = {}
        if cfg.audio_codebooks:
            params["embed"] = embed_init(keys[0], (cfg.audio_codebooks,
                                                   cfg.vocab_size, d), dtype)
            params["heads"] = dense_init(keys[1], (cfg.audio_codebooks, d,
                                                   cfg.vocab_size), dtype)
        else:
            params["embed"] = embed_init(keys[0], (cfg.vocab_size, d), dtype)
            if not cfg.tie_embeddings:
                params["head"] = dense_init(keys[1], (d, cfg.vocab_size), dtype)
        if cfg.vision:
            ks = split_keys(keys[2], 2)
            params["vis_proj"] = {
                "w1": dense_init(ks[0], (cfg.vision_dim, d), dtype),
                "w2": dense_init(ks[1], (d, d), dtype),
            }
        if cfg.cross_attn:
            params["cond_proj"] = dense_init(keys[3], (cfg.cond_dim, d), dtype)
        if cfg.meta_tokens:
            params["meta"] = embed_init(keys[4], (cfg.meta_tokens, d), dtype)
        for seg, k in zip(self.plan, keys[6:]):
            lk = jax.random.split(k, len(seg.layers))
            init_one = partial(_init_layer, cfg=cfg, dtype=dtype, kind=seg.kind)
            params[seg.name] = jax.vmap(init_one)(lk)
        params["ln_f"] = rmsnorm_init(d)
        if cfg.mtp:
            params["mtp"] = {
                "proj": dense_init(keys[5], (2 * d, d), dtype),
                "ln_h": rmsnorm_init(d),
                "ln_e": rmsnorm_init(d),
                "layer": _init_layer(keys[5], cfg, dtype, kind="moe"),
                "ln_f": rmsnorm_init(d),
            }
        return params

    # -------------------------------------------------------------- embedding
    def _embed_inputs(self, params, batch):
        """Returns (x (B,S,d), positions (S,), loss_mask (S-aligned) or None,
        labels_provider)."""
        cfg = self.cfg
        if cfg.audio_codebooks:
            codes = batch["codes"]                              # (B, nq, S)
            # per-codebook embedding lookup, summed
            x = sum(params["embed"][k][codes[:, k]] for k in range(cfg.audio_codebooks))
            cond = jnp.einsum("btc,cd->btd", batch["cond"].astype(self.dtype),
                              params["cond_proj"])
            return hint(x, "D", None, None), None, cond
        toks = batch["tokens"]
        x = params["embed"][toks]                               # (B, S_text, d)
        if cfg.vision:
            pv = params["vis_proj"]
            h = jnp.einsum("bpc,cd->bpd", batch["patches"].astype(self.dtype),
                           pv["w1"])
            h = jax.nn.gelu(h.astype(jnp.float32)).astype(self.dtype)
            h = jnp.einsum("bpd,de->bpe", h, pv["w2"])
            x = jnp.concatenate([h, x], axis=1)
        if cfg.meta_tokens:
            B = x.shape[0]
            meta = jnp.broadcast_to(params["meta"][None], (B, cfg.meta_tokens,
                                                           x.shape[-1]))
            x = jnp.concatenate([meta, x], axis=1)
        return hint(x, "D", None, None), None, None

    def _run_segments(self, params, x, positions, cond, mode, remat="dots"):
        """mode: 'train' | 'prefill'. Returns (x, caches, aux_list)."""
        cfg = self.cfg
        caches: Dict[str, Any] = {}
        auxes = []
        for seg in self.plan:
            sink = cfg.meta_tokens if seg.window is not None else 0
            body = partial(_layer_apply, cfg=cfg, positions=positions,
                           kind=seg.kind, window=seg.window, sink=sink, cond=cond)

            def scan_body(h, layer_p, _body=body, _mode=mode):
                h, cache, aux = _body(layer_p, h)
                if _mode == "train":
                    cache = None   # don't stack per-layer KV during training
                return h, (cache, aux)

            if mode == "train":
                scan_body = _ckpt(scan_body, remat)
            if len(seg.layers) == 1:
                sp = jax.tree.map(lambda a: a[0], params[seg.name])
                x, (cache, aux) = scan_body(x, sp)
                cache = jax.tree.map(lambda a: a[None], cache) if cache is not None else None
                aux = jax.tree.map(lambda a: a[None], aux) if aux is not None else None
            else:
                x, (cache, aux) = jax.lax.scan(
                    lambda h, lp: scan_body(h, lp), x, params[seg.name])
            caches[seg.name] = cache
            if aux is not None:
                auxes.append(aux)
        return x, caches, auxes

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch, remat="full"):
        cfg = self.cfg
        x, _, cond = self._embed_inputs(params, batch)
        B, S, d = x.shape
        positions = jnp.arange(S)
        x, _, auxes = self._run_segments(params, x, positions, cond, "train", remat)
        x = rmsnorm(x, params["ln_f"])

        metrics: Dict[str, Any] = {}
        if cfg.audio_codebooks:
            codes = batch["codes"]                              # (B, nq, S)
            losses = []
            for k in range(cfg.audio_codebooks):
                losses.append(chunked_cross_entropy(x[:, :-1], params["heads"][k],
                                                    codes[:, k, 1:]))
            loss = sum(losses) / cfg.audio_codebooks
        else:
            prefix = (cfg.num_patches if cfg.vision else 0) + cfg.meta_tokens
            head = params["embed"].T if cfg.tie_embeddings else params["head"]
            h = x[:, prefix:, :]
            loss = chunked_cross_entropy(h[:, :-1], head, batch["tokens"][:, 1:])

        if auxes:
            load = jnp.concatenate([a["load"] for a in auxes], axis=0)  # (Lmoe,E)
            metrics["moe_load"] = load
            metrics["moe_dropped"] = jnp.mean(
                jnp.concatenate([jnp.atleast_1d(a["dropped"]) for a in auxes]))
            # switch-style balance penalty (small, optional)
            loss = loss + 1e-3 * cfg.num_experts * jnp.mean(
                jnp.sum(load * load, axis=-1))

        if cfg.mtp:
            loss = loss + 0.3 * self._mtp_loss(params, x, batch, positions)
        metrics["loss"] = loss
        return loss, metrics

    def _mtp_loss(self, params, h, batch, positions):
        """DeepSeek multi-token prediction: one extra layer predicting t+2."""
        cfg = self.cfg
        mp = params["mtp"]
        toks = batch["tokens"]
        emb_next = params["embed"][toks[:, 1:]]                 # (B,S-1,d)
        hh = jnp.concatenate([rmsnorm(h[:, :-1], mp["ln_h"]),
                              rmsnorm(emb_next, mp["ln_e"])], axis=-1)
        x = jnp.einsum("bse,ed->bsd", hh, mp["proj"])
        x, _, _ = _layer_apply(mp["layer"], x, cfg, positions[:-1], kind="moe",
                               window=None, sink=0)
        x = rmsnorm(x, mp["ln_f"])
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        return chunked_cross_entropy(x[:, :-1], head, toks[:, 2:])  # predict t+2

    # --------------------------------------------------------------- prefill
    def prefill(self, params, batch, max_seq=None):
        """Run the full prompt; build decode caches. Returns (cache, logits)."""
        cfg = self.cfg
        x, _, cond = self._embed_inputs(params, batch)
        B, S, d = x.shape
        positions = jnp.arange(S)
        x, caches, _ = self._run_segments(params, x, positions, cond, "prefill")
        x = rmsnorm(x, params["ln_f"])
        if cfg.audio_codebooks:
            logits = jnp.stack([
                jnp.einsum("bd,dv->bv", x[:, -1], params["heads"][k])
                for k in range(cfg.audio_codebooks)], axis=1)
        else:
            head = params["embed"].T if cfg.tie_embeddings else params["head"]
            logits = jnp.einsum("bd,dv->bv", x[:, -1], head)
        cache = self._layout_cache(caches, S, max_seq or (2 * S))
        return cache, logits

    def _layout_cache(self, caches, S, max_seq):
        """Convert prefill per-layer outputs into fixed-size decode caches."""
        cfg = self.cfg
        # S is the prefill length *including* any meta/patch prefix
        out = {"pos": jnp.asarray(S, jnp.int32)}
        total = max_seq + (cfg.meta_tokens or 0) + (cfg.num_patches if cfg.vision else 0)
        for seg in self.plan:
            c = caches[seg.name]
            if seg.kind in ("mlstm", "slstm"):
                out[seg.name] = c                               # states pass through
                continue
            if seg.kind == "hymba":
                kv, ssm_state = c
            else:
                kv, ssm_state = c, None
            if cfg.mla:
                ckv, kr = kv                                    # (Lseg,B,S',r)
                Ls, B = ckv.shape[0], ckv.shape[1]
                Sp = ckv.shape[2]
                ckv_c = jnp.zeros((Ls, B, total, ckv.shape[-1]), ckv.dtype)
                kr_c = jnp.zeros((Ls, B, total, kr.shape[-1]), kr.dtype)
                ckv_c = jax.lax.dynamic_update_slice(ckv_c, ckv, (0, 0, 0, 0))
                kr_c = jax.lax.dynamic_update_slice(kr_c, kr, (0, 0, 0, 0))
                out[seg.name] = (ckv_c, kr_c)
            else:
                k, v = kv                                       # (Lseg,B,S',K,hd)
                if seg.window is not None:
                    out[seg.name] = self._ring_from_prefill(k, v, seg)
                else:
                    Ls, B, Sp, K, hd = k.shape
                    kc = jnp.zeros((Ls, B, total, K, hd), k.dtype)
                    vc = jnp.zeros((Ls, B, total, K, hd), v.dtype)
                    kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0, 0))
                    vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0, 0))
                    out[seg.name] = (kc, vc)
            if ssm_state is not None:
                out[seg.name] = (out[seg.name], ssm_state)
        return out

    def _ring_from_prefill(self, k, v, seg):
        """Ring (sliding-window) cache: keep last W positions + meta prefix."""
        cfg = self.cfg
        W = cfg.window
        Ls, B, Sp, K, hd = k.shape
        meta = cfg.meta_tokens or 0
        mk, mv = k[:, :, :meta], v[:, :, :meta]                 # meta prefix
        kt, vt = k[:, :, meta:], v[:, :, meta:]
        St = Sp - meta
        if St >= W:
            tail_k, tail_v = kt[:, :, -W:], vt[:, :, -W:]
            tail_pos = jnp.arange(St - W, St) + meta
            slots = jnp.mod(tail_pos - meta, W)
        else:
            pad = W - St
            tail_k = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            tail_v = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            tail_pos = jnp.concatenate([jnp.arange(St) + meta,
                                        jnp.full((pad,), -1)])
            slots = jnp.arange(W)
        ring_k = jnp.zeros_like(tail_k).at[:, :, slots].set(tail_k)
        ring_v = jnp.zeros_like(tail_v).at[:, :, slots].set(tail_v)
        Ls = k.shape[0]
        ring_pos = jnp.broadcast_to(
            jnp.full((W,), -1, jnp.int32).at[slots].set(tail_pos.astype(jnp.int32)),
            (Ls, W))
        return {"meta_k": mk, "meta_v": mv, "ring_k": ring_k, "ring_v": ring_v,
                "ring_pos": ring_pos}

    # ---------------------------------------------------------------- decode
    def init_cache(self, B, max_seq):
        """Zero-initialized decode cache (for dry-run decode cells)."""
        cfg = self.cfg
        dtype = self.dtype
        total = max_seq + (cfg.meta_tokens or 0) + (cfg.num_patches if cfg.vision else 0)
        K, hd = cfg.num_kv_heads, cfg.head_dim
        di = cfg.d_model * cfg.ssm_expand
        cache: Dict[str, Any] = {"pos": jnp.asarray(total - 1, jnp.int32)}
        for seg in self.plan:
            Ls = len(seg.layers)
            if seg.kind == "mlstm":
                dh = 2 * cfg.d_model // cfg.num_heads
                cache[seg.name] = (
                    jnp.zeros((Ls, B, cfg.num_heads, dh, dh), jnp.float32),
                    jnp.zeros((Ls, B, cfg.num_heads, dh), jnp.float32),
                    jnp.full((Ls, B, cfg.num_heads), -1e30, jnp.float32))
                continue
            if seg.kind == "slstm":
                dh = cfg.d_model // cfg.num_heads
                z = jnp.zeros((Ls, B, cfg.num_heads, dh), jnp.float32)
                cache[seg.name] = (z, z, jnp.full((Ls, B, cfg.num_heads), -1e30,
                                                  jnp.float32), z)
                continue
            if cfg.mla:
                kv = (jnp.zeros((Ls, B, total, cfg.kv_lora_rank), dtype),
                      jnp.zeros((Ls, B, total, cfg.qk_rope_dim), dtype))
            elif seg.window is not None:
                meta = cfg.meta_tokens or 0
                kv = {"meta_k": jnp.zeros((Ls, B, meta, K, hd), dtype),
                      "meta_v": jnp.zeros((Ls, B, meta, K, hd), dtype),
                      "ring_k": jnp.zeros((Ls, B, cfg.window, K, hd), dtype),
                      "ring_v": jnp.zeros((Ls, B, cfg.window, K, hd), dtype),
                      "ring_pos": jnp.full((Ls, cfg.window), -1, jnp.int32)}
            else:
                kv = (jnp.zeros((Ls, B, total, K, hd), dtype),
                      jnp.zeros((Ls, B, total, K, hd), dtype))
            if seg.kind == "hymba":
                st = (jnp.zeros((Ls, B, di, cfg.ssm_state), jnp.float32),
                      jnp.zeros((Ls, B, cfg.conv_width - 1, di), dtype))
                cache[seg.name] = (kv, st)
            else:
                cache[seg.name] = kv
        return cache

    def decode(self, params, cache, batch, pos=None):
        """One decode step. batch: {'tokens': (B,)} (or codes (B,nq), +cond).

        Returns (logits, new_cache)."""
        cfg = self.cfg
        pos = cache["pos"] if pos is None else pos
        if cfg.audio_codebooks:
            codes = batch["tokens"]                             # (B, nq)
            x = sum(params["embed"][k][codes[:, k]]
                    for k in range(cfg.audio_codebooks))[:, None, :]
            cond = jnp.einsum("btc,cd->btd", batch["cond"].astype(self.dtype),
                              params["cond_proj"])
        else:
            x = params["embed"][batch["tokens"]][:, None, :]    # (B,1,d)
            cond = None
        new_cache: Dict[str, Any] = {"pos": pos + 1}
        for seg in self.plan:
            c = cache[seg.name]
            if seg.kind in ("mlstm", "slstm"):
                fn = xlstm_mod.mlstm_decode if seg.kind == "mlstm" else xlstm_mod.slstm_decode

                def body(h, lp_c, _fn=fn, _seg=seg):
                    lp, cc = lp_c
                    hh, st = _fn(lp["core"], rmsnorm(h, lp["ln"]), cfg, cc)
                    return h + hh, st
                x, new_c = jax.lax.scan(body, x, (params[seg.name], c))
                new_cache[seg.name] = new_c
                continue
            if seg.window is not None:
                x, new_c = self._decode_ring_seg(params[seg.name], x, seg, c, pos,
                                                 cond)
            else:
                def body(h, lp_c, _seg=seg):
                    lp, cc = lp_c
                    return _layer_decode(lp, h, cfg, cc, pos, kind=_seg.kind,
                                         window=None, cond=cond)
                x, new_c = jax.lax.scan(body, x, (params[seg.name], c))
            new_cache[seg.name] = new_c
        x = rmsnorm(x, params["ln_f"])[:, 0]                    # (B,d)
        if cfg.audio_codebooks:
            logits = jnp.stack([jnp.einsum("bd,dv->bv", x, params["heads"][k])
                                for k in range(cfg.audio_codebooks)], axis=1)
        else:
            head = params["embed"].T if cfg.tie_embeddings else params["head"]
            logits = jnp.einsum("bd,dv->bv", x, head)
        return logits, new_cache

    def _decode_ring_seg(self, seg_params, x, seg, cache, pos, cond):
        cfg = self.cfg

        def body(h, lp_c):
            lp, cc = lp_c
            hh, ncc = _ring_layer_decode(lp, h, cfg, cc, pos, cond)
            return hh, ncc

        return jax.lax.scan(body, x, (seg_params, cache))

    # --------------------------------------------------------------- specs
    def input_specs(self, shape):
        """ShapeDtypeStructs for the batch of a given ShapeConfig."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        f32 = jnp.float32
        if shape.kind in ("train", "prefill"):
            if cfg.audio_codebooks:
                return {"codes": jax.ShapeDtypeStruct((B, cfg.audio_codebooks, S), i32),
                        "cond": jax.ShapeDtypeStruct((B, cfg.cond_len, cfg.cond_dim), f32)}
            if cfg.vision:
                return {"tokens": jax.ShapeDtypeStruct((B, S - cfg.num_patches), i32),
                        "patches": jax.ShapeDtypeStruct((B, cfg.num_patches,
                                                         cfg.vision_dim), f32)}
            if cfg.meta_tokens:
                return {"tokens": jax.ShapeDtypeStruct((B, S - cfg.meta_tokens), i32)}
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        # decode: one new token against a cache of length S
        if cfg.audio_codebooks:
            return {"tokens": jax.ShapeDtypeStruct((B, cfg.audio_codebooks), i32),
                    "cond": jax.ShapeDtypeStruct((B, cfg.cond_len, cfg.cond_dim), f32)}
        return {"tokens": jax.ShapeDtypeStruct((B,), i32)}


def _ring_layer_decode(p, x, cfg, cache, pos, cond):
    """Hymba SWA layer decode with ring cache + meta prefix + parallel SSM."""
    kvc, ssm_state = cache
    h = rmsnorm(x, p["ln1"])
    a, kvc = _ring_attend(p["attn"], h, cfg, kvc, pos)
    s, ssm_state = ssm_mod.ssm_decode_block(p["ssm"], h, cfg, ssm_state[0],
                                            ssm_state[1])
    a = (rmsnorm(a, p["norm_a"]) * p["mix_a"].astype(a.dtype)
         + rmsnorm(s, p["norm_s"]) * p["mix_s"].astype(a.dtype))
    x = x + a
    hh = rmsnorm(x, p["ln2"])
    m = mlp_block(p["mlp"], hh)
    return x + m, (kvc, ssm_state)


def _ring_attend(p, x, cfg, kvc, pos):
    """Attention over meta prefix + ring window cache."""
    from repro.models.attention import _qkv
    B = x.shape[0]
    K, G, hd = cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim
    W = cfg.window
    meta = cfg.meta_tokens or 0
    positions = jnp.reshape(pos, (1,)).astype(jnp.int32)
    q, k_new, v_new = _qkv(p, x, cfg, positions)
    slot = jnp.mod(pos - meta, W)
    kvc = dict(kvc)
    kvc["ring_k"] = jax.lax.dynamic_update_slice(
        kvc["ring_k"], k_new.astype(kvc["ring_k"].dtype), (0, slot, 0, 0))
    kvc["ring_v"] = jax.lax.dynamic_update_slice(
        kvc["ring_v"], v_new.astype(kvc["ring_v"].dtype), (0, slot, 0, 0))
    kvc["ring_pos"] = jax.lax.dynamic_update_slice(
        kvc["ring_pos"], jnp.reshape(pos, (1,)).astype(jnp.int32), (slot,))
    k_all = jnp.concatenate([kvc["meta_k"], kvc["ring_k"]], axis=1)
    v_all = jnp.concatenate([kvc["meta_v"], kvc["ring_v"]], axis=1)
    pos_all = jnp.concatenate([jnp.arange(meta), kvc["ring_pos"]])
    valid = (pos_all >= 0) & (pos_all <= pos) & (
        (pos - pos_all < W) | (jnp.arange(meta + W) < meta))
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k_all,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[None, None, None, None, :], s, -0.7 * jnp.finfo(jnp.float32).max)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", w, v_all.astype(jnp.float32))
    o = jnp.moveaxis(o, 3, 1).reshape(B, 1, K * G, hd)
    out = jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), p["wo"])
    return out, kvc
