"""Mixture-of-Experts block: shared expert(s) + routed top-k with sort-based
capacity dispatch (Megablocks-style grouping, dropping on overflow).

Expert weights carry the expert dim first -> sharded over the "model" axis
(**EP**). The dispatch is written with sort + scatter/gather (no (N, E)
one-hot materialization), so the per-device working set stays
O(N·k + E·C·d / ep_degree).

DeepSeek-style aux-loss-free balancing: a non-trainable per-expert bias is
added to the routing scores for *selection only*; the train step nudges it
against the observed load (see repro.train.step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys
from repro.models.mlp import init_mlp, mlp_block
from repro.parallel.sharding import hint


def init_moe(key, cfg, dtype):
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32, scale=0.02),
        "bias": jnp.zeros((E,), jnp.float32),  # aux-free balancing bias (not a grad param)
        "wg": dense_init(ks[1], (E, d, f), dtype),
        "wu": dense_init(ks[2], (E, d, f), dtype),
        "wd": dense_init(ks[3], (E, f, d), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * cfg.n_shared_experts, "swiglu", dtype)
    return p


def _route(p, x2d, cfg):
    """x2d (N, d) -> (expert_ids (N,k), weights (N,k), router_probs (N,E))."""
    logits = jnp.einsum("nd,de->ne", x2d.astype(jnp.float32), p["router"])
    if cfg.router_gate == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    sel = scores + p["bias"][None, :]               # bias affects selection only
    _, ids = jax.lax.top_k(sel, cfg.top_k)          # (N, k)
    w = jnp.take_along_axis(scores, ids, axis=-1)   # original scores as weights
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return ids, w, scores


def moe_block(p, x, cfg):
    """x (B,S,d) -> (y (B,S,d), aux dict with load stats).

    With REPRO_MOE_SHARDMAP=1 and an active mesh, dispatch runs inside
    shard_map: each (data, model) device scatters ITS tokens into ITS local
    expert shard's buffer and the outputs combine with one psum over "model"
    — GSPMD-auto otherwise replicates the (E, C, d) dispatch buffers, which
    costs terabytes of all-reduce on deepseek-v3 (§Perf iteration 2)."""
    import os
    if os.environ.get("REPRO_MOE_SHARDMAP") == "1":
        from repro.compat import current_mesh
        env_mesh = current_mesh()
        if env_mesh is not None and "model" in env_mesh.axis_names \
                and cfg.num_experts % env_mesh.shape["model"] == 0:
            return _moe_block_shardmap(p, x, cfg, env_mesh)
    return _moe_block_gspmd(p, x, cfg)


def _dispatch_compute_combine(p_local, x2d, ids, w, cfg, n_local_experts,
                              expert_offset):
    """Local-token x local-expert-shard MoE. Returns partial y (N, d)."""
    N, d = x2d.shape
    k = cfg.top_k
    flat_ids = ids.reshape(N * k) - expert_offset
    flat_w = w.reshape(N * k)
    tok_idx = jnp.repeat(jnp.arange(N), k)
    mine = (flat_ids >= 0) & (flat_ids < n_local_experts)
    lids = jnp.where(mine, flat_ids, 0)
    order = jnp.argsort(jnp.where(mine, lids, n_local_experts))  # mine first
    s_ids = lids[order]
    s_tok = tok_idx[order]
    s_w = flat_w[order]
    s_mine = mine[order]
    start = jnp.searchsorted(s_ids, jnp.arange(n_local_experts), side="left")
    rank = jnp.arange(N * k) - start[s_ids]
    C = int(max(8, (N * k / cfg.num_experts) * cfg.capacity_factor))
    C = -(-C // 8) * 8
    keep = s_mine & (rank < C)
    slot_e = jnp.where(keep, s_ids, 0)
    slot_c = jnp.where(keep, rank, 0)
    xbuf = jnp.zeros((n_local_experts, C, d), x2d.dtype)
    xbuf = xbuf.at[slot_e, slot_c].add(x2d[s_tok] * keep[:, None].astype(x2d.dtype))
    g = jnp.einsum("ecd,edf->ecf", xbuf, p_local["wg"])
    u = jnp.einsum("ecd,edf->ecf", xbuf, p_local["wu"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x2d.dtype) * u
    ybuf = jnp.einsum("ecf,efd->ecd", h, p_local["wd"])
    y_tok = ybuf[slot_e, slot_c] * (s_w * keep)[:, None].astype(x2d.dtype)
    return jnp.zeros((N, d), x2d.dtype).at[s_tok].add(y_tok)


def _moe_block_shardmap(p, x, cfg, mesh):
    """Expert parallelism via shard_map: tokens sharded over ("pod","data"),
    experts over "model"; combine = one psum("model") of the (N_local, d)
    partial outputs."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    B, S, d = x.shape
    E = cfg.num_experts
    data_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    ep = mesh.shape["model"]
    assert E % ep == 0
    n_local = E // ep

    def local(x_loc, router, bias, wg, wu, wd, shared):
        # x_loc (B/dp, S, d); wg (E/ep, d, f)
        Bl, Sl, _ = x_loc.shape
        x2d = x_loc.reshape(Bl * Sl, d)
        logits = jnp.einsum("nd,de->ne", x2d.astype(jnp.float32), router)
        scores = (jax.nn.sigmoid(logits) if cfg.router_gate == "sigmoid"
                  else jax.nn.softmax(logits, axis=-1))
        sel = scores + bias[None, :]
        _, ids = jax.lax.top_k(sel, cfg.top_k)
        w = jnp.take_along_axis(scores, ids, axis=-1)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
        off = jax.lax.axis_index("model") * n_local
        y = _dispatch_compute_combine({"wg": wg, "wu": wu, "wd": wd}, x2d,
                                      ids, w, cfg, n_local, off)
        y = jax.lax.psum(y, "model")
        load = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
        load = jax.lax.pmean(load, ("model",) + data_axes) / (Bl * Sl * cfg.top_k)
        if shared is not None:
            # shared expert: d_ff sharded over model -> partial sums psum'ed
            sg = jnp.einsum("nd,df->nf", x2d, shared["wg"])
            su = jnp.einsum("nd,df->nf", x2d, shared["wu"])
            sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x2d.dtype) * su
            y = y + jax.lax.psum(jnp.einsum("nf,fd->nd", sh, shared["wd"]),
                                 "model")
        return y.reshape(Bl, Sl, d), load

    dp = P(data_axes)
    shared_p = p.get("shared")
    shared_specs = ({"wg": P(None, "model"), "wu": P(None, "model"),
                     "wd": P("model", None)} if shared_p is not None else None)
    y, load = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp[0] if len(data_axes) == 1 else data_axes, None, None),
                  P(None, None), P(None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None), shared_specs),
        out_specs=(P(data_axes if len(data_axes) > 1 else data_axes[0],
                     None, None), P()),
        check_rep=False,
    )(x, p["router"].astype(jnp.float32), p["bias"], p["wg"], p["wu"],
      p["wd"], shared_p)
    aux = {"load": load,
           "router_entropy": jnp.zeros(()),
           "dropped": jnp.zeros(())}
    return y, aux


def _moe_block_gspmd(p, x, cfg):
    B, S, d = x.shape
    N = B * S
    E, k, f = cfg.num_experts, cfg.top_k, cfg.moe_d_ff
    x2d = x.reshape(N, d)
    ids, w, probs = _route(p, x2d, cfg)

    # --- sort-based dispatch -------------------------------------------------
    flat_ids = ids.reshape(N * k)
    flat_w = w.reshape(N * k)
    tok_idx = jnp.repeat(jnp.arange(N), k)
    order = jnp.argsort(flat_ids)                  # stable
    s_ids = flat_ids[order]
    s_tok = tok_idx[order]
    s_w = flat_w[order]
    # rank of each entry within its expert = position - first position of expert
    start = jnp.searchsorted(s_ids, jnp.arange(E), side="left")   # (E,)
    rank = jnp.arange(N * k) - start[s_ids]
    C = int(max(8, (N * k / E) * cfg.capacity_factor))
    C = -(-C // 8) * 8                              # round up to x8
    keep = rank < C
    slot_e = jnp.where(keep, s_ids, 0)
    slot_c = jnp.where(keep, rank, 0)

    xbuf = jnp.zeros((E, C, d), x.dtype)
    gathered = hint(x2d[s_tok] * keep[:, None].astype(x.dtype), "D", None)
    xbuf = hint(xbuf.at[slot_e, slot_c].add(gathered), "M", "D", None)

    # --- grouped expert FFN (E sharded over "model" = EP) --------------------
    g = hint(jnp.einsum("ecd,edf->ecf", xbuf, p["wg"]), "M", "D", None)
    u = hint(jnp.einsum("ecd,edf->ecf", xbuf, p["wu"]), "M", "D", None)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ybuf = hint(jnp.einsum("ecf,efd->ecd", h, p["wd"]), "M", "D", None)

    # --- combine --------------------------------------------------------------
    y_tok = hint(ybuf[slot_e, slot_c] * (s_w * keep)[:, None].astype(x.dtype),
                 "D", None)
    y2d = hint(jnp.zeros((N, d), x.dtype).at[s_tok].add(y_tok), "D", None)
    y = y2d.reshape(B, S, d)

    if cfg.n_shared_experts:
        y = y + mlp_block(p["shared"], x)

    load = jnp.zeros((E,), jnp.float32).at[flat_ids].add(1.0) / (N * k)
    aux = {
        "load": load,                               # fraction of assignments per expert
        "router_entropy": -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-9), axis=-1)),
        "dropped": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y, aux
