"""Multi-head Latent Attention (DeepSeek-V3).

Train/prefill expand the KV latent to per-head keys/values and reuse the
blocked attention. Decode runs in the *absorbed* form (scores and output
computed against the (kv_lora + rope) latent cache directly) — this is the
faithful DeepSeek inference scheme and what makes the compressed cache pay
off: cache per token = kv_lora_rank + qk_rope_dim (576 for V3) instead of
2 * H * head_dim (32768).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import causal_attention
from repro.models.common import apply_rope, dense_init, rmsnorm, rmsnorm_init, split_keys
from repro.parallel.sharding import hint

_NEG = -0.7 * jnp.finfo(jnp.float32).max


def init_mla(key, cfg, dtype):
    d, H = cfg.d_model, cfg.num_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = split_keys(key, 7)
    return {
        "w_dq": dense_init(ks[0], (d, rq), dtype),
        "q_norm": rmsnorm_init(rq),
        "w_uq": dense_init(ks[1], (rq, H, dn + dr), dtype),
        "w_dkv": dense_init(ks[2], (d, rkv), dtype),
        "kv_norm": rmsnorm_init(rkv),
        "w_kr": dense_init(ks[3], (d, dr), dtype),
        "w_uk": dense_init(ks[4], (rkv, H, dn), dtype),
        "w_uv": dense_init(ks[5], (rkv, H, dv), dtype),
        "wo": dense_init(ks[6], (H, dv, d), dtype),
    }


def _q_proj(p, x, cfg, positions):
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"])
    q = hint(jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"]), "D", None, "M", None)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _kv_latent(p, x, cfg, positions):
    ckv = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"])
    kr = jnp.einsum("bsd,dk->bsk", x, p["w_kr"])[:, :, None, :]  # (B,S,1,dr)
    kr = apply_rope(kr, positions, cfg.rope_theta)[:, :, 0, :]
    return ckv, kr


def mla_block(p, x, cfg, positions):
    """Train/prefill path. Returns (out, (ckv, kr)) — the compressed cache."""
    B, S, _ = x.shape
    H, dn, dr, dv = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _q_proj(p, x, cfg, positions)
    ckv, kr = _kv_latent(p, x, cfg, positions)
    ckv = hint(ckv, "D", None, None)
    k_nope = hint(jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"]), "D", None, "M", None)
    v = hint(jnp.einsum("bsr,rhv->bshv", ckv, p["w_uv"]), "D", None, "M", None)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)         # (B,S,H,dn+dr)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, S, H, dr))],
                        axis=-1)
    qg = q[:, :, :, None, :]                               # K=H, G=1
    o = causal_attention(qg.reshape(B, S, H, 1, dn + dr), k, v, positions,
                         chunk=cfg.attn_chunk)
    # note: v dim dv != qk dim is fine — accumulator follows v
    out = jnp.einsum("bshv,hvd->bsd", o.astype(x.dtype), p["wo"])
    return out, (ckv, kr)


def mla_decode_block(p, x, cfg, ckv_cache, kr_cache, pos):
    """Absorbed single-token decode against the latent cache.

    ckv_cache (B, Smax, rkv), kr_cache (B, Smax, dr).
    """
    B = x.shape[0]
    H, dn, dr, dv = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    positions = jnp.full((1,), pos, jnp.int32)
    q_nope, q_rope = _q_proj(p, x, cfg, positions)         # (B,1,H,dn/dr)
    ckv_new, kr_new = _kv_latent(p, x, cfg, positions)
    ckv_cache = jax.lax.dynamic_update_slice(
        ckv_cache, ckv_new.astype(ckv_cache.dtype), (0, pos, 0))
    kr_cache = jax.lax.dynamic_update_slice(
        kr_cache, kr_new.astype(kr_cache.dtype), (0, pos, 0))
    # absorb W_uk into q: q̃ (B,1,H,rkv)
    q_abs = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["w_uk"])
    s_nope = jnp.einsum("bqhr,bsr->bhqs", q_abs.astype(jnp.float32),
                        ckv_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bqhk,bsk->bhqs", q_rope.astype(jnp.float32),
                        kr_cache.astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(jnp.asarray(dn + dr, jnp.float32))
    s = (s_nope + s_rope) * scale
    idx = jnp.arange(ckv_cache.shape[1])
    s = jnp.where((idx <= pos)[None, None, None, :], s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", w, ckv_cache.astype(jnp.float32))
    o = jnp.einsum("bqhr,rhv->bqhv", o_lat.astype(x.dtype), p["w_uv"])
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    return out, (ckv_cache, kr_cache)
