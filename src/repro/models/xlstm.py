"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, most blocks) and
sLSTM (scalar memory with recurrent gate mixing, at cfg.slstm_layers).

Both are exact sequential recurrences evaluated with a chunk-rematerialized
lax.scan (outer scan keeps chunk-boundary states for backward, inner steps
recompute), which bounds train memory: without it the mLSTM matrix state
(B,H,dh,dh) would be saved for every timestep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm, rmsnorm_init, split_keys
from repro.parallel.sharding import hint


def _chunked_time_scan(cell, carry, xs, chunk):
    """scan over time with inner-chunk remat. xs leaves are (B,S,...)."""
    S = jax.tree_util.tree_leaves(xs)[0].shape[1]
    chunk = min(chunk, S)
    if S % chunk != 0:
        chunk = S
    nc = S // chunk

    def inner(carry, xs_c):
        # xs_c leaves (chunk, B, ...) -> scan over time
        return jax.lax.scan(cell, carry, xs_c)

    xs_t = jax.tree.map(lambda v: jnp.moveaxis(
        v.reshape(v.shape[0], nc, chunk, *v.shape[2:]), 0, 2), xs)
    # leaves now (nc, chunk, B, ...)
    carry, ys = jax.lax.scan(jax.checkpoint(inner), carry, xs_t)
    # ys leaves (nc, chunk, B, ...) -> (B, S, ...)
    return carry, jax.tree.map(
        lambda v: jnp.moveaxis(v.reshape(nc * chunk, *v.shape[2:]), 0, 1), ys)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg, dtype):
    d, H = cfg.d_model, cfg.num_heads
    di = 2 * d
    dh = di // H
    ks = split_keys(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, di), dtype),
        "w_z": dense_init(ks[1], (d, di), dtype),
        "wq": dense_init(ks[2], (di, H, dh), dtype),
        "wk": dense_init(ks[3], (di, H, dh), dtype),
        "wv": dense_init(ks[4], (di, H, dh), dtype),
        "w_if": dense_init(ks[5], (di, 2 * H), dtype, scale=0.02),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(jnp.float32),
        "h_norm": rmsnorm_init(dh),
        "w_down": dense_init(ks[6], (di, d), dtype),
    }


def _mlstm_cell(carry, xs):
    C, n, m = carry                                   # (B,H,dh,dh),(B,H,dh),(B,H)
    q, k, v, it, ft = xs                              # (B,H,dh) x3, (B,H) x2
    m_new = jnp.maximum(ft + m, it)
    f_ = jnp.exp(ft + m - m_new)
    i_ = jnp.exp(it - m_new)
    C = f_[..., None, None] * C + i_[..., None, None] * (v[..., :, None] * k[..., None, :])
    n = f_[..., None] * n + i_[..., None] * k
    num = jnp.einsum("bhij,bhj->bhi", C, q)           # C @ q  (v-index out)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)), 1.0)
    h = num / den[..., None]
    return (C, n, m_new), h


def mlstm_states(p, x, cfg):
    B, S, d = x.shape
    H = cfg.num_heads
    di = 2 * d
    dh = di // H
    up = hint(jnp.einsum("bsd,de->bse", x, p["w_up"]), "D", None, "M")
    z = hint(jnp.einsum("bsd,de->bse", x, p["w_z"]), "D", None, "M")
    q = jnp.einsum("bse,ehk->bshk", up, p["wq"]).astype(jnp.float32) / jnp.sqrt(float(dh))
    k = jnp.einsum("bse,ehk->bshk", up, p["wk"]).astype(jnp.float32) / jnp.sqrt(float(dh))
    v = jnp.einsum("bse,ehk->bshk", up, p["wv"]).astype(jnp.float32)
    gates = (jnp.einsum("bse,eg->bsg", up, p["w_if"]).astype(jnp.float32)
             + p["b_if"][None, None, :])
    it, ft = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])
    return up, z, q, k, v, it, ft


def mlstm_block(p, x, cfg, state=None, chunk=64):
    """Returns (out, state). state = (C, n, m)."""
    B, S, d = x.shape
    H = cfg.num_heads
    dh = 2 * d // H
    up, z, q, k, v, it, ft = mlstm_states(p, x, cfg)
    if state is None:
        state = (jnp.zeros((B, H, dh, dh), jnp.float32),
                 jnp.zeros((B, H, dh), jnp.float32),
                 jnp.full((B, H), -1e30, jnp.float32))
    state, hs = _chunked_time_scan(_mlstm_cell, state, (q, k, v, it, ft), chunk)
    h = rmsnorm(hs, p["h_norm"]).reshape(B, S, 2 * d).astype(x.dtype)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", h, p["w_down"])
    return out, state


def mlstm_decode(p, x, cfg, state):
    out, state = mlstm_block(p, x, cfg, state, chunk=1)
    return out, state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg, dtype):
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    ks = split_keys(key, 4)
    dff = int(d * 8 / 3) // 8 * 8
    return {
        "w_g": dense_init(ks[0], (d, 4 * d), dtype),          # z,i,f,o pre-acts
        "r_g": dense_init(ks[1], (H, dh, 4 * dh), dtype, scale=0.02),
        "b_g": jnp.zeros((4 * d,), jnp.float32),
        "h_norm": rmsnorm_init(d),
        # gated FFN that follows each sLSTM cell in the xLSTM block stack
        "ffn_norm": rmsnorm_init(d),
        "wg": dense_init(ks[2], (d, dff), dtype),
        "wu": dense_init(ks[2], (d, dff), dtype),
        "wd": dense_init(ks[3], (dff, d), dtype),
    }


def _slstm_cell_fn(p, H, dh):
    def cell(carry, xs):
        c, n, m, h_prev = carry                       # (B,H,dh) x3... m (B,H)
        wx = xs                                       # (B, 4d) precomputed Wx+b
        B = wx.shape[0]
        rh = jnp.einsum("bhk,hkg->bhg", h_prev.astype(jnp.float32),
                        p["r_g"].astype(jnp.float32))  # (B,H,4dh)
        pre = wx.reshape(B, H, 4 * dh) + rh
        z_, i_, f_, o_ = jnp.split(pre, 4, axis=-1)   # (B,H,dh)
        z = jnp.tanh(z_)
        o = jax.nn.sigmoid(o_)
        logf = jax.nn.log_sigmoid(f_)
        m_new = jnp.maximum(logf + m[..., None], i_).max(-1)  # (B,H) shared stabilizer
        fe = jnp.exp(logf + m[..., None] - m_new[..., None])
        ie = jnp.exp(i_ - m_new[..., None])
        c = fe * c + ie * z
        n = fe * n + ie
        h = o * c / jnp.maximum(jnp.abs(n), 1.0)
        return (c, n, m_new, h), h
    return cell


def slstm_block(p, x, cfg, state=None, chunk=64):
    B, S, d = x.shape
    H = cfg.num_heads
    dh = d // H
    wx = (jnp.einsum("bsd,dg->bsg", x, p["w_g"]).astype(jnp.float32)
          + p["b_g"][None, None, :])
    if state is None:
        z = jnp.zeros((B, H, dh), jnp.float32)
        state = (z, z, jnp.full((B, H), -1e30, jnp.float32), z)
    cell = _slstm_cell_fn(p, H, dh)
    state, hs = _chunked_time_scan(cell, state, wx, chunk)
    h = rmsnorm(hs.reshape(B, S, d), p["h_norm"]).astype(x.dtype)
    # gated FFN
    y = rmsnorm(h, p["ffn_norm"])
    g = jnp.einsum("bsd,df->bsf", y, p["wg"])
    u = jnp.einsum("bsd,df->bsf", y, p["wu"])
    y = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out = h + jnp.einsum("bsf,fd->bsd", y, p["wd"])
    return out, state


def slstm_decode(p, x, cfg, state):
    return slstm_block(p, x, cfg, state, chunk=1)
