"""Mamba-style selective SSM mixer (hymba's SSM heads).

Recurrence (per channel c, state dim n):
    h_t = exp(dt_t * A) ⊙ h_{t-1} + dt_t * x_t * B_t
    y_t = ⟨h_t, C_t⟩ + D * x_t

Train/prefill use a chunked associative scan (parallel within chunks,
sequential across) wrapped in jax.checkpoint so the backward pass only keeps
chunk-boundary states. Decode is the single-step recurrence.

``repro.kernels.ssm_scan`` is the Pallas TPU version of the chunk kernel;
``ssm_scan_chunked`` below is its oracle.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys
from repro.parallel.sharding import hint

DT_RANK = 64


def init_ssm(key, cfg, dtype):
    d = cfg.d_model
    di = d * cfg.ssm_expand
    n, cw = cfg.ssm_state, cfg.conv_width
    ks = split_keys(key, 8)
    return {
        "w_in": dense_init(ks[0], (d, 2 * di), dtype),          # -> (x, z-gate)
        "conv_w": dense_init(ks[1], (cw, di), dtype, scale=0.5),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "w_dt1": dense_init(ks[2], (di, DT_RANK), dtype),
        "w_dt2": dense_init(ks[3], (DT_RANK, di), dtype),
        "b_dt": jnp.full((di,), -4.6, jnp.float32),             # softplus^-1(0.01)
        "w_B": dense_init(ks[4], (di, n), dtype),
        "w_C": dense_init(ks[5], (di, n), dtype),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                          (di, n)) + 0.0),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[6], (di, d), dtype),
    }


def _conv1d(x, w, b, state=None):
    """Causal depthwise conv. x (B,S,di), w (cw,di). Returns (y, new_state).

    ``state`` (B,cw-1,di) carries the last cw-1 inputs for decode."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                      # (B, S+cw-1, di)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(cw))
    y = y + b.astype(x.dtype)[None, None, :]
    new_state = xp[:, -(cw - 1):, :]
    return y, new_state


def _ssm_inputs(p, xz, conv_state=None):
    """xz (B,S,2di) -> (xc, z, dt, Bc, Cc, new_conv_state)."""
    di = p["w_B"].shape[0]
    x_in, z = xz[..., :di], xz[..., di:]
    xc, conv_state = _conv1d(x_in, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(xz.dtype)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dr->bsr", xc, p["w_dt1"]) @ p["w_dt2"]
        + p["b_dt"].astype(xz.dtype)).astype(jnp.float32)       # (B,S,di)
    Bc = jnp.einsum("bsd,dn->bsn", xc, p["w_B"]).astype(jnp.float32)
    Cc = jnp.einsum("bsd,dn->bsn", xc, p["w_C"]).astype(jnp.float32)
    return xc, z, dt, Bc, Cc, conv_state


def ssm_scan_chunked(x, dt, A, Bc, Cc, D, h0, chunk=128):
    """Oracle + CPU path for the Pallas kernel. All fp32.

    x/dt (B,S,di); Bc/Cc (B,S,n); A (di,n); D (di,); h0 (B,di,n).
    Returns (y (B,S,di), h_final)."""
    B, S, di = x.shape
    n = A.shape[1]
    chunk = min(chunk, S)
    if S % chunk != 0:
        chunk = S
    nc = S // chunk

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    def chunk_body(h, xs):
        xch, dtch, Bch, Cch = xs                                 # (B,T,...)
        a = hint(jnp.exp(dtch[..., None] * A), "D", None, "M", None)
        b = hint((dtch * xch)[..., None] * Bch[:, :, None, :], "D", None, "M", None)
        a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_t = a_cum * h[:, None] + b_cum                         # (B,T,di,n)
        y = jnp.einsum("btdn,btn->btd", h_t, Cch) + D * xch
        return h_t[:, -1], y

    xs = tuple(v.reshape(B, nc, chunk, *v.shape[2:]).swapaxes(0, 1)
               for v in (x, dt, Bc, Cc))
    h_fin, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, di), h_fin


def ssm_block(p, x, cfg, h0=None, conv_state=None):
    """Full-sequence SSM mixer. Returns (out, (h_final, conv_state))."""
    B, S, d = x.shape
    di = d * cfg.ssm_expand
    xz = hint(jnp.einsum("bsd,de->bse", x, p["w_in"]), "D", None, "M")
    xc, z, dt, Bc, Cc, conv_state = _ssm_inputs(p, xz, conv_state)
    if h0 is None:
        h0 = jnp.zeros((B, di, cfg.ssm_state), jnp.float32)
    A = -jnp.exp(p["A_log"])
    y, h_fin = ssm_scan_chunked(xc.astype(jnp.float32), dt, A, Bc, Cc, p["D"], h0)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, p["w_out"])
    return out, (h_fin, conv_state)


def ssm_decode_block(p, x, cfg, h, conv_state):
    """Single-token decode. x (B,1,d); h (B,di,n); conv_state (B,cw-1,di)."""
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xc, z, dt, Bc, Cc, conv_state = _ssm_inputs(p, xz, conv_state)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)                          # (B,di,n)
    b = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bc[:, 0, :][:, None, :]
    h = a * h + b
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0]) + p["D"] * xc[:, 0].astype(jnp.float32)
    y = y[:, None, :].astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, p["w_out"])
    return out, (h, conv_state)
