"""Attention: GQA/MQA/MHA with optional qk-norm, rope, sliding-window and
blocked (flash-style, online-softmax) computation for long sequences, plus a
KV-cache decode path.

Layouts:
  q            (B, S, K, G, hd)   K = kv heads, G = q heads per kv head
  k, v         (B, S, K, hd)
  weights wq   (d, H, hd)  wk/wv (d, K, hd)  wo (H, hd, d)

On TPU the prefill path is served by ``repro.kernels.flash_attention``; the
blocked jnp path below is its oracle and the CPU/dry-run implementation
(see kernels/ops.py for dispatch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, rmsnorm, rmsnorm_init, split_keys
from repro.parallel.sharding import hint

_NEG = -0.7 * jnp.finfo(jnp.float32).max


def init_attn(key, cfg, dtype):
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), dtype),
        "wk": dense_init(ks[1], (d, K, hd), dtype),
        "wv": dense_init(ks[2], (d, K, hd), dtype),
        "wo": dense_init(ks[3], (H, hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def _qkv(p, x, cfg, positions):
    """Project + rope. Returns q (B,S,K,G,hd), k/v (B,S,K,hd)."""
    K, G = cfg.num_kv_heads, cfg.q_per_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    B, S = x.shape[:2]
    q = q.reshape(B, S, K, G, cfg.head_dim)
    # keep batch on the data axis (GSPMD may otherwise trade it for head
    # sharding and replicate activations across "data"); head dims go to
    # "model" only when divisible.
    q = hint(q, "D", None, "M", None, None)
    k = hint(k, "D", None, "M", None)
    v = hint(v, "D", None, "M", None)
    return q, k, v


def _block_attend(q_blk, pq, k, v, pk, window, chunk, sink=0):
    """Online-softmax over kv chunks for one query block.

    q_blk (B,c,K,G,hd); k/v (B,S,K,hd); pq (c,), pk (S,). fp32 accumulators.
    ``sink``: number of leading positions that bypass the sliding window
    (attention-sink / meta tokens).
    """
    B, c, K, G, hd = q_blk.shape
    hv = v.shape[-1]          # value head dim may differ from qk dim (MLA)
    S = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    nk = S // chunk
    ks = jnp.moveaxis(k.reshape(B, nk, chunk, K, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nk, chunk, K, hv), 1, 0)
    pks = pk.reshape(nk, chunk)

    def kv_step(carry, xs):
        m, l, acc = carry
        k_c, v_c, pk_c = xs
        s = jnp.einsum("bqkgh,bskh->bkgqs", q_blk, k_c,
                       preferred_element_type=jnp.float32) * scale
        s = hint(s, "D", "M", None, None, None)
        mask = pq[:, None] >= pk_c[None, :]
        if window is not None:
            in_win = pq[:, None] - pk_c[None, :] < window
            if sink:
                in_win = in_win | (pk_c[None, :] < sink)
            mask = mask & in_win
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p_ = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p_, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p_, v_c.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((B, K, G, c), _NEG, jnp.float32),
        jnp.zeros((B, K, G, c), jnp.float32),
        jnp.zeros((B, K, G, c, hv), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(kv_step, init, (ks, vs, pks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,K,G,c,hv)
    out = out.astype(q_blk.dtype)                         # leave fp32 inside the block
    return jnp.moveaxis(out, 3, 1).reshape(B, c, K * G, hv)


def causal_attention(q, k, v, positions, window=None, chunk=2048, sink=0):
    """Blocked causal (optionally sliding-window) attention.

    q (B,S,K,G,hd), k/v (B,Skv,K,hd) -> (B,S,H,hd). ``positions`` (S,) are the
    absolute positions of queries; keys are assumed at positions (Skv,).
    """
    B, S, K, G, hd = q.shape
    Skv = k.shape[1]
    pk = jnp.arange(Skv)
    chunk = min(chunk, S)
    if S % chunk != 0:
        chunk = S  # single block (smoke-test sizes)
    nq = S // chunk
    kv_chunk = chunk if Skv % chunk == 0 else Skv
    if nq == 1:
        return _block_attend(q, positions, k, v, pk, window, kv_chunk, sink)
    qs = jnp.moveaxis(q.reshape(B, nq, chunk, K, G, hd), 1, 0)
    pqs = positions.reshape(nq, chunk)

    blk = jax.checkpoint(
        lambda q_blk, pq, k, v: _block_attend(q_blk, pq, k, v, pk, window,
                                              kv_chunk, sink))

    def q_step(_, xs):
        q_blk, pq = xs
        # per-q-block remat: backward recomputes the (c x c) prob tiles instead
        # of stashing the full S^2 attention matrix (flash-attention-bwd shape)
        return None, blk(q_blk, pq, k, v)

    _, outs = jax.lax.scan(q_step, None, (qs, pqs))       # (nq,B,chunk,H,hv)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, K * G, outs.shape[-1])


def attn_block(p, x, cfg, positions, window=None, sink=0):
    """Full attention block for train/prefill. Returns (out, (k, v))."""
    q, k, v = _qkv(p, x, cfg, positions)
    o = causal_attention(q, k, v, positions, window=window, chunk=cfg.attn_chunk,
                         sink=sink)
    o = hint(o, "D", None, "M", None)
    out = hint(jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), p["wo"]),
               "D", None, None)
    return out, (k, v)


def decode_attn_block(p, x, cfg, k_cache, v_cache, pos, window=None):
    """Single-token decode against a (B, Smax, K, hd) cache.

    ``pos`` (scalar int32): index of the current token. Returns out plus
    updated caches. Sequence dim of the cache may be sharded ("SP decode").
    """
    B = x.shape[0]
    K, G, hd = cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim
    positions = jnp.full((1,), pos, jnp.int32)
    q, k_new, v_new = _qkv(p, x, cfg, positions)          # q (B,1,K,G,hd)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0))
    Smax = k_cache.shape[1]
    idx = jnp.arange(Smax)
    valid = idx <= pos
    if window is not None:
        valid = valid & (pos - idx < window)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[None, None, None, None, :], s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", w, v_cache.astype(jnp.float32))
    o = jnp.moveaxis(o, 3, 1).reshape(B, 1, K * G, hd)
    out = jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), p["wo"])
    return out, (k_cache, v_cache)


# ---------------------------------------------------------------------------
# cross attention (musicgen conditioning)
# ---------------------------------------------------------------------------


def init_cross_attn(key, cfg, dtype):
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = split_keys(key, 4)
    return {
        "wq": dense_init(ks[0], (d, H, hd), dtype),
        "wk": dense_init(ks[1], (d, H, hd), dtype),
        "wv": dense_init(ks[2], (d, H, hd), dtype),
        "wo": dense_init(ks[3], (H, hd, d), dtype),
    }


def cross_attn_block(p, x, cond):
    """Non-causal attention of x (B,S,d) over cond (B,T,d)."""
    hd = p["wq"].shape[-1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", cond, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", cond, p["wv"])
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bshk,bthk->bhst", q, k, preferred_element_type=jnp.float32) * scale
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthk->bshk", w, v.astype(jnp.float32))
    return jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), p["wo"])
