from repro.models.lm import LM, build_plan  # noqa: F401
