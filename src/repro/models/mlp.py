"""Feed-forward blocks: SwiGLU (llama family) and non-gated GELU (granite,
musicgen)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys
from repro.parallel.sharding import hint


def init_mlp(key, d_model, d_ff, mlp_type, dtype):
    ks = split_keys(key, 3)
    if mlp_type == "swiglu":
        return {
            "wg": dense_init(ks[0], (d_model, d_ff), dtype),
            "wu": dense_init(ks[1], (d_model, d_ff), dtype),
            "wd": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {
        "wi": dense_init(ks[0], (d_model, d_ff), dtype),
        "wd": dense_init(ks[1], (d_ff, d_model), dtype),
    }


def mlp_block(p, x):
    if "wg" in p:
        g = hint(jnp.einsum("bsd,df->bsf", x, p["wg"]), "D", None, "M")
        u = hint(jnp.einsum("bsd,df->bsf", x, p["wu"]), "D", None, "M")
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = hint(jnp.einsum("bsd,df->bsf", x, p["wi"]), "D", None, "M")
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return hint(jnp.einsum("bsf,fd->bsd", h, p["wd"]), "D", None, None)
