"""Deterministic, shard-aware, resumable synthetic data pipeline.

Every batch is a pure function of (seed, step) so restart-from-checkpoint
reproduces the exact token stream (the fault-tolerance tests rely on this).
A real deployment would swap `_synth_tokens` for a tokenized shard reader;
the iterator state/checkpoint contract stays identical.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict

import numpy as np


@dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self):
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


class SyntheticLMData:
    """Markov-ish synthetic token stream with learnable structure (so tiny
    models show decreasing loss)."""

    def __init__(self, cfg, batch_size: int, seq_len: int, seed: int = 0):
        self.cfg = cfg
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.state = DataState(seed=seed, step=0)
        rng = np.random.default_rng(seed)
        v = cfg.vocab_size
        self._perm = rng.permutation(v)          # fixed bigram successor map

    def _synth_tokens(self, rng, shape):
        v = self.cfg.vocab_size
        first = rng.integers(0, v, shape[:-1] + (1,))
        toks = [first[..., 0]]
        noise = rng.random(shape[:-1] + (shape[-1] - 1,))
        rand = rng.integers(0, v, shape[:-1] + (shape[-1] - 1,))
        for t in range(shape[-1] - 1):
            nxt = self._perm[toks[-1]]
            toks.append(np.where(noise[..., t] < 0.8, nxt, rand[..., t]))
        return np.stack(toks, axis=-1).astype(np.int32)

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((self.state.seed, self.state.step))
        self.state.step += 1
        B, S = self.batch_size, self.seq_len
        if cfg.audio_codebooks:
            return {
                "codes": rng.integers(0, cfg.vocab_size,
                                      (B, cfg.audio_codebooks, S)).astype(np.int32),
                "cond": rng.normal(size=(B, cfg.cond_len,
                                         cfg.cond_dim)).astype(np.float32),
            }
        batch = {}
        s_text = S
        if cfg.vision:
            s_text -= cfg.num_patches
            batch["patches"] = rng.normal(
                size=(B, cfg.num_patches, cfg.vision_dim)).astype(np.float32)
        if cfg.meta_tokens:
            s_text -= cfg.meta_tokens
        batch["tokens"] = self._synth_tokens(rng, (B, s_text))
        return batch
