"""OpenGCRAM-JAX: a differentiable gain-cell memory compiler + the
production LM substrate it is explored against.

The public compiler surface lives in :mod:`repro.api` (``Compiler``,
``DesignTable``, ``explore``) and is lazily re-exported here so that
``import repro`` stays cheap for subsystems (configs, models, kernels) that
never touch the compiler.
"""
from __future__ import annotations

_API_NAMES = (
    "Bucket", "LevelReq", "TaskReq", "SelectionPolicy",
    "MacroConfig", "Macro", "Compiler",
    "DesignTable", "design_space",
    "explore", "DSEReport",
    "compose", "ComposePolicy", "CompositionReport",
    "simulate", "SimPolicy",
    "OperatingPoint", "TechParams", "NOMINAL", "HOT", "CORNERS",
    "gradient_size_macro", "characterize_call_count",
)

__all__ = list(_API_NAMES) + ["api"]


def __getattr__(name):
    if name in _API_NAMES or name == "api":
        import importlib
        api = importlib.import_module("repro.api")
        globals()["api"] = api
        return api if name == "api" else getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
